"""The literal paper demo: transfer a file over n parallel xDFS channels
with the MTEDP engine, and compare against the GridFTP-like MP baseline.

  PYTHONPATH=src python examples/xdfs_file_transfer.py --size-mb 256 --channels 8
"""
import argparse
import os
import tempfile
from pathlib import Path

from repro.core.transfer import TransferSpec, run_transfer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size-mb", type=int, default=256)
    ap.add_argument("--channels", type=int, default=8)
    ap.add_argument("--mode", default="upload", choices=["upload", "download"])
    args = ap.parse_args()

    tmp = Path(tempfile.mkdtemp(prefix="xdfs_demo_"))
    src = tmp / "payload.bin"
    print(f"creating {args.size_mb} MiB payload...")
    with open(src, "wb") as f:
        blk = os.urandom(4 << 20)
        for _ in range(args.size_mb // 4):
            f.write(blk)
    size = args.size_mb << 20

    for engine, label in (("mtedp", "xDFS (MTEDP)"), ("mt", "MT"), ("mp", "GridFTP-like (MP)")):
        # one warmup + one measured run
        for rep in range(2):
            st = run_transfer(TransferSpec(
                engine=engine, mode=args.mode, n_channels=args.channels,
                size=size, src_path=str(src), dst_path=str(tmp / f"out_{engine}.bin"),
            ))
        ok = (tmp / f"out_{engine}.bin").read_bytes()[:1024] == src.read_bytes()[:1024]
        print(
            f"{label:22s} {args.channels} channels: {st.throughput_mbps:8.0f} Mb/s  "
            f"server CPU {100 * st.server_cpu_s / st.wall_s:5.1f}%  "
            f"RSS {st.server_rss_mb:5.0f} MB  vectored-writes {st.writev_calls:4d}  "
            f"integrity={'OK' if ok else 'FAIL'}"
        )
    for f in tmp.glob("*"):
        f.unlink()
    tmp.rmdir()


if __name__ == "__main__":
    main()
