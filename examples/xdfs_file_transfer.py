"""The paper demo on the persistent-session API: one ``XdfsServer``, one
negotiated ``XdfsClient`` session per engine, a large-file transfer plus a
small-file ``put_many`` burst over the SAME channels (EOFR reuse), and the
one-shot ``run_transfer`` baseline for contrast.

  PYTHONPATH=src python examples/xdfs_file_transfer.py --size-mb 256 --channels 8
"""
import argparse
import os
import tempfile
import time
from pathlib import Path

from repro.core.api import XdfsClient, XdfsServer
from repro.core.transfer import TransferSpec, run_transfer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size-mb", type=int, default=256)
    ap.add_argument("--channels", type=int, default=8)
    ap.add_argument("--small-files", type=int, default=16)
    args = ap.parse_args()

    tmp = Path(tempfile.mkdtemp(prefix="xdfs_demo_"))
    src = tmp / "payload.bin"
    print(f"creating {args.size_mb} MiB payload...")
    with open(src, "wb") as f:
        blk = os.urandom(4 << 20)
        for _ in range(args.size_mb // 4):
            f.write(blk)
    smalls = []
    for i in range(args.small_files):
        p = tmp / f"small_{i}.bin"
        p.write_bytes(os.urandom(256 << 10))
        smalls.append(p)

    for engine, label in (("mtedp", "xDFS (MTEDP)"), ("mt", "MT"),
                          ("mp", "GridFTP-like (MP)")):
        with XdfsServer(engine=engine, root=str(tmp / f"srv_{engine}")) as srv:
            with XdfsClient.connect(srv.address, n_channels=args.channels,
                                    engine=engine) as cli:
                # large file: one warmup + one measured put over the session
                cli.put(str(src), "payload.bin").result()
                big = cli.put(str(src), "payload.bin").result()
                # small-file burst through the SAME channels (EOFR reuse)
                t0 = time.perf_counter()
                for r in cli.put_many([(str(p), f"in/{p.name}") for p in smalls]):
                    r.result()
                t_burst = time.perf_counter() - t0
                # integrity check: mp's forked receivers cannot capture to
                # parent memory, so round-trip through a file for all engines
                check = tmp / f"check_{engine}.bin"
                cli.get("payload.bin", str(check)).result()
                back = check.read_bytes()[:1024]
            srv.wait_closed_sessions(1, timeout=120)
            ok = back == src.read_bytes()[:1024]
            st = srv.stats
            print(
                f"{label:22s} {args.channels} channels: "
                f"{big.throughput_mbps:8.0f} Mb/s  "
                f"{args.small_files} small files in {t_burst * 1e3:6.1f} ms  "
                f"negotiations={st['negotiations']}  "
                f"EOFR={st['eofr_frames']:4d}  vectored-writes "
                f"{st['writev_calls']:4d}  integrity={'OK' if ok else 'FAIL'}"
            )

    # contrast: the deprecated one-shot path pays fork+negotiation per file
    t0 = time.perf_counter()
    for p in smalls[:4]:
        run_transfer(TransferSpec(
            engine="mtedp", mode="upload", n_channels=args.channels,
            size=p.stat().st_size, src_path=str(p), dst_path=str(tmp / "o.bin"),
        ))
    per = (time.perf_counter() - t0) / 4
    print(f"one-shot run_transfer baseline: {per * 1e3:.1f} ms/file "
          f"(session amortizes this away)")

    import shutil
    shutil.rmtree(tmp)


if __name__ == "__main__":
    main()
