"""Quickstart: an xDFS file-transfer session, then build a reduced model,
run a forward pass, one train step, and a prefill+decode — the whole
public API in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py [--arch llama3-8b]
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, list_configs
from repro.launch.mesh import make_local_mesh
from repro.models.transformer import build_model
from repro.optim import make_optimizer
from repro.runtime.train import init_state, make_train_step


def xdfs_quickstart():
    """The transfer API in six lines: persistent server, one negotiated
    session, files multiplexed over reusable channels (EOFR)."""
    from repro.core.api import XdfsClient, XdfsServer

    with tempfile.TemporaryDirectory() as root:
        with XdfsServer(engine="mtedp", root=root) as srv:
            with XdfsClient.connect(srv.address, n_channels=4) as cli:
                results = cli.put_many(
                    [{"data": bytes([i]) * (64 << 10), "dst": f"obj_{i}.bin"}
                     for i in range(4)]
                )
                total = sum(r.result().bytes for r in results)
                back = cli.get_bytes("obj_0.bin").result().data
        print(f"xDFS session: {total >> 10} KiB over 4 reused channels, "
              f"1 negotiation, roundtrip ok={back == bytes([0]) * (64 << 10)}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=list(list_configs()))
    args = ap.parse_args()

    xdfs_quickstart()

    cfg = get_config(args.arch).smoke()  # reduced config for CPU
    mesh = make_local_mesh(1, 1)
    key = jax.random.key(0)

    with mesh:
        model = build_model(cfg, mesh, "train")
        params = model.init(key)
        n_params = sum(x.size for x in jax.tree.leaves(params))
        print(f"{args.arch} (reduced): {n_params/1e6:.2f}M params, "
              f"pattern={cfg.layer_pattern!r}, profile={cfg.shard_profile}")

        toks = jax.random.randint(key, (2, 64), 0, cfg.vocab_size)
        if cfg.frontend:
            inputs = jax.random.normal(key, (2, 64, cfg.d_model), jnp.bfloat16)
        else:
            inputs = toks
        loss, metrics = jax.jit(model.loss)(
            params, {"inputs": inputs, "labels": toks}
        )
        print(f"initial loss: {float(loss):.4f}")

        opt = make_optimizer(cfg)
        state = init_state(model, key, opt)
        step = jax.jit(make_train_step(model, opt))
        state, metrics = step(state, {"inputs": inputs, "labels": toks})
        print(f"after 1 step: loss={float(metrics['loss']):.4f} "
              f"grad_norm={float(metrics['grad_norm']):.3f}")

        mp = build_model(cfg, mesh, "prefill")
        logits, caches = jax.jit(mp.prefill)(params, {"inputs": inputs})
        md = build_model(cfg, mesh, "decode")
        one = inputs[:, :1] if cfg.frontend else toks[:, :1]
        logits, _ = jax.jit(md.decode_step)(
            params, {"inputs": one, "caches": caches, "pos": jnp.int32(64)}
        )
        print(f"decode logits: {logits.shape}, next token: "
              f"{jnp.argmax(logits[0, 0])}")


if __name__ == "__main__":
    main()
