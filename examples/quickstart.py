"""Quickstart: build a reduced model, run a forward pass, one train step,
and a prefill+decode — the whole public API in ~40 lines.

  PYTHONPATH=src python examples/quickstart.py [--arch llama3-8b]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, list_configs
from repro.launch.mesh import make_local_mesh
from repro.models.transformer import build_model
from repro.optim import make_optimizer
from repro.runtime.train import init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=list(list_configs()))
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()  # reduced config for CPU
    mesh = make_local_mesh(1, 1)
    key = jax.random.key(0)

    with mesh:
        model = build_model(cfg, mesh, "train")
        params = model.init(key)
        n_params = sum(x.size for x in jax.tree.leaves(params))
        print(f"{args.arch} (reduced): {n_params/1e6:.2f}M params, "
              f"pattern={cfg.layer_pattern!r}, profile={cfg.shard_profile}")

        toks = jax.random.randint(key, (2, 64), 0, cfg.vocab_size)
        if cfg.frontend:
            inputs = jax.random.normal(key, (2, 64, cfg.d_model), jnp.bfloat16)
        else:
            inputs = toks
        loss, metrics = jax.jit(model.loss)(
            params, {"inputs": inputs, "labels": toks}
        )
        print(f"initial loss: {float(loss):.4f}")

        opt = make_optimizer(cfg)
        state = init_state(model, key, opt)
        step = jax.jit(make_train_step(model, opt))
        state, metrics = step(state, {"inputs": inputs, "labels": toks})
        print(f"after 1 step: loss={float(metrics['loss']):.4f} "
              f"grad_norm={float(metrics['grad_norm']):.3f}")

        mp = build_model(cfg, mesh, "prefill")
        logits, caches = jax.jit(mp.prefill)(params, {"inputs": inputs})
        md = build_model(cfg, mesh, "decode")
        one = inputs[:, :1] if cfg.frontend else toks[:, :1]
        logits, _ = jax.jit(md.decode_step)(
            params, {"inputs": one, "caches": caches, "pos": jnp.int32(64)}
        )
        print(f"decode logits: {logits.shape}, next token: "
              f"{jnp.argmax(logits[0, 0])}")


if __name__ == "__main__":
    main()
