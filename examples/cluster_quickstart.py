"""Cluster xDFS quickstart: a 3-node striped, replicated cluster in one
process.

Starts a MetaNode and three DataNodes, stripes a multi-MB file across
them with replication factor 2, then KILLS a data node and shows the
read still succeeds from replicas while the failure detector
re-replicates the lost blocks back to full replication.

    PYTHONPATH=src python examples/cluster_quickstart.py [--size-mb 8]
"""
import argparse
import os
import sys
import tempfile
import time

from repro.cluster import ClusterClient, DataNode, MetaNode


def holdings(cli):
    return {n["node_id"]: n["blocks"] for n in cli.state()["nodes"]}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--size-mb", type=int, default=8)
    ap.add_argument("--block-kb", type=int, default=512)
    args = ap.parse_args()
    tmp = tempfile.mkdtemp(prefix="xdfs_cluster_")
    payload = os.urandom(args.size_mb << 20)

    meta = MetaNode(replication=2, heartbeat_timeout=0.6,
                    tick_interval=0.1).start()
    nodes = [
        DataNode(meta.address, os.path.join(tmp, f"node{i}"),
                 node_id=f"node{i}", heartbeat_interval=0.05).start()
        for i in range(3)
    ]
    cli = ClusterClient(meta.address, block_size=args.block_kb << 10)

    t0 = time.perf_counter()
    cli.put("demo/big.bin", data=payload)
    put_s = time.perf_counter() - t0
    print(f"striped put: {args.size_mb} MiB in {put_s:.2f}s "
          f"({args.size_mb / put_s:.0f} MB/s aggregate, rf=2)")
    time.sleep(0.2)  # let block reports land
    print(f"block holdings: {holdings(cli)}")
    print(f"per-block live replicas: {meta.replication_of('demo/big.bin')}")

    t0 = time.perf_counter()
    ok = cli.get("demo/big.bin") == payload
    print(f"striped get: integrity={'OK' if ok else 'FAIL'} "
          f"in {time.perf_counter() - t0:.2f}s")

    print("\n--- killing node0 ---")
    nodes[0].kill()
    ok = cli.get("demo/big.bin") == payload
    print(f"get with node0 dead: integrity={'OK' if ok else 'FAIL'} "
          f"(read failed over to replicas)")

    deadline = time.time() + 30
    while time.time() < deadline:
        counts = meta.replication_of("demo/big.bin")
        if all(c >= 2 for c in counts):
            break
        time.sleep(0.1)
    healed = all(c >= 2 for c in meta.replication_of("demo/big.bin"))
    print(f"re-replication: {'healed to rf=2' if healed else 'INCOMPLETE'} "
          f"-> holdings {holdings(cli)}")
    print(f"cluster state: under_replicated="
          f"{cli.state()['under_replicated']}, "
          f"lost={cli.state()['lost']}")

    cli.close()
    for n in nodes[1:]:
        n.stop()
    meta.stop()
    return 0 if ok and healed else 1


if __name__ == "__main__":
    sys.exit(main())
