"""End-to-end training example: SmolLM-135M-family model for a few hundred
steps with async xDFS checkpointing + the fault supervisor.

Reduced config by default so it runs on CPU in minutes; pass --full-config
on a real accelerator for the actual 135M model.

  PYTHONPATH=src python examples/train_smollm.py --steps 300
"""
import argparse
import tempfile

from repro.configs.base import get_config
from repro.launch.mesh import make_local_mesh
from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    cfg = get_config("smollm-135m")
    if not args.full_config:
        cfg = cfg.smoke()
    mesh = make_local_mesh(1, 1)
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="smollm_ck_")

    _, losses, sup = train_loop(
        cfg, mesh,
        steps=args.steps, batch=args.batch, seq=args.seq, lr=args.lr,
        ckpt_dir=ckpt_dir, ckpt_every=100, log_every=25,
    )
    print(
        f"\ntrained {len(losses)} steps: loss {losses[0]:.4f} -> {losses[-1]:.4f} "
        f"(min {min(losses):.4f}); checkpoints in {ckpt_dir}; "
        f"stragglers flagged: {sup.stragglers}"
    )


if __name__ == "__main__":
    main()
