"""Serving example: batched requests through prefill + decode with the
sequence-sharded KV cache (flash-decoding layout).

  PYTHONPATH=src python examples/serve_decode.py --arch qwen3-14b --gen 24
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, list_configs
from repro.launch.mesh import make_local_mesh
from repro.launch.serve import generate
from repro.models.transformer import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b", choices=list(list_configs()))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    mesh = make_local_mesh(1, 1)
    key = jax.random.key(0)
    with mesh:
        model = build_model(cfg, mesh, "prefill")
        params = model.init(key)
    if cfg.frontend:
        prompts = jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.d_model), jnp.bfloat16
        )
    else:
        prompts = jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab_size
        )
    # batched generation: one prefill, then token-by-token decode
    t0 = time.perf_counter()
    toks = generate(cfg, mesh, params, prompts, args.gen, greedy=False, key=key)
    dt = time.perf_counter() - t0
    print(f"[{args.arch}] {args.batch} requests x {args.gen} tokens "
          f"in {dt:.2f}s = {args.batch * args.gen / dt:.1f} tok/s")
    for i in range(min(2, args.batch)):
        print(f"  request {i}: {list(map(int, toks[i]))}")


if __name__ == "__main__":
    main()
