"""Paper-figure reproductions (Figs. 12-19): xDFS (MTEDP) vs GridFTP-like
(MP) vs MT transfer engines over loopback TCP + real disk I/O.

Scaling note: the paper's LAN testbed moved 0.4-4 GB files over a 1 Gb/s
bottleneck with 8-core hosts. This container is 1 core with loopback, so
sizes are scaled (default 64-256 MiB; --full restores 2 GiB) and the
"bottleneck bandwidth" reference is an iperf-like raw single-socket loopback
measurement (the paper's Iperf rows). Claims validated (EXPERIMENTS.md):
  * disk-to-disk: xDFS >= 1.3x GridFTP-like (paper: +30..53%),
  * mem-to-mem: xDFS reaches a higher fraction of the bottleneck than
    GridFTP-like (paper: 98.5% vs 95%),
  * flat xDFS CPU/RSS profiles vs growing MP profiles (Figs. 13/16/17/19).
"""
from __future__ import annotations

import json
import os
import socket
import tempfile
import threading
import time
from pathlib import Path

from repro.core.api import XdfsClient, XdfsServer
from repro.core.transfer import TransferSpec, run_transfer

MB = 1 << 20


def iperf_like(size: int) -> float:
    """Raw single-socket loopback throughput (Mb/s) — the bottleneck ref."""
    lsock = socket.socket()
    lsock.bind(("127.0.0.1", 0))
    lsock.listen(1)
    port = lsock.getsockname()[1]
    buf = bytearray(1 << 20)

    def rx():
        c, _ = lsock.accept()
        got = 0
        while got < size:
            r = c.recv_into(buf, len(buf))
            if r == 0:
                break
            got += r
        c.close()

    t = threading.Thread(target=rx)
    t.start()
    s = socket.socket()
    s.connect(("127.0.0.1", port))
    payload = bytes(1 << 20)
    t0 = time.perf_counter()
    sent = 0
    while sent < size:
        s.sendall(payload)
        sent += len(payload)
    s.close()
    t.join()
    dt = time.perf_counter() - t0
    lsock.close()
    return size * 8 / dt / 1e6


def _mkfile(path: str, size: int):
    with open(path, "wb") as f:
        blk = os.urandom(4 * MB)
        left = size
        while left > 0:
            f.write(blk[: min(left, len(blk))])
            left -= len(blk)


def _spec(engine, mode, n, size, src, dst):
    return TransferSpec(
        engine=engine, mode=mode, n_channels=n, size=size,
        src_path=src, dst_path=dst, block_size=1 * MB,
    )


def fig12_14_single_stream(sizes_mb, tmp: Path, repeats: int = 3):
    """Figs. 12-14: single-stream throughput + CPU, both modes, d2d."""
    rows = []
    for size_mb in sizes_mb:
        size = size_mb * MB
        src = str(tmp / "src.bin")
        _mkfile(src, size)
        for mode in ("download", "upload"):
            for engine, label in (("mtedp", "xdfs"), ("mp", "gridftp_like")):
                best = None
                for rep in range(repeats + 1):  # first run = page-cache warmup
                    st = run_transfer(
                        _spec(engine, mode, 1, size, src, str(tmp / "dst.bin"))
                    )
                    if rep == 0:
                        continue
                    if best is None or st.throughput_mbps > best.throughput_mbps:
                        best = st
                rows.append({
                    "fig": "12-14", "mode": mode, "engine": label,
                    "size_mb": size_mb, "mbps": round(best.throughput_mbps, 1),
                    "srv_cpu_pct": round(100 * best.server_cpu_s / best.wall_s, 1),
                    "cli_cpu_pct": round(100 * best.client_cpu_s / best.wall_s, 1),
                })
    return rows


def fig15_19_parallel(size_mb: int, channels, tmp: Path, repeats: int = 2):
    """Figs. 15-19: throughput/CPU/RSS vs #parallel channels, d2d + m2m."""
    rows = []
    size = size_mb * MB
    src = str(tmp / "src.bin")
    _mkfile(src, size)
    ref = iperf_like(size)
    rows.append({"fig": "15/18", "engine": "iperf_like", "n": 1,
                 "mbps": round(ref, 1), "kind": "m2m", "mode": "-"})
    for mode in ("download", "upload"):
        for engine, label in (("mtedp", "xdfs"), ("mt", "mt"), ("mp", "gridftp_like")):
            for n in channels:
                for kind in ("m2m", "d2d"):
                    best = None
                    for rep in range(repeats + (1 if kind == "d2d" else 0)):
                        st = run_transfer(
                            _spec(
                                engine, mode, n, size,
                                src if kind == "d2d" else None,
                                str(tmp / "dst.bin") if kind == "d2d" else None,
                            )
                        )
                        if kind == "d2d" and rep == 0:
                            continue  # page-cache warmup
                        if best is None or st.throughput_mbps > best.throughput_mbps:
                            best = st
                    rows.append({
                        "fig": "15-19", "mode": mode, "engine": label, "n": n,
                        "kind": kind, "mbps": round(best.throughput_mbps, 1),
                        "srv_cpu_pct": round(100 * best.server_cpu_s / best.wall_s, 1),
                        "cli_cpu_pct": round(100 * best.client_cpu_s / best.wall_s, 1),
                        "srv_rss_mb": round(best.server_rss_mb, 1),
                        "cli_rss_mb": round(best.client_rss_mb, 1),
                        "bottleneck_pct": round(100 * best.throughput_mbps / ref, 1),
                    })
    return rows


def table3_session_amortization(tmp: Path, n_files: int = 16,
                                size_kb: int = 256, n_channels: int = 4):
    """Table 3 / §2.5.3: the EOFR multi-file session vs per-file one-shot
    transfers (fork + negotiation + teardown each). Uses the persistent
    XdfsServer/XdfsClient API directly."""
    rows = []
    files = []
    for i in range(n_files):
        p = tmp / f"small_{i}.bin"
        p.write_bytes(os.urandom(size_kb << 10))
        files.append(p)
    for engine in ("mtedp", "mt", "mp"):
        t0 = time.perf_counter()
        with XdfsServer(engine=engine, root=str(tmp / f"sess_{engine}")) as srv:
            with XdfsClient.connect(srv.address, n_channels=n_channels,
                                    engine=engine, block_size=1 << 17) as cli:
                for r in cli.put_many([(str(p), p.name) for p in files]):
                    r.result()
            srv.wait_closed_sessions(1, timeout=300)
        t_sess = time.perf_counter() - t0
        t0 = time.perf_counter()
        for p in files[:4]:  # one-shot is slow; 4 files extrapolate
            run_transfer(TransferSpec(
                engine=engine, mode="upload", n_channels=n_channels,
                size=size_kb << 10, src_path=str(p),
                dst_path=str(tmp / "one.bin"), block_size=1 << 17,
            ))
        t_one = (time.perf_counter() - t0) / 4 * n_files
        rows.append({
            "fig": "table3", "engine": engine, "files": n_files,
            "session_s": round(t_sess, 3), "oneshot_s_est": round(t_one, 3),
            "negotiations": srv.stats["negotiations"],
            "eofr_frames": srv.stats["eofr_frames"],
            "speedup": round(t_one / t_sess, 2),
        })
    return rows


def run(full: bool = False, out_path: str = "benchmarks/results_paper_figs.json"):
    tmp = Path(tempfile.mkdtemp(prefix="xdfs_bench_"))
    sizes = [64, 128, 256, 512] if not full else [400, 1000, 2000, 4000]
    channels = [1, 2, 4, 8, 16] if not full else [1, 2, 5, 10, 20, 50]
    rows = []
    rows += table3_session_amortization(tmp)
    rows += fig12_14_single_stream(sizes, tmp)
    rows += fig15_19_parallel(sizes[1], channels, tmp)
    Path(out_path).write_text(json.dumps(rows, indent=1))
    # CSV summary to stdout
    for r in rows:
        print(",".join(f"{k}={v}" for k, v in r.items()))
    import shutil

    shutil.rmtree(tmp)
    return rows


if __name__ == "__main__":
    import sys

    run(full="--full" in sys.argv)
