"""Device-channel benchmarks: xDFS ring collectives vs XLA natives.

Run in an 8-host-device subprocess context (see run.py). Reports:
  * wall time per call (uni/bidirectional ring, int8-compressed, lax.psum),
  * per-device collective BYTES from the trip-count-corrected HLO analysis
    — the dry-run-style structural metric that carries to real TPUs
    (compression should show ~0.5x wire bytes; bidirectional rings show
    2 counter-rotating permute streams).
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.channel import ring_all_reduce
from repro.core.compress import Int8Codec
from repro.launch.hlo_analysis import analyze_hlo


def bench(fn, x, iters=20):
    out = fn(x)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(x)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run():
    n_dev = jax.device_count()
    mesh = jax.make_mesh((n_dev,), ("x",))
    size = 4 << 20  # 4M f32 = 16 MB payload
    x = jnp.ones((size,), jnp.float32)

    def sm(f):
        return jax.jit(
            jax.shard_map(f, mesh=mesh, in_specs=P(None), out_specs=P(None),
                          check_vma=False)
        )

    cases = {
        "lax_psum": sm(lambda a: jax.lax.psum(a, "x")),
        "ring_uni": sm(lambda a: ring_all_reduce(a, "x", bidirectional=False)),
        "ring_bidir": sm(lambda a: ring_all_reduce(a, "x", bidirectional=True)),
        "ring_int8": sm(lambda a: ring_all_reduce(a, "x", codec=Int8Codec)),
    }
    rows = []
    for name, fn in cases.items():
        us = bench(fn, x)
        hlo = fn.lower(x).compile().as_text()
        a = analyze_hlo(hlo)
        coll_bytes = sum(v["operand_bytes"] for v in a["collectives"].values())
        rows.append({
            "bench": "device_channel", "case": name, "us_per_call": round(us, 1),
            "collective_bytes_per_dev": int(coll_bytes),
            "payload_mb": size * 4 / 2**20,
        })
        print(f"device_channel,{name},us_per_call={us:.1f},"
              f"coll_bytes/dev={coll_bytes/2**20:.2f}MiB")
    return rows


if __name__ == "__main__":
    run()
