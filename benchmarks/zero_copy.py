"""Zero-copy send-path A/B microbenchmark.

Three sender datapaths pushing the same framed block stream through a
loopback socketpair, mem-to-mem and disk-to-disk:

* ``copy``     — the legacy frame build: ``hdr.pack() + payload`` (a fresh
  header allocation plus a full-frame concat copy per block; on the disk
  path the payload itself is a fresh ``os.pread`` heap buffer too);
* ``sg``       — scatter-gather ``sendmsg([header_view, block_view])``:
  reusable per-channel header buffer + a view into the source mmap, zero
  user-space payload copies;
* ``sendfile`` — header then ``os.sendfile`` straight from the page cache
  (file-backed sources only; the kernel never surfaces the payload to
  user space at all).

The receiver drains into one reusable buffer (and, in disk mode, appends
to a sink file) so both sides are allocation-free and the A/B isolates
the SENDER datapath.

  PYTHONPATH=src python -m benchmarks.zero_copy [--mb 64] [--block-kb 128]
"""
from __future__ import annotations

import os
import socket
import tempfile
import threading
import time
from typing import List, Optional

from repro.core.engines.base import (
    SENDFILE,
    FrameBuilder,
    Source,
    send_all,
    sendfile_all,
    sendmsg_all,
)
from repro.core.header import HEADER_SIZE, ChannelEvent, ChannelHeader

SESSION = b"zero-copy-bench!"  # 16 bytes
SOCK_BUF = 1 << 20


def _drain(sock: socket.socket, total: int, sink_fd: int = -1) -> None:
    try:
        buf = bytearray(1 << 20)
        mv = memoryview(buf)
        got = 0
        while got < total:
            r = sock.recv_into(mv)
            if r == 0:
                raise ConnectionError("sender closed early")
            if sink_fd >= 0:
                os.write(sink_fd, mv[:r])
            got += r
    except BaseException:
        sock.close()  # unblock a mid-send sender (EPIPE) instead of hanging
        raise


def _send_copy(sock: socket.socket, source: Source) -> None:
    for i in range(source.n_blocks):
        ln = source.block_len(i)
        hdr = ChannelHeader(ChannelEvent.xFTSMU, SESSION, 0,
                            i * source.block_size, ln)
        send_all(sock, hdr.pack() + source.read_block(i))


def _send_sg(sock: socket.socket, source: Source) -> None:
    frames = FrameBuilder(SESSION, 1)
    for i in range(source.n_blocks):
        ln = source.block_len(i)
        sendmsg_all(sock, [
            frames.header(0, ChannelEvent.xFTSMU, i * source.block_size, ln),
            source.block_view(i),
        ])


def _send_sendfile(sock: socket.socket, source: Source) -> None:
    frames = FrameBuilder(SESSION, 1)
    fd = source.fileno()
    for i in range(source.n_blocks):
        ln = source.block_len(i)
        off = i * source.block_size
        send_all(sock, frames.header(0, ChannelEvent.xFTSMU, off, ln))
        sendfile_all(sock, fd, off, ln)


_PATHS = {"copy": _send_copy, "sg": _send_sg, "sendfile": _send_sendfile}


def _time_path_once(path: str, make_source, size: int,
                    sink_path: Optional[str]) -> float:
    """One timed run of one datapath; receiver joined before the clock
    stops so the full pipe is accounted."""
    a, b = socket.socketpair()
    for s in (a, b):
        s.setsockopt(socket.SOL_SOCKET, socket.SO_SNDBUF, SOCK_BUF)
        s.setsockopt(socket.SOL_SOCKET, socket.SO_RCVBUF, SOCK_BUF)
    source = make_source()
    sink_fd = (os.open(sink_path, os.O_WRONLY | os.O_CREAT | os.O_TRUNC,
                       0o644) if sink_path else -1)
    total = source.n_blocks * HEADER_SIZE + size
    # daemon + finally-closed sockets: a failing datapath surfaces as a
    # traceback instead of deadlocking the smoke run
    rx = threading.Thread(target=_drain, args=(b, total, sink_fd),
                          daemon=True)
    rx.start()
    try:
        t0 = time.perf_counter()
        _PATHS[path](a, source)
        rx.join()
        return time.perf_counter() - t0
    finally:
        source.close()
        if sink_fd >= 0:
            os.close(sink_fd)
        a.close()
        b.close()


def run(size_mb: int = 64, block_kb: int = 128, repeats: int = 5,
        smoke: bool = False) -> List[dict]:
    """Run the A/B matrix; returns one row per (mode, path). Best-of-N
    with interleaved repeats: on a shared host, each path's best run is
    its least-interfered one, which is the honest hardware comparison."""
    if smoke:
        size_mb, repeats = min(size_mb, 32), 6
    size = size_mb << 20
    block_size = block_kb << 10
    payload = os.urandom(size)

    tmp = tempfile.mkdtemp(prefix="xdfs_zc_")
    src_file = os.path.join(tmp, "src.bin")
    with open(src_file, "wb") as f:
        f.write(payload)
    sink_file = os.path.join(tmp, "dst.bin")

    modes = {
        "mem": (lambda: Source(None, size, block_size, data=payload), None),
        "disk": (lambda: Source(src_file, size, block_size), sink_file),
    }
    rows: List[dict] = []
    for mode, (make_source, sink_path) in modes.items():
        paths = [p for p in ("copy", "sg", "sendfile")
                 if not (p == "sendfile" and (mode == "mem" or not SENDFILE))]
        # interleave the paths per repeat so host-load drift hits every
        # datapath equally; keep each path's best
        best = {p: float("inf") for p in paths}
        for _ in range(repeats):
            for p in paths:
                best[p] = min(best[p],
                              _time_path_once(p, make_source, size, sink_path))
        base_mb_s = size / best["copy"] / 1e6
        for path in paths:
            mb_s = size / best[path] / 1e6
            row = {
                "mode": mode, "path": path, "block_kb": block_kb,
                "size_mb": size_mb, "mb_s": round(mb_s, 1),
                "gain_vs_copy": round(mb_s / base_mb_s, 2),
            }
            rows.append(row)
            print(",".join(f"{k}={v}" for k, v in row.items()), flush=True)

    import shutil
    shutil.rmtree(tmp, ignore_errors=True)
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--mb", type=int, default=64)
    ap.add_argument("--block-kb", type=int, default=128)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    run(args.mb, args.block_kb, args.repeats, smoke=args.smoke)
