"""Zero-copy datapath A/B microbenchmarks — send side AND receive side.

**Send side** (:func:`run`): three sender datapaths pushing the same
framed block stream through a loopback socketpair, mem-to-mem and
disk-to-disk:

* ``copy``     — the legacy frame build: ``hdr.pack() + payload`` (a fresh
  header allocation plus a full-frame concat copy per block; on the disk
  path the payload itself is a fresh ``os.pread`` heap buffer too);
* ``sg``       — scatter-gather ``sendmsg([header_view, block_view])``:
  reusable per-channel header buffer + a view into the source mmap, zero
  user-space payload copies;
* ``sendfile`` — header then ``os.sendfile`` straight from the page cache
  (file-backed sources only; the kernel never surfaces the payload to
  user space at all).

The receiver drains into one reusable buffer (and, in disk mode, appends
to a sink file) so both sides are allocation-free and the A/B isolates
the SENDER datapath.

**Receive side** (:func:`run_recv`): a fast scatter-gather sender streams
the frames; three receiver datapaths drain them, mem (discard) and disk:

* ``copy``   — the seed receive pipeline: a fresh payload buffer per
  frame, copy-in to the locked ring, snapshot copy back out on the drain,
  ``pwritev`` of the snapshots (three payload-size heap touches/block);
* ``pool``   — the registered-buffer path: ``recv_into`` pool slot views,
  headers parsed in place, coalesced ``pwritev`` of the SAME pool memory
  (zero user-space payload copies);
* ``splice`` — kernel-side socket -> pipe -> file ``os.splice`` (disk
  sinks on Linux only; falls back to ``pool`` when unsupported).

**Batched framing** (:func:`run_batched`): per-frame vs syscall-batched
datapaths at a SMALL block size (framing-bound), counter-based syscall
accounting on both ends:

* ``frame``   — one ``sendmsg`` per frame, header+payload ``recv_into``
  pairs per frame (the ``batch_frames == 1`` datapath);
* ``batch64`` — 64 frames per scatter-gather ``sendmsg``, slab
  ``recv_into`` reads spanning many frames (``SlabChannel``).

Each row carries ``syscalls_per_gb`` (sender sendmsg + receiver
recv_into, normalized to 1 GB); the check_json gate enforces the >=4x
reduction invariant between the two rows.

  PYTHONPATH=src python -m benchmarks.zero_copy [--mb 64] [--block-kb 128]
"""
from __future__ import annotations

import os
import socket
import sys
import tempfile
import threading
import time
from typing import List, Optional

from repro.core.engines.base import (
    SENDFILE,
    SPLICE,
    FrameBuilder,
    SendStats,
    Sink,
    SlabChannel,
    Source,
    SpliceReceiver,
    SpliceUnsupported,
    recv_exact,
    send_all,
    sendfile_all,
    sendmsg_all,
    sendmsg_batched,
    slab_span,
)
from repro.core.header import HEADER_SIZE, ChannelEvent, ChannelHeader
from repro.core.ringbuf import LockedRing, RecvBufferPool, RecvSlab

SESSION = b"zero-copy-bench!"  # 16 bytes
SOCK_BUF = 1 << 20


def _drain(sock: socket.socket, total: int, sink_fd: int = -1) -> None:
    try:
        buf = bytearray(1 << 20)
        mv = memoryview(buf)
        got = 0
        while got < total:
            r = sock.recv_into(mv)
            if r == 0:
                raise ConnectionError("sender closed early")
            if sink_fd >= 0:
                os.write(sink_fd, mv[:r])
            got += r
    except BaseException:
        sock.close()  # unblock a mid-send sender (EPIPE) instead of hanging
        raise


def _send_copy(sock: socket.socket, source: Source) -> None:
    for i in range(source.n_blocks):
        ln = source.block_len(i)
        hdr = ChannelHeader(ChannelEvent.xFTSMU, SESSION, 0,
                            i * source.block_size, ln)
        send_all(sock, hdr.pack() + source.read_block(i))


def _send_sg(sock: socket.socket, source: Source) -> None:
    frames = FrameBuilder(SESSION, 1)
    for i in range(source.n_blocks):
        ln = source.block_len(i)
        sendmsg_all(sock, [
            frames.header(0, ChannelEvent.xFTSMU, i * source.block_size, ln),
            source.block_view(i),
        ])


def _send_sendfile(sock: socket.socket, source: Source) -> None:
    frames = FrameBuilder(SESSION, 1)
    fd = source.fileno()
    for i in range(source.n_blocks):
        ln = source.block_len(i)
        off = i * source.block_size
        send_all(sock, frames.header(0, ChannelEvent.xFTSMU, off, ln))
        sendfile_all(sock, fd, off, ln)


_PATHS = {"copy": _send_copy, "sg": _send_sg, "sendfile": _send_sendfile}


def _time_path_once(path: str, make_source, size: int,
                    sink_path: Optional[str]) -> float:
    """One timed run of one datapath; receiver joined before the clock
    stops so the full pipe is accounted."""
    a, b = socket.socketpair()
    for s in (a, b):
        s.setsockopt(socket.SOL_SOCKET, socket.SO_SNDBUF, SOCK_BUF)
        s.setsockopt(socket.SOL_SOCKET, socket.SO_RCVBUF, SOCK_BUF)
    source = make_source()
    sink_fd = (os.open(sink_path, os.O_WRONLY | os.O_CREAT | os.O_TRUNC,
                       0o644) if sink_path else -1)
    total = source.n_blocks * HEADER_SIZE + size
    # daemon + finally-closed sockets: a failing datapath surfaces as a
    # traceback instead of deadlocking the smoke run
    rx = threading.Thread(target=_drain, args=(b, total, sink_fd),
                          daemon=True)
    rx.start()
    try:
        t0 = time.perf_counter()
        _PATHS[path](a, source)
        rx.join()
        return time.perf_counter() - t0
    finally:
        source.close()
        if sink_fd >= 0:
            os.close(sink_fd)
        a.close()
        b.close()


def run(size_mb: int = 64, block_kb: int = 128, repeats: int = 5,
        smoke: bool = False) -> List[dict]:
    """Run the A/B matrix; returns one row per (mode, path). Best-of-N
    with interleaved repeats: on a shared host, each path's best run is
    its least-interfered one, which is the honest hardware comparison."""
    if smoke:
        size_mb, repeats = min(size_mb, 32), 6
    size = size_mb << 20
    block_size = block_kb << 10
    payload = os.urandom(size)

    tmp = tempfile.mkdtemp(prefix="xdfs_zc_")
    src_file = os.path.join(tmp, "src.bin")
    with open(src_file, "wb") as f:
        f.write(payload)
    sink_file = os.path.join(tmp, "dst.bin")

    modes = {
        "mem": (lambda: Source(None, size, block_size, data=payload), None),
        "disk": (lambda: Source(src_file, size, block_size), sink_file),
    }
    rows: List[dict] = []
    for mode, (make_source, sink_path) in modes.items():
        paths = [p for p in ("copy", "sg", "sendfile")
                 if not (p == "sendfile" and (mode == "mem" or not SENDFILE))]
        # interleave the paths per repeat so host-load drift hits every
        # datapath equally; keep each path's best
        best = {p: float("inf") for p in paths}
        for _ in range(repeats):
            for p in paths:
                best[p] = min(best[p],
                              _time_path_once(p, make_source, size, sink_path))
        base_mb_s = size / best["copy"] / 1e6
        for path in paths:
            mb_s = size / best[path] / 1e6
            row = {
                "mode": mode, "path": path, "block_kb": block_kb,
                "size_mb": size_mb, "mb_s": round(mb_s, 1),
                "gain_vs_copy": round(mb_s / base_mb_s, 2),
            }
            rows.append(row)
            print(",".join(f"{k}={v}" for k, v in row.items()), flush=True)

    import shutil
    shutil.rmtree(tmp, ignore_errors=True)
    return rows


# ---------------------------------------------------------------------------
# receive-side A/B
# ---------------------------------------------------------------------------


RECV_DRAIN_EVERY = 16  # blocks buffered before the batched write-out


def _recv_frames(sock: socket.socket, n_blocks: int, on_block) -> None:
    """Shared frame loop: header parsed in place from one reusable buffer,
    payload handling delegated to the path-specific ``on_block``."""
    hdr_buf = memoryview(bytearray(HEADER_SIZE))
    for _ in range(n_blocks):
        recv_exact(sock, HEADER_SIZE, hdr_buf)
        hdr = ChannelHeader.unpack(hdr_buf)
        on_block(sock, hdr)


def _recv_copy(sock: socket.socket, sink: Sink, n_blocks: int,
               block_size: int) -> None:
    """The seed MT pipeline, faithfully: a fresh payload buffer per frame,
    copy-in to the pessimistically locked shared ring, a disk thread that
    snapshot-copies the batch back out and writes the snapshots — two
    payload copies per block plus the lock handoffs."""
    ring = LockedRing(32, block_size)
    err: List[BaseException] = []

    def disk():
        try:
            while True:
                batch = ring.get_batch()
                if batch:
                    sink.writev_coalesced([(off, len(d), d)
                                           for off, d in batch])
                elif ring.closed:
                    return
        except BaseException as e:  # noqa: BLE001 - surfaced after join
            err.append(e)
            ring.close()

    dt = threading.Thread(target=disk)
    dt.start()

    def on_block(sock, hdr):
        payload = recv_exact(sock, hdr.length)  # fresh bytearray per frame
        ring.put(payload, hdr.offset)

    try:
        _recv_frames(sock, n_blocks, on_block)
    finally:
        ring.close()
        dt.join()
    if err:
        raise err[0]


def _pool_datapath(sink: Sink, block_size: int):
    """The registered-buffer datapath as an (on_block, drain) pair —
    shared verbatim by the ``pool`` path and the ``splice`` path's
    fallback, so both rows always measure the SAME pool code."""
    pool = RecvBufferPool(32, block_size)

    def drain():
        blocks = pool.drain()
        sink.writev_views(
            [(off, pool.view(slot)[:ln]) for off, ln, slot in blocks])
        pool.release_all(slot for _, _, slot in blocks)

    def on_block(sock, hdr):
        slot = pool.acquire()
        if slot is None:
            drain()
            slot = pool.acquire()
        recv_exact(sock, hdr.length, pool.view(slot))
        pool.commit(slot, hdr.offset, hdr.length)
        if pool.n_committed >= RECV_DRAIN_EVERY:
            drain()

    return on_block, drain


def _recv_pool(sock: socket.socket, sink: Sink, n_blocks: int,
               block_size: int) -> None:
    """Registered-buffer path: recv_into pool slot views, pwritev the same
    memory, release. Zero user-space payload copies."""
    on_block, drain = _pool_datapath(sink, block_size)
    _recv_frames(sock, n_blocks, on_block)
    drain()


def _recv_splice(sock: socket.socket, sink: Sink, n_blocks: int,
                 block_size: int) -> None:
    """Kernel-side socket->pipe->file; on first-call fallback the remaining
    frames take the pool path (mirroring the engines)."""
    spl = SpliceReceiver()
    pool_block, drain = _pool_datapath(sink, block_size)
    state = {"spl": True}

    def on_block(sock, hdr):
        if state["spl"]:
            try:
                spl.splice_block(sock, sink.fileno(), hdr.offset, hdr.length)
                if not spl.ok:
                    state["spl"] = False
                return
            except SpliceUnsupported:
                state["spl"] = False
        pool_block(sock, hdr)

    try:
        _recv_frames(sock, n_blocks, on_block)
        drain()
    finally:
        spl.close()


_RECV_PATHS = {"copy": _recv_copy, "pool": _recv_pool, "splice": _recv_splice}


def _time_recv_path_once(path: str, source: Source, sink_path: Optional[str],
                         block_size: int) -> float:
    """One timed run of one receiver datapath. The sender is a forked
    process running the scatter-gather path from an in-memory source — a
    separate process so no GIL contention caps the receiver under test."""
    a, b = socket.socketpair()
    for s in (a, b):
        s.setsockopt(socket.SOL_SOCKET, socket.SO_SNDBUF, SOCK_BUF)
        s.setsockopt(socket.SOL_SOCKET, socket.SO_RCVBUF, SOCK_BUF)
    sink = Sink(sink_path, source.size)
    pid = os.fork()
    if pid == 0:  # sender child (source pages shared copy-on-write)
        try:
            b.close()
            _send_sg(a, source)
            os._exit(0)
        except BaseException:
            os._exit(1)
    a.close()
    try:
        t0 = time.perf_counter()
        _RECV_PATHS[path](b, sink, source.n_blocks, block_size)
        elapsed = time.perf_counter() - t0
        if sink.file_backed:
            # flush dirty pages OUTSIDE the timed region so this run's
            # writeback doesn't contaminate the next path's timing
            os.fsync(sink.fileno())
        return elapsed
    finally:
        sink.close()
        b.close()
        _, status = os.waitpid(pid, 0)
        # a receiver exception closes b mid-stream and EPIPEs the child;
        # only surface the child's failure when nothing else is propagating
        if (os.waitstatus_to_exitcode(status) != 0
                and sys.exc_info()[0] is None):
            raise RuntimeError("recv-bench sender child failed")


def run_recv(size_mb: int = 64, block_kb: int = 128, repeats: int = 12,
             smoke: bool = False) -> List[dict]:
    """Receive-side A/B matrix; one row per (mode, path), best-of-N with
    interleaved repeats (same protocol as the send-side :func:`run`, but
    more repeats: disk-write latency on a sandboxed host is erratic enough
    that each path needs many shots at a quiet window)."""
    if smoke:
        size_mb, repeats = min(size_mb, 32), 12
    size = size_mb << 20
    block_size = block_kb << 10
    payload = os.urandom(size)
    source = Source(None, size, block_size, data=payload)

    tmp = tempfile.mkdtemp(prefix="xdfs_zcr_")
    sink_file = os.path.join(tmp, "dst.bin")

    modes = {"mem": None, "disk": sink_file}
    rows: List[dict] = []
    for mode, sink_path in modes.items():
        paths = [p for p in ("copy", "pool", "splice")
                 if not (p == "splice" and (mode == "mem" or not SPLICE))]
        best = {p: float("inf") for p in paths}
        for _ in range(repeats):
            for p in paths:
                best[p] = min(
                    best[p],
                    _time_recv_path_once(p, source, sink_path, block_size))
        base_mb_s = size / best["copy"] / 1e6
        for path in paths:
            mb_s = size / best[path] / 1e6
            row = {
                "mode": mode, "path": path, "block_kb": block_kb,
                "size_mb": size_mb, "mb_s": round(mb_s, 1),
                "gain_vs_copy": round(mb_s / base_mb_s, 2),
            }
            rows.append(row)
            print(",".join(f"{k}={v}" for k, v in row.items()), flush=True)

    source.close()
    import shutil
    shutil.rmtree(tmp, ignore_errors=True)
    return rows


# ---------------------------------------------------------------------------
# batched-framing A/B (syscalls per GB, per-frame vs batched)
# ---------------------------------------------------------------------------


BATCH_DEPTH = 64  # the batched path's fixed depth (the ladder's top rung)


def _send_frames_child(sock: socket.socket, source: Source, depth: int,
                       count_fd: int) -> None:
    """Child-side sender: ``depth`` frames per scatter-gather
    ``sendmsg_batched`` (depth 1 == the per-frame datapath). The sendmsg
    syscall count travels back over ``count_fd``."""
    frames = FrameBuilder(SESSION, 1, depth=depth + 1)
    stats = SendStats()
    b = 0
    while b < source.n_blocks:
        iov = []
        sizes = []
        while len(sizes) < depth and b < source.n_blocks:
            ln = source.block_len(b)
            iov.append(frames.header(0, ChannelEvent.xFTSMU,
                                     b * source.block_size, ln))
            iov.append(source.block_view(b))
            sizes.append(HEADER_SIZE + ln)
            b += 1
        sendmsg_batched(sock, iov, sizes, stats)
    send_all(sock, frames.header(0, ChannelEvent.EOFT, 0, 0))
    stats.syscalls += 1  # the end frame's send
    os.write(count_fd, stats.syscalls.to_bytes(8, "little"))


def _recv_per_frame_counted(sock: socket.socket, sink: Sink,
                            block_size: int) -> int:
    """The ``batch_frames == 1`` receive shape — header ``recv_into`` then
    payload ``recv_into`` per frame, registered pool, coalesced drain —
    returning the exact number of recv syscalls issued."""
    pool = RecvBufferPool(32, block_size)
    hdr_buf = memoryview(bytearray(HEADER_SIZE))
    calls = 0

    def recv_counted(view, n) -> int:
        nonlocal calls
        got = 0
        while got < n:
            r = sock.recv_into(view[got:n], n - got)
            if r == 0:
                raise ConnectionError("sender closed early")
            got += r
            calls += 1
        return n

    def drain():
        blocks = pool.drain()
        sink.writev_views(
            [(off, pool.view(slot)[:ln]) for off, ln, slot in blocks])
        pool.release_all(slot for _, _, slot in blocks)

    while True:
        recv_counted(hdr_buf, HEADER_SIZE)
        hdr = ChannelHeader.unpack(hdr_buf)
        if hdr.event == ChannelEvent.EOFT:
            break
        slot = pool.acquire()
        if slot is None:
            drain()
            slot = pool.acquire()
        recv_counted(pool.view(slot), hdr.length)
        pool.commit(slot, hdr.offset, hdr.length)
        if pool.n_committed >= RECV_DRAIN_EVERY:
            drain()
    drain()
    return calls


def _recv_batched_counted(sock: socket.socket, sink: Sink,
                          block_size: int) -> int:
    """The slab receive shape: large multi-frame ``recv_into`` reads
    parsed in place; returns the recv syscall count."""
    sc = SlabChannel(RecvSlab(slab_span(BATCH_DEPTH, block_size)),
                     block_size)
    while sc.end_event is None:
        if sc.free_space() == 0:
            sink.writev_views(sc.take_pending())
            sc.compact()
        sc.receive_once(sock)
    sink.writev_views(sc.take_pending())
    return sc.recv_calls


_BATCH_PATHS = {
    "frame": (1, _recv_per_frame_counted),
    f"batch{BATCH_DEPTH}": (BATCH_DEPTH, _recv_batched_counted),
}


def _time_batch_path_once(path: str, source: Source,
                          block_size: int) -> tuple:
    """One timed mem-to-mem run; the sender is forked (no GIL contention)
    and pipes its sendmsg count back. Returns (elapsed, total_syscalls)."""
    depth, recv_fn = _BATCH_PATHS[path]
    a, b = socket.socketpair()
    for s in (a, b):
        s.setsockopt(socket.SOL_SOCKET, socket.SO_SNDBUF, SOCK_BUF)
        s.setsockopt(socket.SOL_SOCKET, socket.SO_RCVBUF, SOCK_BUF)
    sink = Sink(None, source.size)  # discard: isolates the framing cost
    r_cnt, w_cnt = os.pipe()
    pid = os.fork()
    if pid == 0:  # sender child (source pages shared copy-on-write)
        try:
            b.close()
            os.close(r_cnt)
            _send_frames_child(a, source, depth, w_cnt)
            os._exit(0)
        except BaseException:
            os._exit(1)
    a.close()
    os.close(w_cnt)
    try:
        t0 = time.perf_counter()
        rx_calls = recv_fn(b, sink, block_size)
        elapsed = time.perf_counter() - t0
        tx_calls = int.from_bytes(os.read(r_cnt, 8), "little")
        return elapsed, rx_calls + tx_calls
    finally:
        os.close(r_cnt)
        sink.close()
        b.close()
        _, status = os.waitpid(pid, 0)
        if (os.waitstatus_to_exitcode(status) != 0
                and sys.exc_info()[0] is None):
            raise RuntimeError("batch-bench sender child failed")


def run_batched(size_mb: int = 64, block_kb: int = 16, repeats: int = 6,
                smoke: bool = False) -> List[dict]:
    """Batched-framing A/B at a small (framing-bound) block size.

    One row per path with ``syscalls_per_gb`` (sender sendmsg + receiver
    recv_into, normalized) next to ``mb_s``. Smoke mode caps the moved
    bytes and repeats so the CI smoke job's wall-clock budget is
    unchanged (this section is mem-to-mem and stays well under a second
    per run)."""
    if smoke:
        size_mb, repeats = min(size_mb, 24), 4
    size = size_mb << 20
    block_size = block_kb << 10
    payload = os.urandom(size)
    source = Source(None, size, block_size, data=payload)

    rows: List[dict] = []
    best = {p: (float("inf"), 0) for p in _BATCH_PATHS}
    for _ in range(repeats):
        for p in _BATCH_PATHS:  # interleaved: drift hits both paths equally
            t, calls = _time_batch_path_once(p, source, block_size)
            if t < best[p][0]:
                best[p] = (t, calls)
    base_mb_s = size / best["frame"][0] / 1e6
    for path, (t, calls) in best.items():
        mb_s = size / t / 1e6
        rows.append({
            "mode": "mem", "path": path, "block_kb": block_kb,
            "size_mb": size_mb, "mb_s": round(mb_s, 1),
            "gain_vs_frame": round(mb_s / base_mb_s, 2),
            "syscalls_per_gb": round(calls * (1 << 30) / size),
        })
        print(",".join(f"{k}={v}" for k, v in rows[-1].items()), flush=True)
    source.close()
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--mb", type=int, default=64)
    ap.add_argument("--block-kb", type=int, default=128)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--recv", action="store_true",
                    help="run only the receive-side A/B")
    ap.add_argument("--send", action="store_true",
                    help="run only the send-side A/B")
    ap.add_argument("--batched", action="store_true",
                    help="run only the batched-framing A/B")
    args = ap.parse_args()
    # no flags (or several) = all A/Bs; a single flag selects one
    only = args.recv or args.send or args.batched
    if args.send or not only:
        run(args.mb, args.block_kb, args.repeats, smoke=args.smoke)
    if args.recv or not only:
        run_recv(args.mb, args.block_kb, args.repeats, smoke=args.smoke)
    if args.batched or not only:
        run_batched(args.mb, repeats=args.repeats, smoke=args.smoke)
