"""Schema check for BENCH_*.json perf baselines (the CI gate).

  PYTHONPATH=src python -m benchmarks.check_json BENCH_host.json

Exits non-zero (listing every violation) if the file is missing,
malformed, or lacks the sections/row keys the perf trajectory depends on.
"""
from __future__ import annotations

import json
import sys
from typing import List

REQUIRED_TOP = ("schema", "host", "python", "sections")
REQUIRED_SECTIONS = {
    "session_reuse": {"engine", "channels", "speedup", "session_s"},
    "zero_copy": {"mode", "path", "block_kb", "mb_s", "gain_vs_copy"},
    "host_transfer": {"engine", "channels", "block_kb", "mb_s",
                      "writev_calls"},
}
SCALAR = (int, float, str, bool)


def check(path: str) -> List[str]:
    errors: List[str] = []
    try:
        with open(path) as f:
            doc = json.load(f)
    except FileNotFoundError:
        return [f"{path}: file not found"]
    except json.JSONDecodeError as e:
        return [f"{path}: malformed JSON: {e}"]
    if not isinstance(doc, dict):
        return [f"{path}: top level must be an object"]
    for key in REQUIRED_TOP:
        if key not in doc:
            errors.append(f"missing top-level key {key!r}")
    sections = doc.get("sections")
    if not isinstance(sections, dict) or not sections:
        errors.append("'sections' must be a non-empty object")
        return errors
    for name, required_keys in REQUIRED_SECTIONS.items():
        rows = sections.get(name)
        if not isinstance(rows, list) or not rows:
            errors.append(f"section {name!r} missing or empty")
            continue
        for i, row in enumerate(rows):
            if not isinstance(row, dict) or not row:
                errors.append(f"{name}[{i}]: row must be a non-empty object")
                continue
            missing = required_keys - row.keys()
            if missing:
                errors.append(f"{name}[{i}]: missing keys {sorted(missing)}")
            bad = [k for k, v in row.items() if not isinstance(v, SCALAR)]
            if bad:
                errors.append(f"{name}[{i}]: non-scalar values for {bad}")
    return errors


def main() -> None:
    if len(sys.argv) != 2:
        print("usage: python -m benchmarks.check_json BENCH.json",
              file=sys.stderr)
        sys.exit(2)
    errors = check(sys.argv[1])
    if errors:
        for e in errors:
            print(f"SCHEMA ERROR: {e}", file=sys.stderr)
        sys.exit(1)
    print(f"{sys.argv[1]}: OK")


if __name__ == "__main__":
    main()
