"""Schema check + throughput-regression gate for BENCH_*.json baselines.

Two modes (docs/BENCHMARKING.md has the full story):

* **schema** (always) — the candidate file must carry every required
  section with every required row key, scalar values only; the
  ``zero_copy_batched`` section additionally carries a baseline-free
  invariant: batched rows must show at least ``SYSCALL_BATCH_FACTOR``x
  fewer syscalls/GB than their per-frame twin, and ``integrity`` crc_on
  rows must keep ``1 - INTEGRITY_MAX_PENALTY`` of their crc_off twin's
  throughput::

      PYTHONPATH=src python -m benchmarks.check_json BENCH_host.json

* **regression gate** (``--baseline``) — additionally match each
  candidate row against the committed baseline by its section's identity
  key and fail if the row's throughput metric dropped below
  ``(1 - tolerance) * baseline``. Rows present in the baseline but
  missing from the candidate are lost coverage and fail too::

      PYTHONPATH=src python -m benchmarks.check_json CANDIDATE.json \
          --baseline BENCH_host.json [--tolerance 0.2]

Per-section default tolerances live in ``SECTION_TOLERANCE`` (looser for
the sections that measure multi-process wall time, which is noisier on a
shared host); ``--tolerance`` overrides all of them, e.g. a large value
for CI runners whose absolute speed differs from the committed host.
Exits non-zero listing every violation.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Tuple

REQUIRED_TOP = ("schema", "host", "python", "sections")
REQUIRED_SECTIONS = {
    "session_reuse": {"engine", "channels", "speedup", "session_s"},
    "zero_copy": {"mode", "path", "block_kb", "mb_s", "gain_vs_copy"},
    "zero_copy_recv": {"mode", "path", "block_kb", "mb_s", "gain_vs_copy"},
    "zero_copy_batched": {"mode", "path", "block_kb", "mb_s",
                          "gain_vs_frame", "syscalls_per_gb"},
    "host_transfer": {"engine", "channels", "block_kb", "mb_s",
                      "writev_calls"},
    "cluster_stripe": {"mode", "path", "nodes", "mb_s", "gain_vs_single"},
    "integrity": {"mode", "path", "block_kb", "mb_s", "gain_vs_off"},
    "control_plane": {"mode", "path", "ops_per_s"},
    "c10k": {"mode", "path", "sessions", "ops_per_s", "p50_ms", "p99_ms",
             "accepted", "rejected"},
    "durability": {"mode", "path", "mb_s"},
}
SCALAR = (int, float, str, bool)

# the batched datapath's reason to exist: every batched row must issue at
# most 1/SYSCALL_BATCH_FACTOR the syscalls/GB of its per-frame twin
SYSCALL_BATCH_FACTOR = 4

# Ceiling on the end-to-end integrity penalty: every crc_on row must keep
# gain_vs_off >= 1 - INTEGRITY_MAX_PENALTY. On a single-core host with
# both endpoints colocated the CRC compute floor alone costs ~13% and the
# steady-state penalty is ~25% (benchmarks/integrity_bench.py has the
# budget math); 0.45 clears the worst scheduler-noise outliers while
# still failing the failure modes that matter — an unmemoized
# crc32_combine or a lost native-CRC path costs 10-20x, not 1.45x.
INTEGRITY_MAX_PENALTY = 0.45

# Ceiling on the WAL's commit-path cost: every control_plane
# commit/fsync_on row must keep gain_vs_nofsync >= 1/DURABILITY_MAX_SLOWDOWN
# of its fsync_off twin (same run, so host disk speed cancels out of the
# comparison between the two arms). The fsync itself legitimately costs a
# large constant factor — ~10x measured on this container's overlay fs
# (benchmarks/control_plane.py; docs/BENCHMARKING.md has the budget) — so
# the bound sits at 100x: wide enough for slower commit-path storage,
# tight enough to catch the structural failure it exists for (per-commit
# snapshot re-serialization or multi-fsync appends land 1000x+).
DURABILITY_MAX_SLOWDOWN = 100

# Slack on the throttled scrub row: a token-bucket-limited pass may
# overshoot its configured rate by at most the final chunk's rounding
# plus timer coarseness. Anything past this factor means the limiter is
# not actually pacing reads (the structural failure the row exists for).
SCRUB_RATE_SLACK = 1.25

# A failover row records wall clock from leader kill to a read served by
# the promoted standby; with the benchmark's 0.5 s lease, anything past
# this many seconds means promotion or client failover is structurally
# broken, not slow (ops_per_s = 1/seconds, hence the 1/x floor).
FAILOVER_MAX_SECONDS = 10.0

# Baseline-free tail-latency invariant for the c10k session storm: every
# traffic-mix row must keep p99 within this factor of p50. The measured
# ratio on this host is ~1.6 for both server paths
# (benchmarks/session_reuse.py run_c10k); 20x absorbs scheduler noise on
# shared CI runners while still catching the structural failure the
# event-loop core exists to prevent — a starved session's latency is
# bounded by the whole storm's wall clock, which lands 100x+ over p50.
C10K_P99_P50_MAX = 20

# regression-gate config: identity key (matches a candidate row to its
# baseline row) and the higher-is-better throughput metric per section
SECTION_KEYS = {
    "session_reuse": ("engine", "channels"),
    "zero_copy": ("mode", "path", "block_kb"),
    "zero_copy_recv": ("mode", "path", "block_kb"),
    "zero_copy_batched": ("mode", "path", "block_kb"),
    "host_transfer": ("engine", "channels", "block_kb"),
    "cluster_stripe": ("mode", "path", "nodes"),
    "integrity": ("mode", "path", "block_kb"),
    "control_plane": ("mode", "path"),
    "c10k": ("mode", "path"),
    "durability": ("mode", "path"),
}
SECTION_METRIC = {
    "session_reuse": "speedup",
    "zero_copy": "mb_s",
    "zero_copy_recv": "mb_s",
    "zero_copy_batched": "mb_s",
    "host_transfer": "mb_s",
    "cluster_stripe": "mb_s",
    "integrity": "mb_s",
    "control_plane": "ops_per_s",
    "c10k": "ops_per_s",
    "durability": "mb_s",
}
# Default allowed fractional drop below the baseline before the gate
# fails. The microbench sections are best-of-N on one process (tight);
# session_reuse and host_transfer time forked client/server pairs and see
# much larger scheduler noise on a shared host (see docs/BENCHMARKING.md).
SECTION_TOLERANCE = {
    "session_reuse": 0.50,
    "zero_copy": 0.20,
    "zero_copy_recv": 0.20,
    "zero_copy_batched": 0.25,
    "host_transfer": 0.40,
    # an in-process 3-node cluster multiplies threads per byte moved, so
    # scheduler noise on a shared host dominates (best-of-N still swings
    # ~2x run to run); the gate only catches order-of-magnitude breaks
    "cluster_stripe": 0.60,
    # absolute MB/s of the integrity A/B swings with the host like
    # host_transfer; the tight check is the baseline-free ratio invariant
    # (check_integrity_invariant), not this cross-run throughput gate
    "integrity": 0.40,
    # commit rate is fsync-latency dominated (container fs barriers swing
    # run to run) and the failover row tracks a configured lease timeout;
    # the tight checks are the baseline-free invariants
    # (check_durability_invariant), not this cross-run gate
    "control_plane": 0.60,
    # session-storm throughput multiplies short-lived threads and sockets,
    # the noisiest thing a shared host schedules; the tight check is the
    # baseline-free p99/p50 tail invariant (check_c10k_invariant)
    "c10k": 0.60,
    # fsync/rename latency is container-fs dependent and the throttled
    # scrub row is pinned to its configured limit; the tight checks are
    # the baseline-free invariants (check_scrub_invariant)
    "durability": 0.60,
}


def _load(path: str):
    try:
        with open(path) as f:
            doc = json.load(f)
    except FileNotFoundError:
        return None, [f"{path}: file not found"]
    except json.JSONDecodeError as e:
        return None, [f"{path}: malformed JSON: {e}"]
    if not isinstance(doc, dict):
        return None, [f"{path}: top level must be an object"]
    return doc, []


def check_schema(doc: dict) -> List[str]:
    errors: List[str] = []
    for key in REQUIRED_TOP:
        if key not in doc:
            errors.append(f"missing top-level key {key!r}")
    sections = doc.get("sections")
    if not isinstance(sections, dict) or not sections:
        errors.append("'sections' must be a non-empty object")
        return errors
    for name, required_keys in REQUIRED_SECTIONS.items():
        rows = sections.get(name)
        if not isinstance(rows, list) or not rows:
            errors.append(f"section {name!r} missing or empty")
            continue
        for i, row in enumerate(rows):
            if not isinstance(row, dict) or not row:
                errors.append(f"{name}[{i}]: row must be a non-empty object")
                continue
            missing = required_keys - row.keys()
            if missing:
                errors.append(f"{name}[{i}]: missing keys {sorted(missing)}")
            bad = [k for k, v in row.items() if not isinstance(v, SCALAR)]
            if bad:
                errors.append(f"{name}[{i}]: non-scalar values for {bad}")
    return errors


def check_batched_invariant(doc: dict) -> List[str]:
    """The zero_copy_batched section's acceptance invariant, checked on
    EVERY candidate (no baseline needed): each batched row must show at
    least a ``SYSCALL_BATCH_FACTOR``x reduction in syscalls/GB over the
    per-frame row of the same (mode, block_kb)."""
    errors: List[str] = []
    rows = (doc.get("sections") or {}).get("zero_copy_batched") or []
    frame = {(r.get("mode"), r.get("block_kb")): r for r in rows
             if isinstance(r, dict) and r.get("path") == "frame"}
    for row in rows:
        if not isinstance(row, dict) or row.get("path") == "frame":
            continue
        base = frame.get((row.get("mode"), row.get("block_kb")))
        ident = f"mode={row.get('mode')}, path={row.get('path')}"
        if base is None:
            errors.append(
                f"zero_copy_batched[{ident}]: no per-frame twin row to "
                f"compare syscalls_per_gb against")
            continue
        b_calls, f_calls = row.get("syscalls_per_gb"), base.get(
            "syscalls_per_gb")
        if not all(isinstance(v, (int, float)) and v > 0
                   for v in (b_calls, f_calls)):
            errors.append(
                f"zero_copy_batched[{ident}]: non-numeric syscalls_per_gb")
            continue
        if b_calls * SYSCALL_BATCH_FACTOR > f_calls:
            errors.append(
                f"zero_copy_batched[{ident}]: syscalls/GB only "
                f"{f_calls / b_calls:.1f}x below per-frame "
                f"({b_calls:g} vs {f_calls:g}; must be >= "
                f"{SYSCALL_BATCH_FACTOR}x)")
    return errors


def check_integrity_invariant(doc: dict) -> List[str]:
    """The integrity section's acceptance invariant, checked on EVERY
    candidate (no baseline needed): each crc_on row must keep at least
    ``1 - INTEGRITY_MAX_PENALTY`` of its crc_off twin's throughput —
    both rows come from the same run, so the ratio is immune to the
    host-speed drift that the cross-run gate must tolerate."""
    errors: List[str] = []
    rows = (doc.get("sections") or {}).get("integrity") or []
    floor = 1.0 - INTEGRITY_MAX_PENALTY
    for row in rows:
        if not isinstance(row, dict) or row.get("path") != "crc_on":
            continue
        gain = row.get("gain_vs_off")
        ident = f"mode={row.get('mode')}, block_kb={row.get('block_kb')}"
        if not isinstance(gain, (int, float)):
            errors.append(f"integrity[{ident}]: non-numeric gain_vs_off")
            continue
        if gain < floor:
            errors.append(
                f"integrity[{ident}]: crc_on keeps only {gain:.0%} of "
                f"crc_off throughput (must keep >= {floor:.0%}; "
                f"integrity penalty {1 - gain:.0%} exceeds "
                f"{INTEGRITY_MAX_PENALTY:.0%})")
    return errors


def check_durability_invariant(doc: dict) -> List[str]:
    """The control_plane section's acceptance invariants, checked on
    EVERY candidate (no baseline needed): the journal's fsync arm must
    keep ``1/DURABILITY_MAX_SLOWDOWN`` of its no-fsync twin's commit
    rate (both from the same run, so absolute disk speed cancels), and
    a failover row must complete within ``FAILOVER_MAX_SECONDS``."""
    errors: List[str] = []
    rows = (doc.get("sections") or {}).get("control_plane") or []
    floor = 1.0 / DURABILITY_MAX_SLOWDOWN
    for row in rows:
        if not isinstance(row, dict):
            continue
        if row.get("mode") == "commit" and row.get("path") == "fsync_on":
            gain = row.get("gain_vs_nofsync")
            if not isinstance(gain, (int, float)):
                errors.append(
                    "control_plane[commit/fsync_on]: missing or "
                    "non-numeric gain_vs_nofsync")
            elif gain < floor:
                errors.append(
                    f"control_plane[commit/fsync_on]: journaled commits "
                    f"run {1 / gain:.0f}x slower than no-fsync (must be "
                    f"<= {DURABILITY_MAX_SLOWDOWN}x; the WAL is doing "
                    f"per-commit work beyond one append+fsync)")
        if row.get("mode") == "failover":
            ops = row.get("ops_per_s")
            if not isinstance(ops, (int, float)) or ops <= 0:
                errors.append(
                    f"control_plane[failover/{row.get('path')}]: missing "
                    f"or non-positive ops_per_s")
            elif 1.0 / ops > FAILOVER_MAX_SECONDS:
                errors.append(
                    f"control_plane[failover/{row.get('path')}]: "
                    f"{1.0 / ops:.1f} s to serve reads from the promoted "
                    f"standby (must be <= {FAILOVER_MAX_SECONDS:.0f} s)")
    return errors


def check_scrub_invariant(doc: dict) -> List[str]:
    """The durability section's acceptance invariant, checked on EVERY
    candidate (no baseline needed): the throttled scrub row must exist
    and must NOT exceed its own configured ``limit_mb_s`` by more than
    ``SCRUB_RATE_SLACK`` — the limit rides in the row, so the check
    needs no baseline and no assumption about host speed."""
    errors: List[str] = []
    rows = (doc.get("sections") or {}).get("durability") or []
    throttled = [r for r in rows if isinstance(r, dict)
                 and r.get("mode") == "scrub"
                 and r.get("path") == "throttled"]
    if not throttled:
        errors.append(
            "durability: no throttled scrub row — the rate limiter is "
            "not being exercised")
    for row in throttled:
        mb_s, limit = row.get("mb_s"), row.get("limit_mb_s")
        if not all(isinstance(v, (int, float)) and v > 0
                   for v in (mb_s, limit)):
            errors.append(
                "durability[scrub/throttled]: missing or non-positive "
                "mb_s/limit_mb_s")
        elif mb_s > limit * SCRUB_RATE_SLACK:
            errors.append(
                f"durability[scrub/throttled]: scrub ran at {mb_s:g} MB/s "
                f"against a {limit:g} MB/s limit (must be <= "
                f"{SCRUB_RATE_SLACK}x; the token bucket is not pacing "
                f"reads)")
    return errors


def check_c10k_invariant(doc: dict) -> List[str]:
    """The c10k section's acceptance invariants, checked on EVERY
    candidate (no baseline needed): traffic-mix rows must keep
    ``p99_ms <= C10K_P99_P50_MAX * p50_ms`` (both percentiles come from
    the same storm, so host speed cancels out of the ratio), and the
    admission row must show the cap actually refusing sessions while
    still completing some."""
    errors: List[str] = []
    rows = (doc.get("sections") or {}).get("c10k") or []
    for row in rows:
        if not isinstance(row, dict):
            continue
        ident = f"mode={row.get('mode')}, path={row.get('path')}"
        if row.get("mode") == "mix":
            p50, p99 = row.get("p50_ms"), row.get("p99_ms")
            if not all(isinstance(v, (int, float)) and v > 0
                       for v in (p50, p99)):
                errors.append(f"c10k[{ident}]: non-positive p50_ms/p99_ms")
            elif p99 > C10K_P99_P50_MAX * p50:
                errors.append(
                    f"c10k[{ident}]: p99 {p99:g} ms is {p99 / p50:.1f}x "
                    f"p50 {p50:g} ms (must be <= {C10K_P99_P50_MAX}x; "
                    f"sessions are being starved, not scheduled)")
            rej = row.get("rejected")
            if isinstance(rej, (int, float)) and rej > 0:
                errors.append(
                    f"c10k[{ident}]: {rej:g} sessions refused with NO "
                    f"admission cap configured")
        if row.get("mode") == "admission":
            acc, rej = row.get("accepted"), row.get("rejected")
            if not isinstance(acc, (int, float)) or acc <= 0:
                errors.append(
                    f"c10k[{ident}]: capped storm completed no sessions")
            if not isinstance(rej, (int, float)) or rej <= 0:
                errors.append(
                    f"c10k[{ident}]: admission cap refused no sessions — "
                    f"the cap is not being enforced")
    return errors


def _index_rows(rows: List[dict], key_fields: Tuple[str, ...]) -> Dict:
    out = {}
    for row in rows:
        if isinstance(row, dict) and all(k in row for k in key_fields):
            out[tuple(row[k] for k in key_fields)] = row
    return out


def check_regression(candidate: dict, baseline: dict,
                     tolerance: Optional[float] = None) -> List[str]:
    """Fail any candidate row whose throughput metric dropped more than
    the section's tolerance below the committed baseline."""
    errors: List[str] = []
    cand_sections = candidate.get("sections") or {}
    base_sections = baseline.get("sections") or {}
    for name, key_fields in SECTION_KEYS.items():
        metric = SECTION_METRIC[name]
        tol = tolerance if tolerance is not None else SECTION_TOLERANCE[name]
        base_rows = _index_rows(base_sections.get(name) or [], key_fields)
        cand_rows = _index_rows(cand_sections.get(name) or [], key_fields)
        for key, base_row in base_rows.items():
            base_val = base_row.get(metric)
            if not isinstance(base_val, (int, float)) or base_val <= 0:
                continue  # baseline row carries no usable metric
            cand_row = cand_rows.get(key)
            ident = ", ".join(f"{f}={v}" for f, v in zip(key_fields, key))
            if cand_row is None:
                errors.append(
                    f"{name}[{ident}]: row present in baseline but missing "
                    f"from candidate (lost benchmark coverage)")
                continue
            cand_val = cand_row.get(metric)
            if not isinstance(cand_val, (int, float)):
                errors.append(f"{name}[{ident}]: non-numeric {metric!r}")
                continue
            floor = base_val * (1.0 - tol)
            if cand_val < floor:
                drop = 100.0 * (1.0 - cand_val / base_val)
                errors.append(
                    f"{name}[{ident}]: {metric} regressed {drop:.0f}% "
                    f"({cand_val:g} < floor {floor:g}; baseline {base_val:g}, "
                    f"tolerance {tol:.0%})")
    return errors


def check(path: str, baseline_path: Optional[str] = None,
          tolerance: Optional[float] = None) -> List[str]:
    doc, errors = _load(path)
    if doc is None:
        return errors
    errors = (check_schema(doc) + check_batched_invariant(doc)
              + check_integrity_invariant(doc)
              + check_durability_invariant(doc)
              + check_scrub_invariant(doc)
              + check_c10k_invariant(doc))
    if errors or baseline_path is None:
        return errors
    base, base_errors = _load(baseline_path)
    if base is None:
        return [f"baseline {e}" for e in base_errors]
    return check_regression(doc, base, tolerance)


def main() -> None:
    ap = argparse.ArgumentParser(
        description="schema + regression gate for BENCH_*.json")
    ap.add_argument("candidate", help="BENCH json to validate")
    ap.add_argument("--baseline", default=None,
                    help="committed baseline to gate throughput against")
    ap.add_argument("--tolerance", type=float, default=None,
                    help="override every section's allowed fractional drop "
                         "(e.g. 0.2 = fail below 80%% of baseline)")
    args = ap.parse_args()
    errors = check(args.candidate, args.baseline, args.tolerance)
    if errors:
        for e in errors:
            print(f"BENCH GATE ERROR: {e}", file=sys.stderr)
        sys.exit(1)
    mode = "schema+regression" if args.baseline else "schema"
    print(f"{args.candidate}: OK ({mode})")


if __name__ == "__main__":
    main()
