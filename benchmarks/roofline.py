"""Roofline table from the dry-run results (deliverable g).

Per (arch x shape) on the single-pod mesh (multi-pod rows available with
--mesh pod2x16x16):

  compute term    = dot_FLOPs/dev / 197 TF/s          (bf16 MXU peak, v5e)
  memory term     = HBM bytes/dev / 819 GB/s
  collective term = collective operand bytes/dev / 50 GB/s (one ICI link)

All three inputs are per-device and trip-count-corrected (see
launch/hlo_analysis.py — compiled.cost_analysis() counts scan bodies once).

  step bound      = max(terms)        (perfect overlap)
  roofline frac   = (MODEL_FLOPS/dev / 197 TF/s) / step bound
                    — how much of the achievable step is useful model math.
  flops ratio     = MODEL_FLOPS / HLO dot FLOPs (remat/attention/capacity
                    overheads show up here).

Usage: PYTHONPATH=src python -m benchmarks.roofline [--mesh pod16x16] [--md]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s
LINK_BW = 50e9  # bytes/s/link ICI

RESULTS = Path(__file__).resolve().parent / "dryrun_results"


def load(mesh: str):
    rows = []
    for f in sorted(RESULTS.glob(f"*__{mesh}.json")):
        r = json.loads(f.read_text())
        dev = r["devices"]
        comp = r["dot_flops_per_dev"] / PEAK_FLOPS
        mem = r["hbm_bytes_per_dev"] / HBM_BW
        coll_bytes = sum(
            v.get("wire_bytes", v["operand_bytes"])
            for v in r.get("collectives", {}).values()
        )
        coll = coll_bytes / LINK_BW
        bound = max(comp, mem, coll, 1e-12)
        model_term = r["model_flops"] / dev / PEAK_FLOPS
        mem_an = r.get("memory_analysis", {})
        live = (
            mem_an.get("argument_size_in_bytes", 0)
            + mem_an.get("temp_size_in_bytes", 0)
            + mem_an.get("output_size_in_bytes", 0)
            - mem_an.get("alias_size_in_bytes", 0)
        )
        adj = live - r.get("bf16_upcast_artifact_bytes", 0)
        dom = ("compute", "memory", "collective")[
            [comp, mem, coll].index(max(comp, mem, coll))
        ]
        rows.append({
            "arch": r["arch"],
            "shape": r["shape"],
            "kind": r["kind"],
            "compute_s": comp,
            "memory_s": mem,
            "collective_s": coll,
            "bound_s": bound,
            "dominant": dom,
            "roofline_frac": model_term / bound,
            "flops_ratio": r["model_flops"] / max(r["dot_flops_per_dev"] * dev, 1e-9),
            "mem_gib": live / 2**30,
            "mem_adj_gib": adj / 2**30,
            "coll_gib": coll_bytes / 2**30,
            "params_b": r["params_total"] / 1e9,
        })
    return rows


ADVICE = {
    "compute": "increase arithmetic efficiency: remat policy / fused kernels",
    "memory": "cut HBM round-trips: flash-attention kernel fuses the O(S^2) "
              "score traffic; bigger fusion regions",
    "collective": "re-shard or compress: fewer all-gathers (layout), ZxDFS "
                  "int8 channel, overlap with compute",
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    rows = load(args.mesh)
    if args.md:
        print(f"| arch | shape | compute s | memory s | collective s | "
              f"dominant | roofline frac | model/HLO flops | mem GiB (adj) |")
        print("|---|---|---|---|---|---|---|---|---|")
        for r in rows:
            print(
                f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3g} | "
                f"{r['memory_s']:.3g} | {r['collective_s']:.3g} | "
                f"{r['dominant']} | {r['roofline_frac']:.3f} | "
                f"{r['flops_ratio']:.2f} | {r['mem_gib']:.1f} ({r['mem_adj_gib']:.1f}) |"
            )
    else:
        hdr = (f"{'arch':<18} {'shape':<12} {'comp_s':>9} {'mem_s':>9} "
               f"{'coll_s':>9} {'dom':<10} {'r_frac':>7} {'f_ratio':>8} "
               f"{'memGiB':>7} {'adj':>6}")
        print(hdr)
        for r in rows:
            print(
                f"{r['arch']:<18} {r['shape']:<12} {r['compute_s']:>9.3g} "
                f"{r['memory_s']:>9.3g} {r['collective_s']:>9.3g} "
                f"{r['dominant']:<10} {r['roofline_frac']:>7.3f} "
                f"{r['flops_ratio']:>8.2f} {r['mem_gib']:>7.1f} {r['mem_adj_gib']:>6.1f}"
            )
    return rows


if __name__ == "__main__":
    main()
