"""Durability A/B/C: negotiated commit-policy cost + scrub throughput.

Moves the same payload through one persistent ``mt`` session three
times, once per negotiated at-rest policy — ``none`` (page cache owns
the bytes), ``fsync`` (file fsync before the final ack), ``atomic``
(temp file + fsync + rename + dir fsync before the ack) — and reports
put MB/s plus each row's ratio against the ``none`` twin
(``gain_vs_none``). Both ends negotiate integrity too, so every arm
pays the same CRC cost and the delta isolates the commit sequence.

The scrub rows measure the at-rest verification loop on the store the
atomic arm just wrote (data file + ``.xdfs-manifest`` sidecar):

* ``unthrottled`` — a full :class:`~repro.cluster.scrub.Scrubber` pass
  with no rate limit: the CRC re-read ceiling of this host.
* ``throttled`` — the same pass capped at ``limit_mb_s``; the row
  carries the configured limit so ``check_json.py`` can enforce the
  baseline-free invariant that a throttled pass NEVER exceeds its
  budget (``SCRUB_RATE_SLACK`` absorbs the final-chunk rounding).

fsync latency is container-fs dependent and swings run to run, so the
cross-run regression gate for this section is loose; the tight checks
are the same-run ratios and the rate-limit invariant.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time
from pathlib import Path
from typing import List

ENGINE = "mt"
N_CHANNELS = 2
BLOCK = 1 << 17
BATCH_FRAMES = 8
POLICIES = ("none", "fsync", "atomic")
LIMIT_MB_S = 50  # throttled scrub budget; well under any host's CRC rate


def _best(fn, repeats: int) -> float:
    return max(fn() for _ in range(repeats))


def run(smoke: bool = False) -> List[dict]:
    from repro.cluster.scrub import Scrubber
    from repro.core.api import XdfsClient, XdfsServer

    size = (8 if smoke else 32) << 20
    repeats = 2 if smoke else 3
    tmp = Path(tempfile.mkdtemp(prefix="xdfs_durability_"))
    src = tmp / "src.bin"
    src.write_bytes(os.urandom(size))

    measured = {}  # policy -> put mb_s
    for policy in POLICIES:
        root = tmp / policy
        with XdfsServer(engine=ENGINE, root=str(root),
                        durability=policy) as srv:
            with XdfsClient.connect(srv.address, n_channels=N_CHANNELS,
                                    engine=ENGINE, block_size=BLOCK,
                                    batch_frames=BATCH_FRAMES,
                                    integrity=True,
                                    durability=policy) as cli:

                def put_once() -> float:
                    t0 = time.perf_counter()
                    cli.put(str(src), "bench.bin").result()
                    return size / (time.perf_counter() - t0) / 1e6

                measured[policy] = _best(put_once, repeats)

    rows = []
    for policy in POLICIES:
        mb_s = measured[policy]
        rows.append({
            "mode": "put", "path": policy, "block_kb": BLOCK >> 10,
            "size_mb": size >> 20, "mb_s": round(mb_s, 1),
            "gain_vs_none": round(mb_s / measured["none"], 3),
        })

    # scrub the atomic arm's store: bench.bin + its manifest sidecar
    store = str(tmp / "atomic")
    for path_name, limit in (("unthrottled", 0),
                             ("throttled", LIMIT_MB_S)):
        scrubber = Scrubber(store, rate_limit=limit * 1e6 or None)
        t0 = time.perf_counter()
        report = scrubber.scrub_once()
        elapsed = time.perf_counter() - t0
        mb_s = report.bytes / elapsed / 1e6 if elapsed > 0 else 0.0
        rows.append({
            "mode": "scrub", "path": path_name, "block_kb": BLOCK >> 10,
            "size_mb": report.bytes >> 20, "mb_s": round(mb_s, 1),
            "limit_mb_s": limit, "verified": report.verified,
            "corrupt": len(report.corrupt),
        })

    for row in rows:
        print(",".join(f"{k}={v}" for k, v in row.items()), flush=True)
    shutil.rmtree(tmp, ignore_errors=True)
    return rows


if __name__ == "__main__":
    run(smoke=True)
