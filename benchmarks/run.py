"""Benchmark entrypoint: one section per paper table/figure + system benches.

  PYTHONPATH=src python -m benchmarks.run [--full] [--quick] [--smoke]

``--smoke`` runs ONLY the session-reuse microbenchmark (one negotiated
multi-file session vs N one-shot transfers) — the CI fast path.

Sections:
  0. session_reuse   — §2.5.3 amortization: EOFR channel reuse vs one-shot
  1. paper_figs      — Figs. 12-19 transfer reproductions (MTEDP vs MT vs MP)
  2. device_channels — xDFS ring collectives vs lax.psum (8-dev subprocess)
  3. kernels_bench   — attention / wkv / rglru scaling micro-benches
  4. ckpt_bench      — sync/async checkpoint throughput (disk-thread claim)

Roofline numbers live in the dry-run pipeline (repro.launch.dryrun +
benchmarks/roofline.py), not here: this module measures what is REAL on this
host (sockets, disks, CPU); the dry-run derives what is structural for TPU.
CSV lines: ``name,us_per_call,derived`` style per section.
"""
from __future__ import annotations

import os
import subprocess
import sys


def main() -> None:
    full = "--full" in sys.argv
    quick = "--quick" in sys.argv

    print("== section 0: session reuse (EOFR amortization) ==", flush=True)
    from benchmarks import session_reuse

    session_reuse.run(n_files=8, size_kb=64 if "--smoke" in sys.argv else 256)
    if "--smoke" in sys.argv:
        print("== done (smoke) ==")
        return

    print("== section 1: paper figures 12-19 (host transfer engines) ==", flush=True)
    from benchmarks import paper_figs

    if quick:
        import tempfile
        from pathlib import Path

        tmp = Path(tempfile.mkdtemp(prefix="xdfs_q_"))
        rows = paper_figs.fig12_14_single_stream([64], tmp, repeats=1)
        rows += paper_figs.fig15_19_parallel(64, [1, 4], tmp, repeats=1)
        for r in rows:
            print(",".join(f"{k}={v}" for k, v in r.items()))
    else:
        paper_figs.run(full=full)

    print("== section 2: device channels (8-device subprocess) ==", flush=True)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.device_channels"],
        env=env, text=True, capture_output=True, timeout=900,
    )
    print(r.stdout, end="")
    if r.returncode != 0:
        print(r.stderr[-1500:])

    print("== section 3: kernel micro-benches ==", flush=True)
    from benchmarks import kernels_bench

    kernels_bench.run()

    print("== section 4: checkpoint throughput ==", flush=True)
    from benchmarks import ckpt_bench

    ckpt_bench.run(size_mb=64 if quick else 256)

    print("== done ==")


if __name__ == "__main__":
    main()
