"""Benchmark entrypoint: one section per paper table/figure + system benches.

  PYTHONPATH=src python -m benchmarks.run [--full] [--quick] [--smoke]
                                          [--json PATH]

``--smoke`` runs ONLY the fast sections (session reuse, zero-copy A/B,
host transfer matrix) — the CI fast path.

``--json PATH`` additionally writes every section's rows as a
machine-readable baseline (the ``BENCH_host.json`` committed at the repo
root; schema-checked by ``benchmarks/check_json.py``), so every future
perf PR is measured against a committed trajectory.

Sections:
  0. session_reuse   — §2.5.3 amortization: EOFR channel reuse vs one-shot
  0b. zero_copy      — copy vs scatter-gather vs sendfile send datapaths
  0b2. zero_copy_recv — copy vs registered-pool vs splice receive datapaths
  0b3. zero_copy_batched — per-frame vs syscall-batched framing (+ syscalls/GB)
  0c. host_transfer  — engine x channels matrix (MB/s + writev calls)
  0d. cluster_stripe — striped 3-node cluster vs single-node session
  0e. integrity      — CRC-on vs CRC-off A/B on the batched datapath
  0g. c10k           — session storm: event-loop vs thread-per-session core
  1. paper_figs      — Figs. 12-19 transfer reproductions (MTEDP vs MT vs MP)
  2. device_channels — xDFS ring collectives vs lax.psum (8-dev subprocess)
  3. kernels_bench   — attention / wkv / rglru scaling micro-benches
  4. ckpt_bench      — sync/async checkpoint throughput (disk-thread claim)

Roofline numbers live in the dry-run pipeline (repro.launch.dryrun +
benchmarks/roofline.py), not here: this module measures what is REAL on this
host (sockets, disks, CPU); the dry-run derives what is structural for TPU.
CSV lines: ``name,us_per_call,derived`` style per section.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import subprocess
import sys
import tempfile
import time
from pathlib import Path
from typing import Dict, List

BENCH_SCHEMA = 1


def host_transfer_matrix(smoke: bool = False) -> List[dict]:
    """Disk-to-disk engine x channels matrix: the per-section rows of the
    BENCH_*.json baseline (engine, channels, block size, MB/s, writev)."""
    from repro.core.transfer import TransferSpec, run_transfer

    size = (8 if smoke else 64) << 20
    block = 1 << 17
    tmp = Path(tempfile.mkdtemp(prefix="xdfs_matrix_"))
    src = tmp / "src.bin"
    src.write_bytes(os.urandom(size))
    rows = []
    for engine in ("mtedp", "mt", "mp"):
        for channels in (1, 4):
            st = run_transfer(TransferSpec(
                engine=engine, mode="upload", n_channels=channels,
                size=size, src_path=str(src), dst_path=str(tmp / "dst.bin"),
                block_size=block,
            ))
            row = {
                "engine": engine, "channels": channels,
                "block_kb": block >> 10, "size_mb": size >> 20,
                "mb_s": round(size / st.wall_s / 1e6, 1),
                "mbit_s": round(st.throughput_mbps, 1),
                "writev_calls": st.writev_calls,
            }
            rows.append(row)
            print(",".join(f"{k}={v}" for k, v in row.items()), flush=True)
    import shutil
    shutil.rmtree(tmp, ignore_errors=True)
    return rows


def write_json(path: str, sections: Dict[str, List[dict]]) -> None:
    doc = {
        "schema": BENCH_SCHEMA,
        "host": platform.node(),
        "platform": platform.platform(),
        "python": platform.python_version(),
        "generated_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "sections": sections,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {path} ({len(sections)} sections)", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write all section rows as a BENCH_*.json baseline")
    args = ap.parse_args()
    sections: Dict[str, List[dict]] = {}

    print("== section 0: session reuse (EOFR amortization) ==", flush=True)
    from benchmarks import session_reuse

    sections["session_reuse"] = [
        session_reuse.run(n_files=8, size_kb=64 if args.smoke else 256)
    ]

    print("== section 0b: zero-copy send datapath A/B ==", flush=True)
    from benchmarks import zero_copy

    sections["zero_copy"] = zero_copy.run(smoke=args.smoke or args.quick)

    print("== section 0b2: zero-copy receive datapath A/B ==", flush=True)
    sections["zero_copy_recv"] = zero_copy.run_recv(
        smoke=args.smoke or args.quick)

    print("== section 0b3: syscall-batched framing A/B ==", flush=True)
    sections["zero_copy_batched"] = zero_copy.run_batched(
        smoke=args.smoke or args.quick)

    print("== section 0c: host transfer matrix ==", flush=True)
    sections["host_transfer"] = host_transfer_matrix(
        smoke=args.smoke or args.quick)

    print("== section 0d: cluster striping A/B ==", flush=True)
    from benchmarks import cluster_stripe

    sections["cluster_stripe"] = cluster_stripe.run(
        smoke=args.smoke or args.quick)

    print("== section 0e: integrity CRC-on vs CRC-off A/B ==", flush=True)
    from benchmarks import integrity_bench

    sections["integrity"] = integrity_bench.run(
        smoke=args.smoke or args.quick)

    print("== section 0f: control-plane durability + failover ==", flush=True)
    from benchmarks import control_plane

    sections["control_plane"] = control_plane.run(
        smoke=args.smoke or args.quick)

    print("== section 0g: c10k session storm (loop vs threads) ==", flush=True)
    sections["c10k"] = session_reuse.run_c10k(smoke=args.smoke or args.quick)

    print("== section 0h: at-rest durability policies + scrub ==", flush=True)
    from benchmarks import durability_bench

    sections["durability"] = durability_bench.run(
        smoke=args.smoke or args.quick)

    if args.smoke:
        if args.json:
            write_json(args.json, sections)
        print("== done (smoke) ==")
        return

    print("== section 1: paper figures 12-19 (host transfer engines) ==", flush=True)
    from benchmarks import paper_figs

    if args.quick:
        tmp = Path(tempfile.mkdtemp(prefix="xdfs_q_"))
        rows = paper_figs.fig12_14_single_stream([64], tmp, repeats=1)
        rows += paper_figs.fig15_19_parallel(64, [1, 4], tmp, repeats=1)
        for r in rows:
            print(",".join(f"{k}={v}" for k, v in r.items()))
        sections["paper_figs"] = rows
    else:
        sections["paper_figs"] = paper_figs.run(full=args.full)

    print("== section 2: device channels (8-device subprocess) ==", flush=True)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.device_channels"],
        env=env, text=True, capture_output=True, timeout=900,
    )
    print(r.stdout, end="")
    if r.returncode != 0:
        print(r.stderr[-1500:])

    print("== section 3: kernel micro-benches ==", flush=True)
    from benchmarks import kernels_bench

    kernels_bench.run()

    print("== section 4: checkpoint throughput ==", flush=True)
    from benchmarks import ckpt_bench

    ckpt_bench.run(size_mb=64 if args.quick else 256)

    if args.json:
        write_json(args.json, sections)
    print("== done ==")


if __name__ == "__main__":
    main()
