"""Kernel micro-benchmarks on the XLA paths (CPU wall times are NOT TPU
projections — they verify scaling behavior; roofline numbers come from the
dry-run). CSV: name,us_per_call,derived."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.models.attention import attention_chunked
from repro.models.rglru import linear_scan_chunked
from repro.models.rwkv6 import wkv_chunked


def timeit(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    rows = []
    key = jax.random.key(0)

    for s in (512, 1024, 2048):
        q = jax.random.normal(key, (1, s, 8, 64), jnp.bfloat16)
        k = jax.random.normal(key, (1, s, 2, 64), jnp.bfloat16)
        v = jax.random.normal(key, (1, s, 2, 64), jnp.bfloat16)
        fn = jax.jit(lambda a, b, c: attention_chunked(a, b, c, scale=0.125, chunk=256))
        us = timeit(fn, q, k, v)
        flops = 4 * s * s * 8 * 64 / 2  # causal
        rows.append(("attention_chunked", s, us, flops / (us * 1e-6) / 1e9))
        print(f"attention_chunked_s{s},us_per_call={us:.0f},gflops={flops/(us*1e-6)/1e9:.2f}")

    for s in (512, 2048):
        b, h, hd = 1, 8, 64
        r = jax.random.normal(key, (b, s, h, hd), jnp.bfloat16)
        kk = jax.random.normal(key, (b, s, h, hd), jnp.bfloat16)
        vv = jax.random.normal(key, (b, s, h, hd), jnp.bfloat16)
        lw = -jnp.exp(jax.random.normal(key, (b, s, h, hd)) * 0.5)
        u = jnp.zeros((h, hd))
        st = jnp.zeros((b, h, hd, hd), jnp.float32)
        fn = jax.jit(lambda *a: wkv_chunked(*a)[0])
        us = timeit(fn, r, kk, vv, lw, u, st)
        rows.append(("wkv_chunked", s, us, s / (us * 1e-6) / 1e6))
        print(f"wkv_chunked_s{s},us_per_call={us:.0f},mtok_s={s/(us*1e-6)/1e6:.2f}")

    for s in (1024, 4096):
        a = jax.nn.sigmoid(jax.random.normal(key, (1, s, 256)))
        bx = jax.random.normal(key, (1, s, 256))
        h0 = jnp.zeros((1, 256))
        fn = jax.jit(lambda *x: linear_scan_chunked(*x)[0])
        us = timeit(fn, a, bx, h0)
        rows.append(("rglru_scan", s, us, s / (us * 1e-6) / 1e6))
        print(f"rglru_scan_s{s},us_per_call={us:.0f},mtok_s={s/(us*1e-6)/1e6:.2f}")
    return rows


if __name__ == "__main__":
    run()
