"""Checkpoint throughput: sync save, async save (train-overlap), restore.
The xDFS 'disk thread' claim: async save should hide most disk time."""
from __future__ import annotations

import shutil
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import xdfs_ckpt
from repro.checkpoint.async_ckpt import AsyncCheckpointer


def run(size_mb: int = 256):
    n = size_mb * (1 << 20) // 4
    tree = {"w": jnp.arange(n, dtype=jnp.float32)}
    d = tempfile.mkdtemp(prefix="ckpt_bench_")
    rows = []

    t0 = time.perf_counter()
    xdfs_ckpt.save(tree, d, step=0)
    sync_s = time.perf_counter() - t0
    rows.append(("ckpt_sync_save", sync_s, size_mb / sync_s))
    print(f"ckpt_sync_save,us_per_call={sync_s*1e6:.0f},mb_s={size_mb/sync_s:.0f}")

    ck = AsyncCheckpointer(d)
    t0 = time.perf_counter()
    fut = ck.save(tree, 1)
    submit_s = time.perf_counter() - t0
    fut.result()
    total_s = time.perf_counter() - t0
    ck.close()
    rows.append(("ckpt_async_submit", submit_s, size_mb / max(total_s, 1e-9)))
    print(
        f"ckpt_async_submit,us_per_call={submit_s*1e6:.0f},"
        f"hidden_frac={1 - submit_s / max(total_s, 1e-9):.2f}"
    )

    like = jax.eval_shape(lambda: tree)
    t0 = time.perf_counter()
    xdfs_ckpt.restore(d, like)
    r_s = time.perf_counter() - t0
    rows.append(("ckpt_restore", r_s, size_mb / r_s))
    print(f"ckpt_restore,us_per_call={r_s*1e6:.0f},mb_s={size_mb/r_s:.0f}")
    shutil.rmtree(d, ignore_errors=True)
    return rows


if __name__ == "__main__":
    run()
