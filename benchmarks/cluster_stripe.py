"""Cluster striping A/B: aggregate striped throughput vs one session.

Moves the SAME payload twice — once over a single `XdfsServer` session
(the tuned single-host datapath) and once striped across a 3-node
in-process cluster (`MetaNode` + 3 `DataNode`s, replication factor 1 so
both paths write each byte exactly once) — and reports MB/s plus the
striped path's gain over the single-node reference.

On one host all nodes share the same disks and loopback stack, so the
stripe measures the cluster layer's overhead/aggregation behavior, not
real multi-machine scaling; the row shape (`nodes`, `gain_vs_single`)
is what a multi-host run would fill with real numbers.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time
from pathlib import Path
from typing import List

CLUSTER_BLOCK = 1 << 20


def _best(fn, repeats: int) -> float:
    return max(fn() for _ in range(repeats))


def run(smoke: bool = False) -> List[dict]:
    from repro.cluster import ClusterClient, DataNode, MetaNode
    from repro.core.api import XdfsClient, XdfsServer

    size = (16 if smoke else 64) << 20
    repeats = 3 if smoke else 4
    payload = os.urandom(size)
    tmp = Path(tempfile.mkdtemp(prefix="xdfs_stripe_"))

    # single-node reference: one negotiated session, same bytes
    with XdfsServer(engine="mtedp", root=str(tmp / "single")) as srv:
        with XdfsClient.connect(srv.address, n_channels=2) as cli:

            def put_once() -> float:
                t0 = time.perf_counter()
                cli.put(None, "bench.bin", data=payload).result()
                return size / (time.perf_counter() - t0) / 1e6

            def get_once() -> float:
                t0 = time.perf_counter()
                got = cli.get_bytes("bench.bin").result().data
                assert len(got) == size
                return size / (time.perf_counter() - t0) / 1e6

            single_put = _best(put_once, repeats)
            single_get = _best(get_once, repeats)

    # striped: 3 data nodes, rf=1 (each byte written once, like single)
    meta = MetaNode(replication=1).start()
    nodes = [
        DataNode(meta.address, str(tmp / f"n{i}"), node_id=f"n{i}").start()
        for i in range(3)
    ]
    ccli = ClusterClient(meta.address, block_size=CLUSTER_BLOCK)
    try:
        seq = iter(range(100))

        def cput_once() -> float:
            # a fresh name per repeat: overwriting would enqueue block
            # reclaims whose disk churn bleeds into the next repeat
            t0 = time.perf_counter()
            ccli.put(f"bench_{next(seq)}.bin", data=payload)
            return size / (time.perf_counter() - t0) / 1e6

        def cget_once() -> float:
            t0 = time.perf_counter()
            assert len(ccli.get("bench_0.bin")) == size
            return size / (time.perf_counter() - t0) / 1e6

        striped_put = _best(cput_once, repeats)
        striped_get = _best(cget_once, repeats)
    finally:
        ccli.close()
        for n in nodes:
            n.stop()
        meta.stop()
    shutil.rmtree(tmp, ignore_errors=True)

    rows = []
    for mode, single, striped in (("put", single_put, striped_put),
                                  ("get", single_get, striped_get)):
        rows.append({
            "mode": mode, "path": "single", "nodes": 1,
            "size_mb": size >> 20, "block_kb": CLUSTER_BLOCK >> 10,
            "mb_s": round(single, 1), "gain_vs_single": 1.0,
        })
        rows.append({
            "mode": mode, "path": "striped", "nodes": 3,
            "size_mb": size >> 20, "block_kb": CLUSTER_BLOCK >> 10,
            "mb_s": round(striped, 1),
            "gain_vs_single": round(striped / single, 2),
        })
    for row in rows:
        print(",".join(f"{k}={v}" for k, v in row.items()), flush=True)
    return rows
