"""Session-reuse microbenchmark (paper §2.5.3 / Table 3 amortization).

Moves N small files two ways and reports wall-clock per file:

* ``session``  — ONE ``XdfsClient`` session: negotiate once, stream all N
  files over the same n channels with EOFR reuse;
* ``one-shot`` — N ``run_transfer`` calls: every file pays fork +
  negotiation + teardown (the per-transfer overhead GridFTP-style tools
  pay, which dominates small-file workloads).

  PYTHONPATH=src python -m benchmarks.session_reuse [--files 8] [--kb 256]
"""
from __future__ import annotations

import os
import tempfile
import time
from pathlib import Path

from repro.core.api import XdfsClient, XdfsServer
from repro.core.transfer import TransferSpec, run_transfer


def run(n_files: int = 8, size_kb: int = 256, n_channels: int = 4,
        engine: str = "mtedp") -> dict:
    tmp = Path(tempfile.mkdtemp(prefix="xdfs_sess_"))
    size = size_kb << 10
    files = []
    for i in range(n_files):
        p = tmp / f"f{i}.bin"
        p.write_bytes(os.urandom(size))
        files.append(p)

    t0 = time.perf_counter()
    with XdfsServer(engine=engine, root=str(tmp / "srv")) as srv:
        with XdfsClient.connect(srv.address, n_channels=n_channels,
                                engine=engine, block_size=1 << 17) as cli:
            for r in cli.put_many([(str(p), p.name) for p in files]):
                r.result()
        srv.wait_closed_sessions(1, timeout=120)
    t_session = time.perf_counter() - t0
    negotiations = srv.stats["negotiations"]
    eofr = srv.stats["eofr_frames"]

    t0 = time.perf_counter()
    for p in files:
        run_transfer(TransferSpec(
            engine=engine, mode="upload", n_channels=n_channels, size=size,
            src_path=str(p), dst_path=str(tmp / "out.bin"), block_size=1 << 17,
        ))
    t_oneshot = time.perf_counter() - t0

    row = {
        "engine": engine, "files": n_files, "size_kb": size_kb,
        "channels": n_channels, "negotiations": negotiations,
        "eofr_frames": eofr,
        "session_s": round(t_session, 4),
        "oneshot_s": round(t_oneshot, 4),
        "session_ms_per_file": round(1e3 * t_session / n_files, 2),
        "oneshot_ms_per_file": round(1e3 * t_oneshot / n_files, 2),
        "speedup": round(t_oneshot / t_session, 2),
    }
    print(",".join(f"{k}={v}" for k, v in row.items()))
    if t_session < t_oneshot:
        print(f"session reuse beats {n_files}x one-shot by "
              f"{row['speedup']}x (1 negotiation vs {n_files})")
    else:
        print("WARNING: session reuse did NOT beat one-shot on this host")
    import shutil
    shutil.rmtree(tmp)
    return row


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--files", type=int, default=8)
    ap.add_argument("--kb", type=int, default=256)
    ap.add_argument("--channels", type=int, default=4)
    ap.add_argument("--engine", default="mtedp")
    args = ap.parse_args()
    run(args.files, args.kb, args.channels, args.engine)
