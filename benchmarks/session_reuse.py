"""Session-reuse microbenchmark (paper §2.5.3 / Table 3 amortization).

Moves N small files two ways and reports wall-clock per file:

* ``session``  — ONE ``XdfsClient`` session: negotiate once, stream all N
  files over the same n channels with EOFR reuse;
* ``one-shot`` — N ``run_transfer`` calls: every file pays fork +
  negotiation + teardown (the per-transfer overhead GridFTP-style tools
  pay, which dominates small-file workloads).

  PYTHONPATH=src python -m benchmarks.session_reuse [--files 8] [--kb 256]
"""
from __future__ import annotations

import os
import statistics
import tempfile
import threading
import time
from pathlib import Path

from repro.core.api import XdfsClient, XdfsServer
from repro.core.session import BusyError
from repro.core.transfer import TransferSpec, run_transfer


def run(n_files: int = 8, size_kb: int = 256, n_channels: int = 4,
        engine: str = "mtedp") -> dict:
    tmp = Path(tempfile.mkdtemp(prefix="xdfs_sess_"))
    size = size_kb << 10
    files = []
    for i in range(n_files):
        p = tmp / f"f{i}.bin"
        p.write_bytes(os.urandom(size))
        files.append(p)

    t0 = time.perf_counter()
    with XdfsServer(engine=engine, root=str(tmp / "srv")) as srv:
        with XdfsClient.connect(srv.address, n_channels=n_channels,
                                engine=engine, block_size=1 << 17) as cli:
            for r in cli.put_many([(str(p), p.name) for p in files]):
                r.result()
        srv.wait_closed_sessions(1, timeout=120)
    t_session = time.perf_counter() - t0
    negotiations = srv.stats["negotiations"]
    eofr = srv.stats["eofr_frames"]

    t0 = time.perf_counter()
    for p in files:
        run_transfer(TransferSpec(
            engine=engine, mode="upload", n_channels=n_channels, size=size,
            src_path=str(p), dst_path=str(tmp / "out.bin"), block_size=1 << 17,
        ))
    t_oneshot = time.perf_counter() - t0

    row = {
        "engine": engine, "files": n_files, "size_kb": size_kb,
        "channels": n_channels, "negotiations": negotiations,
        "eofr_frames": eofr,
        "session_s": round(t_session, 4),
        "oneshot_s": round(t_oneshot, 4),
        "session_ms_per_file": round(1e3 * t_session / n_files, 2),
        "oneshot_ms_per_file": round(1e3 * t_oneshot / n_files, 2),
        "speedup": round(t_oneshot / t_session, 2),
    }
    print(",".join(f"{k}={v}" for k, v in row.items()))
    if t_session < t_oneshot:
        print(f"session reuse beats {n_files}x one-shot by "
              f"{row['speedup']}x (1 negotiation vs {n_files})")
    else:
        print("WARNING: session reuse did NOT beat one-shot on this host")
    import shutil
    shutil.rmtree(tmp)
    return row


def _pct(sorted_vals, q: float) -> float:
    """Nearest-rank percentile on an already-sorted list."""
    if not sorted_vals:
        return 0.0
    i = min(len(sorted_vals) - 1, int(round(q * (len(sorted_vals) - 1))))
    return sorted_vals[i]


def _session_storm(addr, sessions: int, concurrency: int, size: int,
                   root: Path):
    """``concurrency`` workers churn through ``sessions`` short sessions
    (connect, 1 put + 1 get of a small file, close) and record the
    end-to-end wall clock of each COMPLETED session. Returns
    ``(latencies_s, completed_ops, refused, wall_s)``."""
    payload = os.urandom(size)
    lat: list = []
    counters = {"next": 0, "ops": 0, "refused": 0}
    lock = threading.Lock()

    def worker(w: int) -> None:
        name = f"c10k_w{w}.bin"
        while True:
            with lock:
                if counters["next"] >= sessions:
                    return
                counters["next"] += 1
            t0 = time.perf_counter()
            try:
                with XdfsClient.connect(addr, n_channels=1,
                                        block_size=32 << 10) as cli:
                    cli.put(None, name, data=payload).result(60)
                    got = cli.get_bytes(name).result(60)
                if len(got.data) != size:
                    raise RuntimeError("short read in c10k mix")
                dt = time.perf_counter() - t0
                with lock:
                    lat.append(dt)
                    counters["ops"] += 2
            except (BusyError, OSError):
                # typed admission refusal (or the accept-side close of the
                # pending-cap path): counted, not fatal — that is the point
                with lock:
                    counters["refused"] += 1

    t0 = time.perf_counter()
    threads = [threading.Thread(target=worker, args=(w,), daemon=True)
               for w in range(concurrency)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return lat, counters["ops"], counters["refused"], time.perf_counter() - t0


def run_c10k(smoke: bool = False) -> list:
    """C10k-style traffic mix: hundreds of short-lived small-file sessions
    hammering one server, measured as per-session latency percentiles.

    Rows (section ``c10k`` of BENCH_*.json):

    * ``mix/loop``    — the sharded event-loop core (``loop=2``)
    * ``mix/threads`` — the thread-per-session path, same storm
    * ``admission/loop`` — the same storm against a ``max_sessions`` cap:
      the interesting numbers are ``accepted``/``rejected`` (every refusal
      is the TYPED ``ERR busy`` path, not a reset)

    The baseline-free gate (`benchmarks/check_json.py`) checks
    ``p99_ms <= C10K_P99_P50_MAX * p50_ms`` on the mix rows: a scheduler
    that starves sessions fats the tail even when the mean stays healthy.
    """
    sessions = 150 if smoke else 600
    concurrency = 32
    size = 8 << 10
    rows = []
    for path, loop in (("loop", 2), ("threads", False)):
        tmp = Path(tempfile.mkdtemp(prefix=f"xdfs_c10k_{path}_"))
        with XdfsServer(engine="mtedp", root=str(tmp), loop=loop) as srv:
            lat, ops, refused, wall = _session_storm(
                srv.address, sessions, concurrency, size, tmp)
            accepted = srv.stats["sessions"]
        lat.sort()
        rows.append({
            "mode": "mix", "path": path, "sessions": sessions,
            "concurrency": concurrency, "file_kb": size >> 10,
            "accepted": accepted, "rejected": refused,
            "ops_per_s": round(ops / wall, 1),
            "p50_ms": round(1e3 * _pct(lat, 0.50), 2),
            "p99_ms": round(1e3 * _pct(lat, 0.99), 2),
            "mean_ms": round(1e3 * statistics.fmean(lat), 2) if lat else 0.0,
        })
        import shutil
        shutil.rmtree(tmp, ignore_errors=True)

    # admission arm: a hard session cap under the same storm — refusals
    # must be typed (BusyError) and the survivors must still finish
    cap = 8
    tmp = Path(tempfile.mkdtemp(prefix="xdfs_c10k_adm_"))
    with XdfsServer(engine="mtedp", root=str(tmp), loop=2,
                    max_sessions=cap) as srv:
        lat, ops, refused, wall = _session_storm(
            srv.address, sessions // 2, concurrency, size, tmp)
        accepted = srv.stats["sessions"]
        srv_rejected = srv.stats["rejected"]
    lat.sort()
    rows.append({
        "mode": "admission", "path": "loop", "sessions": sessions // 2,
        "concurrency": concurrency, "file_kb": size >> 10,
        "max_sessions": cap,
        "accepted": accepted, "rejected": srv_rejected,
        "ops_per_s": round(ops / wall, 1),
        "p50_ms": round(1e3 * _pct(lat, 0.50), 2),
        "p99_ms": round(1e3 * _pct(lat, 0.99), 2),
        "mean_ms": round(1e3 * statistics.fmean(lat), 2) if lat else 0.0,
    })
    import shutil
    shutil.rmtree(tmp, ignore_errors=True)
    for row in rows:
        print(",".join(f"{k}={v}" for k, v in row.items()), flush=True)
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--files", type=int, default=8)
    ap.add_argument("--kb", type=int, default=256)
    ap.add_argument("--channels", type=int, default=4)
    ap.add_argument("--engine", default="mtedp")
    ap.add_argument("--c10k", action="store_true",
                    help="run the c10k session-storm section instead")
    args = ap.parse_args()
    if args.c10k:
        run_c10k()
    else:
        run(args.files, args.kb, args.channels, args.engine)
