"""Integrity A/B: CRC-on vs CRC-off throughput on the same datapath.

Moves the same payload through one persistent ``mt`` session twice per
direction — once on a plain session and once with the negotiated
integrity datapath (per-block CRC32 trailers verified on receive + the
file-level manifest exchange) — and reports MB/s plus the CRC-on row's
throughput ratio against its CRC-off twin (``gain_vs_off``).

The ``mt`` engine with several channels on the BATCHED datapath is the
representative host for this A/B: it is the tuned configuration (hill-
climbed multi-frame sendmsg batches, slab receive), trailers ride the
existing scatter-gather iovecs instead of their own syscalls, and both
ends checksum through the native libdeflate CRC (~17 GB/s measured).

What the gate can honestly demand depends on the host. The paper-ideal
"CRC within 10% of plain" holds when checksumming runs on cores the
datapath isn't using. On a single-core host with BOTH endpoints
colocated (this CI container), every CRC byte is serial with the
transfer: the compute floor alone — 2 x payload at ~17 GB/s against a
~1 GB/s loopback baseline — costs ~13%, and manifest/trailer
bookkeeping takes the steady-state penalty to ~25% (scheduler noise
reaches ~45% on outliers). ``check_json.py`` therefore gates
``gain_vs_off`` against ``INTEGRITY_MAX_PENALTY`` = 0.45 — wide enough
to never flake on timeslice noise, tight enough to catch the
order-of-magnitude collapses this gate exists for (an unmemoized
crc32_combine or a lost native CRC path both land far below it).
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time
from pathlib import Path
from typing import List

ENGINE = "mt"
N_CHANNELS = 4
BLOCK = 1 << 17
BATCH_FRAMES = 16  # both arms run the tuned batched datapath


def _best(fn, repeats: int) -> float:
    return max(fn() for _ in range(repeats))


def run(smoke: bool = False) -> List[dict]:
    from repro.core.api import XdfsClient, XdfsServer

    size = (16 if smoke else 64) << 20
    repeats = 3 if smoke else 4
    tmp = Path(tempfile.mkdtemp(prefix="xdfs_integrity_"))
    src = tmp / "src.bin"
    src.write_bytes(os.urandom(size))

    measured = {}  # (mode, path) -> mb_s
    for path_name, integrity in (("crc_off", False), ("crc_on", True)):
        with XdfsServer(engine=ENGINE, root=str(tmp / path_name)) as srv:
            with XdfsClient.connect(srv.address, n_channels=N_CHANNELS,
                                    engine=ENGINE, block_size=BLOCK,
                                    batch_frames=BATCH_FRAMES,
                                    integrity=integrity) as cli:

                def put_once() -> float:
                    t0 = time.perf_counter()
                    cli.put(str(src), "bench.bin").result()
                    return size / (time.perf_counter() - t0) / 1e6

                def get_once() -> float:
                    t0 = time.perf_counter()
                    cli.get("bench.bin", str(tmp / "back.bin")).result()
                    return size / (time.perf_counter() - t0) / 1e6

                measured[("upload", path_name)] = _best(put_once, repeats)
                measured[("download", path_name)] = _best(get_once, repeats)

    rows = []
    for mode in ("upload", "download"):
        off = measured[(mode, "crc_off")]
        for path_name in ("crc_off", "crc_on"):
            mb_s = measured[(mode, path_name)]
            row = {
                "mode": mode, "path": path_name, "block_kb": BLOCK >> 10,
                "size_mb": size >> 20, "mb_s": round(mb_s, 1),
                "gain_vs_off": round(mb_s / off, 3),
            }
            rows.append(row)
            print(",".join(f"{k}={v}" for k, v in row.items()), flush=True)
    shutil.rmtree(tmp, ignore_errors=True)
    return rows


if __name__ == "__main__":
    run(smoke=True)
