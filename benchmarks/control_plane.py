"""Control-plane durability/failover benchmark: what the WAL costs and
what failover buys.

Three rows in one section (``control_plane``):

* ``commit/fsync_on`` and ``commit/fsync_off`` — in-process journaled
  MetaNode commit throughput (``handle_commit`` calls/s, best of N), the
  A/B being the per-record ``fsync``. This is the price of "an
  acknowledged commit survives kill -9": one fsync on the commit path.
  Each fsync_on row carries ``gain_vs_nofsync`` (its throughput relative
  to the fsync_off twin, same run) — ``check_json.py`` gates it with the
  baseline-free ``DURABILITY_MAX_SLOWDOWN`` invariant: fsyncing may cost
  a large constant factor (it is a disk barrier per commit; tens of
  microseconds to milliseconds depending on the backing store), but a
  collapse beyond that factor means the journal started doing per-commit
  work it shouldn't (re-serializing the namespace, re-opening the file,
  fsyncing more than once).
* ``failover/standby_promotion`` — real-socket wall clock from killing
  the leader to a committed name being readable from the promoted
  standby (lease expiry + promotion + client failover). Reported as
  ``ops_per_s`` = 1/seconds so the regression gate's higher-is-better
  convention holds; the absolute number tracks the configured lease
  timeout, so the gate only catches order-of-magnitude breaks (a
  standby that never promotes, a client that never fails over).

docs/BENCHMARKING.md ("Control plane") has the threshold derivation.
"""
from __future__ import annotations

import shutil
import tempfile
import time
from pathlib import Path
from typing import List

HEARTBEAT = 0.25
LEASE = 0.5


def _best(fn, repeats: int) -> float:
    return max(fn() for _ in range(repeats))


def _commit_rate(journal_dir, fsync: bool, n_commits: int,
                 repeats: int) -> float:
    from repro.cluster import MetaNode

    def once() -> float:
        d = Path(tempfile.mkdtemp(dir=journal_dir))
        meta = MetaNode(journal_dir=str(d), journal_fsync=fsync,
                        snapshot_every=10 ** 9)  # pure append path
        meta.handle_register({"node_id": "a", "host": "h", "port": 1})
        t0 = time.perf_counter()
        for i in range(n_commits):
            meta.handle_commit({
                "name": f"f{i}", "size": 4096, "block_size": 4096,
                "blocks": [{"id": f"b{i}", "offset": 0, "length": 4096,
                            "crc32": 0, "nodes": ["a"]}],
            })
        dt = time.perf_counter() - t0
        meta.journal.close()
        shutil.rmtree(d, ignore_errors=True)
        return n_commits / dt

    return _best(once, repeats)


def _failover_seconds(tmp: Path) -> float:
    """Wall clock: leader killed -> committed name readable from the
    promoted standby through a failover client."""
    from repro.cluster import ClusterClient, ClusterError, MetaNode
    from repro.core.faults import RetriesExhausted, RetryPolicy

    m1 = MetaNode(heartbeat_timeout=HEARTBEAT, tick_interval=0.05,
                  journal_dir=str(tmp / "m1"), meta_id="m1").start()
    m2 = MetaNode(heartbeat_timeout=HEARTBEAT, tick_interval=0.05,
                  journal_dir=str(tmp / "m2"), meta_id="m2",
                  peers=[m1.address], lease_timeout=LEASE).start()
    cli = ClusterClient([m1.address, m2.address],
                        policy=RetryPolicy(attempts=2, base_delay=0.02,
                                           connect_timeout=1.0,
                                           io_timeout=2.0))
    try:
        # a name in the namespace (no datanodes needed for LOOKUP)
        m1.handle_register({"node_id": "a", "host": "h", "port": 1})
        m1.handle_commit({
            "name": "probe", "size": 1, "block_size": 1,
            "blocks": [{"id": "p", "offset": 0, "length": 1, "crc32": 0,
                        "nodes": ["a"]}],
        })
        deadline = time.monotonic() + 30.0
        while m2.seq < m1.seq:  # standby must have tailed the commit
            time.sleep(0.01)
            if time.monotonic() > deadline:
                raise RuntimeError("standby never caught up")
        t0 = time.perf_counter()
        m1.kill()
        while True:
            try:
                from repro.cluster.wire import ClusterMsg
                cli._call(ClusterMsg.LOOKUP, {"name": "probe"})
                break
            except (ClusterError, RetriesExhausted, OSError):
                if time.monotonic() > deadline:
                    raise RuntimeError("failover never completed")
                time.sleep(0.02)
        return time.perf_counter() - t0
    finally:
        cli.close()
        m2.stop()


def run(smoke: bool = False) -> List[dict]:
    n_commits = 200 if smoke else 1000
    repeats = 2 if smoke else 3
    tmp = Path(tempfile.mkdtemp(prefix="xdfs_ctrl_"))

    measured = {
        "fsync_off": _commit_rate(tmp, False, n_commits, repeats),
        "fsync_on": _commit_rate(tmp, True, n_commits, repeats),
    }
    rows = []
    for path_name in ("fsync_off", "fsync_on"):
        ops = measured[path_name]
        rows.append({
            "mode": "commit", "path": path_name,
            "ops_per_s": round(ops, 1),
            "gain_vs_nofsync": round(ops / measured["fsync_off"], 4),
        })
    seconds = _failover_seconds(tmp)
    rows.append({
        "mode": "failover", "path": "standby_promotion",
        "ops_per_s": round(1.0 / seconds, 3),
        "seconds": round(seconds, 3),
    })
    for row in rows:
        print(",".join(f"{k}={v}" for k, v in row.items()), flush=True)
    shutil.rmtree(tmp, ignore_errors=True)
    return rows


if __name__ == "__main__":
    run(smoke=True)
