"""Robustness: end-to-end integrity (CRC trailers + manifest verify),
interrupted-transfer RESUME, deadline/retry policy, and the
fault-injection matrix (kill / corrupt / stall, single-host and cluster).

The e2e matrix drives real sockets through ``FaultyProxy``, which
corrupts, severs, or stalls the byte stream at exact offsets — so every
recovery path here is exercised against an actual mid-flight failure,
not a mock.
"""
import os
import tempfile
import time
import zlib
from pathlib import Path

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.api import XdfsClient, XdfsServer
from repro.core.faults import (
    Deadline,
    DeadlineExceeded,
    Fault,
    FaultyProxy,
    RetriesExhausted,
    RetryPolicy,
    Trigger,
)
from repro.core.header import (
    FLAG_BLOCK_CRC,
    HEADER_SIZE,
    TRAILER_SIZE,
    ChannelEvent,
    ChannelHeader,
    Negotiation,
    new_session_id,
)
from repro.core.integrity import (
    CrcManifest,
    IntegrityError,
    block_crc,
    crc32_combine,
)
from repro.core.resume import SIDECAR_SUFFIX, ResumeSidecar
from repro.core.session import IntegrityFailure

BS = 32 << 10  # block size for the e2e matrix: small enough for many
#                blocks per file, big enough to stay fast


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _await(pred, timeout=30.0, msg="condition"):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return
        time.sleep(0.02)
    raise AssertionError(f"timed out waiting for {msg}")


# ---------------------------------------------------------------------------
# crc32_combine + CrcManifest (pure units)
# ---------------------------------------------------------------------------


@given(a=st.binary(min_size=0, max_size=4096),
       b=st.binary(min_size=0, max_size=4096))
@settings(max_examples=50, deadline=None)
def test_crc32_combine_matches_zlib(a, b):
    assert crc32_combine(zlib.crc32(a) & 0xFFFFFFFF,
                         zlib.crc32(b) & 0xFFFFFFFF,
                         len(b)) == (zlib.crc32(a + b) & 0xFFFFFFFF)


def test_manifest_fold_and_holes():
    data = os.urandom(5 * 1000 + 17)
    m = CrcManifest()
    # add out of order; the fold must still match a straight crc32
    offs = list(range(0, len(data), 1000))
    for off in reversed(offs):
        chunk = data[off:off + 1000]
        m.add(off, len(chunk), block_crc(chunk))
    assert m.file_crc(len(data)) == (zlib.crc32(data) & 0xFFFFFFFF)
    assert m.missing(len(data), 1000) == []
    hole = CrcManifest()
    hole.add(0, 1000, 1)
    hole.add(2000, 1000, 2)
    assert hole.missing(5017, 1000) == [1000, 3000, 4000, 5000]
    with pytest.raises(IntegrityError):
        hole.file_crc(5017)


def test_manifest_merge_and_autosave_cadence():
    saves = []
    m = CrcManifest(autosave=lambda man: saves.append(len(man)),
                    autosave_every=4)
    for i in range(9):
        m.add(i * 10, 10, i)
    assert saves == [4, 8]  # every 4 verified blocks, not per add
    other = CrcManifest()
    other.add(0, 10, 999)   # merge must NOT overwrite verified entries
    other.add(90, 10, 9)
    m.merge(other)
    assert len(m) == 10
    assert m.blocks[0] == (10, 0)  # the verified entry won
    assert 90 in m


def test_resume_sidecar_roundtrip_and_geometry(tmp_path):
    p = tmp_path / "f.bin"
    sc = ResumeSidecar(str(p))
    m = CrcManifest()
    m.add(0, 100, 7)
    m.add(100, 100, 8)
    sc.save(200, 100, m)
    assert Path(str(p) + SIDECAR_SUFFIX).exists()
    size, bs, loaded = sc.load_any()
    assert (size, bs) == (200, 100) and 100 in loaded
    assert sc.load(200, 100) is not None
    assert sc.load(200, 64) is None      # geometry mismatch -> unusable
    assert sc.load(999, 100) is None
    sc.clear()
    assert sc.load_any() is None


# ---------------------------------------------------------------------------
# wire format: flags + integrity negotiation tail
# ---------------------------------------------------------------------------


def test_header_flag_roundtrip():
    sid = new_session_id()
    h = ChannelHeader(ChannelEvent.xFTSMU, sid, 3, 1 << 20, 4096,
                      flags=FLAG_BLOCK_CRC)
    h2 = ChannelHeader.unpack(h.pack())
    assert h2.flags == FLAG_BLOCK_CRC
    assert len(h.pack()) == HEADER_SIZE
    assert TRAILER_SIZE == 4


def test_negotiation_integrity_tail():
    sid = new_session_id()
    neg = Negotiation(sid, 2, 1 << 16, 1 << 20, "", "", file_size=0,
                      integrity=True)
    assert Negotiation.unpack(neg.pack()).integrity is True
    off = Negotiation(sid, 2, 1 << 16, 1 << 20, "", "", file_size=0)
    blob = off.pack()
    assert Negotiation.unpack(blob).integrity is False
    # pre-integrity peer: blob truncated before the tail still parses
    assert Negotiation.unpack(blob[:-1]).integrity is False


# ---------------------------------------------------------------------------
# Deadline / RetryPolicy (fake clock, no sleeping)
# ---------------------------------------------------------------------------


def test_deadline_budget_and_expiry():
    clk = FakeClock()
    d = Deadline(5.0, clock=clk)
    assert d.budget(10.0) == 5.0 and d.budget(2.0) == 2.0
    clk.advance(4.9999)
    assert d.budget(10.0) >= 0.001  # never settimeout(0) == non-blocking
    clk.advance(1.0)
    assert d.expired()
    with pytest.raises(DeadlineExceeded):
        d.check("op")
    assert Deadline(None, clock=clk).budget(3.0) == 3.0


def test_retry_policy_backoff_shape():
    import random

    p = RetryPolicy(attempts=5, base_delay=0.1, multiplier=2.0,
                    max_delay=0.3, jitter=0.0, rng=random.Random(0))
    assert p.delays() == [0.1, 0.2, 0.3, 0.3]  # capped, 4 = attempts-1
    with pytest.raises(ValueError):
        RetryPolicy(attempts=0)
    with pytest.raises(ValueError):
        RetryPolicy(jitter=1.5)


def test_retry_policy_run_retries_then_exhausts():
    slept = []
    p = RetryPolicy(attempts=3, base_delay=0.01, jitter=0.0,
                    sleep=slept.append)
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise ConnectionResetError("boom")
        return "ok"

    assert p.run(flaky, what="flaky") == "ok"
    assert len(calls) == 3 and len(slept) == 2

    def always():
        raise TimeoutError("stall")

    with pytest.raises(RetriesExhausted):
        p.run(always, what="always")


def test_retry_policy_never_retries_deadline_or_app_errors():
    p = RetryPolicy(attempts=3, base_delay=0.01, sleep=lambda _: None)
    calls = []

    def dead():
        calls.append(1)
        raise DeadlineExceeded("gone")

    with pytest.raises(DeadlineExceeded):
        p.run(dead)
    assert len(calls) == 1  # the budget is gone; retrying is lying

    def app():
        calls.append(1)
        raise ValueError("not a transport fault")

    calls.clear()
    with pytest.raises(ValueError):
        p.run(app)
    assert len(calls) == 1


def test_trigger_fires_exactly_once_even_when_action_raises():
    """A raising action still counts as the one firing: the error is
    recorded and the poll loop exits instead of re-invoking the action
    on every subsequent true predicate."""
    calls = []

    def boom():
        calls.append(1)
        raise RuntimeError("action failed")

    trig = Trigger(lambda: True, boom, poll=0.001, timeout=5.0)
    assert trig.wait(5.0)
    trig._thread.join(2.0)
    time.sleep(0.02)  # a few poll periods: the old bug re-fired here
    assert calls == [1]
    assert isinstance(trig.error, RuntimeError)
    trig.cancel()


# ---------------------------------------------------------------------------
# FaultyProxy (the injector itself)
# ---------------------------------------------------------------------------


def _echo_server():
    import socket
    import threading

    lst = socket.socket()
    lst.bind(("127.0.0.1", 0))
    lst.listen(8)

    def serve():
        while True:
            try:
                c, _ = lst.accept()
            except OSError:
                return
            def pump(conn=c):
                try:
                    while True:
                        b = conn.recv(65536)
                        if not b:
                            return
                        conn.sendall(b)
                except OSError:
                    pass
            threading.Thread(target=pump, daemon=True).start()

    threading.Thread(target=serve, daemon=True).start()
    return lst


def test_faulty_proxy_corrupts_exact_byte():
    import socket

    lst = _echo_server()
    try:
        with FaultyProxy(lst.getsockname(),
                         c2s=Fault(corrupt_at=5, conn=0)) as px:
            s = socket.create_connection(px.address)
            s.sendall(b"0123456789")
            got = b""
            while len(got) < 10:
                got += s.recv(10 - len(got))
            assert got[5] == (b"5"[0] ^ 0xFF) and got[:5] == b"01234"
            s.close()
    finally:
        lst.close()


def test_faulty_proxy_drop_severs_all_connections():
    import socket

    lst = _echo_server()
    try:
        with FaultyProxy(lst.getsockname(),
                         c2s=Fault(drop_after=4, conn=1)) as px:
            bystander = socket.create_connection(px.address)
            victim = socket.create_connection(px.address)
            bystander.sendall(b"hi")
            assert bystander.recv(2) == b"hi"
            victim.sendall(b"123456")  # crosses drop_after=4 -> kill_all
            for s in (victim, bystander):
                s.settimeout(5.0)
                with pytest.raises((ConnectionError, OSError)) as ei:
                    while True:
                        if s.recv(4096) == b"":
                            raise ConnectionResetError("peer gone")
                assert ei.value is not None
                s.close()
    finally:
        lst.close()


# ---------------------------------------------------------------------------
# integrity e2e: CRC-clean roundtrips on every engine, batched and not
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine,batch", [
    ("mtedp", 1), ("mtedp", 4), ("mt", 1), ("mt", 4), ("mp", 1), ("mp", 4),
])
def test_integrity_roundtrip_all_engines(engine, batch, tmp_path, xdfs_server):
    data = os.urandom(6 * BS + 123)
    src = tmp_path / "src.bin"
    src.write_bytes(data)
    with xdfs_server(engine=engine, root=str(tmp_path / "srv")) as srv:
        with XdfsClient.connect(srv.address, n_channels=2, engine=engine,
                                block_size=BS, batch_frames=batch,
                                integrity=True) as cli:
            assert cli.put(str(src), "up.bin").result().bytes == len(data)
            cli.get("up.bin", str(tmp_path / "back.bin")).result()
        srv.wait_closed_sessions(1, timeout=60)
        assert not srv.errors, srv.errors
    assert (tmp_path / "back.bin").read_bytes() == data
    assert srv.stats["crc_mismatches"] == 0


# ---------------------------------------------------------------------------
# corruption: detected on the wire, healed by an in-session resume
# ---------------------------------------------------------------------------


@pytest.mark.fault
def test_corrupt_block_detected_and_resumed_same_session(tmp_path, xdfs_server):
    data = os.urandom(6 * BS + 123)
    src = tmp_path / "src.bin"
    src.write_bytes(data)
    # conn 1 == data channel 1; its c2s stream is hello(48) then block 1's
    # frame — corrupt byte 7 of block 1's payload, surgically
    fault = Fault(conn=1, corrupt_at=48 + HEADER_SIZE + 7)
    with xdfs_server(engine="mtedp", root=str(tmp_path / "srv")) as srv:
        with FaultyProxy(srv.address, c2s=fault) as px:
            with XdfsClient.connect(px.address, n_channels=2,
                                    block_size=BS, integrity=True) as cli:
                with pytest.raises(IntegrityFailure):
                    cli.put(str(src), "up.bin").result()
                # the session SURVIVED the integrity failure: resume on it
                r = cli.put(str(src), "up.bin", resume=True).result()
                assert r.bytes == BS  # exactly the one corrupted block
            srv.wait_closed_sessions(1, timeout=60)
            assert not srv.errors, srv.errors
    assert (tmp_path / "srv" / "up.bin").read_bytes() == data
    assert srv.stats["crc_mismatches"] == 1


# ---------------------------------------------------------------------------
# kill mid-flight: resume over a FRESH connection moves only the delta
# ---------------------------------------------------------------------------


@pytest.mark.fault
def test_kill_mid_put_then_resume_fresh_connection(tmp_path, xdfs_server):
    # 96 blocks through a 32-slot pool: by the time channel 1 has pushed
    # 40 frames, the receiver has flushed (and manifested) at least one
    # pool's worth of verified blocks to disk — the resume delta is real
    data = os.urandom(96 * BS)
    src = tmp_path / "src.bin"
    src.write_bytes(data)
    sidecar = tmp_path / "srv" / ("up.bin" + SIDECAR_SUFFIX)
    fault = Fault(conn=1, drop_after=48 + 40 * (HEADER_SIZE + BS
                                                + TRAILER_SIZE) + 99)
    with xdfs_server(engine="mtedp", root=str(tmp_path / "srv")) as srv:
        with FaultyProxy(srv.address, c2s=fault) as px:
            cli = XdfsClient.connect(px.address, n_channels=2,
                                     block_size=BS, integrity=True)
            try:
                with pytest.raises((OSError, RuntimeError)):
                    cli.put(str(src), "up.bin").result()
            finally:
                cli.close()
        # the dying server session persisted its verified-block manifest
        _await(sidecar.exists, msg="server resume sidecar")
        with XdfsClient.connect(srv.address, n_channels=2, block_size=BS,
                                integrity=True) as cli:
            r = cli.put(str(src), "up.bin", resume=True).result()
            assert 0 < r.bytes < len(data)  # only missing blocks re-sent
            # idempotent re-resume: the manifest is complete, zero data moves
            assert cli.put(str(src), "up.bin", resume=True).result().bytes == 0
    assert (tmp_path / "srv" / "up.bin").read_bytes() == data


@pytest.mark.fault
def test_kill_mid_get_then_resume_fresh_connection(tmp_path, xdfs_server):
    data = os.urandom(96 * BS)
    dst = tmp_path / "back.bin"
    sidecar = Path(str(dst) + SIDECAR_SUFFIX)
    (tmp_path / "srv").mkdir()
    with xdfs_server(engine="mtedp", root=str(tmp_path / "srv")) as srv:
        (tmp_path / "srv" / "f.bin").write_bytes(data)
        fault = Fault(conn=1, drop_after=40 * (HEADER_SIZE + BS
                                               + TRAILER_SIZE) + 99)
        with FaultyProxy(srv.address, s2c=fault) as px:
            cli = XdfsClient.connect(px.address, n_channels=2,
                                     block_size=BS, integrity=True)
            try:
                with pytest.raises((OSError, RuntimeError)):
                    cli.get("f.bin", str(dst)).result()
            finally:
                cli.close()
        assert sidecar.exists()  # client persisted its own manifest
        with XdfsClient.connect(srv.address, n_channels=2, block_size=BS,
                                integrity=True) as cli:
            r = cli.get("f.bin", str(dst), resume=True).result()
            assert 0 < r.bytes < len(data)
    assert dst.read_bytes() == data
    assert not sidecar.exists()  # verified-complete download cleans up


@pytest.mark.fault
def test_stall_surfaces_as_typed_timeout(tmp_path, xdfs_server):
    data = os.urandom(8 * BS)
    (tmp_path / "srv").mkdir()
    with xdfs_server(engine="mtedp", root=str(tmp_path / "srv")) as srv:
        (tmp_path / "srv" / "f.bin").write_bytes(data)
        fault = Fault(conn=1, stall_after=HEADER_SIZE + BS + TRAILER_SIZE)
        with FaultyProxy(srv.address, s2c=fault) as px:
            cli = XdfsClient.connect(px.address, n_channels=2,
                                     block_size=BS, integrity=True,
                                     io_timeout=0.5)
            try:
                t0 = time.monotonic()
                with pytest.raises(TimeoutError):
                    cli.get("f.bin", str(tmp_path / "back.bin")).result()
                assert time.monotonic() - t0 < 30.0  # typed, not a hang
            finally:
                cli.close()


def test_connect_deadline_is_enforced(tmp_path, xdfs_server):
    with xdfs_server(engine="mtedp", root=str(tmp_path / "srv")) as srv:
        with pytest.raises(DeadlineExceeded):
            XdfsClient.connect(srv.address, n_channels=2,
                               connect_deadline=0.0)


# ---------------------------------------------------------------------------
# cluster: node death mid-put -> bounded re-plan onto the survivors
# ---------------------------------------------------------------------------


@pytest.mark.fault
def test_cluster_put_replans_around_dead_node(tmp_path):
    from repro.cluster import ClusterClient, DataNode, MetaNode

    # heartbeat_timeout huge: the detector still believes in the dead
    # node, so the FIRST plan places blocks on it and the client's
    # re-plan (with exclude) is what saves the put
    meta = MetaNode(replication=1, heartbeat_timeout=300.0,
                    tick_interval=60.0).start()
    nodes = [DataNode(meta.address, str(tmp_path / f"n{i}"),
                      node_id=f"n{i}", heartbeat_interval=60.0).start()
             for i in range(2)]
    cli = ClusterClient(meta.address, block_size=64 << 10,
                        policy=RetryPolicy(attempts=3, base_delay=0.01,
                                           jitter=0.0))
    try:
        nodes[1].kill()
        data = os.urandom(8 * (64 << 10) + 17)
        cli.put("f.bin", data=data)
        assert cli.stats["replans"] >= 1
        assert cli.get("f.bin") == data
    finally:
        cli.close()
        for n in nodes:
            n.stop()
        meta.stop()


# ---------------------------------------------------------------------------
# property: random kill/corrupt points always converge to a clean file
# ---------------------------------------------------------------------------


@pytest.mark.fault
@pytest.mark.parametrize("loop", [
    pytest.param(False, id="threads"),
    pytest.param(True, id="loop", marks=pytest.mark.loopmatrix),
])
@given(offset=st.integers(min_value=96, max_value=140_000),
       kill=st.booleans())
@settings(max_examples=5, deadline=None)
def test_random_faults_always_resume_byte_identical(offset, kill, loop):
    workdir = Path(tempfile.mkdtemp(prefix="xdfs-fuzz-"))
    data = os.urandom(8 * BS + 321)
    src = workdir / "src.bin"
    src.write_bytes(data)
    fault = (Fault(drop_after=offset) if kill
             else Fault(conn=1, corrupt_at=offset))
    with XdfsServer(engine="mtedp", root=str(workdir / "srv"),
                    loop=loop) as srv:
        with FaultyProxy(srv.address, c2s=fault) as px:
            cli = XdfsClient.connect(px.address, n_channels=2,
                                     block_size=BS, integrity=True)
            try:
                cli.put(str(src), "f.bin").result()
            except Exception:
                pass  # any failure mode is fine; resume must heal it
            finally:
                try:
                    cli.close()
                except Exception:
                    pass
        # bounded resume loop over FRESH direct connections
        for _ in range(5):
            try:
                with XdfsClient.connect(srv.address, n_channels=2,
                                        block_size=BS,
                                        integrity=True) as cli:
                    cli.put(str(src), "f.bin", resume=True).result()
                    # CRC-clean proof: a second resume moves zero bytes
                    r = cli.put(str(src), "f.bin", resume=True).result()
                    assert r.bytes == 0
                break
            except Exception:
                continue
        else:
            raise AssertionError("resume never converged")
        assert (workdir / "srv" / "f.bin").read_bytes() == data


# ---------------------------------------------------------------------------
# checkpoint: kill mid-save, resume the save instead of re-sending
# ---------------------------------------------------------------------------


@pytest.mark.fault
def test_checkpoint_kill_mid_save_then_resume(tmp_path, monkeypatch):
    np = pytest.importorskip("numpy")
    from contextlib import contextmanager

    from repro.checkpoint import xdfs_ckpt

    monkeypatch.setattr(xdfs_ckpt, "BLOCK", 64 << 10)
    tree = {"w": np.arange(256 * 1024, dtype=np.uint8),
            "b": np.ones((64 * 1024,), dtype=np.uint8)}
    ckdir = tmp_path / "ck"
    real_session = xdfs_ckpt._session

    @contextmanager
    def faulty_session(root, integrity=False):
        srv = XdfsServer(engine=xdfs_ckpt.ENGINE, root=str(root)).start()
        px = FaultyProxy(srv.address, c2s=Fault(drop_after=96 << 10))
        cli = XdfsClient.connect(px.address,
                                 n_channels=xdfs_ckpt.N_CHANNELS,
                                 engine=xdfs_ckpt.ENGINE,
                                 block_size=xdfs_ckpt.BLOCK,
                                 integrity=True)
        try:
            yield cli
        finally:
            try:
                cli.close()
            except Exception:
                pass
            px.close()
            srv.stop()

    monkeypatch.setattr(xdfs_ckpt, "_session", faulty_session)
    with pytest.raises(Exception):
        xdfs_ckpt.save(tree, str(ckdir), step=1, integrity=True)
    tmp_step = ckdir / "step_00000001.tmp"
    assert tmp_step.exists()  # torn save left the in-flight dir ...
    assert list(tmp_step.glob("*" + SIDECAR_SUFFIX))  # ... with manifests
    monkeypatch.setattr(xdfs_ckpt, "_session", real_session)
    committed = xdfs_ckpt.save(tree, str(ckdir), step=1, resume=True)
    assert not list(Path(committed).glob("*" + SIDECAR_SUFFIX))
    like = {k: np.empty_like(v) for k, v in tree.items()}
    restored, step = xdfs_ckpt.restore(str(ckdir), like)
    assert step == 1
    assert np.array_equal(restored["w"], tree["w"])
    assert np.array_equal(restored["b"], tree["b"])
