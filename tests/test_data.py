"""Data stream determinism + prefetch pipeline resume semantics."""
import numpy as np

from repro.data.pipeline import PrefetchPipeline
from repro.data.synthetic import StreamSpec, batch_at


def test_stream_pure_function_of_step():
    spec = StreamSpec(vocab_size=1000, seq_len=16, global_batch=4, seed=7)
    a = batch_at(spec, 42)
    b = batch_at(spec, 42)
    np.testing.assert_array_equal(a["inputs"], b["inputs"])
    c = batch_at(spec, 43)
    assert not np.array_equal(a["inputs"], c["inputs"])
    assert a["inputs"].max() < 1000 and a["inputs"].min() >= 0


def test_pipeline_resume_bit_exact():
    spec = StreamSpec(vocab_size=512, seq_len=8, global_batch=2, seed=1)
    p1 = PrefetchPipeline(spec, start_step=0)
    first = [next(p1) for _ in range(6)]
    p1.close()
    # resume at step 3: must replay the same batches
    p2 = PrefetchPipeline(spec, start_step=3)
    resumed = [next(p2) for _ in range(3)]
    p2.close()
    for (s1, b1), (s2, b2) in zip(first[3:], resumed):
        assert s1 == s2
        np.testing.assert_array_equal(b1["labels"], b2["labels"])


def test_embed_mode_for_stub_frontends():
    spec = StreamSpec(vocab_size=512, seq_len=8, global_batch=2, embed_dim=32)
    b = batch_at(spec, 0)
    assert b["inputs"].shape == (2, 8, 32)
    assert b["labels"].shape == (2, 8)
