"""docs/ARCHITECTURE.md is normative and machine-checked: the wire-protocol
tables must match the constants in header.py and the transition relations
in fsm.py, and the docs linter must pass on every committed doc."""
import re
import subprocess
import sys
from pathlib import Path

import pytest

from repro.core import header
from repro.core.fsm import FSM_BUILDERS
from repro.core.header import HEADER_SIZE, MAGIC, VERSION, ChannelEvent

REPO = Path(__file__).resolve().parent.parent
ARCH = REPO / "docs" / "ARCHITECTURE.md"
DOCS = [REPO / "README.md", ARCH, REPO / "docs" / "BENCHMARKING.md"]

pytestmark = pytest.mark.skipif(not ARCH.exists(),
                                reason="docs not present in this checkout")


def _arch_text() -> str:
    return ARCH.read_text()


# ---------------------------------------------------------------------------
# frame header + negotiation constants
# ---------------------------------------------------------------------------


def test_header_struct_format_documented():
    text = _arch_text()
    assert f"`{header._FMT.format}`" in text, (
        "ARCHITECTURE.md frame-header struct format drifted from header.py"
    )
    assert f"**{HEADER_SIZE} bytes**" in text
    assert f"`{MAGIC:#010x}`" in text
    # version row: the wire version constant must appear as documented
    assert re.search(rf"\|\s*1\s*\|\s*version\s*\|\s*`H`\s*\|\s*2\s*\|\s*"
                     rf"`{VERSION}`", text), (
        "documented header version row missing or drifted"
    )


def test_negotiation_formats_documented():
    text = _arch_text()
    # the implementation's own struct strings (pack/unpack in header.py)
    assert "`<16sHIIQQB??HH`" in text  # negotiation head
    assert "`<II?`" in text  # tuning tail
    # batch tail row: the <H batch_frames field must be documented
    assert re.search(r"\|\s*batch tail\s*\|\s*`<H`\s*\|\s*batch_frames",
                     text), "batch_frames negotiation tail row missing"


def test_batch_ceiling_documented():
    from repro.core.session import MAX_BATCH_FRAMES

    assert f"**{MAX_BATCH_FRAMES}**" in _arch_text(), (
        "documented batch_frames ceiling drifted from session.MAX_BATCH_FRAMES"
    )


def test_autotuner_constants_documented():
    """The autotuner section is normative too: the depth ladder and the
    splice arbiter's phase names must match core/autotune.py."""
    from repro.core import autotune

    text = _arch_text()
    ladder = "(" + ", ".join(str(d) for d in autotune.LADDER) + ")"
    assert f"`{ladder}`" in text, (
        f"documented batch-depth ladder drifted from autotune.LADDER {ladder}"
    )
    arrow = (f"{autotune.SPLICE_TRIAL} --window--> {autotune.POOL_TRIAL} "
             f"--window--> {autotune.DECIDED}")
    assert arrow in text, (
        "documented splice-arbiter phase machine drifted from autotune.py"
    )


def test_integrity_trailer_documented():
    from repro.core.header import FLAG_BLOCK_CRC, TRAILER_SIZE

    text = _arch_text()
    assert "### Integrity trailer" in text
    assert f"`FLAG_BLOCK_CRC` (" in text or "`FLAG_BLOCK_CRC`" in text
    assert f"`{FLAG_BLOCK_CRC:#04x}`" in text, (
        "documented FLAG_BLOCK_CRC bit drifted from header.py"
    )
    assert f"**{TRAILER_SIZE}-byte `<I` CRC32 trailer**" in text, (
        "documented trailer format drifted from header.CRC_TRAILER"
    )
    assert re.search(r"\|\s*integrity tail\s*\|\s*`<B`\s*\|\s*integrity",
                     text), "integrity negotiation tail row missing"


def test_resume_flow_documented():
    from repro.core.resume import SIDECAR_SUFFIX

    text = _arch_text()
    assert "## RESUME flow" in text
    assert f"`<path>{SIDECAR_SUFFIX}`" in text, (
        "documented sidecar suffix drifted from resume.SIDECAR_SUFFIX"
    )
    # both resume request shapes are documented
    assert '{"mode": "put"' in text
    assert '{"mode": "get"' in text


def test_failure_policy_documented():
    text = _arch_text()
    assert "## Failure policy" in text
    for name in ("Deadline", "RetryPolicy", "DeadlineExceeded",
                 "connect_timeout", "io_timeout"):
        assert f"`{name}`" in text, f"Failure policy section missing {name}"


def test_channel_event_table_matches_enum():
    text = _arch_text()
    rows = re.findall(r"^\|\s*`(\w+)`\s*\|\s*(\d+)\s*\|", text, re.M)
    documented = {name: int(val) for name, val in rows
                  if name in ChannelEvent.__members__}
    actual = {e.name: int(e) for e in ChannelEvent}
    assert documented == actual, (
        f"ARCHITECTURE.md event table drifted from ChannelEvent: "
        f"documented {documented}, actual {actual}"
    )


# ---------------------------------------------------------------------------
# server event loop
# ---------------------------------------------------------------------------


def _evloop_section(sub_start: str, sub_end: str) -> str:
    text = _arch_text()
    start = text.index("## Server event loop")
    end = text.index("## Cluster control plane", start)
    section = text[start:end]
    lo = section.index(sub_start)
    hi = section.index(sub_end, lo) if sub_end else len(section)
    return section[lo:hi]


def test_evloop_demux_state_table_matches_module():
    """The handshake demux state table is normative: its rows must be
    exactly ``evloop.HS_STATES``."""
    from repro.core import evloop

    sub = _evloop_section("### Handshake demux", "### Admission")
    rows = re.findall(r"^\|\s*`(\w+)`\s*\|", sub, re.M)
    assert rows == list(evloop.HS_STATES), (
        f"ARCHITECTURE.md demux state table drifted from evloop.HS_STATES: "
        f"documented {rows}, actual {list(evloop.HS_STATES)}"
    )


def test_evloop_error_kind_table_matches_module():
    """The admission/eviction error-kind table is normative: its rows
    must be exactly ``evloop.ERR_KINDS``, and the two kinds the client
    types as BusyError must say so."""
    from repro.core import evloop

    sub = _evloop_section("### Admission and typed errors", "### Fairness")
    rows = re.findall(r"^\|\s*`(\w+)`\s*\|", sub, re.M)
    assert rows == list(evloop.ERR_KINDS), (
        f"ARCHITECTURE.md error-kind table drifted from evloop.ERR_KINDS: "
        f"documented {rows}, actual {list(evloop.ERR_KINDS)}"
    )
    for kind, exc in ((evloop.ERR_BUSY, "BusyError"),
                      (evloop.ERR_DRAINING, "BusyError"),
                      (evloop.ERR_IDLE, "SessionError"),
                      (evloop.ERR_DISK_FULL, "DiskFullError")):
        assert re.search(rf"^\|\s*`{kind}`\s*\|.*\|\s*`{exc}`\s*\|", sub,
                         re.M), f"kind {kind!r} must document raising {exc}"


def test_evloop_scheduler_constants_documented():
    from repro.core import evloop

    sub = _evloop_section("### Fairness and drain", "")
    assert f"**{evloop.DRR_QUANTUM >> 10} KiB**" in sub, (
        "documented DRR quantum drifted from evloop.DRR_QUANTUM"
    )
    assert f"**{evloop.TURN_BUDGET >> 20} MiB**" in sub, (
        "documented turn budget drifted from evloop.TURN_BUDGET"
    )


# ---------------------------------------------------------------------------
# cluster control plane
# ---------------------------------------------------------------------------


def _cluster_section() -> str:
    text = _arch_text()
    start = text.index("## Cluster control plane")
    return text[start:]


def test_cluster_message_table_matches_enum():
    """The Cluster control plane message table is normative: every
    documented (name, value) row must match wire.ClusterMsg exactly."""
    from repro.cluster.wire import ClusterMsg

    rows = re.findall(r"^\|\s*`(\w+)`\s*\|\s*(\d+)\s*\|", _cluster_section(),
                      re.M)
    documented = {name: int(val) for name, val in rows}
    actual = {m.name: int(m) for m in ClusterMsg}
    assert documented == actual, (
        f"ARCHITECTURE.md cluster message table drifted from ClusterMsg: "
        f"documented {documented}, actual {actual}"
    )


def test_cluster_framing_documented():
    from repro.cluster import wire

    text = _cluster_section()
    assert f"`{wire._FMT.format}`" in text, (
        "documented cluster control header struct drifted from wire.py"
    )
    assert f"`{wire.MAGIC:#010x}`" in text
    assert f"version `{wire.VERSION}`" in text


def test_journal_record_table_matches_module():
    """The Control-plane durability record-tag table is normative: the
    documented (tag, id) rows must match journal.RECORDS exactly. Ids
    are backticked in the doc so these rows stay invisible to the
    ClusterMsg table scraper above."""
    from repro.cluster import journal

    rows = re.findall(r"^\|\s*`(\w+)`\s*\|\s*`(\d+)`\s*\|",
                      _cluster_section(), re.M)
    documented = {name: int(val) for name, val in rows}
    actual = {tag: tag_id for tag_id, tag in journal.RECORDS.items()}
    assert documented == actual, (
        f"ARCHITECTURE.md journal record table drifted from "
        f"journal.RECORDS: documented {documented}, actual {actual}"
    )


def test_durability_section_documented():
    from repro.cluster.journal import JOURNAL_NAME, SNAPSHOT_NAME

    text = _cluster_section()
    assert "### Control-plane durability" in text
    assert f"`{JOURNAL_NAME}`" in text, (
        "documented journal file name drifted from journal.JOURNAL_NAME"
    )
    assert f"`{SNAPSHOT_NAME}`" in text, (
        "documented snapshot file name drifted from journal.SNAPSHOT_NAME"
    )


def test_epoch_fencing_documented():
    """The fencing contract names the wire constants: the epoch reply
    field and both control-plane error codes."""
    from repro.cluster.wire import (EPOCH_FIELD, ERR_NOT_LEADER,
                                    ERR_UNREGISTERED)

    text = _cluster_section()
    assert "### Leader epochs and fencing" in text
    assert f"`{EPOCH_FIELD}`" in text, (
        "documented epoch reply field drifted from wire.EPOCH_FIELD"
    )
    for code in (ERR_NOT_LEADER, ERR_UNREGISTERED):
        assert f"`{code}`" in text, (
            f"documented error code {code!r} drifted from wire.py"
        )


def test_durability_tail_documented():
    """The negotiation's durability tail is wire contract: the `<B` row,
    every policy byte value, and the floor rule must be documented."""
    from repro.core.engines.base import DURABILITY_NAMES

    text = _arch_text()
    assert re.search(r"\|\s*durability tail\s*\|\s*`<B`\s*\|\s*durability",
                     text), "durability negotiation tail row missing"
    for byte, name in enumerate(DURABILITY_NAMES):
        assert f"{name} (`{byte}`)" in text, (
            f"durability policy {name!r} (byte {byte}) missing from the "
            f"at-rest policy table"
        )
    assert "max(server floor, client request)" in text, (
        "the durability floor rule must be documented verbatim"
    )


def test_data_at_rest_durability_documented():
    """The Data-at-rest durability section is normative: the atomic
    commit sequence, the sidecar/temp-file names, and the scrub-and-
    repair heartbeat fields must match the code's constants."""
    from repro.cluster.wire import CMD_DROP
    from repro.core.engines.base import TMP_INFIX
    from repro.core.resume import MANIFEST_SUFFIX

    text = _cluster_section()
    assert "### Data-at-rest durability" in text
    assert f"`<path>{MANIFEST_SUFFIX}`" in text, (
        "documented manifest sidecar suffix drifted from "
        "resume.MANIFEST_SUFFIX"
    )
    assert f"<path>{TMP_INFIX}" in text, (
        "documented atomic temp-file infix drifted from base.TMP_INFIX"
    )
    # the commit sequence is the crash-consistency contract
    assert "`os.replace(temp, path)`" in text
    assert "`fsync(dir)`" in text
    # scrub-and-repair loop: heartbeat fields and the repair command
    for token in ("`corrupt`", "`free_bytes`", f"`{CMD_DROP}`"):
        assert token in text, (
            f"Data-at-rest durability section missing {token}"
        )


def test_cluster_command_ops_documented():
    """The heartbeat command table must carry exactly the op strings the
    DataNode executes (wire.CMD_REPLICATE / wire.CMD_DROP)."""
    from repro.cluster.wire import CMD_DROP, CMD_REPLICATE

    text = _cluster_section()
    ops = re.findall(r"^\|\s*`(\w+)`\s*\|\s*`block_id`", text, re.M)
    assert set(ops) == {CMD_REPLICATE, CMD_DROP}, (
        f"documented command ops {ops} drifted from wire.py constants"
    )


# ---------------------------------------------------------------------------
# FSM transition tables
# ---------------------------------------------------------------------------


def _doc_fsm_rows(section_marker: str, end_marker: str):
    """All `| `state` | `event` | `next` |` triples between two markers."""
    text = _arch_text()
    start = text.index(section_marker)
    end = text.index(end_marker, start)
    return set(re.findall(
        r"^\|\s*`([\w]+)`\s*\|\s*`([\w]+)`\s*\|\s*`([\w]+)`\s*\|",
        text[start:end], re.M))


def _machine_rows(name: str):
    """The machine's transition relation minus the uniformly generated
    error/handled edges (documented as a note, not table rows)."""
    m = FSM_BUILDERS[name]()
    return {(s, e, t) for (s, e), t in m.transitions.items()
            if e not in ("error", "handled")}


@pytest.mark.parametrize("name,start,end", [
    ("server_upload", "`server_upload` transition relation",
     "`client_upload` machine"),
    ("client_upload", "`client_upload` machine", "Every non-final state"),
])
def test_fsm_tables_match_machines(name, start, end):
    documented = _doc_fsm_rows(start, end)
    actual = _machine_rows(name)
    assert documented == actual, (
        f"ARCHITECTURE.md {name} table drifted from fsm.py:\n"
        f"  documented-only: {sorted(documented - actual)}\n"
        f"  machine-only:    {sorted(actual - documented)}"
    )


# ---------------------------------------------------------------------------
# docs linter (fences + links), same entry point CI uses
# ---------------------------------------------------------------------------


def test_docs_lint_passes():
    r = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_docs.py"),
         *map(str, DOCS)],
        capture_output=True, text=True,
    )
    assert r.returncode == 0, f"docs lint failed:\n{r.stderr}"
