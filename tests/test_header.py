"""Channel header framing: roundtrips, corruption detection (hypothesis)."""
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.header import (
    HEADER_SIZE,
    ChannelEvent,
    ChannelHeader,
    Negotiation,
    ProtocolError,
    new_session_id,
)


@given(
    ev=st.sampled_from(list(ChannelEvent)),
    chan=st.integers(0, 2**31 - 1),
    off=st.integers(0, 2**63 - 1),
    ln=st.integers(0, 2**63 - 1),
    flags=st.integers(0, 255),
    session=st.binary(min_size=16, max_size=16),
)
@settings(max_examples=300, deadline=None)
def test_header_roundtrip(ev, chan, off, ln, flags, session):
    h = ChannelHeader(ev, session, chan, off, ln, flags)
    buf = h.pack()
    assert len(buf) == HEADER_SIZE
    h2 = ChannelHeader.unpack(buf)
    assert h2 == h


@given(pos=st.integers(0, HEADER_SIZE - 5), bit=st.integers(0, 7))
@settings(max_examples=100, deadline=None)
def test_header_corruption_detected(pos, bit):
    h = ChannelHeader(ChannelEvent.xFTSMU, new_session_id(), 3, 1 << 20, 4096)
    buf = bytearray(h.pack())
    buf[pos] ^= 1 << bit
    try:
        h2 = ChannelHeader.unpack(bytes(buf))
        # a flipped bit that survives must still decode to a DIFFERENT header
        assert h2 != h
    except (ProtocolError, ValueError):
        pass  # detected


@given(
    n=st.integers(1, 512),
    bs=st.integers(1, 1 << 24),
    comp=st.booleans(),
    rn=st.text(min_size=0, max_size=40),
    ln=st.text(min_size=0, max_size=40),
)
@settings(max_examples=100, deadline=None)
def test_negotiation_roundtrip(n, bs, comp, rn, ln):
    neg = Negotiation(
        new_session_id(), n, bs, 1 << 20, rn, ln, compressed=comp, file_size=123
    )
    neg2 = Negotiation.unpack(neg.pack())
    assert neg2.n_channels == n and neg2.block_size == bs
    assert neg2.remote_name == rn and neg2.local_name == ln
    assert neg2.compressed == comp and neg2.file_size == 123
