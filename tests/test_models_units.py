"""Model-layer unit + property tests: rope, norms, windows, rwkv/rglru
equivalences, MoE routing invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models.attention import attention_chunked
from repro.models.layers import rms_norm, rope, softcap
from repro.models.rglru import linear_scan_chunked
from repro.models.rwkv6 import best_chunk, wkv_chunked, wkv_step


def test_rope_preserves_norm_and_relativity(key):
    x = jax.random.normal(key, (1, 8, 2, 32))
    pos = jnp.arange(8)[None]
    y = rope(x, pos, 10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, 32))
    k = jax.random.normal(jax.random.fold_in(key, 2), (1, 1, 1, 32))

    def dot_at(i, j):
        qi = rope(q, jnp.array([[i]]), 10000.0)
        kj = rope(k, jnp.array([[j]]), 10000.0)
        return float(jnp.sum(qi * kj))

    assert abs(dot_at(3, 1) - dot_at(10, 8)) < 1e-3


def test_rms_norm_unit_variance(key):
    x = jax.random.normal(key, (4, 64)) * 7.0
    w = jnp.ones((64,))
    y = rms_norm(x, w, 1e-6, gemma_style=False)
    rms = np.sqrt(np.mean(np.asarray(y, np.float32) ** 2, axis=-1))
    np.testing.assert_allclose(rms, 1.0, atol=2e-2)  # bf16-path tolerance
    # gemma (1+w) convention: zero weight == identity scale
    y2 = rms_norm(x, jnp.zeros((64,)), 1e-6, gemma_style=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), atol=1e-5)


@given(cap=st.floats(1.0, 100.0), v=st.floats(-1e4, 1e4))
@settings(max_examples=100, deadline=None)
def test_softcap_bounds(cap, v):
    out = float(softcap(jnp.float32(v), cap))
    assert abs(out) <= cap + 1e-3
    if abs(v) < cap / 10:
        assert abs(out - v) < cap / 50  # near-identity in the linear regime


@pytest.mark.parametrize("chunk", [1, 7, 16, 64])
def test_attention_chunk_invariance(chunk, key):
    """Chunked attention must be chunk-size invariant."""
    q = jax.random.normal(key, (1, 48, 4, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 48, 2, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 48, 2, 16))
    ref = attention_chunked(q, k, v, scale=0.25, chunk=48)
    out = attention_chunked(q, k, v, scale=0.25, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_attention_window_equals_masked_full(key):
    q = jax.random.normal(key, (1, 32, 2, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 32, 2, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 32, 2, 16))
    out = attention_chunked(q, k, v, scale=0.25, window=8, chunk=16)
    # manual reference
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) * 0.25
    qpos, kpos = jnp.arange(32)[:, None], jnp.arange(32)[None, :]
    mask = (kpos <= qpos) & (qpos - kpos < 8)
    s = jnp.where(mask[None, None], s, -1e30)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@pytest.mark.parametrize("s,chunk", [(12, 4), (37, 64), (64, 16)])
def test_wkv_chunked_matches_stepwise(s, chunk, key):
    """RWKV6 chunked form == sequential per-token recurrence."""
    b, h, hd = 2, 3, 8
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    logw = -jnp.exp(jax.random.normal(ks[3], (b, s, h, hd)) * 0.5)
    u = jax.random.normal(ks[4], (h, hd)) * 0.1
    state0 = jnp.zeros((b, h, hd, hd), jnp.float32)

    o_chunk, s_chunk = wkv_chunked(r, k, v, logw, u, state0, chunk=chunk)

    st = state0
    outs = []
    for t in range(s):
        o, st = wkv_step(r[:, t], k[:, t], v[:, t], logw[:, t], u, st)
        outs.append(o)
    o_ref = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(o_chunk), np.asarray(o_ref), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_chunk), np.asarray(st), atol=2e-4)


@given(s=st.integers(1, 100), chunk=st.integers(1, 300))
@settings(max_examples=50, deadline=None)
def test_best_chunk_divides(s, chunk):
    c = best_chunk(s, chunk)
    assert 1 <= c <= max(1, min(chunk, s))
    assert s % c == 0


@pytest.mark.parametrize("chunk", [16, 64, 256])
def test_rglru_chunked_scan_matches_ref(chunk, key):
    from repro.kernels.rglru_scan.ref import linear_scan_ref

    b, s, c = 2, 96, 24
    a = jax.nn.sigmoid(jax.random.normal(key, (b, s, c)))
    bx = jax.random.normal(jax.random.fold_in(key, 1), (b, s, c))
    h0 = jax.random.normal(jax.random.fold_in(key, 2), (b, c))
    h_all, h_last = linear_scan_chunked(a, bx, h0, chunk=chunk)
    ref_all, ref_last = linear_scan_ref(a, bx, h0)
    np.testing.assert_allclose(np.asarray(h_all), np.asarray(ref_all), atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(ref_last), atol=1e-5)


def test_moe_zero_drop_routing(mesh11, key):
    """With ample capacity every (token, k) assignment is honored and gate
    weights are a convex combination."""
    from repro.configs.base import get_config
    from repro.models.moe import moe_apply
    from repro.runtime.shard import make_policy

    cfg = dataclasses.replace(get_config("olmoe-1b-7b").smoke(), capacity_factor=8.0)
    pol = make_policy(cfg, mesh11, "train")
    d = cfg.d_model
    params = {
        "router": jax.random.normal(key, (d, cfg.num_experts), jnp.float32) * 0.1,
        "w_in": jax.random.normal(jax.random.fold_in(key, 1), (cfg.num_experts, d, cfg.moe_dff), jnp.bfloat16) * 0.05,
        "w_gate": jax.random.normal(jax.random.fold_in(key, 2), (cfg.num_experts, d, cfg.moe_dff), jnp.bfloat16) * 0.05,
        "w_out": jax.random.normal(jax.random.fold_in(key, 3), (cfg.num_experts, cfg.moe_dff, d), jnp.bfloat16) * 0.05,
    }
    x = jax.random.normal(jax.random.fold_in(key, 4), (64, d), jnp.bfloat16)
    with mesh11:
        out, metrics = jax.jit(
            lambda p, xx: moe_apply(
                p, xx, cfg, group=64, capacity=64 * cfg.top_k, policy=pol, batch=2
            )
        )(params, x)
    assert out.shape == x.shape
    assert float(metrics.drop_frac) == 0.0
    assert bool(jnp.all(jnp.isfinite(out.astype(jnp.float32))))
    assert float(metrics.aux_loss) > 0.0
