"""Ring buffer / block pool invariants (hypothesis FIFO model checking)."""
import threading

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.ringbuf import BlockPool, LockedRing, RingBuffer


@given(st.lists(st.tuples(st.booleans(), st.binary(min_size=1, max_size=32)),
                min_size=1, max_size=200))
@settings(max_examples=200, deadline=None)
def test_ringbuffer_fifo_model(ops):
    """Model-check RingBuffer against a plain list queue."""
    rb = RingBuffer(8, 32)
    model = []
    off = 0
    for is_push, payload in ops:
        if is_push:
            if rb.push(payload, off):
                model.append((off, bytes(payload)))
                off += len(payload)
            else:
                assert rb.full()
        else:
            got = rb.peek()
            if got is None:
                assert not model
            else:
                o, mv = got
                assert (o, bytes(mv)) == model[0]
                rb.pop()
                model.pop(0)
    assert len(rb) == len(model)


def test_ringbuffer_drain_order():
    rb = RingBuffer(4, 16)
    for i in range(4):
        assert rb.push(bytes([i] * 4), i * 4)
    assert rb.full() and rb.produce_view() is None
    drained = rb.drain_contiguous()
    assert [off for off, _ in drained] == [0, 4, 8, 12]
    assert rb.empty()


@given(st.integers(1, 16))
@settings(max_examples=30, deadline=None)
def test_blockpool_acquire_release(n):
    pool = BlockPool(n, 64)
    blks = []
    for _ in range(n):
        b = pool.acquire()
        assert b is not None
        blks.append(b)
    assert pool.acquire() is None
    for i, b in enumerate(blks):
        pool.commit(b, i * 64, 64)
    drained = pool.drain()
    assert [o for o, _, _ in drained] == [i * 64 for i in range(n)]
    for _, _, b in drained:
        pool.release(b)
    assert pool.n_free == n


def test_lockedring_threaded_integrity():
    ring = LockedRing(8, 64)
    n_items = 200
    out = []

    def consumer():
        while True:
            batch = ring.get_batch(timeout=0.05)
            out.extend(batch)
            if ring.closed and not batch:
                return

    t = threading.Thread(target=consumer)
    t.start()
    for i in range(n_items):
        ring.put(bytes([i % 256] * 8), i * 8)
    ring.close()
    t.join(timeout=10)
    assert sorted(o for o, _ in out) == [i * 8 for i in range(n_items)]
