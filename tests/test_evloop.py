"""Event-loop server core: handshake demux, admission, fairness, drain.

Boundary and property tests for the sharded ``selectors`` session core
(``repro.core.evloop``) that sits behind ``XdfsServer(loop=...)``:

- partial-hello sweep: the handshake state machine must assemble hellos
  and negotiations delivered one byte at a time, then run a normal put
- garbled / duplicate hellos are contained (typed into
  ``handshake_errors``, no socket leaks in the shard maps)
- admission control refuses over-capacity sessions with a TYPED error
  (``BusyError``) the client actually reads, instead of a raw RST
- idle sessions are evicted on an injectable clock
- graceful drain: ``stop()`` finishes the in-flight file, closes idle
  sessions, refuses new work
- deficit-round-robin keeps two greedy sessions within 2x of each other
- ``stop(timeout=...)`` is a GLOBAL deadline, not a per-thread one
- ``-m slow``: 1k-connection accept/evict soak
"""

import resource
import socket
import struct
import threading
import time

import pytest

from repro.core import evloop
from repro.core.api import XdfsClient, XdfsServer
from repro.core.header import (ChannelEvent, ChannelHeader, HEADER_SIZE,
                               Negotiation, new_session_id)
from repro.core.session import (BusyError, SessionError, recv_ctrl, send_ctrl)

BS = 32 << 10  # small blocks: several frames per file, still fast
ACK = b"\x06"


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _await(pred, timeout=30.0, msg="condition"):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return
        time.sleep(0.02)
    raise AssertionError(f"timed out waiting for {msg}")


def _handshake(addr, sid=None, n_channels=1, chunk=None, timeout=10.0):
    """Open a raw n-channel session (hello per channel + negotiation on
    ctrl). ``chunk`` dribbles the handshake bytes that many at a time to
    exercise the partial-read demux."""
    sid = sid or new_session_id()
    socks = []
    for ch in range(n_channels):
        s = socket.create_connection(addr, timeout=timeout)
        s.settimeout(timeout)
        s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        wire = ChannelHeader(ChannelEvent.CONM, sid, ch, 0, 0).pack()
        if ch == 0:
            raw = Negotiation(sid, n_channels, BS, 1 << 20, "", "").pack()
            wire += struct.pack("<I", len(raw)) + raw
        if chunk is None:
            s.sendall(wire)
        else:
            for i in range(0, len(wire), chunk):
                s.sendall(wire[i:i + chunk])
                time.sleep(0.001)  # let the loop observe each fragment
        socks.append(s)
    return sid, socks


def _raw_put(sock, sid, data, dst):
    """One-channel put in plain frames: ctrl request, data, EOFR, ack."""
    send_ctrl(sock, ChannelEvent.xFTSMU, sid,
              {"remote": dst, "size": len(data), "block_size": BS})
    recv_ctrl(sock)  # open reply (raises on typed EXCEPTION)
    for off in range(0, len(data), BS):
        blk = data[off:off + BS]
        sock.sendall(ChannelHeader(ChannelEvent.xFTSMU, sid, 0,
                                   off, len(blk)).pack() + blk)
    sock.sendall(ChannelHeader(ChannelEvent.EOFR, sid, 0, 0, 0).pack())
    assert sock.recv(1) == ACK


def _shards_empty(srv):
    return all(not sh.sessions and not sh.handshakes for sh in srv._shards)


# ---------------------------------------------------------------------------
# handshake demux
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("chunk", [1, 7])
def test_partial_hello_byte_at_a_time(tmp_path, chunk):
    """Hellos and negotiations fragmented down to single bytes must still
    assemble; the session then serves a normal put."""
    data = bytes(range(256)) * 300  # ~75 KiB -> 3 blocks
    with XdfsServer(engine="mtedp", root=str(tmp_path), loop=2) as srv:
        sid, (sock,) = _handshake(srv.address, chunk=chunk)
        _raw_put(sock, sid, data, "frag.bin")
        send_ctrl(sock, ChannelEvent.EOFT, sid)
        _await(lambda: srv.stats["sessions_closed"] == 1, msg="session close")
        sock.close()
        assert (tmp_path / "frag.bin").read_bytes() == data
        assert srv.stats["sessions"] == 1
        assert srv.stats["files"] == 1
        assert not srv.errors and not srv.handshake_errors
        assert _shards_empty(srv)


def test_garbled_hello_contained_without_leaks(tmp_path):
    """A connection that speaks garbage is closed and recorded; the shard
    keeps no reference to it and keeps serving real sessions."""
    with XdfsServer(engine="mtedp", root=str(tmp_path), loop=2) as srv:
        s = socket.create_connection(srv.address, timeout=10)
        s.settimeout(10)
        s.sendall(b"\xff" * HEADER_SIZE)
        assert s.recv(1) == b""  # server hung up on us
        s.close()
        _await(lambda: len(srv.handshake_errors) == 1, msg="handshake error")
        assert _shards_empty(srv)
        # the loop is unharmed: a well-formed session still works
        with XdfsClient.connect(srv.address, n_channels=2) as cli:
            cli.put(None, "after.bin", data=b"still alive").result(30)
        assert (tmp_path / "after.bin").read_bytes() == b"still alive"
        assert not srv.errors


def test_duplicate_hello_newer_socket_wins(tmp_path):
    """Re-sending a channel hello (client retry) replaces the parked
    socket: the stale one is closed, the session completes on the new."""
    with XdfsServer(engine="mtedp", root=str(tmp_path), loop=2) as srv:
        sid = new_session_id()
        stale = socket.create_connection(srv.address, timeout=10)
        stale.settimeout(10)
        stale.sendall(ChannelHeader(ChannelEvent.CONM, sid, 1, 0, 0).pack())
        _await(lambda: 1 in srv._pending.get(sid, {}), msg="parked channel")

        fresh = socket.create_connection(srv.address, timeout=10)
        fresh.settimeout(10)
        fresh.sendall(ChannelHeader(ChannelEvent.CONM, sid, 1, 0, 0).pack())
        assert stale.recv(1) == b""  # superseded socket was closed

        # the negotiation arrives LAST: the session must assemble from the
        # ctrl channel plus the REPLACEMENT socket for channel 1
        ctrl = socket.create_connection(srv.address, timeout=10)
        ctrl.settimeout(10)
        ctrl.sendall(ChannelHeader(ChannelEvent.CONM, sid, 0, 0, 0).pack())
        raw = Negotiation(sid, 2, BS, 1 << 20, "", "").pack()
        ctrl.sendall(struct.pack("<I", len(raw)) + raw)
        _await(lambda: srv.stats["sessions"] == 1, msg="session start")
        send_ctrl(ctrl, ChannelEvent.EOFT, sid)
        _await(lambda: srv.stats["sessions_closed"] == 1, msg="session close")
        for s in (ctrl, stale, fresh):
            s.close()
        assert not srv.errors and not srv.handshake_errors
        assert _shards_empty(srv)


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------


def test_admission_over_capacity_is_typed_busy(tmp_path):
    """Session cap reached -> the extra session is parked on a reject
    shell whose every request answers ``EXCEPTION {kind: busy}``; the
    client surfaces it as BusyError, not a connection reset."""
    with XdfsServer(engine="mtedp", root=str(tmp_path), loop=1,
                    max_sessions=1) as srv:
        with XdfsClient.connect(srv.address, n_channels=2) as keeper:
            keeper.put(None, "one.bin", data=b"x" * BS).result(30)
            with XdfsClient.connect(srv.address, n_channels=2) as extra:
                with pytest.raises(BusyError):
                    extra.put(None, "two.bin", data=b"y").result(30)
            assert srv.stats["rejected"] == 1
            # capacity freed by the keeper -> next session is admitted
        _await(lambda: srv._loop_live == 0, msg="capacity release")
        with XdfsClient.connect(srv.address, n_channels=2) as cli:
            cli.put(None, "three.bin", data=b"z" * 17).result(30)
        assert (tmp_path / "three.bin").read_bytes() == b"z" * 17
        assert srv.stats["sessions"] == 2  # reject shells are not sessions


def test_admission_pending_cap_closes_excess_connects(tmp_path):
    """Half-open handshakes are bounded too: past ``max_pending`` the
    listener closes new connections instead of parking more state."""
    with XdfsServer(engine="mtedp", root=str(tmp_path), loop=1,
                    max_pending=2) as srv:
        hung = []
        for _ in range(2):  # connect but never say hello
            s = socket.create_connection(srv.address, timeout=10)
            s.settimeout(10)
            hung.append(s)
        _await(lambda: srv._pending_load() == 2, msg="pending handshakes")
        extra = socket.create_connection(srv.address, timeout=10)
        extra.settimeout(10)
        assert extra.recv(1) == b""  # refused at accept
        assert srv.stats["rejected_pending"] >= 1
        extra.close()
        for s in hung:
            s.close()


# ---------------------------------------------------------------------------
# idle eviction (injectable clock)
# ---------------------------------------------------------------------------


def test_idle_eviction_with_fake_clock(tmp_path):
    clk = FakeClock()
    with XdfsServer(engine="mtedp", root=str(tmp_path), loop=1,
                    idle_timeout=5.0, clock=clk) as srv:
        with XdfsClient.connect(srv.address, n_channels=2) as cli:
            cli.put(None, "a.bin", data=b"a" * BS).result(30)
            clk.advance(4.0)  # under the limit: still alive
            cli.put(None, "b.bin", data=b"b" * BS).result(30)
            clk.advance(6.0)
            _await(lambda: srv.stats["evicted"] == 1, msg="eviction")
            _await(lambda: srv.stats["sessions_closed"] == 1, msg="close")
            with pytest.raises((SessionError, OSError)):
                cli.put(None, "c.bin", data=b"c").result(30)
        assert _shards_empty(srv)
        assert srv.stats["files"] == 2


# ---------------------------------------------------------------------------
# graceful drain
# ---------------------------------------------------------------------------


def test_graceful_drain_completes_inflight_file(tmp_path):
    """``stop()`` mid-transfer: the in-flight file lands byte-exact and
    is acked; a session idling at the control channel is closed at once."""
    data = bytes([i % 251 for i in range(4 * BS)])
    with XdfsServer(engine="mtedp", root=str(tmp_path), loop=1) as srv:
        sid, (sock,) = _handshake(srv.address)
        _sid2, (idle,) = _handshake(srv.address)
        _await(lambda: srv.stats["sessions"] == 2, msg="sessions up")

        send_ctrl(sock, ChannelEvent.xFTSMU, sid,
                  {"remote": "drain.bin", "size": len(data), "block_size": BS})
        recv_ctrl(sock)
        half = data[:2 * BS + BS // 2]  # two frames and a torn third
        for off in range(0, 2 * BS, BS):
            sock.sendall(ChannelHeader(ChannelEvent.xFTSMU, sid, 0,
                                       off, BS).pack() + data[off:off + BS])
        sock.sendall(ChannelHeader(ChannelEvent.xFTSMU, sid, 0,
                                   2 * BS, BS).pack() + half[2 * BS:])

        stopper = threading.Thread(target=srv.stop, kwargs={"timeout": 30.0})
        stopper.start()
        _await(lambda: srv._draining, msg="drain flag")
        assert idle.recv(1) == b""  # idle session closed immediately
        idle.close()

        time.sleep(0.1)  # let drain observe the torn frame, then finish it
        sock.sendall(data[len(half):3 * BS])
        sock.sendall(ChannelHeader(ChannelEvent.xFTSMU, sid, 0,
                                   3 * BS, BS).pack() + data[3 * BS:])
        sock.sendall(ChannelHeader(ChannelEvent.EOFR, sid, 0, 0, 0).pack())
        assert sock.recv(1) == ACK
        stopper.join(25.0)
        assert not stopper.is_alive()
        sock.close()
        assert (tmp_path / "drain.bin").read_bytes() == data
        assert srv.stats["files"] == 1
        assert srv.stats["sessions_closed"] == 2
        assert not srv.errors


# ---------------------------------------------------------------------------
# fairness
# ---------------------------------------------------------------------------


def test_drr_fairness_two_greedy_sessions(tmp_path):
    """Two sessions blasting puts through ONE shard advance within 2x of
    each other: the deficit-round-robin grant caps how far ahead either
    can run while the other has bytes queued."""
    size = 12 << 20
    blob = b"\x5a" * size
    with XdfsServer(engine="mtedp", root=str(tmp_path), loop=1,
                    drr_quantum=64 << 10, turn_budget=128 << 10) as srv:
        a = XdfsClient.connect(srv.address, n_channels=2, block_size=64 << 10)
        b = XdfsClient.connect(srv.address, n_channels=2, block_size=64 << 10)
        try:
            fa = a.put(None, "a.bin", data=blob)
            fb = b.put(None, "b.bin", data=blob)
            gate = size // 2
            sample = None
            deadline = time.monotonic() + 60
            while time.monotonic() < deadline:
                prog = sorted(s.progress for s in srv.loop_sessions())
                if len(prog) == 2 and prog[1] >= gate and prog[1] < size:
                    sample = prog
                    break
                if len(prog) < 2 and (fa.done() or fb.done()):
                    break  # raced past the window; fall through to assert
                time.sleep(0.002)
            fa.result(60)
            fb.result(60)
            assert sample is not None, "never observed both sessions mid-flight"
            lo, hi = sample
            assert lo * 2 >= hi, f"starved session: {lo} vs {hi}"
        finally:
            a.close()
            b.close()
        assert (tmp_path / "a.bin").stat().st_size == size
        assert (tmp_path / "b.bin").stat().st_size == size


# ---------------------------------------------------------------------------
# stop() deadline (thread mode regression)
# ---------------------------------------------------------------------------


def test_stop_timeout_is_a_global_deadline(tmp_path):
    """Thread-mode ``stop(timeout=t)`` must bound the WHOLE shutdown by
    ``t``, not join each of N idle session threads for ``t`` serially
    (6 idle sessions used to take 6 * t)."""
    srv = XdfsServer(engine="mtedp", root=str(tmp_path), loop=False)
    srv.start()
    clients = [XdfsClient.connect(srv.address, n_channels=1)
               for _ in range(6)]
    try:
        t0 = time.monotonic()
        srv.stop(timeout=0.6)
        elapsed = time.monotonic() - t0
        assert elapsed < 2.0, f"stop took {elapsed:.2f}s for 6 idle sessions"
    finally:
        for cli in clients:
            for s in cli.socks:
                try:
                    s.shutdown(socket.SHUT_RDWR)
                except OSError:
                    pass
            try:
                cli.close()
            except Exception:
                pass


# ---------------------------------------------------------------------------
# soak
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_soak_1k_sessions_accept_and_evict(tmp_path):
    """1000 sessions through 2 shards with an aggressive idle timeout:
    every one is admitted and every one is evicted, and the shards end
    holding no sockets at all."""
    soft, hard = resource.getrlimit(resource.RLIMIT_NOFILE)
    n = 1000 if soft >= 4096 else 250
    with XdfsServer(engine="mtedp", root=str(tmp_path), loop=2,
                    idle_timeout=0.4) as srv:
        socks = []
        for _ in range(n):
            _sid, (s,) = _handshake(srv.address)
            socks.append(s)
        _await(lambda: srv.stats["sessions"] == n, timeout=60.0,
               msg="all sessions admitted")
        _await(lambda: srv.stats["evicted"] == n, timeout=120.0,
               msg="all sessions evicted")
        _await(lambda: srv.stats["sessions_closed"] == n, timeout=60.0,
               msg="all sessions closed")
        assert _shards_empty(srv)
        assert not srv.errors and not srv.handshake_errors
        for s in socks:
            s.close()


def test_evloop_error_kinds_are_stable():
    """The typed admission/drain/evict kinds are wire contract: clients
    match on them (BusyError) and the docs table lists them."""
    assert evloop.ERR_BUSY == "busy"
    assert evloop.ERR_DRAINING == "draining"
    assert evloop.ERR_IDLE == "idle"
    assert evloop.ERR_DISK_FULL == "disk_full"
    assert set(evloop.ERR_KINDS) == {"busy", "draining", "idle", "disk_full"}
