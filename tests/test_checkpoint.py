"""Checkpoint invariants: roundtrip, atomicity, corruption fallback, GC,
async disk thread."""
import json
import os
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import xdfs_ckpt
from repro.checkpoint.async_ckpt import AsyncCheckpointer


def _tree(seed=0):
    k = jax.random.key(seed)
    return {
        "a": jax.random.normal(k, (33, 17), jnp.float32),
        "b": {"w": jax.random.normal(jax.random.fold_in(k, 1), (128,), jnp.bfloat16)},
        "step": jnp.int32(7),
    }


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    xdfs_ckpt.save(t, str(tmp_path), step=10)
    like = jax.eval_shape(lambda: t)
    restored, step = xdfs_ckpt.restore(str(tmp_path), like)
    assert step == 10
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_no_tmp_dirs_visible_after_save(tmp_path):
    xdfs_ckpt.save(_tree(), str(tmp_path), step=1)
    assert not list(Path(tmp_path).glob("*.tmp"))


def test_corrupt_newest_falls_back(tmp_path):
    t0, t1 = _tree(0), _tree(1)
    xdfs_ckpt.save(t0, str(tmp_path), step=1)
    xdfs_ckpt.save(t1, str(tmp_path), step=2)
    # corrupt a leaf of step 2
    victim = next(Path(tmp_path).glob("step_00000002/leaf_*.bin"))
    raw = bytearray(victim.read_bytes())
    raw[0] ^= 0xFF
    victim.write_bytes(bytes(raw))
    like = jax.eval_shape(lambda: t0)
    restored, step = xdfs_ckpt.restore(str(tmp_path), like)
    assert step == 1  # fell back past the corrupt step
    np.testing.assert_array_equal(
        np.asarray(restored["a"]), np.asarray(t0["a"])
    )


def test_keep_last_gc(tmp_path):
    for s in range(6):
        xdfs_ckpt.save(_tree(s), str(tmp_path), step=s, keep_last=2)
    steps = sorted(p.name for p in Path(tmp_path).glob("step_*"))
    assert steps == ["step_00000004", "step_00000005"]


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep_last=3)
    futs = [ck.save(_tree(s), s) for s in range(3)]
    ck.wait()
    assert all(f.done() and f.exception() is None for f in futs)
    assert xdfs_ckpt.latest_step(str(tmp_path)) == 2
    ck.close()


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        xdfs_ckpt.restore(str(tmp_path / "nope"), {"a": jnp.zeros(3)})


def test_cluster_checkpoint_roundtrip_gc_and_failover(tmp_path):
    """Opt-in cluster mode: shards stripe over the fleet with rf=2, the
    manifest is the commit point, keep_last GC reclaims old steps'
    blocks, and a restore survives a dead data node."""
    from repro.cluster import ClusterClient, DataNode, MetaNode

    meta = MetaNode(replication=2, heartbeat_timeout=0.5,
                    tick_interval=0.1).start()
    nodes = [
        DataNode(meta.address, str(tmp_path / f"n{i}"), node_id=f"n{i}",
                 heartbeat_interval=0.05).start()
        for i in range(3)
    ]
    cli = ClusterClient(meta.address, block_size=256 << 10)
    try:
        like = jax.eval_shape(_tree)
        for s in (3, 4, 5):
            xdfs_ckpt.save(_tree(s), "ckpt", step=s, keep_last=2,
                           cluster=cli)
        assert xdfs_ckpt.latest_step("ckpt", cluster=cli) == 5
        # GC: only the last two steps' files remain in the namespace
        steps = {n.split("/")[1] for n in cli.list("ckpt/")}
        assert steps == {"step_00000004", "step_00000005"}
        restored, step = xdfs_ckpt.restore("ckpt", like, step=5, cluster=cli)
        assert step == 5
        for a, b in zip(jax.tree.leaves(_tree(5)), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # a dead node must not lose the checkpoint (rf=2 replicas)
        nodes[0].kill()
        restored, step = xdfs_ckpt.restore("ckpt", like, cluster=cli)
        assert step == 5
        for a, b in zip(jax.tree.leaves(_tree(5)), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    finally:
        cli.close()
        for n in nodes[1:]:
            n.stop()
        meta.stop()


def test_cluster_checkpoint_survives_metanode_death(tmp_path):
    """The control plane is no longer the single point of checkpoint
    loss: with a journaled MetaNode, a save / kill-metanode / restart /
    restore cycle round-trips — and ``cluster=`` accepts plain metanode
    addresses (a throwaway failover client per call) as well as a live
    ``ClusterClient``."""
    from repro.cluster import DataNode, MetaNode
    from repro.core.faults import RetryPolicy

    jdir = tmp_path / "wal"
    meta = MetaNode(replication=2, heartbeat_timeout=0.5,
                    tick_interval=0.1, journal_dir=str(jdir)).start()
    port = meta.address[1]
    nodes = [
        DataNode(meta.address, str(tmp_path / f"n{i}"), node_id=f"n{i}",
                 heartbeat_interval=0.05,
                 policy=RetryPolicy(attempts=4, base_delay=0.05,
                                    connect_timeout=2.0)).start()
        for i in range(2)
    ]
    try:
        like = jax.eval_shape(_tree)
        # address form instead of a client instance
        xdfs_ckpt.save(_tree(1), "ckpt", step=1, cluster=meta.address)
        meta.kill()  # crash between save and restore
        meta = MetaNode(replication=2, heartbeat_timeout=0.5,
                        tick_interval=0.1, port=port,
                        journal_dir=str(jdir)).start()
        assert xdfs_ckpt.latest_step("ckpt", cluster=meta.address) == 1
        restored, step = xdfs_ckpt.restore("ckpt", like,
                                           cluster=meta.address)
        assert step == 1
        for a, b in zip(jax.tree.leaves(_tree(1)),
                        jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # the recovered control plane keeps checkpointing: next step
        # saves and becomes the latest
        xdfs_ckpt.save(_tree(2), "ckpt", step=2, cluster=meta.address)
        assert xdfs_ckpt.latest_step("ckpt", cluster=meta.address) == 2
    finally:
        for n in nodes:
            n.stop()
        meta.stop()
