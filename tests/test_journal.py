"""Unit tests for the MetaNode write-ahead journal (cluster/journal.py).

Covers the durability contract in isolation: append/replay round-trips,
torn-tail tolerance (every way a crash can mangle the final record),
replay idempotence at the MetaNode level, and the snapshot+truncate
cycle being equivalent to replaying the full history.
"""
import json
import struct

import pytest

from repro.cluster.journal import (
    JOURNAL_NAME,
    REC_COMMIT,
    REC_HEADER_SIZE,
    REC_MAGIC,
    RECORDS,
    Journal,
    encode_record,
    load_snapshot,
    recover,
    replay,
    valid_length,
    write_snapshot,
)
from repro.cluster.metanode import MetaNode
from repro.cluster.wire import CMD_DROP


def _records(n, start=1):
    return [(start + i, REC_COMMIT,
             {"name": f"f{start + i}", "size": 1, "block_size": 1,
              "blocks": []})
            for i in range(n)]


# -- append / replay round-trip ---------------------------------------------


def test_round_trip(tmp_path):
    j = Journal(tmp_path)
    for seq, tag, body in _records(5):
        j.append(seq, tag, body)
    j.close()
    assert j.replay() == _records(5)


def test_replay_empty_and_missing(tmp_path):
    assert list(replay(tmp_path / "nope")) == []
    (tmp_path / JOURNAL_NAME).write_bytes(b"")
    assert list(replay(tmp_path / JOURNAL_NAME)) == []


def test_fsync_off_same_format(tmp_path):
    j = Journal(tmp_path, fsync=False)
    for seq, tag, body in _records(3):
        j.append(seq, tag, body)
    j.close()
    assert j.stats["fsyncs"] == 0
    assert len(j.replay()) == 3


# -- torn tails --------------------------------------------------------------


def _journal_with(tmp_path, n=3):
    j = Journal(tmp_path)
    for seq, tag, body in _records(n):
        j.append(seq, tag, body)
    j.close()
    return j.path


@pytest.mark.parametrize("cut", [1, REC_HEADER_SIZE - 1,
                                 REC_HEADER_SIZE + 2])
def test_torn_final_record(tmp_path, cut):
    """A crash mid-append leaves a partial final record: replay returns
    every earlier record and stops, never raising."""
    path = _journal_with(tmp_path, n=3)
    whole = path.read_bytes()
    last = encode_record(*_records(1, start=3)[0])
    path.write_bytes(whole[:len(whole) - len(last) + cut])
    got = list(replay(path))
    assert got == _records(2)


def test_corrupt_crc_stops_replay(tmp_path):
    path = _journal_with(tmp_path, n=3)
    data = bytearray(path.read_bytes())
    data[-1] ^= 0xFF  # flip a bit in the last record's body
    path.write_bytes(bytes(data))
    assert list(replay(path)) == _records(2)


def test_garbage_mid_file_hides_suffix(tmp_path):
    """Records after a corrupt one are never yielded, even if they would
    verify individually — their prefix is broken."""
    recs = _records(3)
    good = b"".join(encode_record(*r) for r in recs)
    first = encode_record(*recs[0])
    data = bytearray(good)
    data[len(first) + 4] ^= 0xFF  # corrupt record 2's seq field
    path = tmp_path / JOURNAL_NAME
    path.write_bytes(bytes(data))
    assert list(replay(path)) == recs[:1]


def test_bad_magic_and_tag_rejected(tmp_path):
    path = tmp_path / JOURNAL_NAME
    head = struct.Struct("<IQHII").pack(0xDEAD, 1, 1, 0, 0)
    path.write_bytes(head)
    assert list(replay(path)) == []
    bad_tag = struct.Struct("<IQHII").pack(REC_MAGIC, 1, 999, 0, 0)
    path.write_bytes(bad_tag)
    assert list(replay(path)) == []


def test_reopen_truncates_torn_tail(tmp_path):
    """Reopening after a crash cuts the journal back to its last intact
    record, so new appends land on the valid prefix — not after garbage
    that replay stops at."""
    path = _journal_with(tmp_path, n=3)
    whole = path.read_bytes()
    last = encode_record(*_records(1, start=3)[0])
    path.write_bytes(whole[:len(whole) - len(last) + 3])  # tear record 3

    j = Journal(tmp_path)
    assert j.stats["torn_bytes_dropped"] > 0
    assert valid_length(path) == path.stat().st_size
    j.close()


def test_appends_after_torn_tail_survive_second_restart(tmp_path):
    """The double-crash data-loss shape: crash #1 tears the tail,
    records are acked after restart, crash #2 replays. Before the
    reopen-truncate fix those post-restart records sat behind the
    garbage and were silently lost."""
    path = _journal_with(tmp_path, n=3)
    whole = path.read_bytes()
    last = encode_record(*_records(1, start=3)[0])
    path.write_bytes(whole[:len(whole) - len(last) + 3])  # crash #1

    j = Journal(tmp_path)  # restart: torn tail truncated
    j.append(*_records(1, start=3)[0])  # acked-and-fsynced post-crash
    j.close()  # crash #2 (fsynced, so close == kill here)

    assert list(replay(path)) == _records(3)


# -- snapshot ----------------------------------------------------------------


def test_snapshot_atomic_replace(tmp_path):
    p = tmp_path / "snap.json"
    write_snapshot(p, {"v": 1})
    assert load_snapshot(p) == {"v": 1}
    write_snapshot(p, {"v": 2})
    assert load_snapshot(p) == {"v": 2}
    assert not p.with_suffix(".tmp").exists()


def test_load_snapshot_rejects_garbage(tmp_path):
    p = tmp_path / "snap.json"
    assert load_snapshot(p) is None
    p.write_text("{not json")
    assert load_snapshot(p) is None
    p.write_text("[1,2]")  # valid JSON, wrong shape
    assert load_snapshot(p) is None


def test_snapshot_truncates_journal(tmp_path):
    j = Journal(tmp_path)
    for seq, tag, body in _records(4):
        j.append(seq, tag, body)
    j.write_snapshot({"seq": 4})
    assert j.replay() == []
    assert j.load_snapshot() == {"seq": 4}
    assert j.stats["truncations"] == 1
    j.close()


def test_recover_cold_start(tmp_path):
    j, state, records = recover(tmp_path)
    assert state is None and records == []
    j.close()


# -- MetaNode-level equivalences ---------------------------------------------


def _commit(meta, name, nodes=("n1", "n2"), block="b"):
    meta.handle_commit({
        "name": name, "size": 4, "block_size": 4,
        "blocks": [{"id": f"{block}-{name}", "offset": 0, "length": 4,
                    "crc32": 7, "nodes": list(nodes)}],
    })


def _namespace(meta):
    return (meta.files, {b: sorted(h) for b, h in meta.locations.items()})


def test_replay_recovers_namespace(tmp_path):
    m1 = MetaNode(journal_dir=tmp_path)
    m1.handle_register({"node_id": "n1", "host": "h", "port": 1})
    m1.handle_register({"node_id": "n2", "host": "h", "port": 2})
    _commit(m1, "a")
    _commit(m1, "b")
    m1.handle_delete({"name": "a"})
    want = _namespace(m1)
    m1.journal.close()

    m2 = MetaNode(journal_dir=tmp_path)
    assert _namespace(m2) == want
    assert set(m2.nodes) == {"n1", "n2"}
    assert m2.seq == m1.seq
    assert m2.stats["replayed_records"] == m1.stats["journal_records"]
    m2.journal.close()


def test_replay_is_idempotent(tmp_path):
    """Recovering twice from the same journal yields identical state
    (apply overwrites, never accumulates)."""
    m1 = MetaNode(journal_dir=tmp_path)
    m1.handle_register({"node_id": "n1", "host": "h", "port": 1})
    _commit(m1, "a")
    _commit(m1, "a")  # overwrite: reclaim + re-commit
    m1.journal.close()
    m2 = MetaNode(journal_dir=tmp_path)
    m2.journal.close()
    m3 = MetaNode(journal_dir=tmp_path)
    m3.journal.close()
    assert _namespace(m2) == _namespace(m3) == _namespace(m1)


def test_snapshot_then_replay_equivalent_to_full_replay(tmp_path, tmp_path_factory):
    """snapshot + journal suffix == replaying the whole history."""
    full_dir = tmp_path_factory.mktemp("full")
    snap = MetaNode(journal_dir=tmp_path)
    full = MetaNode(journal_dir=full_dir)
    for m in (snap, full):
        m.handle_register({"node_id": "n1", "host": "h", "port": 1})
        _commit(m, "a")
    snap.snapshot()  # snapshot mid-history; full keeps journaling
    for m in (snap, full):
        _commit(m, "b")
        m.handle_delete({"name": "a"})
        m.journal.close()
    r_snap = MetaNode(journal_dir=tmp_path)
    r_full = MetaNode(journal_dir=full_dir)
    assert _namespace(r_snap) == _namespace(r_full)
    assert r_snap.seq == r_full.seq
    # and the snapshot path replayed only the post-snapshot suffix
    assert r_snap.stats["replayed_records"] < r_full.stats["replayed_records"]
    r_snap.journal.close()
    r_full.journal.close()


def test_replay_skips_records_covered_by_snapshot(tmp_path):
    """A crash between the snapshot's os.replace and the journal
    truncate leaves both on disk. Replay must skip the overlap: before
    the seq guard, a duplicated commit took the overwrite path and
    reclaimed its OWN live blocks — enqueueing drops to every holder."""
    m1 = MetaNode(journal_dir=tmp_path)
    m1.handle_register({"node_id": "n1", "host": "h", "port": 1})
    m1.handle_register({"node_id": "n2", "host": "h", "port": 2})
    _commit(m1, "a")
    want = _namespace(m1)
    overlap = m1.journal.path.read_bytes()
    m1.snapshot()
    m1.journal.close()
    m1.journal.path.write_bytes(overlap)  # crash window: truncate lost

    m2 = MetaNode(journal_dir=tmp_path)
    assert _namespace(m2) == want
    assert m2.seq == m1.seq
    assert m2.stats["replayed_records"] == 0
    # the acknowledged file's blocks are still located and no drop was
    # queued for them
    assert "b-a" in m2.locations
    assert all(not cmds for cmds in m2._commands.values())
    m2.journal.close()


def test_overwrite_reclaims_only_dropped_blocks(tmp_path):
    """Re-committing a name drops exactly the blocks the new version no
    longer references — never blocks both versions share."""
    m = MetaNode(journal_dir=tmp_path)
    m.handle_register({"node_id": "n1", "host": "h", "port": 1})
    _commit(m, "a", nodes=("n1",), block="old")
    _commit(m, "a", nodes=("n1",), block="new")
    assert "old-a" not in m.locations
    assert sorted(m.locations["new-a"]) == ["n1"]
    drops = [c for c in m._commands["n1"] if c["op"] == CMD_DROP]
    assert [c["block_id"] for c in drops] == ["old-a"]

    # identical re-commit: nothing is stale, nothing gets dropped
    _commit(m, "a", nodes=("n1",), block="new")
    assert sorted(m.locations["new-a"]) == ["n1"]
    drops = [c for c in m._commands["n1"] if c["op"] == CMD_DROP]
    assert [c["block_id"] for c in drops] == ["old-a"]
    m.journal.close()


def test_state_snapshot_is_decoupled_from_live_state(tmp_path):
    """handle_sync serializes the snapshot after the lock is released,
    so it must hold copies, not references into the live namespace."""
    m = MetaNode(journal_dir=tmp_path)
    m.handle_register({"node_id": "n1", "host": "h", "port": 1})
    _commit(m, "a")
    snap = m._state_snapshot()
    snap["files"]["a"]["blocks"][0]["id"] = "mutated"
    snap["files"]["a"]["size"] = 999
    snap["files"].pop("a")
    assert m.files["a"]["size"] == 4
    assert m.files["a"]["blocks"][0]["id"] == "b-a"
    m.journal.close()


def test_epoch_survives_restart(tmp_path):
    m1 = MetaNode(journal_dir=tmp_path)
    m1._assume_leadership(7)
    m1.journal.close()
    m2 = MetaNode(journal_dir=tmp_path)
    assert m2.epoch == 7
    m2.journal.close()


def test_record_table_is_dense_and_stable():
    """Tag ids are a stable on-disk format: dense from 1, never reused."""
    assert sorted(RECORDS) == list(range(1, len(RECORDS) + 1))
    assert len(set(RECORDS.values())) == len(RECORDS)


def test_encode_record_body_is_json(tmp_path):
    rec = encode_record(1, REC_COMMIT, {"k": "v"})
    assert json.loads(rec[REC_HEADER_SIZE:]) == {"k": "v"}
