"""Cluster xDFS: control wire framing, placement/re-replication/rebalance
planners, the fake-clock failure detector (no sleeps — injectable clock,
same idiom as the ChannelTuner tests in test_batched.py), MetaNode
command planning, SessionPool reuse, and the end-to-end 3-node cluster:
striped put, node kill, replica-failover get, and heartbeat-driven
re-replication back to full replication asserted via block reports."""
import os
import socket
import time

import pytest

from repro.cluster import (
    CMD_DROP,
    CMD_REPLICATE,
    ClusterClient,
    ClusterError,
    ClusterMsg,
    DataNode,
    FailureDetector,
    MetaNode,
    Move,
    block_name,
    choose_replicas,
    plan_put,
    plan_rebalance,
    plan_replication,
)
from repro.cluster import wire
from repro.core.api import SessionPool, XdfsServer


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# ---------------------------------------------------------------------------
# control wire framing
# ---------------------------------------------------------------------------


def test_wire_roundtrip():
    a, b = socket.socketpair()
    try:
        body = {"node_id": "n1", "blocks": ["x", "y"], "n": 7}
        wire.send_msg(a, ClusterMsg.HEARTBEAT, body)
        msg, got = wire.recv_msg(b)
        assert msg == ClusterMsg.HEARTBEAT and got == body
        wire.send_msg(b, ClusterMsg.OK, {})
        assert wire.recv_msg(a) == (ClusterMsg.OK, {})
    finally:
        a.close()
        b.close()


def test_wire_bad_magic_and_err_reply():
    a, b = socket.socketpair()
    try:
        a.sendall(b"\x00" * wire.MSG_HEADER_SIZE)
        with pytest.raises(ClusterError):
            wire.recv_msg(b)
    finally:
        a.close()
        b.close()
    a, b = socket.socketpair()
    try:
        wire.send_msg(b, ClusterMsg.ERR, {"error": "boom"})
        with pytest.raises(ClusterError, match="boom"):
            wire.request(a, ClusterMsg.LOOKUP, {"name": "x"})
    finally:
        a.close()
        b.close()


# ---------------------------------------------------------------------------
# placement planners (pure)
# ---------------------------------------------------------------------------


def test_choose_replicas_least_loaded_and_exclude():
    load = {"a": 3, "b": 1, "c": 2}
    assert choose_replicas(load, 2) == ["b", "c"]
    assert choose_replicas(load, 2, exclude={"b"}) == ["c", "a"]
    # ties break on node id (determinism)
    assert choose_replicas({"a": 1, "b": 1}, 1) == ["a"]
    # a cluster smaller than k returns what exists
    assert choose_replicas({"a": 0}, 3) == ["a"]


def test_plan_put_stripes_instead_of_piling():
    load = {"a": 0, "b": 0, "c": 0}
    plan = plan_put(6, load, rf=2)
    assert all(len(nodes) == 2 and len(set(nodes)) == 2 for nodes in plan)
    counts = {}
    for nodes in plan:
        for n in nodes:
            counts[n] = counts.get(n, 0) + 1
    # 12 replicas over 3 nodes: an even stripe, not a pile-up
    assert set(counts.values()) == {4}


def test_plan_replication_heals_to_rf():
    replicas = {"x": {"a"}, "y": {"a", "b"}}
    moves = plan_replication(replicas, alive={"a", "b", "c"}, rf=2,
                             load={"a": 2, "b": 1, "c": 0})
    assert moves == [Move("x", "a", "c")]  # y already at rf


def test_plan_replication_skip_and_lost():
    # in-flight suppression: the planned (block, dst) is not re-planned
    assert plan_replication({"x": {"a"}}, {"a", "b"}, 2, {"a": 1, "b": 0},
                            skip=[("x", "b")]) == []
    # zero live holders = lost: no move (nothing to copy from)
    assert plan_replication({"x": set()}, {"b", "c"}, 2,
                            {"b": 0, "c": 0}) == []


def test_plan_rebalance_evens_out_and_respects_holders():
    holdings = {"a": {"1", "2", "3", "4"}, "b": set(), "c": {"5"}}
    moves = plan_rebalance(holdings)
    held = {n: set(b) for n, b in holdings.items()}
    for mv in moves:
        assert mv.block_id not in held[mv.dst]  # never duplicate onto holder
        held[mv.src].discard(mv.block_id)
        held[mv.dst].add(mv.block_id)
    counts = sorted(len(b) for b in held.values())
    assert counts[-1] - counts[0] <= 1
    assert plan_rebalance({"a": {"1", "2"}, "b": set()}) == [
        Move("1", "a", "b")]
    assert plan_rebalance({"a": {"1"}, "b": set()}) == []  # spread 1 is even
    assert plan_rebalance({"a": {"1"}, "b": {"2"}}) == []


# ---------------------------------------------------------------------------
# failure detector (fake clock, no sleeps)
# ---------------------------------------------------------------------------


def test_failure_detector_marks_dead_after_timeout():
    clock = FakeClock()
    det = FailureDetector(timeout=1.0, clock=clock)
    det.beat("a")
    det.beat("b")
    assert det.alive() == {"a", "b"}
    clock.advance(0.9)
    det.beat("b")
    assert det.sweep() == []
    clock.advance(0.5)  # a last seen 1.4 ago, b 0.5 ago
    assert det.sweep() == ["a"]
    assert det.alive() == {"b"}
    assert det.sweep() == []  # death reported exactly once


def test_failure_detector_revives_on_beat():
    clock = FakeClock()
    det = FailureDetector(timeout=1.0, clock=clock)
    det.beat("a")
    clock.advance(2.0)
    assert det.sweep() == ["a"]
    det.beat("a")
    assert det.is_alive("a") and det.sweep() == []


# ---------------------------------------------------------------------------
# MetaNode planning under a fake clock (handlers called directly)
# ---------------------------------------------------------------------------


def _meta3(clock, rf=2):
    meta = MetaNode(replication=rf, heartbeat_timeout=1.0, clock=clock)
    for n in ("a", "b", "c"):
        meta.handle_register({"node_id": n, "host": "h", "port": 1})
    return meta


def _commit(meta, name, blocks):
    """blocks: list of (block_id, holders)."""
    meta.handle_commit({
        "name": name, "size": 128 * len(blocks), "block_size": 128,
        "blocks": [{"id": b, "offset": 128 * i, "length": 128, "crc32": 0,
                    "nodes": list(h)} for i, (b, h) in enumerate(blocks)],
    })
    for node in ("a", "b", "c"):
        held = [b for b, h in blocks if node in h]
        meta.handle_heartbeat({"node_id": node, "blocks": held})


def test_metanode_death_triggers_re_replication_commands():
    clock = FakeClock()
    meta = _meta3(clock)
    _commit(meta, "f", [("x", "ab"), ("y", "bc")])
    assert meta.replication_of("f") == [2, 2]
    clock.advance(1.5)
    for n in ("b", "c"):  # b and c keep beating; a goes silent
        meta.handle_heartbeat({"node_id": n,
                               "blocks": ["x", "y"] if n == "b" else ["y"]})
    assert meta.tick() == ["a"]
    assert meta.replication_of("f") == [1, 2]  # x lost its a-replica
    # the surviving holder of x was commanded to copy it to c
    reply = meta.handle_heartbeat({"node_id": "b", "blocks": ["x", "y"]})
    cmds = [c for c in reply["commands"] if c["op"] == CMD_REPLICATE]
    assert len(cmds) == 1 and cmds[0]["block_id"] == "x"
    assert cmds[0]["target"]["node_id"] == "c"
    # in-flight suppression: an immediate re-tick plans nothing new
    assert meta.tick() == [] and meta.stats["re_replications"] == 1
    assert not meta.handle_heartbeat(
        {"node_id": "b", "blocks": ["x", "y"]})["commands"]
    # the copy lands: c's block report restores full replication
    meta.handle_heartbeat({"node_id": "c", "blocks": ["x", "y"]})
    assert meta.replication_of("f") == [2, 2]
    assert meta.handle_state({})["under_replicated"] == 0


def test_metanode_expired_copy_command_is_replanned():
    clock = FakeClock()
    meta = _meta3(clock)
    _commit(meta, "f", [("x", "a")])  # degraded commit: one replica
    meta.tick()  # plans a->? copy
    assert meta.stats["re_replications"] == 1
    meta.tick()  # suppressed while in flight
    assert meta.stats["re_replications"] == 1
    # past the grace period with no block report: presumed failed
    clock.advance(3.5)
    for n in ("a", "b", "c"):
        meta.handle_heartbeat({"node_id": n,
                               "blocks": ["x"] if n == "a" else []})
    meta.tick()
    assert meta.stats["re_replications"] == 2


def test_metanode_lost_block_reported_not_planned():
    clock = FakeClock()
    meta = _meta3(clock)
    _commit(meta, "f", [("x", "a")])
    clock.advance(1.5)
    for n in ("b", "c"):
        meta.handle_heartbeat({"node_id": n, "blocks": []})
    meta.tick()
    assert "x" in meta.lost_blocks
    assert meta.stats["re_replications"] == 0
    assert meta.handle_state({})["lost"] == ["x"]


def test_metanode_rebalance_defers_source_drop():
    clock = FakeClock()
    meta = _meta3(clock, rf=1)
    _commit(meta, "f", [("1", "a"), ("2", "a"), ("3", "a"), ("4", "a")])
    moves = meta.rebalance()
    assert moves and all(mv.src == "a" for mv in moves)
    # re-running plans nothing new while moves are in flight
    assert meta.rebalance() == []
    # source keeps everything until a destination CONFIRMS via report
    assert not any(
        c["op"] == CMD_DROP
        for c in meta.handle_heartbeat(
            {"node_id": "a", "blocks": ["1", "2", "3", "4"]})["commands"]
        if c["op"] == CMD_DROP)
    mv = moves[0]
    meta.handle_heartbeat({"node_id": mv.dst, "blocks": [mv.block_id]})
    reply = meta.handle_heartbeat(
        {"node_id": "a", "blocks": ["1", "2", "3", "4"]})
    drops = [c for c in reply["commands"] if c["op"] == CMD_DROP]
    assert [c["block_id"] for c in drops] == [mv.block_id]


def test_metanode_delete_reclaims_blocks():
    clock = FakeClock()
    meta = _meta3(clock)
    _commit(meta, "f", [("x", "ab")])
    meta.handle_delete({"name": "f"})
    with pytest.raises(ClusterError):
        meta.handle_lookup({"name": "f"})
    for n in ("a", "b"):
        reply = meta.handle_heartbeat({"node_id": n, "blocks": ["x"]})
        assert [c["op"] for c in reply["commands"]] == [CMD_DROP]


def test_metanode_plan_put_degrades_rf_to_cluster_size():
    clock = FakeClock()
    meta = MetaNode(replication=3, heartbeat_timeout=1.0, clock=clock)
    meta.handle_register({"node_id": "a", "host": "h", "port": 1})
    plan = meta.handle_plan_put({"name": "f", "size": 100, "block_size": 64})
    assert plan["rf"] == 1
    assert [b["length"] for b in plan["blocks"]] == [64, 36]
    with pytest.raises(ClusterError):
        MetaNode(clock=clock).handle_plan_put(
            {"name": "f", "size": 1, "block_size": 1})


# ---------------------------------------------------------------------------
# SessionPool (the node-to-node transport hook in core/api.py)
# ---------------------------------------------------------------------------


def test_session_pool_reuses_and_invalidates(tmp_path):
    with XdfsServer(engine="mtedp", root=str(tmp_path)) as srv:
        with SessionPool(n_channels=2) as pool:
            a = pool.lease(srv.address)
            a.put(None, "x.bin", data=b"hello").result()
            assert pool.lease(srv.address) is a
            assert pool.stats == {"connects": 1, "reuses": 1,
                                  "stale_redials": 0}
            pool.invalidate(srv.address)
            b = pool.lease(srv.address)
            assert b is not a and pool.stats["connects"] == 2
            assert b.get_bytes("x.bin").result().data == b"hello"


def test_session_pool_replaces_broken_sessions(tmp_path):
    srv = XdfsServer(engine="mtedp", root=str(tmp_path)).start()
    pool = SessionPool(n_channels=2)
    try:
        cli = pool.lease(srv.address)
        cli.put(None, "x.bin", data=b"ok").result()
        srv.abort()  # crash: live channels severed, listener closed
        with pytest.raises(BaseException):
            cli.put(None, "y.bin", data=b"dead").result()
        assert cli.broken
        # the pool must not lease the broken session out again
        with pytest.raises(OSError):
            pool.lease(srv.address)  # re-dial hits the closed listener
        assert pool.stats["reuses"] == 0
    finally:
        pool.close()
        srv.stop()


# ---------------------------------------------------------------------------
# end-to-end cluster (real sockets, 3 data nodes)
# ---------------------------------------------------------------------------


def _cluster(tmp_path, n=3, rf=2, timeout=0.5):
    meta = MetaNode(replication=rf, heartbeat_timeout=timeout,
                    tick_interval=timeout / 5).start()
    nodes = [
        DataNode(meta.address, str(tmp_path / f"n{i}"), node_id=f"n{i}",
                 heartbeat_interval=timeout / 10).start()
        for i in range(n)
    ]
    return meta, nodes


def _await(pred, timeout=30.0, msg="condition"):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return
        time.sleep(0.05)
    raise AssertionError(f"timed out waiting for {msg}")


def test_cluster_put_get_kill_rereplicate(tmp_path):
    """The acceptance path: 3 nodes, rf=2 — a striped put spreads blocks
    across nodes, killing one node mid-session still serves a
    byte-identical get from replicas, and the failure detector drives
    re-replication until block reports show full replication again."""
    meta, nodes = _cluster(tmp_path)
    cli = ClusterClient(meta.address, block_size=128 << 10)
    try:
        data = os.urandom((2 << 20) + 4321)
        cli.put("f/big.bin", data=data)
        # block reports confirm the stripe: every node holds blocks, and
        # every block is at rf=2
        def striped():
            h = {n["node_id"]: n["blocks"] for n in cli.state()["nodes"]}
            return (len(h) == 3 and all(v > 0 for v in h.values())
                    and all(c == 2
                            for c in meta.replication_of("f/big.bin")))

        _await(striped, msg="block reports confirm the stripe")
        assert cli.get("f/big.bin") == data
        # kill a node that holds blocks, mid-session (pooled sessions open)
        nodes[0].kill()
        assert cli.get("f/big.bin") == data  # replicas serve the read
        # the detector must actually declare n0 dead (replicas on it stop
        # counting) before the heal assertion means anything
        def n0_dead():
            return not {n["node_id"]: n
                        for n in cli.state()["nodes"]}["n0"]["alive"]

        _await(n0_dead, msg="failure detection")
        # re-replication returns every block to rf=2 ON THE SURVIVORS
        # (asserted via the block-report-driven location index)
        _await(lambda: all(c >= 2 for c in meta.replication_of("f/big.bin")),
               msg="re-replication heal")
        assert cli.state()["under_replicated"] == 0
        assert cli.state()["lost"] == []
        assert cli.get("f/big.bin") == data
    finally:
        cli.close()
        for n in nodes[1:]:
            n.stop()
        meta.stop()


def test_cluster_get_fails_over_corrupt_replica(tmp_path):
    meta, nodes = _cluster(tmp_path)
    cli = ClusterClient(meta.address, block_size=64 << 10)
    try:
        data = os.urandom(256 << 10)
        cli.put("c.bin", data=data)
        # corrupt EVERY block replica on one node; CRC failover must pull
        # the intact copies from the others
        victims = list((tmp_path / "n0").glob("blk_*.bin"))
        for p in victims:
            raw = bytearray(p.read_bytes())
            raw[0] ^= 0xFF
            p.write_bytes(bytes(raw))
        assert cli.get("c.bin") == data
        if victims:  # n0 held at least one replica we corrupted
            assert cli.stats["replica_failovers"] >= 0
    finally:
        cli.close()
        for n in nodes:
            n.stop()
        meta.stop()


def test_cluster_put_survives_planned_node_dying(tmp_path):
    """A node that dies between planning and writing degrades its blocks
    (commit records the achieved replicas) instead of failing the put,
    and the tick-driven planner heals back to rf."""
    meta, nodes = _cluster(tmp_path)
    cli = ClusterClient(meta.address, block_size=64 << 10)
    try:
        nodes[2].kill()  # dead but not yet detected: plans still name it
        data = os.urandom(512 << 10)
        cli.put("d.bin", data=data)
        assert cli.stats["degraded_blocks"] > 0
        assert cli.get("d.bin") == data
        _await(lambda: all(c >= 2 for c in meta.replication_of("d.bin")),
               msg="degraded-put heal")
    finally:
        cli.close()
        for n in nodes[:2]:
            n.stop()
        meta.stop()


def test_cluster_namespace_and_empty_file(tmp_path):
    meta, nodes = _cluster(tmp_path, n=2)
    cli = ClusterClient(meta.address, block_size=64 << 10)
    try:
        cli.put("dir/a.bin", data=b"A" * 1000)
        cli.put("dir/b.bin", data=b"")
        cli.put("other.bin", data=b"B")
        assert cli.list("dir/") == ["dir/a.bin", "dir/b.bin"]
        assert cli.get("dir/b.bin") == b""
        cli.delete("dir/a.bin")
        assert cli.list("dir/") == ["dir/b.bin"]
        with pytest.raises(ClusterError):
            cli.get("dir/a.bin")
        # overwrite: new content wins
        cli.put("other.bin", data=b"CC")
        assert cli.get("other.bin") == b"CC"
    finally:
        cli.close()
        for n in nodes:
            n.stop()
        meta.stop()


def test_cluster_rebalance_e2e(tmp_path):
    """Blocks written while only one node was up spread out after new
    nodes join and the rebalancer runs; data stays intact and sources
    are only dropped after destinations confirm."""
    meta = MetaNode(replication=1, heartbeat_timeout=0.5,
                    tick_interval=0.1).start()
    n0 = DataNode(meta.address, str(tmp_path / "n0"), node_id="n0",
                  heartbeat_interval=0.05).start()
    cli = ClusterClient(meta.address, block_size=64 << 10)
    others = []
    try:
        data = os.urandom(640 << 10)  # 10 blocks, all on n0
        cli.put("r.bin", data=data)
        others = [
            DataNode(meta.address, str(tmp_path / f"n{i}"),
                     node_id=f"n{i}", heartbeat_interval=0.05).start()
            for i in (1, 2)
        ]
        _await(lambda: len(cli.state()["nodes"]) == 3, msg="nodes joined")
        # block reports must land before the planner sees n0's holdings
        _await(lambda: sum(n["blocks"] for n in cli.state()["nodes"]) == 10,
               msg="block reports")
        assert meta.rebalance()

        def balanced():
            h = {n["node_id"]: n["blocks"] for n in cli.state()["nodes"]}
            return (max(h.values()) - min(h.values()) <= 1
                    and sum(h.values()) == 10)

        _await(balanced, msg="rebalance convergence")
        assert cli.get("r.bin") == data
        assert meta.stats["rebalance_moves"] > 0
    finally:
        cli.close()
        for n in [n0, *others]:
            n.stop()
        meta.stop()


# ---------------------------------------------------------------------------
# control-plane durability + failover
# ---------------------------------------------------------------------------


def test_metanode_restart_without_journal_loses_namespace(tmp_path):
    """Regression pin for the pre-journal data-loss shape: a MetaNode
    restart with no journal_dir forgets every committed file even though
    the blocks still sit on the data nodes' disks. Kept as the contrast
    case for test_metanode_restart_with_journal_recovers below."""
    meta, nodes = _cluster(tmp_path, n=2)
    cli = ClusterClient(meta.address, block_size=64 << 10)
    port = meta.address[1]
    try:
        cli.put("gone.bin", data=b"x" * 1000)
        assert cli.get("gone.bin") == b"x" * 1000
        meta.stop()
        meta = MetaNode(replication=2, heartbeat_timeout=0.5,
                        tick_interval=0.1, port=port).start()
        cli2 = ClusterClient(meta.address, block_size=64 << 10)
        with pytest.raises(ClusterError, match="unknown file"):
            cli2.get("gone.bin")
        cli2.close()
    finally:
        cli.close()
        for n in nodes:
            n.stop()
        meta.stop()


def test_metanode_restart_with_journal_recovers(tmp_path):
    """The tentpole: kill the journaled MetaNode (no snapshot, no
    goodbye), restart it on the same port + journal dir, and every
    acknowledged commit is back — lookups serve, datanodes re-attach via
    their heartbeats, and new puts work."""
    jdir = tmp_path / "journal"
    meta = MetaNode(replication=2, heartbeat_timeout=0.5,
                    tick_interval=0.1, journal_dir=str(jdir)).start()
    port = meta.address[1]
    nodes = [
        DataNode(meta.address, str(tmp_path / f"n{i}"), node_id=f"n{i}",
                 heartbeat_interval=0.05).start()
        for i in range(2)
    ]
    cli = ClusterClient(meta.address, block_size=64 << 10)
    data = os.urandom(256 << 10)
    try:
        cli.put("kept.bin", data=data)
        meta.kill()  # crash: whatever fsync'd is all the restart gets
        meta = MetaNode(replication=2, heartbeat_timeout=0.5,
                        tick_interval=0.1, port=port,
                        journal_dir=str(jdir)).start()
        cli2 = ClusterClient(meta.address, block_size=64 << 10)
        try:
            assert meta.stats["replayed_records"] > 0
            assert cli2.get("kept.bin") == data
            # datanodes heartbeat their way back in (same node_ids were
            # replayed from the journal, so no re-register needed) and a
            # fresh put stripes normally
            _await(lambda: all(n["alive"]
                               for n in cli2.state()["nodes"]),
                   msg="datanodes re-attach after metanode restart")
            cli2.put("new.bin", data=b"n" * 100)
            assert cli2.get("new.bin") == b"n" * 100
        finally:
            cli2.close()
    finally:
        cli.close()
        for n in nodes:
            n.stop()
        meta.stop()


def test_heartbeat_unregistered_auto_reregisters(tmp_path):
    """A metanode that forgot a node (restarted with a blank namespace)
    answers its heartbeat with the `unregistered` code; the datanode
    recovers by re-registering and beating again instead of erroring
    until a human notices."""
    meta = MetaNode(replication=1, heartbeat_timeout=0.5,
                    tick_interval=0).start()
    dn = DataNode(meta.address, str(tmp_path / "n0"), node_id="n0",
                  auto_heartbeat=False).start()
    try:
        dn.heartbeat_once()
        # simulate the blank restart: forget the node server-side
        with meta._lock:
            meta.nodes.pop("n0")
            meta.detector.forget("n0")
        dn.heartbeat_once()  # would raise before the satellite fix
        assert dn.stats["reregisters"] == 1
        assert dn.stats["heartbeats"] == 2
        assert "n0" in meta.nodes
    finally:
        dn.stop()
        meta.stop()


def test_datanode_error_buffer_is_bounded(tmp_path):
    """The heartbeat loop's error list no longer grows without bound
    while the metanode is down: it is a deque(maxlen) plus a dropped
    counter."""
    meta = MetaNode(replication=1, heartbeat_timeout=0.5,
                    tick_interval=0).start()
    dn = DataNode(meta.address, str(tmp_path / "n0"), node_id="n0",
                  auto_heartbeat=False).start()
    try:
        cap = dn.errors.maxlen
        assert cap is not None and cap > 0
        for _ in range(cap + 5):
            dn._note_error(RuntimeError("x"))
        assert len(dn.errors) == cap
        assert dn.stats["errors_dropped"] == 5
        assert meta.errors.maxlen is not None  # metanode side too
    finally:
        dn.stop()
        meta.stop()


def test_epoch_fencing_discards_stale_commands():
    """A reply stamped with a lower epoch than the channel has observed
    is from a deposed leader: its command batch must be a no-op."""
    from repro.cluster import ControlChannel, EPOCH_FIELD

    ch = ControlChannel([("127.0.0.1", 1)])
    assert not ch.stale({EPOCH_FIELD: 0})  # nothing observed yet
    ch.epoch = 3
    assert ch.stale({EPOCH_FIELD: 2})
    assert not ch.stale({EPOCH_FIELD: 3})
    assert not ch.stale({})  # pre-epoch peers are never fenced
    ch.close()


def test_control_channel_io_timeout_falls_back_to_connect_timeout():
    """A hung (accepting but silent) metanode must time a control call
    out: with io_timeout unset in the policy, the channel falls back to
    connect_timeout instead of blocking forever on recv."""
    from repro.cluster import ControlChannel
    from repro.core.faults import RetriesExhausted, RetryPolicy

    lsock = socket.socket()
    lsock.bind(("127.0.0.1", 0))
    lsock.listen(1)  # backlog accepts the dial; nobody ever replies
    try:
        ch = ControlChannel(
            [lsock.getsockname()[:2]],
            policy=RetryPolicy(attempts=1, connect_timeout=0.5))
        t0 = time.monotonic()
        with pytest.raises(RetriesExhausted):
            ch.call(ClusterMsg.PING, {})
        assert time.monotonic() - t0 < 5.0
        ch.close()
    finally:
        lsock.close()


def test_standby_rejects_mutations_with_leader_hint():
    """A standby answers mutating requests with the not_leader code and
    its leader hint; PING and STATE still serve (observability)."""
    clock = FakeClock()
    meta = MetaNode(clock=clock, peers=[("127.0.0.1", 9)])
    assert meta.role == "standby"
    meta._leader_addr = ("127.0.0.1", 9)
    with pytest.raises(ClusterError) as ei:
        meta.dispatch(ClusterMsg.COMMIT, {"name": "f", "size": 0,
                                          "block_size": 1, "blocks": []})
    assert ei.value.code == wire.ERR_NOT_LEADER
    assert ei.value.hint == ("127.0.0.1", 9)
    assert meta.dispatch(ClusterMsg.PING, {})["role"] == "standby"
    assert meta.dispatch(ClusterMsg.STATE, {})["role"] == "standby"


def test_sync_serves_tail_or_snapshot(tmp_path):
    """SYNC returns the journal tail when the follower is close behind,
    a full snapshot when it is too far behind (or ahead, post-divergence),
    and replies carry the leader's epoch for fencing."""
    clock = FakeClock()
    meta = MetaNode(clock=clock, journal_dir=str(tmp_path))
    meta._assume_leadership(1)
    meta.handle_register({"node_id": "a", "host": "h", "port": 1})
    meta.handle_commit({
        "name": "f", "size": 128, "block_size": 128,
        "blocks": [{"id": "x", "offset": 0, "length": 128, "crc32": 0,
                    "nodes": ["a"]}],
    })
    reply = meta.dispatch(ClusterMsg.SYNC, {"since": 1})
    assert [r[1] for r in reply["records"]] == ["register", "commit"]
    assert reply[wire.EPOCH_FIELD] == 1
    # fully caught up: empty tail
    assert meta.dispatch(ClusterMsg.SYNC, {"since": meta.seq})["records"] == []
    # ahead of the leader (divergence): full snapshot
    assert "snapshot" in meta.dispatch(ClusterMsg.SYNC,
                                       {"since": meta.seq + 10})
    meta.journal.close()


def test_standby_applies_sync_and_promotes():
    """Fake-clock standby lifecycle: applying a SYNC reply replays the
    leader's records; when the lease expires the standby promotes with a
    bumped epoch."""
    clock = FakeClock()
    leader = MetaNode(clock=clock)
    leader._assume_leadership(1)
    leader.handle_register({"node_id": "a", "host": "h", "port": 1})
    leader.handle_commit({
        "name": "f", "size": 128, "block_size": 128,
        "blocks": [{"id": "x", "offset": 0, "length": 128, "crc32": 0,
                    "nodes": ["a"]}],
    })
    standby = MetaNode(clock=clock, peers=[("127.0.0.1", 9)],
                       lease_timeout=1.0)
    reply = leader.handle_sync({"since": 0})
    reply[wire.EPOCH_FIELD] = leader.epoch
    standby._apply_sync(reply)
    assert standby.seq == leader.seq
    assert "f" in standby.files
    assert standby.epoch == 1
    # lease expiry -> promotion past every observed epoch
    clock.advance(1.5)
    assert standby.lease.expired()
    standby.promote()
    assert standby.role == "leader"
    assert standby.epoch == 2
    assert standby.stats["promotions"] == 1


def test_client_fails_over_metanode_list(tmp_path):
    """A client created against [dead, live] metanode addresses fails
    over transparently on the first call."""
    meta = MetaNode(replication=1, heartbeat_timeout=0.5,
                    tick_interval=0.1).start()
    dn = DataNode(meta.address, str(tmp_path / "n0"), node_id="n0",
                  heartbeat_interval=0.05).start()
    # a dead address: bind+close to get a port nobody listens on
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    dead = s.getsockname()[:2]
    s.close()
    from repro.core.faults import RetryPolicy
    cli = ClusterClient([dead, meta.address],
                        block_size=64 << 10,
                        policy=RetryPolicy(attempts=3, base_delay=0.01,
                                           connect_timeout=2.0))
    try:
        cli.put("x.bin", data=b"hello")
        assert cli.get("x.bin") == b"hello"
        assert cli._ctrl.stats["failovers"] >= 1
        assert cli.meta_address == meta.address
    finally:
        cli.close()
        dn.stop()
        meta.stop()
