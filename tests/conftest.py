import importlib.util
import os
import pathlib
import sys

# Smoke tests and benches must see 1 device (dry-runs set 512 themselves,
# in their own process). Keep determinism knobs on.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# hypothesis is optional: when absent, install the tiny deterministic
# fallback so property-test modules still collect and run (weaker sampling,
# same assertions).
try:
    import hypothesis  # noqa: F401
except ImportError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis", pathlib.Path(__file__).with_name("_hypothesis_stub.py")
    )
    _stub = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_stub)
    sys.modules["hypothesis"] = _stub
    sys.modules["hypothesis.strategies"] = _stub.strategies

import jax  # noqa: E402
import pytest  # noqa: E402


# ---------------------------------------------------------------------------
# server-mode matrix: every server-side e2e test runs against BOTH the
# thread-per-session path (loop=False) and the sharded event-loop core
# (loop=True). The loop leg carries the `loopmatrix` marker so CI can
# bound tier-1 time with `-m "not loopmatrix"` if the matrix ever grows.
# ---------------------------------------------------------------------------


@pytest.fixture(params=[
    pytest.param(False, id="threads"),
    pytest.param(True, id="loop", marks=pytest.mark.loopmatrix),
])
def loop_mode(request):
    return request.param


@pytest.fixture
def xdfs_server(loop_mode):
    """Factory: builds an ``XdfsServer`` pinned to the matrix's server
    mode. Tests call it exactly like the class (``with xdfs_server(...)``)
    so assertions and error paths stay construction-identical."""
    from repro.core.api import XdfsServer

    def make(*args, **kwargs):
        kwargs.setdefault("loop", loop_mode)
        return XdfsServer(*args, **kwargs)

    return make


@pytest.fixture(scope="session")
def mesh11():
    from repro.launch.mesh import make_local_mesh

    return make_local_mesh(1, 1)


@pytest.fixture(scope="session")
def key():
    return jax.random.key(0)
