import os

# Smoke tests and benches must see 1 device (dry-runs set 512 themselves,
# in their own process). Keep determinism knobs on.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def mesh11():
    from repro.launch.mesh import make_local_mesh

    return make_local_mesh(1, 1)


@pytest.fixture(scope="session")
def key():
    return jax.random.key(0)
