import importlib.util
import os
import pathlib
import sys

# Smoke tests and benches must see 1 device (dry-runs set 512 themselves,
# in their own process). Keep determinism knobs on.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# hypothesis is optional: when absent, install the tiny deterministic
# fallback so property-test modules still collect and run (weaker sampling,
# same assertions).
try:
    import hypothesis  # noqa: F401
except ImportError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis", pathlib.Path(__file__).with_name("_hypothesis_stub.py")
    )
    _stub = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_stub)
    sys.modules["hypothesis"] = _stub
    sys.modules["hypothesis.strategies"] = _stub.strategies

import jax  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def mesh11():
    from repro.launch.mesh import make_local_mesh

    return make_local_mesh(1, 1)


@pytest.fixture(scope="session")
def key():
    return jax.random.key(0)
