"""End-to-end integration: training converges, fault recovery is bit-exact,
xDFS-channel DP step matches the pjit step."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.launch.train import train_loop


@pytest.mark.slow
def test_training_reduces_loss(mesh11, tmp_path):
    cfg = get_config("smollm-135m").smoke()
    _, losses, sup = train_loop(
        cfg, mesh11, steps=25, batch=4, seq=64, log_every=0, lr=1e-3
    )
    assert len(losses) == 25
    assert losses[-1] < losses[0] - 0.05, f"no learning: {losses[0]} -> {losses[-1]}"
    assert not sup.faults


@pytest.mark.slow
def test_fault_recovery_is_bit_exact(mesh11, tmp_path):
    """Crash-and-restore at step 15 must reproduce the uninterrupted run
    exactly (deterministic data + deterministic step)."""
    cfg = get_config("smollm-135m").smoke()
    kw = dict(steps=20, batch=2, seq=64, log_every=0, lr=1e-3)
    _, clean, _ = train_loop(cfg, mesh11, **kw)
    _, faulty, sup = train_loop(
        cfg, mesh11, ckpt_dir=str(tmp_path / "ck"), ckpt_every=10,
        inject_fault_at=15, **kw
    )
    assert len(sup.faults) == 1
    # compare the last losses (post-recovery steps replay the same stream)
    np.testing.assert_allclose(clean[-1], faulty[-1], rtol=1e-5)


@pytest.mark.slow
def test_xdfs_dp_step_matches_pjit(mesh11):
    """The shard_map + ring-channel DP step computes the same update as the
    standard pjit step on one device."""
    cfg = dataclasses.replace(get_config("smollm-135m").smoke(), fsdp=False)
    k1, losses_pjit, _ = None, None, None
    _, losses_pjit, _ = train_loop(cfg, mesh11, steps=5, batch=2, seq=32, log_every=0)
    _, losses_xdfs, _ = train_loop(
        cfg, mesh11, steps=5, batch=2, seq=32, log_every=0, use_xdfs_dp=True
    )
    np.testing.assert_allclose(losses_pjit, losses_xdfs, rtol=2e-2)
