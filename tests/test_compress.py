"""ZxDFS codec properties (hypothesis): error bounds, shape preservation."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.compress import dequantize_int8, quantize_int8, wire_bytes


@given(
    n=st.integers(1, 3000),
    scale=st.floats(1e-3, 1e3),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=80, deadline=None)
def test_quantize_roundtrip_error_bound(n, scale, seed):
    x = np.random.default_rng(seed).standard_normal(n).astype(np.float32) * scale
    z = quantize_int8(jnp.asarray(x))
    y = np.asarray(dequantize_int8(z))
    assert y.shape == x.shape
    # per-block bound: |err| <= amax/127 * 0.5 (+ rounding slack)
    for i in range(0, n, 256):
        blk = x[i : i + 256]
        err = np.abs(y[i : i + 256] - blk)
        bound = np.abs(blk).max() / 127.0 * 0.51 + 1e-7
        assert err.max() <= bound


def test_wire_bytes_halved():
    x = jnp.ones((100_000,), jnp.float32)
    z = quantize_int8(x)
    bf16_bytes = x.size * 2
    assert wire_bytes(z) < 0.6 * bf16_bytes  # int8 + scale overhead < 60%


@given(shape=st.sampled_from([(7,), (3, 5), (2, 3, 4), (256,), (1, 1)]))
@settings(max_examples=20, deadline=None)
def test_shapes_preserved(shape):
    x = jnp.arange(np.prod(shape), dtype=jnp.float32).reshape(shape)
    y = dequantize_int8(quantize_int8(x))
    assert y.shape == x.shape
