"""Multi-device numerical equivalence (subprocess with 4 host devices):
the sharded TP/SP, CP, and DP paths must produce the same loss as the
single-device reference."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses
    import jax, jax.numpy as jnp
    from repro.configs.base import get_config
    from repro.models.transformer import build_model

    B, S = 4, 64
    for arch, profile in (("llama3-8b", "tp"), ("qwen3-14b", "cp"),
                          ("olmoe-1b-7b", "tp"), ("smollm-135m", "dp")):
        cfg = get_config(arch).smoke()
        assert cfg.shard_profile == profile, arch
        toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
        batch = {"inputs": toks, "labels": toks}
        losses = {}
        for name, shape_axes in (("1dev", (1, 1)), ("2x2", (2, 2))):
            mesh = jax.make_mesh(shape_axes, ("data", "model"))
            with mesh:
                m = build_model(cfg, mesh, "train")
                params = m.init(jax.random.key(0))
                loss, _ = jax.jit(m.loss)(params, batch)
                losses[name] = float(loss)
        diff = abs(losses["1dev"] - losses["2x2"])
        assert diff < 2e-2, f"{arch}: {losses} diff={diff}"
        print(f"{arch}: 1dev={losses['1dev']:.4f} 2x2={losses['2x2']:.4f} OK")
    print("MULTIDEV_OK")
    """
)


@pytest.mark.slow
def test_sharded_paths_match_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True, text=True,
        timeout=560, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert "MULTIDEV_OK" in r.stdout, (r.stdout[-800:], r.stderr[-2000:])
