"""Supervisor FSM flows, straggler detection, elastic resharding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fsm import FSMError
from repro.runtime.fault import Supervisor, supervisor_fsm


def test_supervisor_lifecycle():
    sup = Supervisor()
    sup.start()
    assert sup.fsm.state == "running"
    with sup.checkpoint_scope():
        pass
    assert sup.fsm.state == "running"
    sup.report_fault("node lost")
    assert sup.fsm.state == "restoring"
    sup.restored()
    assert sup.fsm.state == "running"
    sup.fsm.step("stop")
    assert sup.fsm.done


def test_supervisor_rejects_illegal_flow():
    sup = Supervisor()
    with pytest.raises(FSMError):
        sup.report_fault("fault before start")  # init has no 'fault' edge


def test_checkpoint_scope_records_failure():
    sup = Supervisor()
    sup.start()
    with pytest.raises(ValueError):
        with sup.checkpoint_scope():
            raise ValueError("disk died")
    assert sup.fsm.state == "restoring"
    assert sup.faults


def test_straggler_detection():
    sup = Supervisor(straggler_factor=3.0)
    sup.start()
    for i in range(20):
        rec = sup.record_step(i, 0.1)
        assert not rec.straggler
    rec = sup.record_step(20, 1.0)  # 10x the median
    assert rec.straggler
    assert sup.stragglers == 1


def test_heartbeat_timeout():
    sup = Supervisor(heartbeat_timeout=5.0)
    sup.start()
    sup.heartbeat("w0", now=100.0)
    sup.heartbeat("w1", now=103.0)
    assert sup.dead_workers(now=104.0) == []
    assert sup.dead_workers(now=108.0) == ["w0"]


def test_elastic_reshard_roundtrip(mesh11, key):
    from repro.configs.base import get_config
    from repro.models.transformer import build_model
    from repro.optim import make_optimizer
    from repro.runtime.elastic import reshard_state
    from repro.runtime.train import init_state

    cfg = get_config("smollm-135m").smoke()
    with mesh11:
        model = build_model(cfg, mesh11, "train")
        opt = make_optimizer(cfg)
        state = init_state(model, key, opt)
        new_state, new_model = reshard_state(state, model, cfg, mesh11, opt)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(new_state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
