"""Host transfer engines: content integrity, all engines/modes, and the
paper's thread-count laws (Tables 1 and 4)."""
import os
import tempfile

import pytest

from repro.core.transfer import TransferSpec, run_transfer


@pytest.mark.parametrize("engine", ["mtedp", "mt", "mp"])
@pytest.mark.parametrize("mode", ["upload", "download"])
def test_engine_disk_roundtrip(engine, mode, tmp_path):
    data = os.urandom(3 << 20)
    src = tmp_path / "src.bin"
    dst = tmp_path / "dst.bin"
    src.write_bytes(data)
    st = run_transfer(
        TransferSpec(
            engine=engine, mode=mode, n_channels=3, size=len(data),
            src_path=str(src), dst_path=str(dst), block_size=1 << 17,
        )
    )
    assert dst.read_bytes() == data, f"{engine}/{mode} corrupted the payload"
    assert st.bytes == len(data)
    assert st.throughput_mbps > 0


@pytest.mark.parametrize("engine", ["mtedp", "mt", "mp"])
def test_engine_mem_to_mem(engine):
    st = run_transfer(TransferSpec(engine=engine, mode="upload", n_channels=2, size=8 << 20))
    assert st.throughput_mbps > 10


@pytest.mark.parametrize("n", [1, 2, 5, 8])
def test_odd_sizes_and_channels(n, tmp_path):
    """Sizes not divisible by block size or channel count."""
    data = os.urandom((1 << 20) + 12345)
    src = tmp_path / "s.bin"
    dst = tmp_path / "d.bin"
    src.write_bytes(data)
    run_transfer(
        TransferSpec(
            engine="mtedp", mode="upload", n_channels=n, size=len(data),
            src_path=str(src), dst_path=str(dst), block_size=1 << 16,
        )
    )
    assert dst.read_bytes() == data


def test_thread_count_laws():
    """Paper Table 1: T_MT = sum(n_i + 1); T_MTEDP = m. Table 4 hybrid law."""
    sessions = [3, 5, 8]  # n_i parallel channels per session
    m = len(sessions)
    t_mt = sum(n + 1 for n in sessions)
    assert t_mt == sum(sessions) + m
    t_mtedp = m
    assert t_mtedp == 3
    # Table 4: hybrid server with k xThread sessions of S_i threads
    s = [2, 4]
    k = len(s)
    t_hybrid = 3 + m + sum(si + 1 for si in s)
    assert t_hybrid == 3 + m + sum(s) + k
    # the engines embody the laws: MTEDP uses 1 thread/session, MT n+1
    from repro.core import transfer

    assert transfer.mtedp_receive.__name__ == "mtedp_receive"  # 1 event loop
