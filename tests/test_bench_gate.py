"""benchmarks/check_json.py regression-gate mode: a synthetic throughput
regression against the committed BENCH_host.json must fail the gate."""
import copy
import json
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
BASELINE = REPO / "BENCH_host.json"

sys.path.insert(0, str(REPO))  # benchmarks/ is a top-level package
from benchmarks.check_json import check, check_schema  # noqa: E402

pytestmark = pytest.mark.skipif(not BASELINE.exists(),
                                reason="no committed baseline")


def _baseline_doc() -> dict:
    return json.loads(BASELINE.read_text())


def _write(tmp_path, doc) -> str:
    p = tmp_path / "candidate.json"
    p.write_text(json.dumps(doc))
    return str(p)


def test_committed_baseline_passes_schema():
    assert check_schema(_baseline_doc()) == []


def test_identical_candidate_passes_gate(tmp_path):
    cand = _write(tmp_path, _baseline_doc())
    assert check(cand, str(BASELINE)) == []


def test_synthetic_regression_fails_gate(tmp_path):
    """The acceptance gate: >20% throughput drop in a zero-copy section
    must fail against the committed baseline."""
    doc = copy.deepcopy(_baseline_doc())
    row = doc["sections"]["zero_copy_recv"][0]
    row["mb_s"] = round(row["mb_s"] * 0.75, 1)  # a 25% regression
    errors = check(_write(tmp_path, doc), str(BASELINE))
    assert any("zero_copy_recv" in e and "regressed" in e for e in errors), (
        f"gate did not fire on a 25% regression: {errors}"
    )


def test_small_wobble_within_tolerance_passes(tmp_path):
    doc = copy.deepcopy(_baseline_doc())
    for row in doc["sections"]["zero_copy_recv"]:
        row["mb_s"] = round(row["mb_s"] * 0.9, 1)  # 10% < 20% tolerance
    assert check(_write(tmp_path, doc), str(BASELINE)) == []


def test_lost_coverage_fails_gate(tmp_path):
    """Dropping a baseline row (e.g. silently skipping a path) fails."""
    doc = copy.deepcopy(_baseline_doc())
    rows = doc["sections"]["zero_copy_recv"]
    assert len(rows) > 1
    doc["sections"]["zero_copy_recv"] = rows[1:]
    errors = check(_write(tmp_path, doc), str(BASELINE))
    assert any("lost benchmark coverage" in e for e in errors)


def test_tolerance_override_relaxes_gate(tmp_path):
    doc = copy.deepcopy(_baseline_doc())
    row = doc["sections"]["zero_copy_recv"][0]
    row["mb_s"] = round(row["mb_s"] * 0.75, 1)
    assert check(_write(tmp_path, doc), str(BASELINE), tolerance=0.5) == []


def test_batched_syscall_invariant_fails_on_lost_batching(tmp_path):
    """A batched row whose syscalls/GB creeps above 1/4 of the per-frame
    row fails even with NO baseline — losing the batching win is a bug
    regardless of absolute throughput."""
    doc = copy.deepcopy(_baseline_doc())
    rows = doc["sections"]["zero_copy_batched"]
    frame = next(r for r in rows if r["path"] == "frame")
    batched = next(r for r in rows if r["path"] != "frame")
    batched["syscalls_per_gb"] = int(frame["syscalls_per_gb"] * 0.5)
    errors = check(_write(tmp_path, doc))
    assert any("syscalls/GB" in e for e in errors), errors


def test_batched_syscall_invariant_passes_committed_baseline():
    from benchmarks.check_json import check_batched_invariant

    assert check_batched_invariant(_baseline_doc()) == []


def test_integrity_invariant_fails_on_collapsed_crc_path(tmp_path):
    """A crc_on row that keeps less than 1 - INTEGRITY_MAX_PENALTY of its
    crc_off twin's throughput fails with NO baseline — an integrity
    datapath that collapses (unmemoized combine, lost native CRC) is a
    bug regardless of absolute host speed."""
    doc = copy.deepcopy(_baseline_doc())
    row = next(r for r in doc["sections"]["integrity"]
               if r["path"] == "crc_on")
    row["gain_vs_off"] = 0.05  # the pre-fix 20x collapse
    errors = check(_write(tmp_path, doc))
    assert any("integrity" in e and "penalty" in e for e in errors), errors


def test_integrity_invariant_passes_committed_baseline():
    from benchmarks.check_json import check_integrity_invariant

    assert check_integrity_invariant(_baseline_doc()) == []
