"""CFSM conformance tests: legality, duality, and random-walk properties."""
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.fsm import FSM_BUILDERS, FSMError, Machine, dual_pairs


@pytest.mark.parametrize("name", list(FSM_BUILDERS))
def test_machines_reach_final(name):
    m = FSM_BUILDERS[name]()
    # happy path: greedily pick the first non-error event until final
    for _ in range(200):
        if m.done:
            break
        evs = [e for e in m.events_from() if e != "error"]
        # prefer events that change state forward
        assert evs, f"{name}: dead end in {m.state}"
        m.step(evs[-1])
    assert m.done or len(m.trace) == 200


@pytest.mark.parametrize("name", list(FSM_BUILDERS))
def test_illegal_event_raises(name):
    m = FSM_BUILDERS[name]()
    with pytest.raises(FSMError):
        m.step("definitely_not_an_event")


@pytest.mark.parametrize("name", list(FSM_BUILDERS))
def test_error_path_reaches_final(name):
    m = FSM_BUILDERS[name]()
    first = m.events_from()[0]
    m.step(first)
    m.step("error")
    assert m.state == "err"
    m.step("handled")
    assert m.done


def test_duality_pairs_exist():
    """Paper §4.1: server CFSM of one mode mirrors the client of the other."""
    for a, b in dual_pairs():
        ma, mb = FSM_BUILDERS[a](), FSM_BUILDERS[b]()
        # duality proxy: both machines have matching data-phase arity
        assert len(ma.states) >= 8 and len(mb.states) >= 8


@given(st.lists(st.integers(0, 5), min_size=1, max_size=60))
@settings(max_examples=200, deadline=None)
def test_random_walk_stays_in_state_space(choices):
    """Property: any legal-event walk keeps the machine inside its declared
    state set and the trace is replayable."""
    m = FSM_BUILDERS["server_upload"]()
    for c in choices:
        evs = sorted(m.events_from())
        if not evs:
            break
        m.step(evs[c % len(evs)])
        assert m.state in m.states
    # trace replay gives the same final state
    m2 = FSM_BUILDERS["server_upload"]()
    for s, e in m.trace:
        assert m2.state == s
        m2.step(e)
    assert m2.state == m.state
