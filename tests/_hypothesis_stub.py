"""Tiny deterministic fallback for ``hypothesis`` (used when the real
package is not installed — see conftest.py).

Implements just the surface this test suite uses: ``given``, ``settings``,
and the ``strategies`` constructors ``integers``, ``floats``, ``booleans``,
``binary``, ``text``, ``sampled_from``, ``lists``, ``tuples``. Each
``@given`` test runs against a fixed-seed random sample instead of
hypothesis's adaptive search — weaker, but keeps every property test
executable in minimal environments.
"""
from __future__ import annotations

import functools
import inspect
import random
import string
import types

_DEFAULT_EXAMPLES = 25
_MAX_EXAMPLES = 50  # cap: this is a smoke fallback, not a fuzzer


class _Strategy:
    def __init__(self, sample):
        self.sample = sample  # fn(rng) -> value


def integers(min_value, max_value):
    return _Strategy(lambda r: r.randint(min_value, max_value))


def floats(min_value, max_value):
    return _Strategy(lambda r: r.uniform(min_value, max_value))


def booleans():
    return _Strategy(lambda r: bool(r.getrandbits(1)))


def binary(min_size=0, max_size=20):
    return _Strategy(
        lambda r: bytes(r.getrandbits(8) for _ in range(r.randint(min_size, max_size)))
    )


def text(min_size=0, max_size=20, alphabet=string.printable):
    return _Strategy(
        lambda r: "".join(
            r.choice(alphabet) for _ in range(r.randint(min_size, max_size))
        )
    )


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda r: r.choice(elements))


def lists(elements, min_size=0, max_size=10):
    return _Strategy(
        lambda r: [elements.sample(r) for _ in range(r.randint(min_size, max_size))]
    )


def tuples(*elements):
    return _Strategy(lambda r: tuple(e.sample(r) for e in elements))


def given(*arg_strategies, **kw_strategies):
    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = min(getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES),
                    _MAX_EXAMPLES)
            rng = random.Random(0xD0DF5)
            for _ in range(n):
                pos = [s.sample(rng) for s in arg_strategies]
                named = {k: s.sample(rng) for k, s in kw_strategies.items()}
                fn(*args, *pos, **named, **kwargs)

        # pytest must only see params NOT filled by strategies (fixtures):
        # positional strategies fill the leading params, keyword strategies
        # fill by name. Everything else stays in the visible signature.
        params = list(inspect.signature(fn).parameters.values())
        leftover = [p for p in params[len(arg_strategies):]
                    if p.name not in kw_strategies]
        del wrapper.__wrapped__  # stop inspect from unwrapping to fn
        wrapper.__signature__ = inspect.Signature(leftover)
        return wrapper
    return decorate


def settings(max_examples=None, deadline=None, **_ignored):
    def decorate(fn):
        if max_examples is not None:
            fn._max_examples = max_examples
        return fn
    return decorate


strategies = types.ModuleType("hypothesis.strategies")
for _name in ("integers", "floats", "booleans", "binary", "text",
              "sampled_from", "lists", "tuples"):
    setattr(strategies, _name, globals()[_name])
