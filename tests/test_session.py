"""Persistent-session API: multi-file channel reuse (EOFR), one negotiation
per session, engine registry, FSM multi-file loop, and the amortization
claim (session reuse beats one-shot transfers for small files)."""
import os
import time

import pytest

from repro.core.api import XdfsClient, XdfsServer
from repro.core.engines import (
    Engine,
    UnknownEngineError,
    available_engines,
    get_engine,
    register_engine,
)
from repro.core.fsm import FSM_BUILDERS
from repro.core.session import SessionError
from repro.core.transfer import TransferSpec, run_transfer


def _mkfiles(d, n, base=1 << 17):
    out = []
    for i in range(n):
        data = os.urandom(base + i * 997)  # distinct odd sizes
        p = d / f"f{i}.bin"
        p.write_bytes(data)
        out.append((p, data))
    return out


@pytest.mark.parametrize("engine", ["mtedp", "mt", "mp"])
def test_multi_file_session_roundtrip(engine, tmp_path, xdfs_server):
    """>= 3 files per session, byte-exact both directions, all engines."""
    files = _mkfiles(tmp_path, 3)
    with xdfs_server(engine=engine, root=str(tmp_path / "srv")) as srv:
        with XdfsClient.connect(srv.address, n_channels=3, engine=engine,
                                block_size=1 << 16) as cli:
            ups = cli.put_many(
                [(str(p), f"up/{p.name}") for p, _ in files]
            )
            for r in ups:
                assert r.result().bytes > 0
            downs = cli.get_many(
                [(f"up/{p.name}", str(tmp_path / f"back_{p.name}"))
                 for p, _ in files]
            )
            for r in downs:
                r.result()
        srv.wait_closed_sessions(1, timeout=60)
        assert not srv.errors, srv.errors
    for p, data in files:
        assert (tmp_path / f"back_{p.name}").read_bytes() == data, \
            f"{engine} corrupted {p.name}"
    assert srv.stats["negotiations"] == 1  # ONE negotiation for 6 files
    assert srv.stats["files"] == 6


def test_put_many_reuses_channels(tmp_path, xdfs_server):
    """The acceptance claim: 8 small files over one session = exactly one
    negotiation, and every file ends with one EOFR per channel (channels
    stay open and are reused, Table 3)."""
    n_channels, n_files = 4, 8
    files = _mkfiles(tmp_path, n_files, base=1 << 15)
    with xdfs_server(engine="mtedp", root=str(tmp_path / "srv")) as srv:
        with XdfsClient.connect(srv.address, n_channels=n_channels,
                                block_size=1 << 14) as cli:
            for r in cli.put_many([(str(p), p.name) for p, _ in files]):
                r.result()
            assert cli.stats["negotiations"] == 1
            assert cli.stats["eofr_sent"] == n_files * n_channels
        srv.wait_closed_sessions(1, timeout=60)
        assert not srv.errors, srv.errors
    assert srv.stats["negotiations"] == 1
    assert srv.stats["sessions"] == 1
    assert srv.stats["eofr_frames"] == n_files * n_channels
    assert srv.stats["eoft_frames"] == 1  # exactly one: the session close
    total = sum(len(d) for _, d in files)
    assert srv.stats["bytes"] == total


def test_mp_receiver_reports_bytes(tmp_path, xdfs_server):
    """Satellite fix: forked mp children pipe byte counts to the parent."""
    files = _mkfiles(tmp_path, 2)
    with xdfs_server(engine="mp", root=str(tmp_path / "srv")) as srv:
        with XdfsClient.connect(srv.address, n_channels=2, engine="mp",
                                block_size=1 << 16) as cli:
            for r in cli.put_many([(str(p), p.name) for p, _ in files]):
                r.result()
        srv.wait_closed_sessions(1, timeout=60)
    assert srv.stats["bytes"] == sum(len(d) for _, d in files)
    assert srv.stats["eofr_frames"] == 2 * 2


def test_unknown_engine_raises_clear_error():
    with pytest.raises(UnknownEngineError, match="mtedp"):
        get_engine("warp-drive")
    with pytest.raises(UnknownEngineError):
        XdfsServer(engine="nope")
    with pytest.raises(UnknownEngineError):
        XdfsClient.connect(("127.0.0.1", 1), engine="nope")
    assert {"mtedp", "mt", "mp"} <= set(available_engines())


def test_register_custom_engine():
    """Third-party engines plug into the same dispatch path."""
    base = get_engine("mtedp")
    register_engine(Engine("custom-mtedp", base.receive, base.send, "alias"))
    try:
        assert get_engine("custom-mtedp").receive is base.receive
        assert "custom-mtedp" in available_engines()
    finally:
        import repro.core.engines.registry as reg
        reg._REGISTRY.pop("custom-mtedp", None)


def test_get_missing_file_keeps_session_alive(tmp_path, xdfs_server):
    """A bad request raises on ITS future; the session keeps serving."""
    files = _mkfiles(tmp_path, 1)
    with xdfs_server(root=str(tmp_path / "srv")) as srv:
        with XdfsClient.connect(srv.address, n_channels=2) as cli:
            bad = cli.get("does/not/exist.bin", str(tmp_path / "x"))
            with pytest.raises(SessionError):
                bad.result()
            p, data = files[0]
            cli.put(str(p), "ok.bin").result()
            back = cli.get_bytes("ok.bin").result().data
            assert back == data


def test_path_escape_rejected(tmp_path, xdfs_server):
    with xdfs_server(root=str(tmp_path / "jail")) as srv:
        with XdfsClient.connect(srv.address, n_channels=1) as cli:
            res = cli.put(None, "../escape.bin", data=b"x" * 64)
            with pytest.raises(SessionError, match="escape"):
                res.result()
    assert not (tmp_path / "escape.bin").exists()


def test_concurrent_sessions_one_server(tmp_path, xdfs_server):
    """The persistent server demuxes interleaved channels of many sessions."""
    files = _mkfiles(tmp_path, 2)
    with xdfs_server(root=str(tmp_path / "srv")) as srv:
        clients = [XdfsClient.connect(srv.address, n_channels=2)
                   for _ in range(3)]
        try:
            futs = [c.put(str(files[0][0]), f"c{i}.bin")
                    for i, c in enumerate(clients)]
            for f in futs:
                f.result()
        finally:
            for c in clients:
                c.close()
        srv.wait_closed_sessions(3, timeout=60)
        assert not srv.errors, srv.errors
    assert srv.stats["sessions"] == 3
    assert srv.stats["negotiations"] == 3
    for i in range(3):
        assert (tmp_path / "srv" / f"c{i}.bin").read_bytes() == files[0][1]


def test_fsm_multi_file_loop_conformance():
    """The extended server-upload CFSM loops 9_open_file -> ... ->
    13_flush --eofr_flush--> 9_open_file per file, then ends on EOFT."""
    m = FSM_BUILDERS["server_upload"]()
    for ev in ("conn", "auth_ok", "ftsm", "params_ok", "new_session",
               "registered", "all_channels"):
        m.step(ev)
    for _ in range(3):  # three files over the same channels
        m.step("opened")
        m.step("read_ready"); m.step("block"); m.step("buffered")
        m.step("read_ready"); m.step("eof_header"); m.step("all_eof")
        m.step("eofr_flush")
        assert m.state == "9_open_file"
    m.step("eoft")
    assert m.done


def test_session_reuse_beats_oneshot(tmp_path):
    """Acceptance benchmark, test-sized: 8 small files through ONE session
    must beat 8 one-shot run_transfer calls (each pays fork + negotiation
    + teardown) on wall-clock."""
    n_files = 8
    files = _mkfiles(tmp_path, n_files, base=1 << 16)

    t0 = time.perf_counter()
    with XdfsServer(engine="mtedp", root=str(tmp_path / "srv")) as srv:
        with XdfsClient.connect(srv.address, n_channels=4,
                                block_size=1 << 16) as cli:
            for r in cli.put_many([(str(p), p.name) for p, _ in files]):
                r.result()
    t_session = time.perf_counter() - t0

    t0 = time.perf_counter()
    for p, data in files:
        run_transfer(TransferSpec(
            engine="mtedp", mode="upload", n_channels=4, size=len(data),
            src_path=str(p), dst_path=str(tmp_path / "one.bin"),
            block_size=1 << 16,
        ))
    t_oneshot = time.perf_counter() - t0

    assert t_session < t_oneshot, (
        f"session reuse ({t_session:.3f}s) should beat "
        f"{n_files}x one-shot ({t_oneshot:.3f}s)"
    )
