"""Zero-copy send datapath: mmap sources, scatter-gather frames, sendfile,
negotiated socket tuning, and the receiver-livelock guards."""
import os
import socket
import threading

import pytest

from repro.core.api import XdfsClient, XdfsServer
from repro.core.engines.base import (
    FrameBuilder,
    Sink,
    Source,
    advance_iovec,
    recv_exact,
    sendmsg_all,
)
from repro.core.engines.mt import mt_receive, worker_send
from repro.core.engines.mtedp import mtedp_receive
from repro.core.header import (
    HEADER_SIZE,
    ChannelEvent,
    ChannelHeader,
    Negotiation,
)
from repro.core.session import SocketTuning

SESSION = b"0123456789abcdef"


# ---------------------------------------------------------------------------
# Source: mmap mode
# ---------------------------------------------------------------------------


def test_block_view_matches_pread(tmp_path):
    """mmap-backed block views are byte-identical to the pread path, odd
    tail block included."""
    data = os.urandom((1 << 18) + 3333)
    p = tmp_path / "src.bin"
    p.write_bytes(data)
    mm = Source(str(p), len(data), 1 << 16)
    pr = Source(str(p), len(data), 1 << 16, use_mmap=False)
    assert mm._map_view is not None, "mmap mode did not engage"
    assert pr._map_view is None
    try:
        for i in range(mm.n_blocks):
            off = i * mm.block_size
            want = data[off : off + mm.block_len(i)]
            assert bytes(mm.block_view(i)) == want
            assert bytes(pr.read_block(i)) == want
    finally:
        mm.close()
        pr.close()


def test_block_view_zero_copy_for_mem_and_zeros():
    data = os.urandom(1 << 16)
    mem = Source(None, len(data), 1 << 14, data=data)
    assert bytes(mem.block_view(1)) == data[1 << 14 : 2 << 14]
    zeros = Source(None, 1 << 15, 1 << 14)
    assert bytes(zeros.block_view(0)) == bytes(1 << 14)
    mem.close()
    zeros.close()


def test_file_send_materializes_nothing(tmp_path):
    """The acceptance gate: no per-block heap copy on the file-backed send
    path, for both the event-driven (mtedp) and worker (mt) senders."""
    data = os.urandom((1 << 20) + 4097)
    src = tmp_path / "in.bin"
    src.write_bytes(data)
    for engine in ("mtedp", "mt"):
        with XdfsServer(engine=engine, root=str(tmp_path / f"srv_{engine}")) as srv:
            Source.materializations = 0
            with XdfsClient.connect(srv.address, n_channels=3, engine=engine,
                                    block_size=1 << 16) as cli:
                cli.put(str(src), "out.bin").result()
            assert Source.materializations == 0, (
                f"{engine}: file-backed send path materialized a heap copy"
            )
            srv.wait_closed_sessions(1, timeout=60)
        got = (tmp_path / f"srv_{engine}" / "out.bin").read_bytes()
        assert got == data


def test_read_block_counts_materializations(tmp_path):
    """Control for the test above: the legacy copy path IS counted."""
    p = tmp_path / "f.bin"
    p.write_bytes(os.urandom(1 << 16))
    s = Source(str(p), 1 << 16, 1 << 14, use_mmap=False)
    before = Source.materializations
    s.read_block(0)
    s.block_view(1)  # pread fallback without a map also materializes
    assert Source.materializations == before + 2
    s.close()


# ---------------------------------------------------------------------------
# scatter-gather framing and partial-send resumption
# ---------------------------------------------------------------------------


def test_advance_iovec_reslices():
    a, b = memoryview(bytes(range(10))), memoryview(bytes(range(10, 16)))
    iov = advance_iovec([a, b], 4)
    assert [bytes(v) for v in iov] == [bytes(range(4, 10)), bytes(range(10, 16))]
    iov = advance_iovec(iov, 6)
    assert [bytes(v) for v in iov] == [bytes(range(10, 16))]
    assert advance_iovec(iov, 6) == []


def _parse_frames(raw: bytes, size: int):
    """Reassemble a framed stream back into the original payload."""
    out = bytearray(size)
    pos = 0
    while pos < len(raw):
        hdr = ChannelHeader.unpack(raw[pos : pos + HEADER_SIZE])
        pos += HEADER_SIZE
        if hdr.event in (ChannelEvent.EOFR, ChannelEvent.EOFT):
            continue
        out[hdr.offset : hdr.offset + hdr.length] = raw[pos : pos + hdr.length]
        pos += hdr.length
    return bytes(out)


def test_sendmsg_partial_resumption_small_sndbuf():
    """A tiny SO_SNDBUF forces partial sendmsg returns; the iovec re-slice
    must still deliver every frame intact."""
    a, b = socket.socketpair()
    a.setsockopt(socket.SOL_SOCKET, socket.SO_SNDBUF, 4096)
    size = (1 << 19) + 777
    payload = os.urandom(size)
    src = Source(None, size, 1 << 16, data=payload)
    frames = FrameBuilder(SESSION, 1)
    total = src.n_blocks * HEADER_SIZE + size
    chunks = []

    def drain():
        got = 0
        while got < total:
            c = b.recv(1 << 16)
            assert c, "sender closed early"
            chunks.append(c)
            got += len(c)

    rx = threading.Thread(target=drain)
    rx.start()
    for i in range(src.n_blocks):
        ln = src.block_len(i)
        sent = sendmsg_all(a, [
            frames.header(0, ChannelEvent.xFTSMU, i * src.block_size, ln),
            src.block_view(i),
        ])
        assert sent == HEADER_SIZE + ln
    rx.join()
    src.close()
    a.close()
    b.close()
    assert _parse_frames(b"".join(chunks), size) == payload


def test_event_send_partial_resumption_via_tuned_session(tmp_path):
    """End-to-end: a session negotiated with tiny socket buffers forces the
    nonblocking event_send through its partial-iovec path; content must
    survive, and the tuning must reach the server."""
    data = os.urandom((1 << 20) + 1234)
    src = tmp_path / "in.bin"
    src.write_bytes(data)
    tuning = SocketTuning(sndbuf=8192, rcvbuf=8192)
    with XdfsServer(engine="mtedp", root=str(tmp_path / "srv")) as srv:
        with XdfsClient.connect(srv.address, n_channels=2, block_size=1 << 16,
                                tuning=tuning) as cli:
            cli.put(str(src), "out.bin").result()
            sndbuf = cli.socks[1].getsockopt(socket.SOL_SOCKET,
                                             socket.SO_SNDBUF)
            assert sndbuf >= 8192  # kernels round up/double, never shrink
        srv.wait_closed_sessions(1, timeout=60)
        assert srv.last_tuning == tuning
    assert (tmp_path / "srv" / "out.bin").read_bytes() == data


# ---------------------------------------------------------------------------
# sendfile fast path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("allow_sendfile", [True, False])
def test_sendfile_and_generic_paths_identical_sinks(tmp_path, allow_sendfile):
    """worker_send with and without the sendfile fast path must produce
    byte-identical sinks."""
    data = os.urandom((1 << 19) + 12345)
    srcp = tmp_path / "src.bin"
    srcp.write_bytes(data)
    dstp = tmp_path / f"dst_{allow_sendfile}.bin"
    pairs = [socket.socketpair() for _ in range(2)]
    sink = Sink(str(dstp), len(data))
    stats = {}

    def rx():
        stats["st"] = mt_receive([b for _, b in pairs], sink, 1 << 16)

    t = threading.Thread(target=rx)
    t.start()
    source = Source(str(srcp), len(data), 1 << 16)
    worker_send([a for a, _ in pairs], source, SESSION, use_processes=False,
                allow_sendfile=allow_sendfile)
    t.join()
    source.close()
    sink.close()
    for a, b in pairs:
        a.close()
        b.close()
    assert stats["st"].bytes == len(data)
    assert dstp.read_bytes() == data


# ---------------------------------------------------------------------------
# socket tuning negotiation
# ---------------------------------------------------------------------------


def test_negotiation_carries_tuning_roundtrip():
    neg = Negotiation(SESSION, 4, 1 << 20, 1 << 20, "r", "l",
                      so_sndbuf=123456, so_rcvbuf=654321, so_nodelay=False,
                      batch_frames=16)
    back = Negotiation.unpack(neg.pack())
    assert back == neg
    from repro.core.session import SocketTuning

    assert SocketTuning.from_negotiation(back) == SocketTuning(
        nodelay=False, sndbuf=123456, rcvbuf=654321)
    # pre-durability blobs (no trailing policy byte) default to none
    pre_dur = Negotiation.unpack(neg.pack()[:-1])
    assert pre_dur.durability == 0
    assert pre_dur.batch_frames == 16 and pre_dur.so_nodelay is False
    # pre-integrity blobs (no trailing flag byte) mean no CRC trailers
    pre_crc = Negotiation.unpack(neg.pack()[:-2])
    assert pre_crc.integrity is False
    assert pre_crc.batch_frames == 16 and pre_crc.so_nodelay is False
    # pre-batching blobs (no <H batch tail) default to the per-frame path
    pre_batch = Negotiation.unpack(neg.pack()[:-4])
    assert pre_batch.batch_frames == 1
    assert pre_batch.so_sndbuf == 123456 and pre_batch.so_nodelay is False
    # blobs without the nodelay byte parse with nodelay defaulting on
    mid = Negotiation.unpack(neg.pack()[:-5])
    assert mid.so_sndbuf == 123456 and mid.so_nodelay is True
    # v1 blobs without any tuning tail still parse (defaults 0 / on / 1)
    legacy = Negotiation.unpack(neg.pack()[:-13])
    assert legacy.so_sndbuf == 0 and legacy.so_rcvbuf == 0
    assert legacy.so_nodelay is True and legacy.batch_frames == 1
    assert legacy.n_channels == 4
    # a wire value of 0 means "no batching", not a zero-depth batch
    zeroed = Negotiation.unpack(neg.pack()[:-4] + b"\x00\x00")
    assert zeroed.batch_frames == 1


def test_tuning_applies_to_socket():
    a, b = socket.socketpair()
    SocketTuning(nodelay=False, sndbuf=32768, rcvbuf=32768).apply(a)
    assert a.getsockopt(socket.SOL_SOCKET, socket.SO_SNDBUF) >= 32768
    assert a.getsockopt(socket.SOL_SOCKET, socket.SO_RCVBUF) >= 32768
    a.close()
    b.close()


def test_mtedp_receive_rejects_oversize_frame():
    """The event-loop receiver classifies oversize frames as ProtocolError,
    like its sibling engines."""
    from repro.core.header import ProtocolError

    a, b = socket.socketpair()
    sink = Sink(None, 1 << 16)
    bad = ChannelHeader(ChannelEvent.xFTSMU, SESSION, 0, 0, 1 << 20)
    threading.Thread(target=lambda: a.sendall(bad.pack()), daemon=True).start()
    try:
        with pytest.raises(ProtocolError, match="exceeds negotiated"):
            mtedp_receive([b], sink, 1 << 16, conformance=False)
    finally:
        sink.close()
        a.close()
        b.close()


def test_get_with_many_channels_pool_sized_up(tmp_path):
    """The client receive pool must outgrow any channel count (livelock
    guard holds for n_channels >= 32)."""
    data = os.urandom(1 << 18)
    with XdfsServer(engine="mtedp", root=str(tmp_path),
                    pool_slots=40) as srv:
        with XdfsClient.connect(srv.address, n_channels=33,
                                block_size=1 << 14) as cli:
            cli.put(None, "big.bin", data=data).result()
            assert cli.get_bytes("big.bin").result().data == data
        srv.wait_closed_sessions(1, timeout=60)
        assert not srv.errors, srv.errors


def test_worker_send_thread_mode_propagates_errors(tmp_path):
    """A dead channel must fail the transfer, not return success (mirror
    of the fork path's exit-code check)."""
    data = os.urandom(1 << 18)
    p = tmp_path / "src.bin"
    p.write_bytes(data)
    a, b = socket.socketpair()
    b.close()  # receiver gone before the first frame
    source = Source(str(p), len(data), 1 << 14)
    try:
        with pytest.raises((ConnectionError, OSError)):
            worker_send([a], source, SESSION, use_processes=False)
    finally:
        source.close()
        a.close()


def test_mt_receive_propagates_channel_errors():
    """An oversize frame must surface as a ProtocolError in the caller, not
    die silently inside the channel thread (which would truncate or hang)."""
    from repro.core.header import ProtocolError

    a, b = socket.socketpair()
    sink = Sink(None, 1 << 16)
    bad = ChannelHeader(ChannelEvent.xFTSMU, SESSION, 0, 0, 1 << 20)
    threading.Thread(target=lambda: a.sendall(bad.pack()), daemon=True).start()
    try:
        with pytest.raises(ProtocolError, match="exceeds negotiated"):
            mt_receive([b], sink, 1 << 16)
    finally:
        sink.close()
        a.close()
        b.close()


# ---------------------------------------------------------------------------
# receiver livelock guards
# ---------------------------------------------------------------------------


def test_pool_slots_must_exceed_channels():
    pairs = [socket.socketpair() for _ in range(4)]
    sink = Sink(None, 0)
    try:
        with pytest.raises(ValueError, match="pool_slots"):
            mtedp_receive([a for a, _ in pairs], sink, 1 << 16,
                          pool_slots=4, conformance=False)
    finally:
        sink.close()
        for a, b in pairs:
            a.close()
            b.close()


def test_session_rejects_livelock_prone_pool(tmp_path):
    """A session whose pool could livelock is refused at setup."""
    with XdfsServer(engine="mtedp", root=str(tmp_path), pool_slots=2) as srv:
        with pytest.raises(Exception):
            with XdfsClient.connect(srv.address, n_channels=4,
                                    block_size=1 << 16) as cli:
                cli.put(None, None, size=1 << 16).result(timeout=30)
        srv.wait_closed_sessions(1, timeout=60)
        assert any("pool_slots" in str(e) for e in srv.errors)
