"""Per-kernel allclose sweeps against pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.quant_channel.ops import roundtrip
from repro.kernels.quant_channel.ref import roundtrip_ref
from repro.kernels.rglru_scan.ops import rglru_scan
from repro.kernels.rglru_scan.ref import linear_scan_ref

FLASH_CASES = [
    # (B, S, Hq, Hkv, D, window, cap, dtype)
    (2, 256, 4, 2, 64, None, None, jnp.float32),
    (1, 128, 4, 4, 32, 64, None, jnp.float32),
    (2, 192, 8, 2, 64, None, 50.0, jnp.float32),
    (1, 100, 2, 1, 64, 32, 30.0, jnp.float32),
    (1, 256, 2, 2, 128, None, None, jnp.bfloat16),
    (2, 64, 3, 3, 64, 16, None, jnp.bfloat16),
]


@pytest.mark.parametrize("case", FLASH_CASES, ids=str)
def test_flash_attention_matches_ref(case, key):
    b, s, hq, hkv, d, window, cap, dtype = case
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, hq, d), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, d), dtype)
    out = flash_attention(
        q, k, v, scale=d**-0.5, window=window, logit_cap=cap,
        block_q=64, block_k=64, interpret=True,
    )
    kr = jnp.repeat(k, hq // hkv, 2)
    vr = jnp.repeat(v, hq // hkv, 2)
    ref = attention_ref(
        q.transpose(0, 2, 1, 3), kr.transpose(0, 2, 1, 3), vr.transpose(0, 2, 1, 3),
        scale=d**-0.5, window=window, logit_cap=cap,
    ).transpose(0, 2, 1, 3)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("n", [17, 256, 1000, 4096])
@pytest.mark.parametrize("scale", [0.1, 10.0])
def test_quant_channel_matches_ref(n, scale, key):
    x = jax.random.normal(key, (n,)) * scale
    out = roundtrip(x, interpret=True)
    ref = roundtrip_ref(x)
    # bit-identical up to f32 association order (scale division vs multiply)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)
    # quantization error bound: per-block amax/127 half-step
    assert float(jnp.max(jnp.abs(out - x))) <= float(jnp.max(jnp.abs(x))) / 127.0 + 1e-6


@pytest.mark.parametrize("shape", [(1, 64, 128), (2, 300, 160), (3, 128, 256)])
def test_rglru_scan_matches_ref(shape, key):
    b, t, c = shape
    ks = jax.random.split(key, 3)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], shape))
    bx = jax.random.normal(ks[1], shape)
    h0 = jax.random.normal(ks[2], (b, c))
    h_all, h_last = rglru_scan(a, bx, h0, interpret=True)
    ref_all, ref_last = linear_scan_ref(a, bx, h0)
    np.testing.assert_allclose(np.asarray(h_all), np.asarray(ref_all), atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(ref_last), atol=1e-5)
