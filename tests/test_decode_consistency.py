"""Decode-vs-prefill logit consistency: prefill(S)+decode(token S) must match
prefill(S+1)'s last logits to bf16 cache tolerance, for every family."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config, list_configs
from repro.models.transformer import build_model

B, S = 2, 64


@pytest.mark.parametrize("arch", list(list_configs()))
def test_decode_matches_prefill(arch, mesh11, key):
    cfg = get_config(arch).smoke()
    if cfg.moe:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no drops
    with mesh11:
        m = build_model(cfg, mesh11, "prefill")
        params = m.init(key)
        if cfg.frontend:
            toks = jax.random.normal(key, (B, S + 1, cfg.d_model), jnp.bfloat16)
        else:
            toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
        ref, _ = jax.jit(m.prefill)(params, {"inputs": toks})
        _, caches = jax.jit(m.prefill)(params, {"inputs": toks[:, :S]})
        md = build_model(cfg, mesh11, "decode")
        dl, _ = jax.jit(md.decode_step)(
            params, {"inputs": toks[:, S : S + 1], "caches": caches, "pos": jnp.int32(S)}
        )
        err = float(jnp.max(jnp.abs(ref[:, 0] - dl[:, 0])))
        assert err < 0.25, f"{arch}: decode/prefill logit divergence {err}"
