"""System-level behaviour: the paper's full story in one test each —
file transfer session over the MTEDP engine with protocol conformance,
checkpoint-restore-serve round trip, and optimizer sanity."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.transfer import TransferSpec, run_transfer
from repro.models.transformer import build_model
from repro.optim import Adafactor, AdamW


def test_xdfs_session_end_to_end(tmp_path):
    """A 16 MiB disk-to-disk xDFS session: MTEDP engine, 4 channels, FSM
    conformance enforced inside the engine (any illegal transition raises)."""
    data = os.urandom(16 << 20)
    src, dst = tmp_path / "a", tmp_path / "b"
    src.write_bytes(data)
    st = run_transfer(
        TransferSpec(
            engine="mtedp", mode="upload", n_channels=4, size=len(data),
            src_path=str(src), dst_path=str(dst),
        )
    )
    assert dst.read_bytes() == data
    assert st.writev_calls >= 1  # vectored I/O actually used
    assert st.throughput_mbps > 50


def test_checkpoint_then_serve(mesh11, tmp_path, key):
    """Train-state params checkpointed via xDFS save, restored, and served:
    logits identical to the original params."""
    from repro.checkpoint import xdfs_ckpt

    cfg = get_config("smollm-135m").smoke()
    with mesh11:
        model = build_model(cfg, mesh11, "prefill")
        params = model.init(key)
        toks = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)
        ref, _ = jax.jit(model.prefill)(params, {"inputs": toks})
        xdfs_ckpt.save(params, str(tmp_path), step=0)
        like = jax.eval_shape(lambda: params)
        restored, _ = xdfs_ckpt.restore(str(tmp_path), like)
        out, _ = jax.jit(model.prefill)(restored, {"inputs": toks})
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))


@pytest.mark.parametrize("opt_cls", [AdamW, Adafactor])
def test_optimizers_minimize_quadratic(opt_cls):
    opt = opt_cls(lr=0.1)
    params = {"w": jnp.ones((8, 4)) * 3.0}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(60):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = jax.tree.map(lambda p, u: p + u, params, upd)
    assert float(loss(params)) < 1.0


def test_adafactor_memory_is_sublinear():
    """The reason arctic-480b uses Adafactor: slot bytes << AdamW's 2x f32."""
    p = {"w": jnp.zeros((1024, 512), jnp.bfloat16)}
    af = Adafactor().init(p)
    aw = AdamW().init(p)
    af_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(af.slots))
    aw_bytes = sum(
        x.size * x.dtype.itemsize for x in jax.tree.leaves((aw.m, aw.v))
    )
    assert af_bytes < aw_bytes / 100
