"""Data-at-rest durability matrix: crash-consistent commits, the
negotiated fsync policy, scrub-and-repair, and disk-full degradation.

Three layers under test:

* the **commit contract** — under the ``atomic`` policy an acked put is
  fully on disk under its final name (temp + fsync + rename + dir fsync
  BEFORE the ACK), an aborted one leaves the previous version untouched,
  and a successful integrity put persists its CRC manifest as the
  at-rest truth;
* the **scrub-and-repair loop** — a rate-limited
  :class:`~repro.cluster.scrub.Scrubber` re-reads blocks against their
  manifests, condemned replicas leave the block report, and the
  MetaNode drops + re-replicates them back to full ``rf``;
* **degradation under disk pressure** — a full store refuses puts with
  the typed ``disk_full`` kind (session survives), heartbeats advertise
  free space, placement avoids nearly-full nodes, and the client
  re-plans around refusals.

Select with ``-m durability`` (the CI fault-matrix job runs
``fault or chaos or durability``).
"""
import os
import time
from concurrent.futures import Future

import pytest

from repro.cluster import ClusterClient, DataNode, MetaNode
from repro.cluster.scrub import Scrubber
from repro.core.api import SessionPool, XdfsClient
from repro.core.engines.base import (
    DURABILITY_ATOMIC,
    DURABILITY_FSYNC,
    DURABILITY_NONE,
    Sink,
    TMP_INFIX,
    durability_byte,
    store_free_bytes,
)
from repro.core.faults import (
    ChaosHarness,
    RetryPolicy,
    inject_bit_rot,
    simulate_power_loss,
    write_ballast,
)
from repro.core.header import Negotiation, new_session_id
from repro.core.resume import (
    MANIFEST_SUFFIX,
    ManifestSidecar,
    ResumeSidecar,
    sweep_sidecars,
)
from repro.core.session import BusyError, DiskFullError, SessionError

pytestmark = pytest.mark.durability

T = 0.5  # heartbeat timeout driving the cluster scenarios


def _await(pred, timeout=30.0, msg="condition"):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return
        time.sleep(0.05)
    raise AssertionError(f"timed out waiting for {msg}")


def _deep_policy():
    return RetryPolicy(attempts=8, base_delay=0.05, max_delay=0.5,
                       connect_timeout=2.0, io_timeout=5.0)


def _no_temps(root):
    return not [p for p in os.listdir(str(root)) if TMP_INFIX in p]


# ---------------------------------------------------------------------------
# policy negotiation + Sink commit contract
# ---------------------------------------------------------------------------


def test_durability_byte_normalizes_names_and_bytes():
    assert durability_byte("none") == DURABILITY_NONE == 0
    assert durability_byte("fsync") == DURABILITY_FSYNC == 1
    assert durability_byte("atomic") == DURABILITY_ATOMIC == 2
    assert durability_byte(1) == 1
    with pytest.raises(ValueError):
        durability_byte("paranoid")
    with pytest.raises(ValueError):
        durability_byte(7)


def test_negotiation_durability_tail_optional():
    """The durability byte is the final Negotiation tail: present blobs
    roundtrip it, pre-durability blobs (one byte shorter) decode as 0."""
    neg = Negotiation(new_session_id(), 2, 1 << 16, 1 << 20, "r", "l",
                      durability=DURABILITY_ATOMIC)
    blob = neg.pack()
    assert Negotiation.unpack(blob).durability == DURABILITY_ATOMIC
    legacy = Negotiation.unpack(blob[:-1])  # sender predates the tail
    assert legacy.durability == DURABILITY_NONE
    assert legacy.integrity == neg.integrity


def test_sink_atomic_commit_replaces_previous_version(tmp_path):
    p = tmp_path / "f.bin"
    p.write_bytes(b"old-version")
    # aborted transfer: close without commit discards the temp and the
    # previous complete version survives untouched
    sink = Sink(str(p), 5, durability="atomic")
    sink.write_at(0, b"hello")
    sink.close()
    assert p.read_bytes() == b"old-version"
    assert _no_temps(tmp_path)
    # committed transfer: temp fsynced and renamed over the final path
    sink = Sink(str(p), 5, durability="atomic")
    sink.write_at(0, b"hello")
    sink.commit()
    sink.close()
    assert p.read_bytes() == b"hello"
    assert _no_temps(tmp_path)


def test_put_atomic_leaves_manifest_and_no_temp(xdfs_server, tmp_path):
    """An atomic integrity put commits before the ACK: once the future
    resolves the file is final-named, temp-free, and its CRC manifest
    sidecar verifies against the bytes on disk (both server modes)."""
    data = os.urandom((1 << 17) + 313)
    root = tmp_path / "srv"
    with xdfs_server(engine="mtedp", root=str(root),
                     durability="atomic") as srv:
        with XdfsClient.connect(srv.address, n_channels=2,
                                block_size=1 << 15, integrity=True,
                                durability="atomic") as cli:
            cli.put(None, "x.bin", data=data).result()
            assert (root / "x.bin").read_bytes() == data
            assert _no_temps(root)
            loaded = ManifestSidecar(str(root / "x.bin")).load_any()
            assert loaded is not None and loaded[0] == len(data)
            assert Scrubber(str(root)).verify_file(str(root / "x.bin"))
        srv.wait_closed_sessions(1, timeout=60)
        assert not srv.errors, srv.errors


def test_client_floor_negotiation_stronger_wins(tmp_path):
    """A client requesting atomic against a no-floor server still gets
    the atomic commit (MAX of request and floor) — observable as a
    same-path overwrite that never exposes a torn file."""
    data = os.urandom(1 << 16)
    root = tmp_path / "srv"
    from repro.core.api import XdfsServer

    with XdfsServer(engine="mt", root=str(root)) as srv:
        with XdfsClient.connect(srv.address, n_channels=2,
                                block_size=1 << 14, integrity=True,
                                durability="atomic") as cli:
            cli.put(None, "x.bin", data=data).result()
            assert (root / "x.bin").read_bytes() == data
            assert _no_temps(root)
            assert ManifestSidecar(str(root / "x.bin")).load_any() is not None


def test_resume_put_on_atomic_server_keeps_file_intact(tmp_path):
    """Resume-mode puts degrade atomic -> fsync (hole-filling re-puts
    are incompatible with temp+rename): a no-op resume re-put of an
    already-complete file must NOT replace it with a sparse temp."""
    data = os.urandom((1 << 16) + 77)
    root = tmp_path / "srv"
    from repro.core.api import XdfsServer

    with XdfsServer(engine="mtedp", root=str(root),
                    durability="atomic") as srv:
        with XdfsClient.connect(srv.address, n_channels=2,
                                block_size=1 << 14, integrity=True) as cli:
            cli.put(None, "x.bin", data=data).result()
            cli.put(None, "x.bin", data=data, resume=True).result()
        assert (root / "x.bin").read_bytes() == data
        assert _no_temps(root)


# ---------------------------------------------------------------------------
# sidecar hygiene
# ---------------------------------------------------------------------------


def test_sweep_sidecars_gcs_orphans_and_temps(tmp_path):
    from repro.core.integrity import CrcManifest

    live = tmp_path / "live.bin"
    live.write_bytes(b"data")
    manifest = CrcManifest()
    manifest.add(0, 4, 123)
    ManifestSidecar(str(live)).save(4, 4, manifest)
    (tmp_path / f"gone.bin{MANIFEST_SUFFIX}").write_bytes(b"{}")
    (tmp_path / "gone2.bin.xdfs-resume").write_bytes(b"{}")
    (tmp_path / f"part.bin{TMP_INFIX}123").write_bytes(b"junk")
    removed = sweep_sidecars(str(tmp_path))
    assert len(removed) == 3
    assert live.exists()
    assert ManifestSidecar(str(live)).load_any() is not None
    assert _no_temps(tmp_path)


def test_delete_gcs_both_sidecars(tmp_path):
    """A datanode drop removes the block AND its transfer state — a
    dangling manifest would make the scrubber report it missing forever."""
    meta = MetaNode(replication=1, heartbeat_timeout=T,
                    tick_interval=0.1).start()
    node = DataNode(meta.address, str(tmp_path / "n0"), node_id="n0",
                    heartbeat_interval=0.05).start()
    cli = ClusterClient(meta.address, block_size=32 << 10,
                        policy=_deep_policy())
    try:
        cli.put("f.bin", data=os.urandom(48 << 10))
        store = tmp_path / "n0"
        blks = list(store.glob("blk_*.bin"))
        assert blks and all(
            ManifestSidecar(str(b)).load_any() is not None for b in blks)
        cli.delete("f.bin")
        _await(lambda: not list(store.glob("blk_*.bin")),
               msg="blocks dropped")
        _await(lambda: not list(store.glob(f"*{MANIFEST_SUFFIX}")),
               msg="manifest sidecars dropped")
        assert node.scrub_once().missing == []
    finally:
        cli.close()
        node.stop()
        meta.stop()


# ---------------------------------------------------------------------------
# scrubber
# ---------------------------------------------------------------------------


def _integrity_put(root, name, data):
    from repro.core.api import XdfsServer

    with XdfsServer(engine="mtedp", root=str(root)) as srv:
        with XdfsClient.connect(srv.address, n_channels=2,
                                block_size=1 << 15, integrity=True) as cli:
            cli.put(None, name, data=data).result()


def test_scrubber_verifies_detects_rot_and_missing(tmp_path):
    data = os.urandom((1 << 17) + 11)
    _integrity_put(tmp_path, "good.bin", data)
    _integrity_put(tmp_path, "rot.bin", data)
    _integrity_put(tmp_path, "gone.bin", data)
    os.unlink(tmp_path / "gone.bin")
    inject_bit_rot(str(tmp_path / "rot.bin"))
    (tmp_path / "naked.bin").write_bytes(b"no manifest")
    report = Scrubber(str(tmp_path)).scrub_once()
    assert report.verified == 1
    assert report.corrupt == [str(tmp_path / "rot.bin")]
    assert report.missing == [str(tmp_path / "gone.bin")]
    assert report.unverified == 1
    # good fully re-read, rot read up to (and including) the bad block —
    # verification stops at the first mismatch
    assert report.bytes > len(data)


def test_bit_rot_is_mtime_invisible(tmp_path):
    p = tmp_path / "f.bin"
    p.write_bytes(os.urandom(4096))
    before = os.stat(p)
    off = inject_bit_rot(str(p))
    after = os.stat(p)
    assert 0 <= off < 4096
    assert after.st_mtime_ns == before.st_mtime_ns


def test_scrubber_rate_limit_paces_reads(tmp_path):
    """Baseline-free invariant: a pass over N bytes at rate R sleeps at
    least N/R seconds (token bucket, injectable clock — no wall time)."""
    data = os.urandom(1 << 18)
    _integrity_put(tmp_path, "f.bin", data)
    t = {"now": 0.0}
    slept = []

    def clock():
        return t["now"]

    def sleep(d):
        slept.append(d)
        t["now"] += d

    rate = 64 << 10  # 64 KiB/s against a 256 KiB file
    scr = Scrubber(str(tmp_path), rate_limit=rate, clock=clock, sleep=sleep)
    report = scr.scrub_once()
    assert report.verified == 1 and report.bytes >= len(data)
    assert sum(slept) >= report.bytes / rate * 0.99
    # unthrottled pass on the same store never sleeps
    slept.clear()
    Scrubber(str(tmp_path), clock=clock, sleep=sleep).scrub_once()
    assert slept == []


# ---------------------------------------------------------------------------
# disk-full degradation
# ---------------------------------------------------------------------------


def test_put_disk_full_typed_and_session_survives(xdfs_server, tmp_path):
    """An oversized put is refused with the typed ``disk_full`` kind
    BEFORE any bytes stream, and the session keeps serving (both server
    modes)."""
    root = tmp_path / "srv"
    with xdfs_server(engine="mtedp", root=str(root),
                     capacity_bytes=32 << 10) as srv:
        with XdfsClient.connect(srv.address, n_channels=2,
                                block_size=8 << 10) as cli:
            with pytest.raises(DiskFullError):
                cli.put(None, "big.bin", data=os.urandom(64 << 10)).result()
            cli.put(None, "small.bin", data=b"fits").result()
            assert cli.get_bytes("small.bin").result().data == b"fits"
    assert not (root / "big.bin").exists()


def test_store_free_bytes_capacity_mode(tmp_path):
    assert store_free_bytes(str(tmp_path), 1 << 20) == 1 << 20
    (tmp_path / "a.bin").write_bytes(b"x" * 1000)
    assert store_free_bytes(str(tmp_path), 1 << 20) == (1 << 20) - 1000
    # statvfs mode reports real headroom
    assert store_free_bytes(str(tmp_path)) > 0


def test_cluster_put_replans_around_full_node(tmp_path):
    """A node that fills up AFTER advertising headroom refuses with
    ``disk_full``; the client counts the refusal, excludes the node,
    re-plans, and the put lands elsewhere. Once the next heartbeat
    advertises the low free space, placement avoids the node upfront."""
    cap = 1 << 20
    meta = MetaNode(replication=1, heartbeat_timeout=10.0,
                    tick_interval=0.2).start()
    n_full = DataNode(meta.address, str(tmp_path / "full"), node_id="full",
                      auto_heartbeat=False, capacity_bytes=cap).start()
    n_ok = DataNode(meta.address, str(tmp_path / "ok"), node_id="ok",
                    auto_heartbeat=False).start()
    cli = ClusterClient(meta.address, block_size=64 << 10,
                        policy=RetryPolicy(attempts=4, base_delay=0.01,
                                           connect_timeout=2.0,
                                           io_timeout=5.0))
    try:
        n_full.heartbeat_once()  # advertises ~1 MiB free
        n_ok.heartbeat_once()
        write_ballast(str(tmp_path / "full"), cap, leave=1024)
        assert n_full.free_bytes() <= 1024
        data = os.urandom(256 << 10)
        cli.put("f.bin", data=data)
        assert cli.get("f.bin") == data
        assert cli.stats["disk_full_refusals"] > 0
        assert cli.stats["replans"] >= 1
        assert not list((tmp_path / "full").glob("blk_*.bin"))
        # next beat tells the metanode the truth; placement now avoids
        # the full node without burning a client refusal round
        n_full.heartbeat_once()
        n_ok.heartbeat_once()
        before = cli.stats["disk_full_refusals"]
        cli.put("g.bin", data=os.urandom(128 << 10))
        assert cli.stats["disk_full_refusals"] == before
        assert meta.stats["full_nodes_avoided"] > 0
    finally:
        cli.close()
        n_full.stop()
        n_ok.stop()
        meta.stop()


# ---------------------------------------------------------------------------
# client retry semantics (busy + restarted-node redial)
# ---------------------------------------------------------------------------


def test_cluster_put_retries_busy_node(tmp_path, monkeypatch):
    """A ``busy`` refusal is transient admission pushback: the client
    backs off and retries the SAME node (no exclusion, no pool
    invalidation) and counts the round in ``busy_retries``."""
    meta = MetaNode(replication=1, heartbeat_timeout=T,
                    tick_interval=0.1).start()
    node = DataNode(meta.address, str(tmp_path / "n0"), node_id="n0",
                    heartbeat_interval=0.05).start()
    cli = ClusterClient(meta.address, block_size=64 << 10,
                        policy=RetryPolicy(attempts=4, base_delay=0.01,
                                           connect_timeout=2.0,
                                           io_timeout=5.0))
    state = {"refused": 0}
    orig = XdfsClient.put

    def busy_once(self, *args, **kwargs):
        if state["refused"] == 0:
            state["refused"] += 1
            fut = Future()
            fut.set_exception(BusyError("session admission pushback"))
            return fut
        return orig(self, *args, **kwargs)

    monkeypatch.setattr(XdfsClient, "put", busy_once)
    data = os.urandom(96 << 10)
    try:
        cli.put("f.bin", data=data)
        assert cli.stats["busy_retries"] == 1
        assert cli.stats["replans"] >= 1
        assert cli.pool.stats["connects"] == 1  # never invalidated
        monkeypatch.setattr(XdfsClient, "put", orig)
        assert cli.get("f.bin") == data
    finally:
        cli.close()
        node.stop()
        meta.stop()


def test_session_pool_redials_restarted_server(tmp_path):
    """A datanode that restarted at the same address leaves the pool
    holding a dead session: ``execute`` detects the stale lease,
    invalidates, and redials exactly once."""
    from repro.core.api import XdfsServer

    data = os.urandom(32 << 10)
    srv = XdfsServer(engine="mtedp", root=str(tmp_path / "a")).start()
    addr = srv.address
    pool = SessionPool(n_channels=2)
    try:
        pool.execute(addr, lambda c: c.put(None, "x.bin", data=data).result())
        srv.abort()
        srv = XdfsServer(engine="mtedp", root=str(tmp_path / "a"),
                         port=addr[1]).start()
        out = pool.execute(
            addr, lambda c: c.get_bytes("x.bin").result().data)
        assert out == data
        assert pool.stats["stale_redials"] == 1
        assert pool.stats["connects"] == 2
    finally:
        pool.close()
        srv.stop()


# ---------------------------------------------------------------------------
# exception typing
# ---------------------------------------------------------------------------


def test_typed_exception_kinds():
    assert DiskFullError.kind == "disk_full"
    assert issubclass(DiskFullError, SessionError)
    assert issubclass(BusyError, SessionError)


# ---------------------------------------------------------------------------
# chaos acceptance scenarios
# ---------------------------------------------------------------------------


@pytest.mark.chaos
def test_datanode_abort_mid_put_atomic_no_acked_block_lost(tmp_path):
    """Kill a datanode (abort(): sockets severed, in-flight sessions
    die) in the middle of a striped put stream under the atomic policy,
    then restart it on the same store: every block acked before the
    crash is present and CRC-valid, no temp files survive, and every
    acked put is readable."""
    meta = MetaNode(replication=2, heartbeat_timeout=T,
                    tick_interval=0.1).start()
    nodes = [
        DataNode(meta.address, str(tmp_path / f"n{i}"), node_id=f"n{i}",
                 heartbeat_interval=0.05, durability=DURABILITY_ATOMIC,
                 policy=RetryPolicy(attempts=3, base_delay=0.05,
                                    connect_timeout=2.0, io_timeout=5.0))
        .start()
        for i in range(3)
    ]
    cli = ClusterClient(meta.address, block_size=32 << 10,
                        policy=_deep_policy(),
                        durability=DURABILITY_ATOMIC)
    acked = {}
    try:
        with ChaosHarness() as chaos:
            chaos.when(lambda: cli.stats["blocks_written"] >= 6,
                       nodes[0].kill, name="datanode crash mid-put")
            for i in range(6):
                data = os.urandom(96 << 10)
                cli.put(f"f{i}.bin", data=data)
                acked[f"f{i}.bin"] = data
            chaos.wait()
        # restart the crashed node on ITS OWN store directory
        nodes[0] = DataNode(meta.address, str(tmp_path / "n0"),
                            node_id="n0", heartbeat_interval=0.05,
                            durability=DURABILITY_ATOMIC).start()
        assert _no_temps(tmp_path / "n0")  # startup sweep GC'd partials
        # every surviving block file in the restarted store is CRC-valid
        # against its committed manifest: the crash lost only unacked work
        report = nodes[0].scrub_once()
        assert report.corrupt == [] and report.missing == []
        for name, data in acked.items():  # no acked put lost
            assert cli.get(name) == data
    finally:
        cli.close()
        for n in nodes:
            n.stop()
        meta.stop()


@pytest.mark.chaos
def test_bit_rot_scrubbed_dropped_and_rereplicated(tmp_path):
    """Rot one replica at rest: the node's scrub condemns it, the
    heartbeat reports it, the MetaNode drops the bad copy and heals the
    block back to full rf from a good holder — and a client read is
    byte-identical with ZERO failovers (it never touches a bad replica)."""
    meta = MetaNode(replication=2, heartbeat_timeout=T,
                    tick_interval=0.1).start()
    nodes = [
        DataNode(meta.address, str(tmp_path / f"n{i}"), node_id=f"n{i}",
                 heartbeat_interval=0.05)
        .start()
        for i in range(3)
    ]
    cli = ClusterClient(meta.address, block_size=64 << 10,
                        policy=_deep_policy())
    data = os.urandom(128 << 10)
    try:
        cli.put("f.bin", data=data)
        victim = next(n for n in nodes
                      if list((tmp_path / n.node_id).glob("blk_*.bin")))
        blk = sorted((tmp_path / victim.node_id).glob("blk_*.bin"))[0]
        inject_bit_rot(str(blk))
        assert victim.scrub_once().corrupt == [str(blk)]
        assert victim.stats["scrub_corrupt"] == 1
        _await(lambda: meta.stats["corrupt_reported"] >= 1,
               msg="corrupt replica reported")

        def healed():
            intact = 0
            for n in nodes:
                root = tmp_path / n.node_id
                for p in root.glob("blk_*.bin"):
                    if Scrubber(str(root)).verify_file(str(p)):
                        intact += 1
            # 2 blocks x rf=2, every surviving copy intact
            bad = [p for n in nodes
                   for p in (tmp_path / n.node_id).glob("blk_*.bin")
                   if not Scrubber(
                       str(tmp_path / n.node_id)).verify_file(str(p))]
            return intact >= 4 and not bad

        _await(healed, msg="re-replication back to full rf")
        with ClusterClient(meta.address, block_size=64 << 10,
                           policy=_deep_policy()) as reader:
            assert reader.get("f.bin") == data
            assert reader.stats["replica_failovers"] == 0
            assert reader.stats["busy_retries"] == 0
    finally:
        cli.close()
        for n in nodes:
            n.stop()
        meta.stop()


@pytest.mark.chaos
def test_power_loss_after_abandoned_atomic_put(tmp_path):
    """A power cut mid-transfer leaves only the atomic temp; the
    simulated loss removes it (those bytes were never promised), the
    committed previous version survives, and the startup sweep leaves a
    clean store."""
    p = tmp_path / "f.bin"
    p.write_bytes(b"committed-version")
    sink = Sink(str(p), 64, durability="atomic")
    sink.write_at(0, b"half-written junk")
    os.close(sink._fd)  # crash: no commit, no close bookkeeping
    sink._fd = -1
    sink.committed = True  # neuter close(); the "crash" already happened
    assert not _no_temps(tmp_path)
    removed = simulate_power_loss(str(tmp_path))
    assert len(removed) == 1 and TMP_INFIX in removed[0]
    assert p.read_bytes() == b"committed-version"
    assert sweep_sidecars(str(tmp_path)) == []
