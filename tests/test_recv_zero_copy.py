"""Registered-buffer receive datapath: recv_into pool slots, in-place
header parsing, pwritev write-out of pool views, the opt-in splice fast
path, backpressure, and the zero-materialization guarantee."""
import os
import socket
import threading
import time

import pytest

from repro.core.api import XdfsClient, XdfsServer
from repro.core.engines.base import (
    SPLICE,
    Sink,
    Source,
    SpliceReceiver,
    SpliceUnsupported,
)
from repro.core.engines.mt import mt_receive, worker_send
from repro.core.engines.mtedp import mtedp_receive
from repro.core.header import ChannelEvent, ChannelHeader
from repro.core.ringbuf import LockedRecvPool, LockedRing, RecvBufferPool

SESSION = b"0123456789abcdef"
ENGINES = ("mtedp", "mt", "mp")


def _splice_available(tmp_path) -> bool:
    """Probe whether socket->pipe->file splice actually works here (it is
    kernel/sandbox dependent; the engines fall back when it doesn't)."""
    if not SPLICE:
        return False
    a, b = socket.socketpair()
    fd = os.open(str(tmp_path / "splice_probe"), os.O_WRONLY | os.O_CREAT)
    try:
        spl = SpliceReceiver()
    except SpliceUnsupported:
        os.close(fd)
        a.close()
        b.close()
        return False
    try:
        a.sendall(b"x" * 1024)
        spl.splice_block(b, fd, 0, 1024)
        return spl.ok
    except (SpliceUnsupported, OSError):
        return False
    finally:
        spl.close()
        os.close(fd)
        a.close()
        b.close()


# ---------------------------------------------------------------------------
# RecvBufferPool: slot lifecycle
# ---------------------------------------------------------------------------


def test_recv_pool_slot_lifecycle():
    pool = RecvBufferPool(4, 64)
    slots = [pool.acquire() for _ in range(4)]
    assert None not in slots and pool.acquire() is None
    # slot views are disjoint windows into ONE registered backing buffer
    for i, s in enumerate(slots):
        pool.view(s)[:] = bytes([i]) * 64
    assert bytes(pool._backing).count(bytes([2]) * 64) == 1
    for i, s in enumerate(slots):
        assert bytes(pool.view(s)) == bytes([i]) * 64
        pool.commit(s, i * 64, 64)
    drained = pool.drain()
    assert [off for off, _, _ in drained] == [i * 64 for i in range(4)]
    assert pool.n_committed == 0
    pool.release_all(s for _, _, s in drained)
    assert pool.n_free == 4


def test_locked_recv_pool_backpressure_blocks_until_release():
    shared = LockedRecvPool(RecvBufferPool(1, 16))
    held = shared.acquire()
    got = []

    def blocked_acquire():
        got.append(shared.acquire())

    t = threading.Thread(target=blocked_acquire)
    t.start()
    time.sleep(0.05)
    assert not got, "acquire must block while the pool is exhausted"
    shared.commit(held, 0, 16)
    batch = shared.drain_wait()
    shared.release_all(s for _, _, s in batch)
    t.join(timeout=5)
    assert got == [held]  # the freed slot went to the waiter


def test_locked_recv_pool_close_unblocks_acquire():
    shared = LockedRecvPool(RecvBufferPool(1, 16))
    shared.acquire()
    err = []

    def blocked_acquire():
        try:
            shared.acquire()
        except RuntimeError as e:
            err.append(e)

    t = threading.Thread(target=blocked_acquire)
    t.start()
    time.sleep(0.05)
    shared.close()
    t.join(timeout=5)
    assert err, "close() must raise in parked acquirers, not strand them"


# ---------------------------------------------------------------------------
# equality: recv_into pool path across all engines
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ENGINES)
def test_recv_pool_roundtrip_equals_source(engine, tmp_path):
    """The registered-buffer receive path must land byte-identical files
    for every engine (the recv_into ≡ copy equality gate), odd tail block
    included."""
    data = os.urandom((1 << 19) + 3333)
    src = tmp_path / "in.bin"
    src.write_bytes(data)
    root = tmp_path / f"srv_{engine}"
    with XdfsServer(engine=engine, root=str(root)) as srv:
        with XdfsClient.connect(srv.address, n_channels=3, engine=engine,
                                block_size=1 << 16) as cli:
            cli.put(str(src), "out.bin").result()
            cli.get("out.bin", str(tmp_path / f"back_{engine}.bin")).result()
        srv.wait_closed_sessions(1, timeout=60)
        assert not srv.errors, srv.errors
    assert (root / "out.bin").read_bytes() == data
    assert (tmp_path / f"back_{engine}.bin").read_bytes() == data


def test_pwritev_writeout_equals_byte_at_a_time_reference(tmp_path):
    """Coalesced pwritev of committed pool views must produce the same
    file as the dumbest possible reference writer."""
    block = 512
    n = 16
    data = os.urandom(block * n)
    pool = RecvBufferPool(n, block)
    # commit blocks out of order so the sort/coalesce logic is exercised
    order = list(range(n))
    order = order[1::2] + order[::2]
    for i in order:
        slot = pool.acquire()
        pool.view(slot)[:] = data[i * block : (i + 1) * block]
        pool.commit(slot, i * block, block)

    vec_path = tmp_path / "vec.bin"
    sink = Sink(str(vec_path), len(data))
    blocks = pool.drain()
    calls = sink.writev_views(
        [(off, pool.view(slot)[:ln]) for off, ln, slot in blocks])
    sink.close()
    assert calls >= 1

    ref_path = tmp_path / "ref.bin"
    fd = os.open(str(ref_path), os.O_WRONLY | os.O_CREAT)
    for i, byte in enumerate(data):
        os.pwrite(fd, bytes([byte]), i)
    os.close(fd)
    assert vec_path.read_bytes() == ref_path.read_bytes() == data


# ---------------------------------------------------------------------------
# splice fast path
# ---------------------------------------------------------------------------


@pytest.mark.skipif(not SPLICE, reason="os.splice unavailable")
def test_splice_and_generic_receivers_identical_sinks(tmp_path):
    """mt_receive with and without the splice fast path must produce
    byte-identical files (the fallback contract guarantees this even
    where splice is unsupported)."""
    data = os.urandom((1 << 19) + 12345)
    srcp = tmp_path / "src.bin"
    srcp.write_bytes(data)
    engaged = _splice_available(tmp_path)
    results = {}
    for use_splice in (True, False):
        dstp = tmp_path / f"dst_{use_splice}.bin"
        pairs = [socket.socketpair() for _ in range(2)]
        sink = Sink(str(dstp), len(data))
        stats = {}

        def rx():
            stats["st"] = mt_receive([b for _, b in pairs], sink, 1 << 16,
                                     use_splice=use_splice)

        t = threading.Thread(target=rx)
        t.start()
        source = Source(str(srcp), len(data), 1 << 16)
        worker_send([a for a, _ in pairs], source, SESSION,
                    use_processes=False)
        t.join()
        source.close()
        sink.close()
        for a, b in pairs:
            a.close()
            b.close()
        assert stats["st"].bytes == len(data)
        results[use_splice] = stats["st"]
        assert dstp.read_bytes() == data
    assert results[False].splice_bytes == 0
    if engaged:  # kernel supports it: the fast path must actually engage
        assert results[True].splice_bytes == len(data)


def test_splice_session_end_to_end(tmp_path):
    """XdfsServer(splice=True) + client download with splice=True: content
    survives and the server reports kernel-side bytes where supported."""
    data = os.urandom((1 << 18) + 99)
    src = tmp_path / "in.bin"
    src.write_bytes(data)
    engaged = SPLICE and _splice_available(tmp_path)
    with XdfsServer(engine="mp", root=str(tmp_path / "srv"),
                    splice=True) as srv:
        with XdfsClient.connect(srv.address, n_channels=2, engine="mp",
                                block_size=1 << 15, splice=True) as cli:
            cli.put(str(src), "out.bin").result()
            cli.get("out.bin", str(tmp_path / "back.bin")).result()
        srv.wait_closed_sessions(1, timeout=60)
        assert not srv.errors, srv.errors
        if engaged:
            assert srv.stats["splice_bytes"] == len(data)
    assert (tmp_path / "srv" / "out.bin").read_bytes() == data
    assert (tmp_path / "back.bin").read_bytes() == data


# ---------------------------------------------------------------------------
# zero-materialization guarantee
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ENGINES)
def test_receive_hot_loop_materializes_nothing(engine, tmp_path):
    """The acceptance gate: a full put+get session must not make a single
    payload-sized heap copy on the receive path, for any engine."""
    data = os.urandom((1 << 19) + 4097)
    src = tmp_path / "in.bin"
    src.write_bytes(data)
    with XdfsServer(engine=engine, root=str(tmp_path / f"s_{engine}")) as srv:
        RecvBufferPool.materializations = 0
        with XdfsClient.connect(srv.address, n_channels=3, engine=engine,
                                block_size=1 << 16) as cli:
            cli.put(str(src), "out.bin").result()
            cli.get("out.bin", str(tmp_path / f"b_{engine}.bin")).result()
        srv.wait_closed_sessions(1, timeout=60)
        assert RecvBufferPool.materializations == 0, (
            f"{engine}: receive hot loop materialized a heap copy"
        )
    assert (tmp_path / f"b_{engine}.bin").read_bytes() == data


def test_legacy_ring_is_counted_as_copying():
    """Control for the test above: the seed's locked-ring pipeline IS
    charged for its copy-in and snapshot-out."""
    ring = LockedRing(8, 32)
    before = RecvBufferPool.materializations
    ring.put(b"x" * 32, 0)
    ring.put(b"y" * 32, 32)
    batch = ring.get_batch(timeout=0)
    assert len(batch) == 2
    assert RecvBufferPool.materializations == before + 4  # 2 in + 2 out


# ---------------------------------------------------------------------------
# pool exhaustion backpressure
# ---------------------------------------------------------------------------


def test_mtedp_tiny_pool_backpressure_flushes_inline():
    """With the minimum legal pool (n_channels + 1 slots) the event loop
    must flush inline under exhaustion and still land every block."""
    a, b = socket.socketpair()
    block = 1 << 12
    data = os.urandom(block * 64)
    sink = Sink(None, len(data), capture=True)

    def tx():
        for i in range(64):
            hdr = ChannelHeader(ChannelEvent.xFTSMU, SESSION, 0,
                                i * block, block)
            a.sendall(hdr.pack() + data[i * block : (i + 1) * block])
        a.sendall(ChannelHeader(ChannelEvent.EOFT, SESSION, 0, 0, 0).pack())

    t = threading.Thread(target=tx)
    t.start()
    st = mtedp_receive([b], sink, block, pool_slots=2, conformance=False)
    t.join()
    assert st.bytes == len(data)
    assert st.flushes >= 64 // 2  # exhaustion forced many inline drains
    assert sink.data == data
    sink.close()
    a.close()
    b.close()


def test_mt_tiny_pool_backpressure_completes(tmp_path):
    """MT channel threads must survive a pool smaller than the in-flight
    block backlog (blocking acquire + disk-thread drain)."""
    data = os.urandom((1 << 18) + 777)
    srcp = tmp_path / "src.bin"
    srcp.write_bytes(data)
    dstp = tmp_path / "dst.bin"
    pairs = [socket.socketpair() for _ in range(2)]
    sink = Sink(str(dstp), len(data))
    stats = {}

    def rx():
        stats["st"] = mt_receive([b for _, b in pairs], sink, 1 << 14,
                                 ring_slots=2)

    t = threading.Thread(target=rx)
    t.start()
    source = Source(str(srcp), len(data), 1 << 14)
    worker_send([a for a, _ in pairs], source, SESSION, use_processes=False)
    t.join()
    source.close()
    sink.close()
    for a, b in pairs:
        a.close()
        b.close()
    assert stats["st"].bytes == len(data)
    assert dstp.read_bytes() == data
