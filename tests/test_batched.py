"""Syscall-batched datapath: slab parsing at arbitrary frame boundaries,
batched partial-send resumption across all three engines, exact
short-sendmsg delivery accounting, autotuner convergence under a fake
clock, and the adaptive splice arbiter's mid-stream path switches."""
import itertools
import os
import socket
import threading

import pytest

from repro.core.api import XdfsClient, XdfsServer
from repro.core.autotune import (
    DECIDED,
    LADDER,
    POOL_TRIAL,
    SPLICE_TRIAL,
    ChannelTuner,
    HillClimber,
    SpliceArbiter,
)
from repro.core.engines import mp as mp_mod
from repro.core.engines import mt as mt_mod
from repro.core.engines.base import (
    SendStats,
    Sink,
    SlabChannel,
    Source,
    recv_exact,
    sendmsg_batched,
    slab_span,
)
from repro.core.engines.mt import mt_receive, worker_send
from repro.core.engines.registry import get_engine
from repro.core.header import HEADER_SIZE, ChannelEvent, ChannelHeader
from repro.core.ringbuf import RecvBufferPool, RecvSlab
from repro.core.session import MAX_BATCH_FRAMES

SESSION = b"0123456789abcdef"
ENGINES = ("mtedp", "mt", "mp")


# ---------------------------------------------------------------------------
# SlabChannel: frame boundaries anywhere relative to reads
# ---------------------------------------------------------------------------


def _frame_stream(data: bytes, block_size: int,
                  end_event=ChannelEvent.EOFT) -> bytes:
    """The exact byte stream one channel's sender puts on the wire."""
    out = bytearray()
    n_blocks = (len(data) + block_size - 1) // block_size
    for i in range(n_blocks):
        off = i * block_size
        ln = min(block_size, len(data) - off)
        hdr = ChannelHeader(ChannelEvent.xFTSMU, SESSION, 0, off, ln)
        out += hdr.pack() + data[off : off + ln]
    out += ChannelHeader(end_event, SESSION, 0, 0, 0).pack()
    return bytes(out)


def _drive_slab(stream: bytes, chunk_sizes, block_size: int,
                slab_bytes: int, size: int):
    """Feed ``stream`` through a socketpair in ``chunk_sizes``-sized
    writes (cycled), draining the SlabChannel after each write — so the
    test controls exactly where frame boundaries land relative to reads.
    Returns (reassembled bytes, SlabChannel)."""
    a, b = socket.socketpair()
    b.setblocking(False)
    sink = Sink(None, size, capture=True)
    sc = SlabChannel(RecvSlab(slab_bytes), block_size)
    sizes = itertools.cycle(chunk_sizes)
    pos = 0
    try:
        while pos < len(stream) and sc.end_event is None:
            n = min(next(sizes), len(stream) - pos)
            a.sendall(stream[pos : pos + n])
            pos += n
            while sc.end_event is None:
                if sc.free_space() == 0:
                    sink.writev_views(sc.take_pending())
                    sc.compact()
                try:
                    sc.receive_once(b)
                except BlockingIOError:
                    break
        sink.writev_views(sc.take_pending())
        return sink.data, sc
    finally:
        a.close()
        b.close()


def test_slab_reads_ending_mid_header():
    """Chunks of 7 bytes: every read lands inside a header or a payload;
    sub-header fragments must wait and reassemble losslessly."""
    block = 256
    data = os.urandom(block * 5 + 91)  # odd tail block included
    got, sc = _drive_slab(_frame_stream(data, block), (7,), block,
                          slab_span(4, block), len(data))
    assert got == data
    assert sc.blocks == 6 and sc.bytes == len(data)
    assert sc.end_event == ChannelEvent.EOFT


def test_slab_reads_ending_mid_payload():
    """Chunks of header + half a block: every payload is split across
    reads and committed as partial (offset, view) pairs."""
    block = 256
    data = os.urandom(block * 4 + 33)
    got, sc = _drive_slab(_frame_stream(data, block), (HEADER_SIZE + 100,),
                          block, slab_span(4, block), len(data))
    assert got == data and sc.bytes == len(data)


def test_slab_one_byte_reads_boundary_sweep():
    """1-byte chunks sweep a boundary through EVERY position of every
    header and payload — the exhaustive fragmentation case."""
    block = 128
    data = os.urandom(block * 3 + 17)
    got, sc = _drive_slab(_frame_stream(data, block), (1,), block,
                          slab_span(2, block), len(data))
    assert got == data and sc.blocks == 4


def test_slab_coalesced_arrival_many_frames_per_read():
    """The whole stream sent at once lands many frames per recv_into —
    the syscall-batching win the slab exists for."""
    block = 1 << 10
    data = os.urandom(block * 16)
    stream = _frame_stream(data, block)
    got, sc = _drive_slab(stream, (len(stream),), block,
                          slab_span(64, block), len(data))
    assert got == data
    assert sc.recv_calls < sc.blocks, (
        f"{sc.recv_calls} reads for {sc.blocks} frames: no coalescing"
    )


def test_slab_smaller_than_one_frame_stays_correct():
    """A slab below one frame's size forces mid-payload commits and
    compact cycles on every block; correctness must not depend on the
    slab fitting a whole batch."""
    block = 256
    data = os.urandom(block * 4 + 5)
    got, sc = _drive_slab(_frame_stream(data, block), (4096,), block,
                          4 * HEADER_SIZE, len(data))
    assert got == data and sc.bytes == len(data)


def test_slab_seed_handoff_roundtrip():
    """handoff() mid-stream and seed() on a fresh parser must resume the
    byte stream exactly (the datapath-switch contract)."""
    block = 256
    data = os.urandom(block * 3)
    stream = _frame_stream(data, block)
    a, b = socket.socketpair()
    sink = Sink(None, len(data), capture=True)
    sc1 = SlabChannel(RecvSlab(slab_span(2, block)), block)
    # land exactly one and a half frames plus 10 bytes of the next header
    cut = (HEADER_SIZE + block) + HEADER_SIZE + block // 2 + 10
    a.sendall(stream[:cut])
    while sc1.bytes < block + block // 2:
        sc1.receive_once(b)
    sink.writev_views(sc1.take_pending())
    tail, hdr, off, left = sc1.handoff()
    # mid-payload handoffs carry no header bytes; this cut is mid-HEADER
    # of frame 2 only after frame 1's payload fully parsed
    sc2 = SlabChannel(RecvSlab(slab_span(2, block)), block)
    if hdr is not None:
        sc2.seed(payload_off=off, payload_left=left)
        assert tail == b""
    else:
        sc2.seed(header_tail=tail)
    a.sendall(stream[cut:])
    while sc2.end_event is None:
        if sc2.free_space() == 0:
            sink.writev_views(sc2.take_pending())
            sc2.compact()
        sc2.receive_once(b)
    sink.writev_views(sc2.take_pending())
    assert sink.data == data
    a.close()
    b.close()


# ---------------------------------------------------------------------------
# sendmsg_batched: exact per-frame delivery accounting under short sends
# ---------------------------------------------------------------------------


class _ScriptedSock:
    """sendmsg that accepts exactly the scripted byte counts (then
    everything), recording stats.frames at each call's ENTRY — the
    regression probe for over-reporting under short sends."""

    def __init__(self, script, stats):
        self.script = list(script)
        self.stats = stats
        self.frames_at_entry = []

    def sendmsg(self, iov):
        self.frames_at_entry.append(self.stats.frames)
        total = sum(len(v) for v in iov)
        n = self.script.pop(0) if self.script else total
        return min(n, total)


def test_sendmsg_batched_short_send_accounting_scripted():
    """A short sendmsg must credit only frames whose LAST byte was
    delivered — never the raw iovec sum of the in-flight batch."""
    stats = SendStats()
    payloads = [os.urandom(10), os.urandom(20), os.urandom(30)]
    frames = []
    sizes = []
    for i, p in enumerate(payloads):
        hdr = ChannelHeader(ChannelEvent.xFTSMU, SESSION, 0, i * 64, len(p))
        frames += [hdr.pack(), p]
        sizes.append(HEADER_SIZE + len(p))
    # 5 bytes (mid-header-0), then to 3 bytes past frame 0's end, then rest
    sock = _ScriptedSock([5, (sizes[0] - 5) + 3], stats)
    sent = sendmsg_batched(sock, frames, sizes, stats)
    assert sent == sum(sizes)
    # entry snapshots: before call 1 nothing credited; before call 2 the
    # 5-byte short send still credits NOTHING; before call 3 exactly one
    # frame (frame 0) is complete despite 3 bytes of frame 1 being out
    assert sock.frames_at_entry == [0, 0, 1]
    assert stats.frames == 3 and stats.bytes == sent
    assert stats.syscalls == 3 and stats.batches == 1


class _WritabilityWait:
    """Nonblocking sendmsg behind a writability wait: the kernel accepts
    only the free SO_SNDBUF space per call, so short sends are REAL, not
    scripted (the same shape as the mtedp event sender's socket)."""

    def __init__(self, sock):
        self.sock = sock
        sock.setblocking(False)

    def sendmsg(self, iov):
        import select

        while True:
            try:
                return self.sock.sendmsg(iov)
            except BlockingIOError:
                select.select([], [self.sock], [])


def test_sendmsg_batched_accounting_under_tiny_sndbuf():
    """The real-socket regression: a tiny SO_SNDBUF forces partial
    sendmsg returns; final accounting must still be exact."""
    a, b = socket.socketpair()
    a.setsockopt(socket.SOL_SOCKET, socket.SO_SNDBUF, 4096)
    b.setsockopt(socket.SOL_SOCKET, socket.SO_RCVBUF, 4096)
    block = 1 << 13
    payloads = [os.urandom(block) for _ in range(8)]
    frames = []
    sizes = []
    for i, p in enumerate(payloads):
        hdr = ChannelHeader(ChannelEvent.xFTSMU, SESSION, 0, i * block,
                            len(p))
        frames += [hdr.pack(), p]
        sizes.append(HEADER_SIZE + len(p))
    total = sum(sizes)
    got = bytearray()

    def drain():
        while len(got) < total:
            chunk = b.recv(1 << 10)
            if not chunk:
                break
            got.extend(chunk)

    t = threading.Thread(target=drain)
    t.start()
    stats = SendStats()
    sent = sendmsg_batched(_WritabilityWait(a), frames, sizes, stats)
    t.join()
    a.close()
    b.close()
    assert sent == total and bytes(got) == b"".join(bytes(f) for f in frames)
    assert stats.bytes == total
    assert stats.frames == 8, "every frame fully delivered exactly once"
    assert stats.syscalls > 1, (
        "tiny SO_SNDBUF should have forced partial sends; the regression "
        "this guards never exercised"
    )


# ---------------------------------------------------------------------------
# batched partial-send resumption, end to end, all three engines
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ENGINES)
def test_batched_partial_send_resumption(engine, tmp_path):
    """batch_frames=4 under tiny socket buffers: every batch is split
    across many partial sendmsg returns and every slab read lands at an
    arbitrary boundary; files must still be byte-identical."""
    data = os.urandom((1 << 18) + 7777)
    srcp = tmp_path / "src.bin"
    srcp.write_bytes(data)
    dstp = tmp_path / f"dst_{engine}.bin"
    eng = get_engine(engine)
    pairs = [socket.socketpair() for _ in range(2)]
    for pa, pb in pairs:
        for s in (pa, pb):
            s.setsockopt(socket.SOL_SOCKET, socket.SO_SNDBUF, 8192)
            s.setsockopt(socket.SOL_SOCKET, socket.SO_RCVBUF, 8192)
    sink = Sink(str(dstp), len(data))
    res = {}

    def rx():
        res["st"] = eng.receive([pb for _, pb in pairs], sink, 1 << 13,
                                batch_frames=4)

    t = threading.Thread(target=rx)
    t.start()
    source = Source(str(srcp), len(data), 1 << 13)
    eng.send([pa for pa, _ in pairs], source, SESSION, batch_frames=4)
    t.join()
    source.close()
    sink.close()
    for pa, pb in pairs:
        pa.close()
        pb.close()
    st = res["st"]
    assert st.bytes == len(data)
    assert st.recv_calls > 0, "slab datapath did not engage"
    assert dstp.read_bytes() == data


@pytest.mark.parametrize("engine", ENGINES)
def test_batched_session_zero_materialization(engine, tmp_path):
    """The acceptance gate with batching ON: a full put+get session at
    batch_frames=8 must keep both zero-copy invariants — no payload-sized
    heap copy on either direction's hot loop."""
    data = os.urandom((1 << 18) + 4097)
    src = tmp_path / "in.bin"
    src.write_bytes(data)
    with XdfsServer(engine=engine, root=str(tmp_path / f"s_{engine}")) as srv:
        RecvBufferPool.materializations = 0
        Source.materializations = 0
        with XdfsClient.connect(srv.address, n_channels=3, engine=engine,
                                block_size=1 << 16, batch_frames=8) as cli:
            assert cli.batch_frames == 8
            cli.put(str(src), "out.bin").result()
            cli.get("out.bin", str(tmp_path / f"b_{engine}.bin")).result()
        srv.wait_closed_sessions(1, timeout=60)
        assert not srv.errors, srv.errors
        assert srv.stats["recv_calls"] > 0, "server did not run the slab path"
        assert RecvBufferPool.materializations == 0, (
            f"{engine}: batched receive hot loop materialized a heap copy"
        )
        assert Source.materializations == 0, (
            f"{engine}: batched send hot loop materialized a heap copy"
        )
    assert (tmp_path / f"b_{engine}.bin").read_bytes() == data


def test_batched_counters_server_mode_parity(xdfs_server, tmp_path):
    """Counter parity across server modes: the slab-datapath counters
    (recv_calls, writev_calls, bytes) must surface in ``XdfsServer.stats``
    whether sessions run on dedicated threads or on the shared event-loop
    core, which absorbs per-session counters on close."""
    data = os.urandom((1 << 17) + 917)
    src = tmp_path / "in.bin"
    src.write_bytes(data)
    with xdfs_server(root=str(tmp_path / "store")) as srv:
        with XdfsClient.connect(srv.address, n_channels=2,
                                block_size=1 << 16, batch_frames=4) as cli:
            cli.put(str(src), "out.bin").result()
            cli.get("out.bin", str(tmp_path / "back.bin")).result()
        srv.wait_closed_sessions(1, timeout=60)
        assert not srv.errors, srv.errors
        assert srv.stats["recv_calls"] > 0, "slab receive counter missing"
        assert srv.stats["bytes"] >= len(data)
        assert srv.stats["sessions_closed"] >= 1
    assert (tmp_path / "back.bin").read_bytes() == data


def test_batch_frames_negotiation_clamped(tmp_path):
    """An absurd requested depth is clamped to MAX_BATCH_FRAMES on both
    ends (it also bounds the per-sendmsg iovec well under IOV_MAX)."""
    data = os.urandom(1 << 16)
    src = tmp_path / "in.bin"
    src.write_bytes(data)
    with XdfsServer(engine="mt", root=str(tmp_path / "srv")) as srv:
        with XdfsClient.connect(srv.address, n_channels=2, engine="mt",
                                block_size=1 << 14,
                                batch_frames=10**6) as cli:
            assert cli.batch_frames == MAX_BATCH_FRAMES
            cli.put(str(src), "out.bin").result()
        srv.wait_closed_sessions(1, timeout=60)
        assert not srv.errors, srv.errors
    assert (tmp_path / "srv" / "out.bin").read_bytes() == data


# ---------------------------------------------------------------------------
# autotuner: deterministic convergence under a fake clock
# ---------------------------------------------------------------------------


def test_hill_climber_converges_to_interior_peak():
    rates = {1: 1.0, 4: 3.0, 16: 2.0, 64: 0.5}
    hc = HillClimber(LADDER)
    for _ in range(20):
        hc.observe(rates[hc.value])
    assert hc.value == 4 and hc.settled


def test_hill_climber_converges_to_edge_peak():
    rates = {1: 5.0, 4: 3.0, 16: 2.0, 64: 1.0}
    hc = HillClimber(LADDER)
    for _ in range(20):
        hc.observe(rates[hc.value])
    assert hc.value == 1 and hc.settled


def test_channel_tuner_converges_with_fake_clock():
    """Goodput peaked at depth 16: the tuner must walk the ladder down
    from the cap and settle on 16 — deterministically, on a fake clock."""
    rate = {1: 100e6, 4: 400e6, 16: 800e6, 64: 300e6}
    t = [0.0]
    tuner = ChannelTuner(cap=64, window_bytes=1 << 20, clock=lambda: t[0])
    for _ in range(200):
        nbytes = 1 << 19
        t[0] += nbytes / rate[tuner.depth]
        tuner.note(nbytes)
    assert tuner.depth == 16
    assert tuner.settled
    assert tuner.windows > 4


def test_channel_tuner_cap_truncates_ladder():
    assert ChannelTuner(cap=4).depth == 4  # climb starts at the cap
    assert ChannelTuner(cap=1).depth == 1
    assert ChannelTuner(cap=200).depth == LADDER[-1]
    # a cap BETWEEN rungs is itself a rung — batching must engage at
    # exactly the negotiated ceiling, not round down to the next rung
    assert ChannelTuner(cap=2).depth == 2
    assert ChannelTuner(cap=8)._climber.ladder == (1, 4, 8)


def test_splice_arbiter_switches_to_faster_pool():
    t = [0.0]
    arb = SpliceArbiter(window_bytes=1 << 20, clock=lambda: t[0])
    assert arb.phase == SPLICE_TRIAL and arb.use_splice
    decisions = []
    while arb.phase == SPLICE_TRIAL:  # splice window at 100 MB/s
        t[0] += (1 << 19) / 100e6
        decisions.append(arb.note(1 << 19))
    assert arb.phase == POOL_TRIAL and not arb.use_splice
    while arb.phase == POOL_TRIAL:  # pool window at 200 MB/s: clear win
        t[0] += (1 << 19) / 200e6
        decisions.append(arb.note(1 << 19))
    assert arb.phase == DECIDED and arb.decided
    assert not arb.use_splice and arb.measured_switch
    # note() flags the deciding observation exactly once
    assert decisions.count(True) == 1 and decisions[-1] is True
    assert arb.note(1 << 19) is False


def test_splice_arbiter_hysteresis_keeps_splice_on_near_tie():
    """Within the 10% margin the path the caller opted into wins."""
    t = [0.0]
    arb = SpliceArbiter(window_bytes=1 << 20, clock=lambda: t[0])
    while arb.phase == SPLICE_TRIAL:
        t[0] += (1 << 19) / 100e6
        arb.note(1 << 19)
    while arb.phase == POOL_TRIAL:  # pool only 5% faster: inside margin
        t[0] += (1 << 19) / 105e6
        arb.note(1 << 19)
    assert arb.decided and arb.use_splice and not arb.measured_switch


def test_splice_arbiter_force_pool_is_not_a_measured_switch():
    arb = SpliceArbiter()
    arb.force_pool()
    assert arb.decided and not arb.use_splice
    assert not arb.measured_switch, (
        "a mechanical splice failure must not count as an autodisable"
    )


# ---------------------------------------------------------------------------
# adaptive splice in the engines (scripted arbiters + fake kernel path,
# so the mid-stream switches run deterministically on any host)
# ---------------------------------------------------------------------------


class _FakeSplice:
    """A user-space stand-in for SpliceReceiver with the same interface:
    lets the arbiter's path-switching logic run on hosts where real
    socket->pipe->file splice is unsupported (e.g. sandboxed kernels)."""

    def __init__(self):
        self.ok = True

    def close(self):
        pass

    def splice_block(self, sock, fd, offset, count):
        buf = memoryview(bytearray(count))
        recv_exact(sock, count, buf)
        os.pwrite(fd, buf, offset)
        return count


class _SwitchToPool(SpliceArbiter):
    """Scripted: keep splice for N frames, then decide pool (a measured
    autodisable)."""

    def __init__(self, frames=2):
        super().__init__()
        self._left = frames

    def note(self, nbytes):
        if self.phase == DECIDED:
            return False
        self._left -= 1
        if self._left <= 0:
            self.phase = DECIDED
            self.chose_splice = False
            self.measured_switch = True
            return True
        return False


class _SwitchToSplice(SpliceArbiter):
    """Scripted: start on the pool/slab path, choose splice after N
    notes (the splice-wins trial outcome)."""

    def __init__(self, notes=2):
        super().__init__()
        self.phase = POOL_TRIAL
        self._left = notes

    def note(self, nbytes):
        if self.phase == DECIDED:
            return False
        self._left -= 1
        if self._left <= 0:
            self.phase = DECIDED
            self.chose_splice = True
            return True
        return False


def _mt_transfer(tmp_path, monkeypatch, *, batch_frames, arbiter_factory,
                 tag):
    """One mt transfer with the fake kernel path patched in; returns
    (RecvStats, data, received bytes)."""
    monkeypatch.setattr(mt_mod, "SpliceReceiver", _FakeSplice)
    monkeypatch.setattr(mt_mod, "SPLICE", True)
    data = os.urandom((1 << 18) + 12345)
    srcp = tmp_path / f"src_{tag}.bin"
    srcp.write_bytes(data)
    dstp = tmp_path / f"dst_{tag}.bin"
    pairs = [socket.socketpair() for _ in range(2)]
    sink = Sink(str(dstp), len(data))
    res = {}

    def rx():
        res["st"] = mt_receive(
            [pb for _, pb in pairs], sink, 1 << 13, use_splice=True,
            batch_frames=batch_frames, arbiter_factory=arbiter_factory,
        )

    t = threading.Thread(target=rx)
    t.start()
    source = Source(str(srcp), len(data), 1 << 13)
    worker_send([pa for pa, _ in pairs], source, SESSION,
                use_processes=False, batch_frames=batch_frames)
    t.join()
    source.close()
    sink.close()
    for pa, pb in pairs:
        pa.close()
        pb.close()
    return res["st"], data, dstp.read_bytes()


def test_mt_adaptive_splice_autodisables_per_frame(tmp_path, monkeypatch):
    """Per-frame mode: each channel's arbiter measures splice slower and
    falls back to the pool path mid-stream; the switch is counted."""
    st, data, got = _mt_transfer(
        tmp_path, monkeypatch, batch_frames=1,
        arbiter_factory=lambda: _SwitchToPool(2), tag="pf")
    assert got == data and st.bytes == len(data)
    assert st.splice_autodisables == 2, "one measured switch per channel"
    assert 0 < st.splice_bytes < len(data)


def test_mt_adaptive_splice_autodisables_batched(tmp_path, monkeypatch):
    """Batched mode: the splice->slab handoff seeds each channel's slab
    parser mid-stream and the rest of the file lands on the slab path."""
    st, data, got = _mt_transfer(
        tmp_path, monkeypatch, batch_frames=4,
        arbiter_factory=lambda: _SwitchToPool(2), tag="ba")
    assert got == data and st.bytes == len(data)
    assert st.splice_autodisables == 2
    assert st.recv_calls > 0, "slab path never engaged after the switch"


def test_mt_adaptive_switchback_to_splice_batched(tmp_path, monkeypatch):
    """The reverse decision: slab trial first, splice wins — the slab
    parser hands off mid-stream (possibly mid-frame) and the remainder
    goes kernel-side. Not an autodisable."""
    st, data, got = _mt_transfer(
        tmp_path, monkeypatch, batch_frames=4,
        arbiter_factory=lambda: _SwitchToSplice(2), tag="sb")
    assert got == data and st.bytes == len(data)
    assert st.splice_autodisables == 0
    assert st.splice_bytes > 0, "splice never engaged after the switchback"


def test_mp_adaptive_splice_autodisable_crosses_fork(tmp_path, monkeypatch):
    """MP children run the same arbiter; the autodisable count must
    travel back over the stats pipe."""
    monkeypatch.setattr(mp_mod, "SpliceReceiver", _FakeSplice)
    monkeypatch.setattr(mp_mod, "SPLICE", True)
    from repro.core.engines.mp import mp_receive

    data = os.urandom((1 << 17) + 999)
    srcp = tmp_path / "src.bin"
    srcp.write_bytes(data)
    dstp = tmp_path / "dst.bin"
    pairs = [socket.socketpair() for _ in range(2)]
    sink = Sink(str(dstp), len(data))
    res = {}

    def rx():
        res["st"] = mp_receive(
            [pb for _, pb in pairs], sink, 1 << 13, use_splice=True,
            arbiter_factory=lambda: _SwitchToPool(2),
        )

    t = threading.Thread(target=rx)
    t.start()
    source = Source(str(srcp), len(data), 1 << 13)
    worker_send([pa for pa, _ in pairs], source, SESSION,
                use_processes=False)
    t.join()
    source.close()
    sink.close()
    for pa, pb in pairs:
        pa.close()
        pb.close()
    st = res["st"]
    assert dstp.read_bytes() == data and st.bytes == len(data)
    assert st.splice_autodisables == 2
