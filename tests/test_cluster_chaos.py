"""Cluster chaos matrix: state-triggered fault injection against the
durable, fail-over-able control plane.

Every scenario drives a real multi-node cluster and fires its faults
with :class:`repro.core.faults.ChaosHarness` triggers — predicates over
live stats ("the first re-replication was planned", "three commits
landed") rather than timers, so the fault hits the interesting moment on
fast and slow machines alike. The invariant under test throughout: **no
acknowledged commit is lost** — every ``put`` that returned is readable
after the dust settles — and puts/gets/checkpoints complete through
metanode crashes, leader failover, and partitions.

Select with ``-m chaos`` (the CI fault-matrix job runs ``fault or
chaos``).
"""
import os
import socket
import time

import pytest

from repro.cluster import ClusterClient, ClusterError, DataNode, MetaNode
from repro.cluster.journal import JOURNAL_NAME
from repro.core.faults import ChaosHarness, FaultyProxy, RetryPolicy

pytestmark = pytest.mark.chaos

T = 0.5  # heartbeat timeout driving every detector/lease in the matrix


def _await(pred, timeout=30.0, msg="condition"):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return
        time.sleep(0.05)
    raise AssertionError(f"timed out waiting for {msg}")


def _deep_policy():
    """A client policy deep enough to ride out a metanode restart or a
    standby promotion (~2s of backoff across redials)."""
    return RetryPolicy(attempts=8, base_delay=0.05, max_delay=0.5,
                       connect_timeout=2.0, io_timeout=5.0)


def _dead_port():
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    addr = s.getsockname()[:2]
    s.close()
    return addr


def _datanodes(metas, tmp_path, n):
    return [
        DataNode(metas, str(tmp_path / f"n{i}"), node_id=f"n{i}",
                 heartbeat_interval=0.05,
                 policy=RetryPolicy(attempts=3, base_delay=0.05,
                                    connect_timeout=2.0, io_timeout=5.0))
        .start()
        for i in range(n)
    ]


def test_metanode_kill_restart_mid_put_stream(tmp_path):
    """Kill -9 the journaled MetaNode in the middle of a stream of puts
    and restart it on the same port: the client retries through the
    outage, every acknowledged commit is readable afterwards, and the
    restarted instance recovered from its journal."""
    jdir = tmp_path / "wal"
    state = {"meta": MetaNode(replication=2, heartbeat_timeout=T,
                              tick_interval=0.1,
                              journal_dir=str(jdir)).start()}
    port = state["meta"].address[1]
    nodes = _datanodes(state["meta"].address, tmp_path, 3)
    cli = ClusterClient(state["meta"].address, block_size=32 << 10,
                        policy=_deep_policy())

    def crash_and_restart():
        state["meta"].kill()
        state["meta"] = MetaNode(replication=2, heartbeat_timeout=T,
                                 tick_interval=0.1, port=port,
                                 journal_dir=str(jdir)).start()

    acked = {}
    try:
        with ChaosHarness() as chaos:
            chaos.when(lambda: state["meta"].stats["commits"] >= 3,
                       crash_and_restart, name="metanode crash+restart")
            for i in range(8):
                data = os.urandom(96 << 10)
                cli.put(f"f{i}.bin", data=data)
                acked[f"f{i}.bin"] = data
            chaos.wait()
        assert state["meta"].stats["replayed_records"] > 0
        for name, data in acked.items():  # no acked commit lost
            assert cli.get(name) == data
        assert sorted(cli.list()) == sorted(acked)
    finally:
        cli.close()
        for n in nodes:
            n.stop()
        state["meta"].stop()


def test_leader_kill_during_rereplication_fails_over(tmp_path):
    """A datanode dies; the leader plans its re-replication — and dies
    mid-heal. The standby's lease expires, it promotes with a bumped
    epoch, datanodes and the client fail over along their address
    lists, and the heal completes under the new leader."""
    m1 = MetaNode(replication=2, heartbeat_timeout=T, tick_interval=0.1,
                  journal_dir=str(tmp_path / "m1"), meta_id="m1").start()
    m2 = MetaNode(replication=2, heartbeat_timeout=T, tick_interval=0.1,
                  journal_dir=str(tmp_path / "m2"), meta_id="m2",
                  peers=[m1.address], lease_timeout=1.0).start()
    assert m1.role == "leader" and m2.role == "standby"
    metas = [m1.address, m2.address]
    nodes = _datanodes(metas, tmp_path, 3)
    cli = ClusterClient(metas, block_size=64 << 10, policy=_deep_policy())
    data = os.urandom(512 << 10)
    try:
        cli.put("r.bin", data=data)
        # the failover guarantee is bounded by replication: wait for the
        # standby to have tailed the commit before faulting
        _await(lambda: m2.seq >= m1.seq, msg="standby caught up")
        with ChaosHarness() as chaos:
            chaos.when(lambda: m1.stats["re_replications"] >= 1,
                       m1.kill, name="leader dies mid-heal")
            nodes[0].kill()
            chaos.wait()
        _await(lambda: m2.role == "leader", msg="standby promotion")
        assert m2.epoch > m1.epoch - 1  # promoted past the dead leader
        assert cli.get("r.bin") == data  # client failed over
        _await(lambda: all(c >= 2 for c in m2.replication_of("r.bin")),
               msg="re-replication heal under the new leader")
        # the cluster is fully writable under the new leader
        cli.put("after.bin", data=b"alive")
        assert cli.get("after.bin") == b"alive"
        assert cli._ctrl.epoch == m2.epoch
    finally:
        cli.close()
        for n in nodes[1:]:
            n.stop()
        m2.stop()


def test_journal_corruption_keeps_intact_prefix(tmp_path):
    """Disk damage to the journal: trailing garbage is ignored entirely,
    and a torn final record costs exactly the mutations from that record
    on — everything before the tear replays."""
    jdir = tmp_path / "wal"
    meta = MetaNode(replication=2, heartbeat_timeout=T, tick_interval=0.1,
                    journal_dir=str(jdir)).start()
    port = meta.address[1]
    nodes = _datanodes(meta.address, tmp_path, 2)
    cli = ClusterClient(meta.address, block_size=64 << 10,
                        policy=_deep_policy())
    a = os.urandom(64 << 10)
    b = os.urandom(64 << 10)
    try:
        cli.put("a.bin", data=a)
        cli.put("b.bin", data=b)
        meta.kill()
        jpath = jdir / JOURNAL_NAME
        raw = jpath.read_bytes()
        # torn tail: garbage appended by a crashing writer
        jpath.write_bytes(raw + b"\xde\xad\xbe\xef")
        meta = MetaNode(replication=2, heartbeat_timeout=T,
                        tick_interval=0.1, port=port,
                        journal_dir=str(jdir)).start()
        assert cli.get("a.bin") == a
        assert cli.get("b.bin") == b
        # torn final record: the last commit (b.bin) is cut mid-record —
        # its ack never left a real crash, so only IT is lost
        meta.kill()
        jpath.write_bytes(raw[:-3])
        meta = MetaNode(replication=2, heartbeat_timeout=T,
                        tick_interval=0.1, port=port,
                        journal_dir=str(jdir)).start()
        assert cli.get("a.bin") == a
        with pytest.raises(ClusterError):
            cli.get("b.bin")
        # and the survivor is a fully functional control plane
        cli.put("c.bin", data=b"c")
        assert cli.get("c.bin") == b"c"
    finally:
        cli.close()
        for n in nodes:
            n.stop()
        meta.stop()


def test_datanode_and_leader_double_fault(tmp_path):
    """The double fault: a datanode and the leader die at the same
    moment. The standby promotes, re-detects the dead datanode with its
    own failure detector, heals replication on the survivors, and the
    data never stops being readable."""
    m1 = MetaNode(replication=2, heartbeat_timeout=T, tick_interval=0.1,
                  journal_dir=str(tmp_path / "m1"), meta_id="m1").start()
    m2 = MetaNode(replication=2, heartbeat_timeout=T, tick_interval=0.1,
                  journal_dir=str(tmp_path / "m2"), meta_id="m2",
                  peers=[m1.address], lease_timeout=1.0).start()
    metas = [m1.address, m2.address]
    nodes = _datanodes(metas, tmp_path, 3)
    cli = ClusterClient(metas, block_size=64 << 10, policy=_deep_policy())
    data = os.urandom(256 << 10)
    try:
        cli.put("d.bin", data=data)
        _await(lambda: m2.seq >= m1.seq, msg="standby caught up")
        with ChaosHarness() as chaos:
            # both faults keyed on the same predicate = simultaneous
            started = time.monotonic()
            chaos.when(lambda: time.monotonic() >= started,
                       nodes[1].kill, name="datanode dies")
            chaos.when(lambda: time.monotonic() >= started,
                       m1.kill, name="leader dies")
            chaos.wait()
        _await(lambda: m2.role == "leader", msg="standby promotion")
        assert cli.get("d.bin") == data
        _await(lambda: all(c >= 2 for c in m2.replication_of("d.bin")),
               msg="heal on survivors under new leader")
        st = cli.state()
        assert st["meta_id"] == "m2" and st["lost"] == []
    finally:
        cli.close()
        for n in (nodes[0], nodes[2]):
            n.stop()
        m2.stop()


def test_heartbeat_partition_declares_dead_then_heals(tmp_path):
    """A FaultyProxy between one datanode and the MetaNode simulates a
    control-plane partition: heartbeats stop crossing, the detector
    declares the node dead (reads keep serving from replicas), and when
    the partition heals the node beats its way right back to alive —
    no restart, no re-registration storm."""
    meta = MetaNode(replication=2, heartbeat_timeout=T,
                    tick_interval=0.1).start()
    proxy = FaultyProxy(meta.address)
    n0 = DataNode(proxy.address, str(tmp_path / "n0"), node_id="n0",
                  heartbeat_interval=0.05,
                  policy=RetryPolicy(attempts=2, base_delay=0.05,
                                     connect_timeout=1.0,
                                     io_timeout=2.0)).start()
    n1 = DataNode(meta.address, str(tmp_path / "n1"), node_id="n1",
                  heartbeat_interval=0.05).start()
    cli = ClusterClient(meta.address, block_size=64 << 10,
                        policy=_deep_policy())
    data = os.urandom(128 << 10)

    def alive(node_id):
        st = {n["node_id"]: n["alive"] for n in cli.state()["nodes"]}
        return st.get(node_id, False)

    try:
        cli.put("p.bin", data=data)
        _await(lambda: alive("n0") and alive("n1"), msg="both nodes alive")
        # partition: the proxy forwards to a dead port and severs every
        # live control connection
        proxy.upstream = _dead_port()
        proxy.kill_all()
        _await(lambda: not alive("n0"), msg="partitioned node declared dead")
        assert alive("n1")
        assert cli.get("p.bin") == data  # rf=2: the replica serves
        # heal: heartbeats cross again, the detector revives the node
        proxy.upstream = meta.address
        _await(lambda: alive("n0"), msg="partition heal")
        assert cli.get("p.bin") == data
        assert cli.state()["lost"] == []
    finally:
        cli.close()
        proxy.close()
        n0.stop()
        n1.stop()
        meta.stop()
