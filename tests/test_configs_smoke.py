"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes and finiteness (deliverable f).
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import SHAPES, get_config, list_configs
from repro.models.transformer import build_model
from repro.optim import make_optimizer
from repro.runtime.train import init_state, make_train_step

ARCHS = list(list_configs())
B, S = 2, 64


def _inputs(cfg, key, b=B, s=S):
    if cfg.frontend:
        return jax.random.normal(key, (b, s, cfg.d_model), jnp.bfloat16)
    return jax.random.randint(key, (b, s), 0, cfg.vocab_size)


@pytest.mark.parametrize("arch", ARCHS)
def test_all_archs_registered_full_configs(arch):
    cfg = get_config(arch)
    assert cfg.num_layers >= 16
    assert cfg.vocab_size >= 2048
    # every arch x shape cell is either runnable or a documented skip
    for name, shape in SHAPES.items():
        if name == "long_500k" and not cfg.supports_long_context:
            continue
        assert shape.global_batch >= 1


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch, mesh11, key):
    cfg = get_config(arch).smoke()
    with mesh11:
        model = build_model(cfg, mesh11, "train")
        params = model.init(key)
        batch = {
            "inputs": _inputs(cfg, key),
            "labels": jax.random.randint(jax.random.fold_in(key, 1), (B, S), 0, cfg.vocab_size),
        }
        loss, metrics = jax.jit(model.loss)(params, batch)
        assert loss.shape == ()
        assert bool(jnp.isfinite(loss)), f"{arch} loss not finite"

        opt = make_optimizer(cfg)
        state = init_state(model, key, opt)
        step = jax.jit(make_train_step(model, opt))
        state2, m2 = step(state, batch)
        assert int(state2.step) == 1
        for leaf in jax.tree.leaves(state2.params):
            assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))
        # params actually changed
        changed = any(
            bool(jnp.any(a != b))
            for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(state2.params))
        )
        assert changed, f"{arch}: train step did not update params"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode_shapes(arch, mesh11, key):
    cfg = get_config(arch).smoke()
    with mesh11:
        mp = build_model(cfg, mesh11, "prefill")
        params = mp.init(key)
        logits, caches = jax.jit(mp.prefill)(params, {"inputs": _inputs(cfg, key)})
        assert logits.shape[0] == B and logits.shape[1] == 1
        md = build_model(cfg, mesh11, "decode")
        one = (
            jax.random.normal(key, (B, 1, cfg.d_model), jnp.bfloat16)
            if cfg.frontend
            else jnp.ones((B, 1), jnp.int32)
        )
        dl, caches2 = jax.jit(md.decode_step)(
            params, {"inputs": one, "caches": caches, "pos": jnp.int32(S)}
        )
        assert dl.shape[:2] == (B, 1)
        assert bool(jnp.all(jnp.isfinite(dl)))
        assert jax.tree.structure(caches) == jax.tree.structure(caches2)
