"""Device-channel collectives == native collectives (8-device subprocess:
the multi-device host platform flag must be set before jax initializes,
so equivalence runs in a child interpreter)."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core.channel import (ring_all_reduce, stream_broadcast,
                                    ring_reduce_scatter, ring_all_gather)
    from repro.core.compress import Int8Codec

    mesh = jax.make_mesh((8,), ("x",))
    x = jax.random.normal(jax.random.key(0), (8, 64, 3))
    expect = jnp.tile(jnp.sum(x, axis=0, keepdims=True), (8, 1, 1))

    def sm(f):
        return jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P("x"),
                                     out_specs=P("x"), check_vma=False))

    for bidir in (True, False):
        out = sm(lambda a: ring_all_reduce(a, "x", bidirectional=bidir))(x)
        assert float(jnp.max(jnp.abs(out - expect))) < 1e-4, bidir

    out = sm(lambda a: ring_all_reduce(a, "x", codec=Int8Codec))(x)
    rel = float(jnp.max(jnp.abs(out - expect)) / jnp.max(jnp.abs(expect)))
    assert rel < 0.05, f"int8 ring error {rel}"

    # rs+ag composition == psum
    def rsag(a):
        flat = a.reshape(-1)
        return ring_all_gather(ring_reduce_scatter(flat, "x"), "x").reshape(a.shape)
    out = sm(rsag)(x)
    assert float(jnp.max(jnp.abs(out - expect))) < 1e-4

    out = sm(lambda a: stream_broadcast(a[0], "x", src=0)[None])(x)
    assert bool(jnp.all(out == jnp.tile(x[0:1], (8, 1, 1))))
    print("CHANNEL_OK")
    """
)


@pytest.mark.slow
def test_ring_collectives_equivalence_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True, text=True,
        timeout=300, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert "CHANNEL_OK" in r.stdout, r.stderr[-2000:]


def test_ring_collectives_single_device(mesh11):
    """n=1 degenerate path stays exact."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.core.channel import ring_all_reduce

    x = jnp.arange(12.0).reshape(4, 3)
    f = jax.shard_map(
        lambda a: ring_all_reduce(a, "model"),
        mesh=mesh11, in_specs=P(), out_specs=P(), check_vma=False,
    )
    with mesh11:
        out = jax.jit(f)(x)
    assert bool(jnp.all(out == x))
