"""Flash attention Pallas TPU kernel.

Grid (B*H, n_q, n_k) with the KV dim minor-most: on TPU the grid is executed
sequentially per core, so the (m, l, acc) online-softmax state lives in VMEM
scratch and persists across the n_k sweep of each (bh, qi) tile — the classic
TPU flash schedule. Block shapes are MXU-aligned (multiples of 128 on the
lane dim; block_q x block_k tiles on the sublane side).

Supports causal masking, sliding windows (local attention), and tanh logit
softcaps (gemma2), matching the model's XLA-path math bit-for-bit in f32
softmax. Fully-masked KV tiles are skipped via @pl.when.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref,  # (1, block_q, d), (1, block_k, d)
    o_ref,  # (1, block_q, d)
    m_s, l_s, acc_s,  # scratch: (block_q, 1), (block_q, 1), (block_q, d)
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    logit_cap: Optional[float],
    block_q: int,
    block_k: int,
    n_k: int,
    seq_k: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q0 = qi * block_q
    k0 = ki * block_k
    # tile-level reachability (skip fully masked tiles)
    reachable = True
    if causal:
        reachable = k0 <= q0 + block_q - 1
    if window is not None:
        reachable = jnp.logical_and(reachable, k0 + block_k > q0 - (window - 1))

    @pl.when(reachable)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale
        if logit_cap is not None:
            s = jnp.tanh(s / logit_cap) * logit_cap
        qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < seq_k
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_s[...] = l_s[...] * corr + p.sum(axis=1, keepdims=True)
        m_s[...] = m_new
        acc_s[...] = acc_s[...] * corr + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(ki == n_k - 1)
    def _finalize():
        o_ref[0] = (acc_s[...] / jnp.maximum(l_s[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_bhsd(
    q, k, v, *,
    scale: float,
    causal: bool = True,
    window: Optional[int] = None,
    logit_cap: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
):
    """q, k, v: (B, H, S, D) (kv heads already aligned) -> (B, H, S, D)."""
    b, h, sq, d = q.shape
    sk = k.shape[2]
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    sq_p, sk_p = sq + pad_q, sk + pad_k
    n_q, n_k = sq_p // block_q, sk_p // block_k

    qr = q.reshape(b * h, sq_p, d)
    kr = k.reshape(b * h, sk_p, d)
    vr = v.reshape(b * h, sk_p, d)

    kernel = functools.partial(
        _kernel,
        scale=scale, causal=causal, window=window, logit_cap=logit_cap,
        block_q=block_q, block_k=block_k, n_k=n_k, seq_k=sk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * h, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, sq_p, d)[:, :, :sq]
