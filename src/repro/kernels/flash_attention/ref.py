"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(
    q,
    k,
    v,
    *,
    scale: float,
    causal: bool = True,
    window: Optional[int] = None,
    logit_cap: Optional[float] = None,
):
    """q, k, v: (B, H, S, D) -> (B, H, S, D). Full-materialization reference."""
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k, preferred_element_type=jnp.float32)
    s = s * scale
    if logit_cap is not None:
        s = jnp.tanh(s / logit_cap) * logit_cap
    sq, sk = q.shape[2], k.shape[2]
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)
