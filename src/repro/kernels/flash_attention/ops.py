"""jit'd public wrapper for the flash attention kernel (GQA-aware)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


@functools.partial(
    jax.jit,
    static_argnames=(
        "scale", "causal", "window", "logit_cap", "block_q", "block_k",
        "interpret",
    ),
)
def flash_attention(
    q, k, v, *,
    scale: float,
    causal: bool = True,
    window: Optional[int] = None,
    logit_cap: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
):
    """q: (B, S, Hq, D); k, v: (B, S, Hkv, D) with Hq % Hkv == 0.

    GQA is handled by repeating kv heads (zero-copy under XLA when fused).
    Returns (B, S, Hq, D).
    """
    hq, hkv = q.shape[2], k.shape[2]
    if hq != hkv:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_bhsd(
        qt, kt, vt,
        scale=scale, causal=causal, window=window, logit_cap=logit_cap,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
    return out.transpose(0, 2, 1, 3)
