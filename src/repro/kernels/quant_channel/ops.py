"""jit'd wrappers for the ZxDFS codec kernels."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.quant_channel.kernel import GROUP, dequant_accumulate, quantize


@functools.partial(jax.jit, static_argnames=("interpret",))
def roundtrip(x, *, interpret: bool = False):
    """quantize -> dequantize (+0), reshaped back to x's shape."""
    q, s = quantize(x, interpret=interpret)
    zero = jnp.zeros_like(q, jnp.float32)
    flat = dequant_accumulate(q, s, zero, interpret=interpret).reshape(-1)
    return flat[: x.size].reshape(x.shape).astype(x.dtype)
