"""Pure-jnp oracle for the ZxDFS int8 channel codec (= core.compress)."""
from repro.core.compress import dequantize_int8, quantize_int8  # noqa: F401


def roundtrip_ref(x, block: int = 256):
    return dequantize_int8(quantize_int8(x, block))
