"""ZxDFS channel codec Pallas kernels: fused int8 quantize / dequant-accumulate.

The paper's zero-copy idea on TPU: payloads are quantized IN VMEM on their
way into the channel (one read of the f32/bf16 source, one write of int8 +
scales — no intermediate HBM round-trip), and the receive side fuses
dequantize with the reduction accumulate. Tiles are (block_rows, 256) with
the quant group = one 256-lane row, matching the VPU lane width.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

GROUP = 256


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)  # (rows, GROUP)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q_ref[...] = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    s_ref[...] = scale


def _dequant_acc_kernel(q_ref, s_ref, acc_ref, o_ref):
    x = q_ref[...].astype(jnp.float32) * s_ref[...]
    o_ref[...] = (acc_ref[...].astype(jnp.float32) + x).astype(o_ref.dtype)


def quantize(x, *, block_rows: int = 256, interpret: bool = False):
    """x: any shape -> (q int8 (n, GROUP), scale f32 (n, 1)). Pads tail."""
    flat = x.reshape(-1)
    pad = (-flat.size) % GROUP
    flat = jnp.pad(flat, (0, pad))
    rows = flat.size // GROUP
    block_rows = min(block_rows, rows)
    rpad = (-rows) % block_rows
    mat = jnp.pad(flat.reshape(rows, GROUP), ((0, rpad), (0, 0)))
    n = mat.shape[0] // block_rows
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(n,),
        in_specs=[pl.BlockSpec((block_rows, GROUP), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, GROUP), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(mat.shape, jnp.int8),
            jax.ShapeDtypeStruct((mat.shape[0], 1), jnp.float32),
        ],
        interpret=interpret,
    )(mat)
    return q[:rows], s[:rows]


def dequant_accumulate(q, s, acc, *, block_rows: int = 256, interpret: bool = False):
    """acc (+)= dequant(q, s). q: (n, GROUP) int8; s: (n, 1); acc: (n, GROUP)."""
    rows = q.shape[0]
    block_rows = min(block_rows, rows)
    rpad = (-rows) % block_rows
    if rpad:
        q = jnp.pad(q, ((0, rpad), (0, 0)))
        s = jnp.pad(s, ((0, rpad), (0, 0)))
        acc = jnp.pad(acc, ((0, rpad), (0, 0)))
    n = q.shape[0] // block_rows
    out = pl.pallas_call(
        _dequant_acc_kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((block_rows, GROUP), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, GROUP), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, GROUP), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, acc.dtype),
        interpret=interpret,
    )(q, s, acc)
    return out[:rows]
