"""Pure-jnp oracle for the RG-LRU diagonal linear scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def linear_scan_ref(a, bx, h0):
    """h_t = a_t * h_{t-1} + bx_t. a, bx: (B, T, C) f32; h0: (B, C) f32.

    Returns (h_all (B, T, C), h_last (B, C))."""

    def step(h, xs):
        at, bt = xs
        h = at * h + bt
        return h, h

    h_last, hs = jax.lax.scan(
        step, h0, (a.transpose(1, 0, 2), bx.transpose(1, 0, 2))
    )
    return hs.transpose(1, 0, 2), h_last
