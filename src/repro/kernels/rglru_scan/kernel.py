"""RG-LRU diagonal linear-recurrence Pallas kernel.

Grid (B, n_c, n_t) with the TIME dim minor-most: the hidden state lives in a
VMEM scratch that persists across the sequential time-tile sweep (same trick
as the flash kernel's online-softmax state). Channels are tiled in 128-lane
multiples; within a (block_t, block_c) tile the recurrence is an unrolled
fori over time ON VMEM-resident data (HBM sees each element exactly once in
and once out — the kernel is bandwidth-optimal, unlike the XLA
associative-scan lowering which materializes log-depth intermediates).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, o_ref, h_s, *, block_t: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_s[...] = jnp.zeros_like(h_s)

    a = a_ref[0].astype(jnp.float32)  # (block_t, block_c)
    b = b_ref[0].astype(jnp.float32)
    h = h_s[...]  # (1, block_c)

    def step(t, carry):
        h, out = carry
        h = a[t][None, :] * h + b[t][None, :]
        out = jax.lax.dynamic_update_slice(out, h, (t, 0))
        return h, out

    out0 = jnp.zeros_like(a)
    h, out = jax.lax.fori_loop(0, block_t, step, (h, out0))
    h_s[...] = h
    o_ref[0] = out.astype(o_ref.dtype)


def linear_scan(
    a, bx, *, block_t: int = 256, block_c: int = 256, interpret: bool = False
):
    """a, bx: (B, T, C); zero initial state. Returns h_all (B, T, C)."""
    b, t, c = a.shape
    block_t = min(block_t, t)
    block_c = min(block_c, c)
    pad_t = (-t) % block_t
    pad_c = (-c) % block_c
    if pad_t or pad_c:
        a = jnp.pad(a, ((0, 0), (0, pad_t), (0, pad_c)))
        bx = jnp.pad(bx, ((0, 0), (0, pad_t), (0, pad_c)))
    tp, cp = t + pad_t, c + pad_c
    n_t, n_c = tp // block_t, cp // block_c

    out = pl.pallas_call(
        functools.partial(_kernel, block_t=block_t),
        grid=(b, n_c, n_t),
        in_specs=[
            pl.BlockSpec((1, block_t, block_c), lambda bi, ci, ti: (bi, ti, ci)),
            pl.BlockSpec((1, block_t, block_c), lambda bi, ci, ti: (bi, ti, ci)),
        ],
        out_specs=pl.BlockSpec((1, block_t, block_c), lambda bi, ci, ti: (bi, ti, ci)),
        out_shape=jax.ShapeDtypeStruct((b, tp, cp), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, block_c), jnp.float32)],
        interpret=interpret,
    )(a, bx)
    return out[:, :t, :c]
