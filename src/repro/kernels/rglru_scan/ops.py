"""jit'd wrapper for the RG-LRU scan kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rglru_scan.kernel import linear_scan


@functools.partial(jax.jit, static_argnames=("interpret",))
def rglru_scan(a, bx, h0=None, *, interpret: bool = False):
    """h_t = a_t o h_{t-1} + bx_t with h_0 = h0 (folded into step 0)."""
    if h0 is not None:
        # fold the initial state into the first step: b_0' = a_0*h0 + b_0
        bx = bx.at[:, 0, :].add(a[:, 0, :] * h0)
    h_all = linear_scan(a, bx, interpret=interpret)
    return h_all, h_all[:, -1, :]
