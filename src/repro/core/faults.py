"""Failure policy + fault injection for the xDFS datapath.

One policy object owns every "how long / how often" knob so callers stop
growing ad-hoc retry loops:

* :class:`Deadline` — a monotonic budget shared across the steps of one
  operation (e.g. dialing all n channels of a connect). ``remaining()``
  feeds socket timeouts; expiry raises :class:`DeadlineExceeded`, a
  ``TimeoutError`` subclass so callers can catch the stdlib type.
* :class:`RetryPolicy` — capped, jittered exponential backoff with an
  injectable clock/sleep/rng (tests run it on a fake clock).
  :meth:`RetryPolicy.run` retries a callable and raises
  :class:`RetriesExhausted` chained to the last failure.
* :class:`FaultyProxy` — the fault-injection harness: a TCP proxy that
  forwards byte streams between a client and an upstream server and, at
  configured per-direction byte offsets, corrupts a byte, severs every
  connection (crash), or stalls forever (hang). Built for the e2e
  kill/resume/corruption matrix in ``tests/test_robustness.py``.
"""
from __future__ import annotations

import os
import random
import socket
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple, Type


class DeadlineExceeded(TimeoutError):
    """An operation ran past its deadline (subclass of TimeoutError)."""


class RetriesExhausted(Exception):
    """Every attempt of a retried operation failed; ``__cause__`` is the
    last underlying failure."""


class Deadline:
    """A monotonic time budget. ``Deadline(None)`` never expires."""

    __slots__ = ("_clock", "_expires")

    def __init__(self, seconds: Optional[float],
                 clock: Callable[[], float] = time.monotonic):
        self._clock = clock
        self._expires = None if seconds is None else clock() + seconds

    @classmethod
    def after(cls, seconds: Optional[float], **kw) -> "Deadline":
        return cls(seconds, **kw)

    def remaining(self) -> float:
        if self._expires is None:
            return float("inf")
        return self._expires - self._clock()

    def expired(self) -> bool:
        return self.remaining() <= 0

    def check(self, what: str = "operation") -> None:
        if self.expired():
            raise DeadlineExceeded(f"{what} deadline exceeded")

    def budget(self, cap: Optional[float] = None) -> Optional[float]:
        """A socket-timeout value: min(cap, remaining), None = unbounded."""
        rem = self.remaining()
        if rem == float("inf"):
            return cap
        rem = max(rem, 0.001)  # settimeout(0) would mean non-blocking
        return rem if cap is None else min(cap, rem)


@dataclass
class RetryPolicy:
    """Capped jittered exponential backoff + the datapath timeout knobs.

    ``connect_timeout`` bounds one TCP dial; ``io_timeout`` (when set)
    bounds one read/write/stall on an established stream. The clock,
    sleeper, and rng are injectable so tests drive it deterministically.
    """

    attempts: int = 3
    base_delay: float = 0.05
    max_delay: float = 2.0
    multiplier: float = 2.0
    jitter: float = 0.5           # each delay is scaled by [1-j, 1+j]
    connect_timeout: float = 10.0
    io_timeout: Optional[float] = None
    clock: Callable[[], float] = field(default=time.monotonic, repr=False)
    sleep: Callable[[float], None] = field(default=time.sleep, repr=False)
    rng: random.Random = field(default_factory=random.Random, repr=False)

    def __post_init__(self) -> None:
        if self.attempts < 1:
            raise ValueError("attempts must be >= 1")
        if not 0.0 <= self.jitter < 1.0:
            raise ValueError("jitter must be in [0, 1)")

    def delays(self) -> List[float]:
        """The ``attempts - 1`` backoff delays (jittered, capped)."""
        out = []
        delay = self.base_delay
        for _ in range(self.attempts - 1):
            capped = min(delay, self.max_delay)
            scale = 1.0 + self.jitter * (2.0 * self.rng.random() - 1.0)
            out.append(capped * scale)
            delay *= self.multiplier
        return out

    def run(self, fn: Callable[[], object], *,
            retry_on: Tuple[Type[BaseException], ...] = (
                ConnectionError, TimeoutError, OSError),
            deadline: Optional[Deadline] = None,
            what: str = "operation"):
        """Call ``fn`` up to ``attempts`` times. DeadlineExceeded is never
        retried (the budget is gone by definition)."""
        last: Optional[BaseException] = None
        for i, delay in enumerate(self.delays() + [None]):
            if deadline is not None:
                deadline.check(what)
            try:
                return fn()
            except DeadlineExceeded:
                raise
            except retry_on as e:
                last = e
                if delay is None:
                    break
                if deadline is not None and deadline.remaining() <= delay:
                    break
                self.sleep(delay)
        raise RetriesExhausted(
            f"{what} failed after {self.attempts} attempts: {last!r}"
        ) from last

    def connect(self, address: Tuple[str, int], *,
                deadline: Optional[Deadline] = None) -> socket.socket:
        """``socket.create_connection`` with the policy's timeout, retried
        with backoff (the cluster control-plane dial path)."""
        def dial() -> socket.socket:
            timeout = self.connect_timeout
            if deadline is not None:
                timeout = deadline.budget(timeout)
            s = socket.create_connection(address, timeout=timeout)
            s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            return s
        return self.run(dial, deadline=deadline,
                        what=f"connect to {address[0]}:{address[1]}")


# ---------------------------------------------------------------------------
# fault injection


@dataclass
class Fault:
    """One direction's fault spec for :class:`FaultyProxy`.

    Offsets are byte positions within ONE proxied connection's stream for
    that direction (accept order selects the connection via ``conn``;
    ``conn=None`` applies the spec independently to every connection).
    """

    corrupt_at: Optional[int] = None   # XOR 0xFF the byte at this offset
    drop_after: Optional[int] = None   # forward this many bytes, then sever
    #                                    EVERY proxied connection (crash)
    stall_after: Optional[int] = None  # forward this many bytes, then stop
    #                                    forwarding but keep the link open
    conn: Optional[int] = None         # accept-order connection index


class _Pump(threading.Thread):
    """One direction of one proxied connection."""

    def __init__(self, proxy: "FaultyProxy", src: socket.socket,
                 dst: socket.socket, fault: Optional[Fault], name: str):
        super().__init__(name=name, daemon=True)
        self.proxy = proxy
        self.src = src
        self.dst = dst
        self.fault = fault
        self.forwarded = 0

    def run(self) -> None:  # noqa: C901 - linear fault ladder
        f = self.fault
        try:
            while not self.proxy._stop.is_set():
                try:
                    chunk = bytearray(self.src.recv(65536))
                except OSError:
                    break
                if not chunk:
                    break
                pos = self.forwarded
                if f is not None:
                    if (f.corrupt_at is not None
                            and pos <= f.corrupt_at < pos + len(chunk)):
                        chunk[f.corrupt_at - pos] ^= 0xFF
                    cut = None
                    for limit in (f.drop_after, f.stall_after):
                        if limit is not None and pos + len(chunk) > limit:
                            cut = limit if cut is None else min(cut, limit)
                    if cut is not None:
                        head = chunk[: max(0, cut - pos)]
                        if head:
                            try:
                                self.dst.sendall(head)
                            except OSError:
                                # a sibling pump crossed ITS drop point and
                                # kill_all()ed every socket mid-send — that
                                # severing is the intended fault, not an
                                # error in this pump
                                break
                            self.forwarded += len(head)
                        if (f.drop_after is not None
                                and self.forwarded >= f.drop_after):
                            self.proxy.kill_all()
                            return
                        # stall: hold both endpoints open, forward nothing
                        self.proxy._stop.wait()
                        return
                try:
                    self.dst.sendall(chunk)
                except OSError:
                    break
                self.forwarded += len(chunk)
        finally:
            for s in (self.src, self.dst):
                try:
                    s.shutdown(socket.SHUT_RDWR)
                except OSError:
                    pass


class FaultyProxy:
    """A byte-level TCP fault injector between a client and ``upstream``.

    Clients connect to :attr:`address` instead of the real server; every
    accepted connection gets its own upstream dial and two pump threads
    (client->server and server->client) that apply the configured
    :class:`Fault` specs at exact byte offsets. ``kill_all()`` severs
    every proxied connection at once — the "network died" event the
    RESUME flow recovers from.
    """

    def __init__(self, upstream: Tuple[str, int], host: str = "127.0.0.1",
                 c2s: Optional[Fault] = None, s2c: Optional[Fault] = None):
        self.upstream = (upstream[0], int(upstream[1]))
        self.c2s = c2s
        self.s2c = s2c
        self._stop = threading.Event()
        self._lock = threading.Lock()
        self._socks: List[socket.socket] = []
        self._pumps: List[_Pump] = []
        self._n_accepted = 0
        self.stats: Dict[str, int] = {"connections": 0, "c2s_bytes": 0,
                                      "s2c_bytes": 0}
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host, 0))
        self._listener.listen(64)
        self._listener.settimeout(0.2)
        self.address: Tuple[str, int] = self._listener.getsockname()
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="faulty-proxy-accept", daemon=True)
        self._accept_thread.start()

    def _pick(self, spec: Optional[Fault], idx: int) -> Optional[Fault]:
        if spec is None or (spec.conn is not None and spec.conn != idx):
            return None
        return spec

    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                cli, _ = self._listener.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            try:
                srv = socket.create_connection(self.upstream, timeout=10.0)
            except OSError:
                cli.close()
                continue
            for s in (cli, srv):
                s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            with self._lock:
                idx = self._n_accepted
                self._n_accepted += 1
                self.stats["connections"] += 1
                self._socks += [cli, srv]
                pumps = [
                    _Pump(self, cli, srv, self._pick(self.c2s, idx),
                          f"proxy-c2s-{idx}"),
                    _Pump(self, srv, cli, self._pick(self.s2c, idx),
                          f"proxy-s2c-{idx}"),
                ]
                self._pumps += pumps
            for p in pumps:
                p.start()

    def kill_all(self) -> None:
        """Sever every proxied connection (both sides see a dead peer);
        the proxy keeps accepting NEW connections afterwards."""
        with self._lock:
            socks, self._socks = self._socks, []
        for s in socks:
            try:
                s.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                s.close()
            except OSError:
                pass

    def close(self) -> None:
        self._stop.set()
        try:
            self._listener.close()
        except OSError:
            pass
        self.kill_all()
        self._accept_thread.join(2.0)
        with self._lock:
            pumps, self._pumps = self._pumps, []
        for p in pumps:
            p.join(2.0)
            if p.name.startswith("proxy-c2s"):
                self.stats["c2s_bytes"] += p.forwarded
            else:
                self.stats["s2c_bytes"] += p.forwarded

    def __enter__(self) -> "FaultyProxy":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------------
# at-rest fault injectors (durability / scrub tests)


def inject_bit_rot(path: str, offset: Optional[int] = None) -> int:
    """Flip one byte of ``path`` in place (XOR 0xFF) — silent at-rest
    corruption that only a scrub or a CRC-checked read can see. Returns
    the offset rotted (default: the middle byte). The mtime is restored
    so the rot is invisible to timestamp-based change detection, exactly
    like a real decayed sector."""
    st = os.stat(path)
    if st.st_size == 0:
        raise ValueError(f"cannot rot an empty file: {path!r}")
    off = st.st_size // 2 if offset is None else offset
    with open(path, "r+b") as f:
        f.seek(off)
        byte = f.read(1)
        f.seek(off)
        f.write(bytes([byte[0] ^ 0xFF]))
    os.utime(path, ns=(st.st_atime_ns, st.st_mtime_ns))
    return off


def simulate_power_loss(root: str) -> List[str]:
    """What a crash-with-power-cut leaves in a store directory: every
    in-flight atomic temp (``*.xdfs-tmp.*``) vanishes — those bytes were
    never fsynced under their final name, so a real power loss gives no
    guarantee they survive. Committed files are untouched (the atomic
    commit fsynced them before the ACK). Returns the removed paths."""
    from repro.core.engines.base import TMP_INFIX

    removed: List[str] = []
    for dirpath, _dirs, files in os.walk(root):
        for name in files:
            if TMP_INFIX in name:
                full = os.path.join(dirpath, name)
                try:
                    os.unlink(full)
                    removed.append(full)
                except OSError:
                    pass
    return removed


def write_ballast(root: str, capacity_bytes: int, leave: int) -> str:
    """Fill a capacity-capped store so exactly ``leave`` bytes remain
    free (drives the ``disk_full`` preflight deterministically in tests
    — no real ENOSPC needed). Returns the ballast file's path."""
    from repro.core.engines.base import store_free_bytes

    path = os.path.join(root, "ballast.bin")
    free = store_free_bytes(root, capacity_bytes)
    size = max(0, free - leave)
    with open(path, "wb") as f:
        if size:
            f.seek(size - 1)
            f.write(b"\0")
    return path


class Trigger:
    """Fire ``action`` exactly once, the first time ``predicate()`` turns
    true. A background thread polls the predicate (``poll`` seconds apart)
    until it fires, ``timeout`` elapses, or the owning harness closes.

    The building block of :class:`ChaosHarness`: chaos scenarios are
    written as *state-triggered* events ("kill the leader after the first
    re-replication is planned") instead of timer-based ones, so they fire
    at the interesting moment on fast and slow machines alike.
    """

    def __init__(self, predicate: Callable[[], bool],
                 action: Callable[[], None], name: str = "trigger",
                 poll: float = 0.01, timeout: float = 30.0):
        self.predicate = predicate
        self.action = action
        self.name = name
        self.poll = poll
        self.timeout = timeout
        self.fired = threading.Event()
        self.timed_out = False
        self.error: Optional[BaseException] = None
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run,
                                        name=f"chaos-{name}", daemon=True)
        self._thread.start()

    def _run(self) -> None:
        deadline = time.monotonic() + self.timeout
        while not self._stop.is_set():
            try:
                hit = self.predicate()
            except Exception as e:  # noqa: BLE001 - a racing predicate
                # (peer mid-death) must not kill the trigger thread
                self.error = e
                hit = False
            if hit:
                # fire exactly once: even a raising action counts as
                # the one invocation (recorded in .error, never retried)
                try:
                    self.action()
                except Exception as e:  # noqa: BLE001
                    self.error = e
                finally:
                    self.fired.set()
                return
            if time.monotonic() >= deadline:
                self.timed_out = True
                return
            self._stop.wait(self.poll)

    def wait(self, timeout: float = 30.0) -> bool:
        """Block until the trigger fired; False on timeout."""
        return self.fired.wait(timeout)

    def cancel(self) -> None:
        self._stop.set()
        self._thread.join(2.0)


class ChaosHarness:
    """A scenario's worth of state-triggered fault injections.

    Register events with :meth:`when` ("once this predicate holds, run
    this action"), drive the workload under test, then :meth:`wait` for
    every trigger to have fired (asserting the scenario actually
    exercised the faults it meant to — a chaos test whose kill never
    fired is a false pass). Use as a context manager so stray trigger
    threads never outlive a failing test.
    """

    def __init__(self, poll: float = 0.01, timeout: float = 30.0):
        self.poll = poll
        self.timeout = timeout
        self.triggers: List[Trigger] = []

    def when(self, predicate: Callable[[], bool],
             action: Callable[[], None], name: str = "") -> Trigger:
        trig = Trigger(predicate, action,
                       name=name or f"event-{len(self.triggers)}",
                       poll=self.poll, timeout=self.timeout)
        self.triggers.append(trig)
        return trig

    def wait(self, timeout: float = 30.0) -> None:
        """Block until every registered trigger fired; raises
        :class:`DeadlineExceeded` naming the stragglers otherwise."""
        deadline = time.monotonic() + timeout
        for trig in self.triggers:
            if not trig.fired.wait(max(0.0, deadline - time.monotonic())):
                raise DeadlineExceeded(
                    f"chaos trigger {trig.name!r} never fired "
                    f"(timed_out={trig.timed_out}, error={trig.error!r})")

    def close(self) -> None:
        for trig in self.triggers:
            trig.cancel()

    def __enter__(self) -> "ChaosHarness":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
