"""ZxDFS compressed-channel payload codec: per-block symmetric int8.

Pure-jnp reference implementation; ``kernels/quant_channel`` is the Pallas
TPU twin (fused quantize-on-the-way-into-the-channel) validated against this
in tests. Used by core/channel.py to halve ICI bytes for gradient sync.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


class Quantized(NamedTuple):
    q: jax.Array  # int8, shape (n_blocks, BLOCK)
    scale: jax.Array  # f32 (n_blocks, 1)
    orig_size: int  # static: original element count
    orig_shape: tuple


def quantize_int8(x: jax.Array, block: int = BLOCK) -> Quantized:
    shape = x.shape
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.size
    pad = (-n) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    amax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return Quantized(q, scale, n, shape)


def dequantize_int8(z: Quantized) -> jax.Array:
    flat = (z.q.astype(jnp.float32) * z.scale).reshape(-1)
    return flat[: z.orig_size].reshape(z.orig_shape)


def wire_bytes(z: Quantized) -> int:
    """Bytes on the wire for a quantized payload (int8 + f32 scales)."""
    return z.q.size + z.scale.size * 4


class Int8Codec:
    """Codec interface used by core.channel ring collectives."""

    name = "int8"
    ratio = 0.5  # vs bf16 payloads (plus per-block scale overhead)

    @staticmethod
    def encode(x):
        return quantize_int8(x)

    @staticmethod
    def decode(z):
        return dequantize_int8(z)


class NullCodec:
    name = "none"
    ratio = 1.0

    @staticmethod
    def encode(x):
        return x

    @staticmethod
    def decode(x):
        return x
