"""xDFS session wire protocol — persistent, multi-file, channel-reusing.

A *session* is one negotiation plus n long-lived TCP channels that carry
many file transfers (paper §2.5.3 and Table 3). Per-transfer overhead is
amortized exactly as DotDFS prescribes:

* every channel introduces itself with a ``CONM`` *hello* header carrying
  the session GUID + channel index, so one server can demux channels of
  many concurrent sessions arriving in any order;
* channel 0 is the **control channel**: after its hello it sends the
  length-prefixed ``Negotiation`` (Table 2) ONCE, then one control frame
  per file — a ``ChannelHeader`` whose event selects the operation
  (``xFTSMU`` = put/upload, ``xFTSMD`` = get/download, ``EOFT`` = close)
  and whose payload is a small JSON metadata blob;
* file streams end with ``EOFR`` on every channel — *end-of-file,
  channel reusable* — so the same sockets carry the next file; ``EOFT``
  appears exactly once, as the session-terminating control frame;
* the server threads ONE ``server_upload`` conformance FSM through the
  whole session (mtedp engine): each file loops ``9_open_file ->
  10..13_flush -> (eofr_flush) -> 9_open_file`` and the terminal ``EOFT``
  must land in ``9_open_file`` for the machine to end legally.

Layering: this module knows the wire protocol and drives an ``Engine``
from the registry; ``core/api.py`` wraps it in the user-facing
``XdfsServer`` / ``XdfsClient`` objects.

Pool-slot lifecycle: a ``ServerSession`` owns ONE registered
``RecvBufferPool`` for the whole session and lends it to every
``engine.receive`` call (pool-using engines fill its slot views via
``recv_into`` and release every slot by their final flush, so cross-file
reuse is safe); control frames are parsed in place from the recv buffer —
no ``bytes()`` copies anywhere on the receive path. ``splice=True`` opts
receives into the kernel-side ``os.splice`` fast path where the engine
supports it.
"""
from __future__ import annotations

import errno
import json
import os
import socket
import struct
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.core.engines import Engine, RecvStats, Sink, Source, recv_exact, send_all
from repro.core.engines.base import (
    DURABILITY_ATOMIC,
    DURABILITY_FSYNC,
    durability_byte,
    store_free_bytes,
)
from repro.core.fsm import FSM_BUILDERS, Machine
from repro.core.header import (
    HEADER_SIZE,
    ChannelEvent,
    ChannelHeader,
    Negotiation,
    ProtocolError,
)
from repro.core.integrity import CrcManifest, IntegrityError
from repro.core.resume import ManifestSidecar, ResumeSidecar, throttled_autosave

CTRL_CHANNEL = 0
DEFAULT_BLOCK = 1 << 20
# hard ceiling on the negotiated batch_frames (the top of the autotuner's
# ladder; also bounds per-frame iovec length well under IOV_MAX)
MAX_BATCH_FRAMES = 64


class SessionError(ProtocolError):
    """A control-level session failure (bad request, remote exception).

    ``kind`` is the typed EXCEPTION discriminator carried on the wire
    (``integrity`` / ``busy`` / ``draining`` / ``disk_full``); ``None``
    for untyped failures."""

    kind: Optional[str] = None


class IntegrityFailure(SessionError):
    """The peer reported an end-to-end verification failure (manifest hole
    or whole-file CRC mismatch). The session itself survives — the caller
    can RESUME the same transfer to re-fetch the bad blocks."""

    kind = "integrity"


class BusyError(SessionError):
    """The server refused the session at admission (over ``max_sessions``
    or draining for shutdown). Typed so callers can distinguish back-off
    and retry-elsewhere from a protocol failure."""

    kind = "busy"


class DiskFullError(SessionError):
    """The server refused a put for lack of store space (preflight check
    or ENOSPC opening the sink). The session survives — callers re-plan
    the placement around the full node."""

    kind = "disk_full"


@dataclass(frozen=True)
class SocketTuning:
    """Per-session socket knobs, carried in the ``Negotiation`` so client
    and server apply the SAME settings to every channel (the tuned-buffer
    factor of the paper's §2.3 analysis; 0 keeps the kernel default).

    TCP fixes the window-scale factor at the handshake, so SO_RCVBUF is
    only fully effective when set BEFORE connect/accept: the client
    applies it pre-connect, and ``XdfsServer(tuning=...)`` applies it to
    the listening socket so accepted channels inherit it. The
    post-handshake per-session apply still grows buffers within the
    already-chosen scale (and SO_SNDBUF/TCP_NODELAY are unaffected)."""

    nodelay: bool = True
    sndbuf: int = 0  # SO_SNDBUF in bytes
    rcvbuf: int = 0  # SO_RCVBUF in bytes

    def apply(self, sock: socket.socket) -> None:
        if sock.family in (socket.AF_INET, getattr(socket, "AF_INET6", None)):
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY,
                            1 if self.nodelay else 0)  # Nagle is TCP-only
        self.apply_buffers(sock)

    def apply_buffers(self, sock: socket.socket) -> None:
        """Just the buffer sizes — also valid on a LISTENING socket, where
        accepted channels inherit them pre-handshake."""
        if self.sndbuf > 0:
            sock.setsockopt(socket.SOL_SOCKET, socket.SO_SNDBUF, self.sndbuf)
        if self.rcvbuf > 0:
            sock.setsockopt(socket.SOL_SOCKET, socket.SO_RCVBUF, self.rcvbuf)

    @classmethod
    def from_negotiation(cls, neg: Negotiation) -> "SocketTuning":
        return cls(nodelay=neg.so_nodelay, sndbuf=neg.so_sndbuf,
                   rcvbuf=neg.so_rcvbuf)


# ---------------------------------------------------------------------------
# control frames: ChannelHeader + JSON payload on the control channel
# ---------------------------------------------------------------------------


def send_ctrl(sock: socket.socket, event: ChannelEvent, session: bytes,
              payload: Optional[dict] = None) -> None:
    body = json.dumps(payload or {}).encode()
    hdr = ChannelHeader(event, session, CTRL_CHANNEL, 0, len(body))
    send_all(sock, hdr.pack() + body)


def recv_ctrl(sock: socket.socket) -> Tuple[ChannelHeader, dict]:
    # header and body are parsed straight from the recv buffers: unpack
    # accepts any buffer, and str(view, "utf-8") decodes without a bytes()
    # round-trip
    hdr = ChannelHeader.unpack(recv_exact(sock, HEADER_SIZE))
    body = str(recv_exact(sock, hdr.length), "utf-8") if hdr.length else "{}"
    payload = json.loads(body)
    if hdr.event == ChannelEvent.EXCEPTION:
        msg = payload.get("error", "remote exception")
        if payload.get("kind") == "integrity":
            raise IntegrityFailure(msg)
        if payload.get("kind") in ("busy", "draining"):
            raise BusyError(msg)
        if payload.get("kind") == "disk_full":
            raise DiskFullError(msg)
        raise SessionError(msg)
    return hdr, payload


def send_hello(sock: socket.socket, session: bytes, channel: int) -> None:
    """Channel self-identification: lets the server demux interleaved
    channel arrivals of concurrent sessions."""
    send_all(sock, ChannelHeader(ChannelEvent.CONM, session, channel, 0, 0).pack())


def recv_hello(sock: socket.socket) -> ChannelHeader:
    hdr = ChannelHeader.unpack(recv_exact(sock, HEADER_SIZE))
    if hdr.event != ChannelEvent.CONM or hdr.length != 0:
        raise ProtocolError(f"expected channel hello, got {hdr.event!r}")
    return hdr


def send_negotiation(sock: socket.socket, neg: Negotiation) -> None:
    raw = neg.pack()
    send_all(sock, struct.pack("<I", len(raw)) + raw)


def recv_negotiation(sock: socket.socket) -> Negotiation:
    (nlen,) = struct.unpack("<I", recv_exact(sock, 4))
    return Negotiation.unpack(recv_exact(sock, nlen))  # parses in place


def resolve_path(root: Optional[str], name: Optional[str],
                 for_write: bool = False) -> Optional[str]:
    """Map a remote name onto the server filesystem. ``root=None`` is the
    trusted local mode (paths used as-is); otherwise names are confined to
    ``root`` and parent directories are created for writes."""
    if name is None:
        return None
    if root is None:
        path = os.path.abspath(name)
    else:
        root = os.path.abspath(root)
        path = os.path.normpath(os.path.join(root, name))
        if os.path.commonpath([root, path]) != root:
            raise SessionError(f"path {name!r} escapes the session root")
    if for_write:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    return path


# ---------------------------------------------------------------------------
# server side of one session
# ---------------------------------------------------------------------------


@dataclass
class SessionStats:
    files: int = 0
    bytes: int = 0
    eofr_frames: int = 0
    eoft_frames: int = 0
    writev_calls: int = 0
    splice_bytes: int = 0
    recv_calls: int = 0
    splice_autodisables: int = 0
    crc_mismatches: int = 0

    def absorb(self, st: RecvStats) -> None:
        self.bytes += st.bytes
        self.eofr_frames += st.eofr_frames
        self.eoft_frames += st.eoft_frames
        self.writev_calls += st.writev_calls
        self.splice_bytes += st.splice_bytes
        self.recv_calls += st.recv_calls
        self.splice_autodisables += st.splice_autodisables
        self.crc_mismatches += st.crc_mismatches


class ServerSession:
    """Runs one accepted session to completion on the server side."""

    def __init__(self, socks, neg: Negotiation, engine: Engine,
                 root: Optional[str], pool_slots: int = 32,
                 splice: bool = False, io_timeout: Optional[float] = None,
                 durability: int = 0, capacity_bytes: Optional[int] = None):
        self.socks = list(socks)
        self.neg = neg
        self.engine = engine
        self.root = root
        self.integrity = bool(neg.integrity)
        # effective at-rest policy = the STRONGER of the client's request
        # and the server's configured floor (unknown wire bytes clamp to
        # atomic rather than failing the handshake)
        self.durability = max(durability_byte(durability),
                              min(int(neg.durability), DURABILITY_ATOMIC))
        # synthetic store capacity for the disk-pressure path (None =
        # trust statvfs); puts that cannot fit are refused with a typed
        # ``disk_full`` EXCEPTION before any byte moves
        self.capacity_bytes = capacity_bytes
        # splice moves payload bytes kernel-side where no CPU can see them,
        # so it cannot verify trailers — integrity sessions stay in userspace
        self.splice = splice and not self.integrity
        # per-operation stall bound while a transfer is in flight (idle
        # control waits between files stay unbounded)
        self.io_timeout = io_timeout
        if engine.pool_livelock_guard and pool_slots <= neg.n_channels:
            # every pool slot could be pinned by a partially-filled block of
            # some channel, livelocking the receiver's backpressure flush
            raise SessionError(
                f"pool_slots ({pool_slots}) must exceed n_channels "
                f"({neg.n_channels})"
            )
        self.pool_slots = pool_slots
        # negotiated syscall-batching ceiling (1 = per-frame datapath)
        self.batch_frames = max(1, min(int(neg.batch_frames), MAX_BATCH_FRAMES))
        self.stats = SessionStats()
        self._pool = None  # RecvBufferPool reused across the session's files
        self._slabs = None  # SlabSet reused across the session's files
        self.fsm: Optional[Machine] = None
        if engine.name == "mtedp":
            # one conformance machine for the WHOLE session: the multi-file
            # loop re-arms it at 9_open_file between files
            self.fsm = FSM_BUILDERS["server_upload"]()
            for ev in ("conn", "auth_ok", "ftsm", "params_ok", "new_session",
                       "registered", "all_channels"):
                self.fsm.step(ev)

    def run(self) -> SessionStats:
        ctrl = self.socks[CTRL_CHANNEL]
        while True:
            try:
                hdr, meta = recv_ctrl(ctrl)
            except (ConnectionError, OSError):
                break  # client vanished; channels die with it
            if hdr.event == ChannelEvent.EOFT:
                self.stats.eoft_frames += 1
                if self.fsm is not None:
                    self.fsm.step("eoft")
                    assert self.fsm.done, (
                        f"conformance: session FSM ended in {self.fsm.state}"
                    )
                break
            try:
                if hdr.event == ChannelEvent.xFTSMU:
                    self._handle_put(ctrl, meta)
                elif hdr.event == ChannelEvent.xFTSMD:
                    self._handle_get(ctrl, meta)
                elif hdr.event == ChannelEvent.RESUME:
                    self._handle_resume(ctrl, meta)
                else:
                    send_ctrl(ctrl, ChannelEvent.EXCEPTION, self.neg.session,
                              {"error": f"unexpected control event {hdr.event!r}"})
            except SessionError as e:
                payload = {"error": str(e)}
                if e.kind is not None:
                    payload["kind"] = e.kind
                send_ctrl(ctrl, ChannelEvent.EXCEPTION, self.neg.session,
                          payload)
            finally:
                if self.io_timeout is not None:
                    # transfer deadlines must not bound the idle wait for
                    # the session's NEXT control frame
                    for s in self.socks:
                        s.settimeout(None)
        return self.stats

    def _handle_resume(self, ctrl, meta: dict) -> None:
        if not self.integrity:
            raise SessionError(
                "RESUME requires an integrity session (negotiate integrity=True)")
        mode = meta.get("mode")
        if mode == "put":
            self._handle_put(ctrl, meta, resume=True)
        elif mode == "get":
            self._handle_get(ctrl, meta, resume=True)
        else:
            raise SessionError(f"unknown resume mode {mode!r}")

    def _handle_put(self, ctrl, meta: dict, resume: bool = False) -> None:
        size = int(meta["size"])
        block_size = int(meta.get("block_size", self.neg.block_size))
        if size and self.root is not None:
            free = store_free_bytes(self.root, self.capacity_bytes)
            if size > free:
                raise DiskFullError(
                    f"store has {free} bytes free; refusing {size}-byte put")
        # a resume-put fills holes of the partially-landed FINAL file in
        # place — incompatible with whole-file temp+rename, so atomic
        # degrades to fsync for that one operation
        durability = (min(self.durability, DURABILITY_FSYNC) if resume
                      else self.durability)
        atomic = durability >= DURABILITY_ATOMIC
        try:
            path = resolve_path(self.root, meta.get("remote"), for_write=True)
            sink = Sink(path, size, durability=durability)
        except OSError as e:
            if e.errno == errno.ENOSPC:
                raise DiskFullError(f"cannot open {meta.get('remote')!r}: {e}")
            raise SessionError(f"cannot open {meta.get('remote')!r}: {e}")
        sidecar = (ResumeSidecar(path)
                   if self.integrity and path is not None else None)
        crc_acc: Optional[CrcManifest] = None
        if self.integrity:
            # no mid-transfer autosave under atomic: resume state would
            # describe blocks living in a temp file that an abort discards
            crc_acc = CrcManifest(
                autosave=throttled_autosave(sidecar, size, block_size)
                if sidecar is not None and not atomic else None)
        reply = {"ok": True}
        if resume:
            prev = sidecar.load(size, block_size) if sidecar is not None else None
            if prev is not None:
                crc_acc.merge(prev)
            # the client diffs these against its LOCAL block CRCs and only
            # re-sends what the server is missing (or holds a stale copy of)
            reply["have"] = {str(off): crc
                            for off, (_ln, crc) in crc_acc.blocks.items()}
        elif sidecar is not None:
            sidecar.clear()  # a fresh put invalidates old resume state
        send_ctrl(ctrl, ChannelEvent.CONM, self.neg.session, reply)
        if self.fsm is not None:
            self.fsm.step("resume" if resume else "opened")
        if self.engine.uses_pool and self.batch_frames <= 1 and (
            self._pool is None or self._pool.block_size != block_size
        ):
            from repro.core.ringbuf import RecvBufferPool

            self._pool = RecvBufferPool(self.pool_slots, block_size)
        if self.engine.uses_pool and self.batch_frames > 1:
            from repro.core.engines.base import slab_span
            from repro.core.ringbuf import SlabSet

            span = slab_span(self.batch_frames, block_size)
            if self._slabs is None or self._slabs.slab_bytes != span:
                self._slabs = SlabSet(self.neg.n_channels, span)
        try:
            st = self.engine.receive(
                self.socks, sink, block_size, pool_slots=self.pool_slots,
                fsm=self.fsm, conformance=self.fsm is not None, reusable=True,
                pool=self._pool, splice=self.splice,
                batch_frames=self.batch_frames, slabs=self._slabs,
                crc_acc=crc_acc, io_timeout=self.io_timeout,
            )
        except BaseException:
            if sidecar is not None:
                if atomic:
                    # the uncommitted temp file is discarded with the sink:
                    # any recorded blocks no longer exist at the final path
                    sidecar.clear()
                elif crc_acc is not None and len(crc_acc):
                    # the stream died mid-file: persist what WAS verified so
                    # the client can RESUME over a fresh connection
                    sidecar.save(size, block_size, crc_acc)
            raise
        finally:
            sink.close()
        self.stats.files += 1
        self.stats.absorb(st)
        if self.integrity:
            self._verify_put(ctrl, crc_acc, sidecar, size, block_size, path)

    def _verify_put(self, ctrl, crc_acc: CrcManifest,
                    sidecar: Optional[ResumeSidecar],
                    size: int, block_size: int,
                    path: Optional[str] = None) -> None:
        """End-of-put manifest exchange: the client reports its whole-file
        CRC; the server folds its verified-block manifest and answers ok or
        a typed integrity EXCEPTION (keeping the sidecar either way — on
        success it makes an identical re-put a no-op, on failure it is the
        RESUME state)."""
        if self.io_timeout is not None:
            ctrl.settimeout(self.io_timeout)
        _hdr, fin = recv_ctrl(ctrl)
        if sidecar is not None:
            sidecar.save(size, block_size, crc_acc)
        try:
            mine = crc_acc.file_crc(size)
        except IntegrityError as e:
            send_ctrl(ctrl, ChannelEvent.EXCEPTION, self.neg.session,
                      {"error": str(e), "kind": "integrity"})
            return
        theirs = fin.get("file_crc")
        if theirs is not None and int(theirs) != mine:
            send_ctrl(ctrl, ChannelEvent.EXCEPTION, self.neg.session,
                      {"error": f"file CRC mismatch: client 0x{int(theirs):08x} "
                                f"!= server 0x{mine:08x}",
                       "kind": "integrity"})
            return
        if path is not None:
            # the at-rest truth: a complete, client-confirmed manifest next
            # to the committed bytes, for the scrubber to verify against
            ManifestSidecar(path).save(size, block_size, crc_acc)
        send_ctrl(ctrl, ChannelEvent.CONM, self.neg.session,
                  {"ok": True, "file_crc": mine})

    def _handle_get(self, ctrl, meta: dict, resume: bool = False) -> None:
        block_size = int(meta.get("block_size", self.neg.block_size))
        remote = meta.get("remote")
        if remote is None:  # mem-to-mem mode: serve zeros
            size = int(meta["size"])
            source = Source(None, size, block_size)
        else:
            try:
                path = resolve_path(self.root, remote)
                size = os.path.getsize(path)
                source = Source(path, size, block_size)
            except OSError as e:
                raise SessionError(f"cannot read {remote!r}: {e}")
        blocks = None
        payload = size
        if resume:
            # the client's sidecar names the block offsets it still wants
            want = meta.get("want") or []
            blocks = sorted({int(off) // block_size for off in want
                             if 0 <= int(off) < size})
            payload = sum(source.block_len(b) for b in blocks)
        send_ctrl(ctrl, ChannelEvent.CONM, self.neg.session,
                  {"ok": True, "size": size})
        try:
            self.engine.send(self.socks, source, self.neg.session,
                             reusable=True, batch_frames=self.batch_frames,
                             integrity=self.integrity, blocks=blocks,
                             io_timeout=self.io_timeout)
        finally:
            source.close()
        self.stats.files += 1
        self.stats.bytes += payload
