"""Sharded server event loop — the C10k core of the xDFS server.

The paper's server claim is *high concurrency*: thousands of mostly-idle
sessions must cost neither a thread each nor unbounded memory, and busy
sessions must not starve each other. ``XdfsServer(loop=True)`` replaces
the thread-per-session internals with N event-loop *shards*, each a
single thread owning one ``selectors`` instance:

* **accept fan-out** — every shard registers the (nonblocking) listening
  socket; whichever shard wakes first wins the ``accept`` race and keeps
  the connection (losers see ``BlockingIOError`` and move on);
* **handshake demux** — a per-connection :class:`HandshakeConn` state
  machine parses the channel hello (and the control channel's
  negotiation) incrementally, tolerating arbitrary fragmentation — a
  byte-at-a-time client holds only a tiny parse state, never a thread;
* **session scheduling** — every channel of every admitted session lives
  on one shard as a :class:`LoopSession`, a nonblocking port of the
  blocking ``ServerSession`` loop reusing the mtedp datapath primitives
  (``SlabChannel`` receive parsing, ``FrameBuilder``/``advance_iovec``
  scatter-gather send, the ``server_upload`` conformance FSM);
* **fair shares** — channel readiness feeds a deficit-round-robin ready
  queue: each loop turn spends a global byte budget, each session earns
  a quantum of deficit when served, and channels the budget ran out on
  keep their place at the FRONT of the queue (starved work ages forward;
  freshly re-armed work joins at the back);
* **admission control** — ``max_sessions`` caps live sessions and
  ``max_pending`` caps in-flight handshakes; a refused session is parked
  in a reject shell that answers every request with a typed
  ``EXCEPTION {kind: "busy"|"draining"}`` (the client surfaces it as
  ``BusyError``) so refusal is an answer, not a reset;
* **idle eviction & graceful drain** — the shard tick (injectable clock,
  the same idiom as ``autotune.ChannelTuner``/``FailureDetector``)
  evicts sessions idle past ``idle_timeout`` and bounds mid-transfer
  stalls by ``io_timeout``; ``stop()`` drains: in-flight files (and
  their integrity verify exchange) complete, new work is refused.

Layering: this module owns scheduling and nonblocking protocol state;
the wire format and per-file semantics are imported from
``core/session.py`` and ``core/engines`` — the loop datapath IS the
slab datapath, byte for byte.
"""
from __future__ import annotations

import errno
import json
import os
import selectors
import socket
import struct
import threading
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

from repro.core.autotune import ChannelTuner
from repro.core.engines.base import (
    ACK,
    DURABILITY_ATOMIC,
    DURABILITY_FSYNC,
    FrameBuilder,
    Sink,
    SlabChannel,
    Source,
    advance_iovec,
    durability_byte,
    slab_span,
    store_free_bytes,
)
from repro.core.fsm import FSM_BUILDERS
from repro.core.header import (
    HEADER_SIZE,
    FLAG_BLOCK_CRC,
    ChannelEvent,
    ChannelHeader,
    Negotiation,
    ProtocolError,
)
from repro.core.integrity import CrcManifest, IntegrityError
from repro.core.resume import ManifestSidecar, ResumeSidecar, throttled_autosave
from repro.core.session import (
    CTRL_CHANNEL,
    MAX_BATCH_FRAMES,
    DiskFullError,
    SessionError,
    SessionStats,
    resolve_path,
)

# -- scheduling constants ----------------------------------------------------

# selector timeout = the shard's housekeeping cadence (eviction, stale
# handshakes, io stalls, drain) — real time, independent of the
# injectable clock that DECIDES those policies
TICK = 0.05
# DRR: deficit earned per service grant; a session may move at most its
# accumulated deficit per grant, so two greedy sessions converge to
# equal byte shares within one quantum of each other
DRR_QUANTUM = 256 << 10
# global bytes one loop turn may move before yielding back to select();
# bounds per-turn latency for control-frame traffic behind bulk data
TURN_BUDGET = 4 << 20
# shards when ``loop=True`` picks the count (an explicit int overrides)
DEFAULT_SHARDS = min(4, os.cpu_count() or 1)

# -- handshake demux states (normative: docs/ARCHITECTURE.md table) ----------

HS_HELLO = "hello"          # accumulating the 48-byte channel hello
HS_NEG_LEN = "neg_len"      # control channel: the 4-byte negotiation length
HS_NEG_BODY = "neg_body"    # control channel: the negotiation blob
HS_PARKED = "parked"        # handed to the session assembler
HS_STATES: Tuple[str, ...] = (HS_HELLO, HS_NEG_LEN, HS_NEG_BODY, HS_PARKED)

# -- admission/eviction error kinds (normative: docs table) ------------------

ERR_BUSY = "busy"           # over max_sessions at admission
ERR_DRAINING = "draining"   # server is stopping; finishes in-flight only
ERR_IDLE = "idle"           # evicted after idle_timeout of inactivity
ERR_DISK_FULL = "disk_full"  # put refused: store cannot fit the file
ERR_KINDS: Tuple[str, ...] = (ERR_BUSY, ERR_DRAINING, ERR_IDLE, ERR_DISK_FULL)

_NEG_LEN = struct.Struct("<I")

# LoopSession states
ST_CTRL = "ctrl"
ST_RECV = "recv"
ST_SEND = "send"


class HandshakeConn:
    """Per-connection nonblocking handshake parser.

    Frame boundaries land anywhere: every read appends to the current
    stage's buffer and the stage advances only when its exact byte count
    arrived. A garbled hello (bad magic, wrong event) raises out of
    :meth:`on_io` into ``server.handshake_errors`` and closes the socket
    — a stray connection never takes a shard down and never leaks."""

    __slots__ = ("shard", "sock", "state", "t0", "_buf", "_got", "_want",
                 "hello", "neg")

    def __init__(self, shard: "EventLoopShard", sock: socket.socket):
        self.shard = shard
        self.sock = sock
        self.state = HS_HELLO
        self.t0 = shard.server._clock()
        self._buf = memoryview(bytearray(HEADER_SIZE))
        self._got = 0
        self._want = HEADER_SIZE
        self.hello: Optional[ChannelHeader] = None
        self.neg: Optional[Negotiation] = None

    def on_io(self, sock: socket.socket, mask: int) -> None:
        try:
            while True:
                r = sock.recv_into(self._buf[self._got:self._want])
                if r == 0:
                    raise ConnectionError("peer closed during handshake")
                self._got += r
                if self._got < self._want:
                    continue
                if self.state == HS_HELLO:
                    hdr = ChannelHeader.unpack(self._buf)
                    if hdr.event != ChannelEvent.CONM or hdr.length != 0:
                        raise ProtocolError(
                            f"expected channel hello, got {hdr.event!r}")
                    self.hello = hdr
                    if hdr.channel == CTRL_CHANNEL:
                        self.state = HS_NEG_LEN
                        self._rearm(_NEG_LEN.size)
                        continue
                    self._park()
                    return
                if self.state == HS_NEG_LEN:
                    (nlen,) = _NEG_LEN.unpack(self._buf[:4])
                    if not 0 < nlen <= 1 << 20:
                        raise ProtocolError(
                            f"implausible negotiation length {nlen}")
                    self.state = HS_NEG_BODY
                    self._rearm(nlen)
                    continue
                # HS_NEG_BODY
                self.neg = Negotiation.unpack(self._buf[:self._want])
                self._park()
                return
        except BlockingIOError:
            return
        except Exception as e:  # noqa: BLE001 - bad/stray connections are
            # recorded, closed, and must not take the shard down
            self.shard.server.handshake_errors.append(e)
            self.close()

    def _rearm(self, want: int) -> None:
        if want > len(self._buf):
            self._buf = memoryview(bytearray(want))
        self._got = 0
        self._want = want

    def _park(self) -> None:
        """Hand the completed (hello[, negotiation]) to the server-level
        session assembler; the socket leaves this shard's selector until
        the session (or reject shell) re-registers it."""
        self.state = HS_PARKED
        shard = self.shard
        shard.handshakes.pop(self.sock, None)
        try:
            shard.sel.unregister(self.sock)
        except (KeyError, ValueError, OSError):
            pass
        shard.server._park_from_loop(shard, self.hello, self.neg, self.sock)

    def close(self) -> None:
        self.shard.handshakes.pop(self.sock, None)
        try:
            self.shard.sel.unregister(self.sock)
        except (KeyError, ValueError, OSError):
            pass
        try:
            self.sock.close()
        except OSError:
            pass


class _CtrlParser:
    """Incremental control-frame parser: header + JSON body, one frame
    per :meth:`read_one` so the caller can stop consuming the moment a
    dispatched frame flips the session into a transfer state."""

    __slots__ = ("_hdr_buf", "_hdr_got", "_hdr", "_body", "_body_got")

    def __init__(self):
        self._hdr_buf = memoryview(bytearray(HEADER_SIZE))
        self._hdr_got = 0
        self._hdr: Optional[ChannelHeader] = None
        self._body: Optional[memoryview] = None
        self._body_got = 0

    def read_one(self, sock: socket.socket) -> Tuple[ChannelHeader, dict]:
        while True:
            if self._hdr is None:
                r = sock.recv_into(self._hdr_buf[self._hdr_got:])
                if r == 0:
                    raise ConnectionError("peer closed")
                self._hdr_got += r
                if self._hdr_got < HEADER_SIZE:
                    continue
                self._hdr = ChannelHeader.unpack(self._hdr_buf)
                self._hdr_got = 0
                self._body = (memoryview(bytearray(self._hdr.length))
                              if self._hdr.length else None)
                self._body_got = 0
            if self._body is not None and self._body_got < len(self._body):
                r = sock.recv_into(self._body[self._body_got:])
                if r == 0:
                    raise ConnectionError("peer closed mid-frame")
                self._body_got += r
                if self._body_got < len(self._body):
                    continue
            hdr, body = self._hdr, self._body
            self._hdr = None
            self._body = None
            meta = json.loads(str(body, "utf-8")) if body is not None else {}
            return hdr, meta


class LoopSession:
    """One admitted session, scheduled cooperatively on its shard.

    A nonblocking port of ``ServerSession.run()``: the CTRL state parses
    control frames (one in flight at a time — the client serializes
    operations); a put flips to RECV (the slab datapath of
    ``mtedp._receive_batched``, byte for byte, including the
    ``server_upload`` FSM milestones); a get flips to SEND (the
    ``event_send`` scatter-gather batches, per-channel depth hill-climbed
    by ``ChannelTuner``). Bulk states are served through the shard's DRR
    queue so concurrent sessions get fair byte shares.

    ``reject_kind`` turns the session into an admission-reject shell: it
    answers every control frame with a typed ``EXCEPTION`` (never
    transfers, never counts as a session) until the client goes away —
    refusing with an answer instead of a close avoids the RST race that
    would destroy the error before the client could read it."""

    def __init__(self, server, shard: "EventLoopShard", socks, neg: Negotiation,
                 reject_kind: Optional[str] = None):
        self.server = server
        self.shard = shard
        self.socks = list(socks)
        self.neg = neg
        self.n = neg.n_channels
        self.root = server.root
        self.integrity = bool(neg.integrity)
        # stronger of the client's requested policy and the server floor
        self.durability = max(durability_byte(getattr(server, "durability", 0)),
                              min(int(neg.durability), DURABILITY_ATOMIC))
        self.capacity_bytes = getattr(server, "capacity_bytes", None)
        self.batch = max(1, min(int(neg.batch_frames), MAX_BATCH_FRAMES))
        self.reject_kind = reject_kind
        self.stats = SessionStats()
        # one conformance machine for the WHOLE session, exactly as the
        # thread path threads it (loop mode always runs the mtedp datapath)
        self.fsm = FSM_BUILDERS["server_upload"]()
        for ev in ("conn", "auth_ok", "ftsm", "params_ok", "new_session",
                   "registered", "all_channels"):
            self.fsm.step(ev)
        self.state = ST_CTRL
        self.closed = False
        self.last_activity = server._clock()
        # DRR bookkeeping (owned by the shard's serve loop)
        self.deficit = 0
        self.queued: set = set()
        self._masks = [0] * self.n
        self._outq = [bytearray() for _ in range(self.n)]
        self._parser = _CtrlParser()
        self._verify_ctx = None
        self._end_close = False  # drain/evict: close once replies flush
        # receive-transfer state
        self._slabs = None  # SlabSet reused across the session's files
        self._chans: Optional[List[SlabChannel]] = None
        self._eof: Optional[List[bool]] = None
        self._sink: Optional[Sink] = None
        self._crc_acc: Optional[CrcManifest] = None
        self._sidecar: Optional[ResumeSidecar] = None
        self._path: Optional[str] = None
        self._file_size = 0
        self._block_size = neg.block_size
        # send-transfer state
        self._source: Optional[Source] = None
        self._frames: Optional[FrameBuilder] = None
        self._tuners = None
        self._queues = None
        self._qpos = None
        self._pend: Optional[List[Optional[list]]] = None
        self._done: Optional[List[bool]] = None
        self._acked: Optional[List[bool]] = None
        self._payload = 0
        # bytes moved for the CURRENT transfer (fairness observability)
        self.progress = 0

    # -- shard plumbing ----------------------------------------------------

    def attach(self) -> None:
        """Runs on the owning shard's thread: register the channels."""
        self.shard.sessions.add(self)
        self._apply_all_masks()

    def _cb(self, ch: int):
        return lambda sock, mask, _ch=ch: self.on_io(_ch, sock, mask)

    def _want_mask(self, ch: int) -> int:
        if self.closed:
            return 0
        mask = selectors.EVENT_WRITE if self._outq[ch] else 0
        if self.state == ST_CTRL:
            if ch == CTRL_CHANNEL:
                mask |= selectors.EVENT_READ
        elif self.state == ST_RECV:
            if not self._eof[ch]:
                mask |= selectors.EVENT_READ
        elif self.state == ST_SEND:
            if self._acked[ch]:
                pass
            elif self._done[ch] and self._pend[ch] is None:
                mask |= selectors.EVENT_READ  # awaiting the 1-byte ack
            else:
                mask |= selectors.EVENT_WRITE
        return mask

    def _apply_mask(self, ch: int) -> None:
        want = self._want_mask(ch)
        cur = self._masks[ch]
        if want == cur:
            return
        sock = self.socks[ch]
        try:
            if cur == 0:
                self.shard.sel.register(sock, want, self._cb(ch))
            elif want == 0:
                self.shard.sel.unregister(sock)
            else:
                self.shard.sel.modify(sock, want, self._cb(ch))
        except (KeyError, ValueError, OSError):
            pass
        self._masks[ch] = want

    def _apply_all_masks(self) -> None:
        for ch in range(self.n):
            self._apply_mask(ch)

    def _enqueue(self, ch: int) -> None:
        if ch not in self.queued:
            self.queued.add(ch)
            self.shard.ready.append((self, ch))

    # -- event entry points ------------------------------------------------

    def on_io(self, ch: int, sock: socket.socket, mask: int) -> None:
        if self.closed:
            return
        self.last_activity = self.server._clock()
        try:
            if mask & selectors.EVENT_WRITE and self._outq[ch]:
                self._flush_out(ch)
            if self.closed:
                return
            if self.state == ST_CTRL:
                if ch == CTRL_CHANNEL and mask & selectors.EVENT_READ:
                    self._pump_ctrl(sock)
            elif self.state == ST_RECV:
                if mask & selectors.EVENT_READ and not self._eof[ch]:
                    self._enqueue(ch)
            elif self.state == ST_SEND:
                if self._acked[ch]:
                    pass
                elif self._done[ch] and self._pend[ch] is None:
                    if mask & selectors.EVENT_READ:
                        self._read_ack(ch, sock)
                elif mask & selectors.EVENT_WRITE:
                    self._enqueue(ch)
            if not self.closed:
                self._apply_mask(ch)
        except BaseException as e:  # noqa: BLE001 - a session failure must
            # not take the shard (and every other session) down
            self._fail(e)

    def service(self, ch: int, limit: int) -> Tuple[int, bool]:
        """One DRR grant: move up to ``limit`` bytes on this channel.
        Returns ``(moved, more)`` — ``more`` means the grant was exhausted
        with the socket still willing (re-queue me); blocked or finished
        channels return ``more=False`` and the selector re-arms them."""
        try:
            if self.state == ST_RECV:
                moved, more = self._serve_recv(ch, limit)
            elif self.state == ST_SEND:
                moved, more = self._serve_send(ch, limit)
            else:
                return 0, False
            if not self.closed:
                self._apply_mask(ch)
            return moved, more and not self.closed
        except BaseException as e:  # noqa: BLE001
            self._fail(e)
            return 0, False

    # -- outbound queue (ctrl replies + acks) ------------------------------

    def _queue_out(self, ch: int, data: bytes) -> None:
        self._outq[ch] += data
        self._flush_out(ch)
        if not self.closed:
            self._apply_mask(ch)

    def _flush_out(self, ch: int) -> None:
        buf = self._outq[ch]
        sock = self.socks[ch]
        while buf:
            try:
                w = sock.send(buf)
            except BlockingIOError:
                return
            del buf[:w]
        self._maybe_finish_close()

    def _send_ctrl_frame(self, event: ChannelEvent, payload: dict) -> None:
        body = json.dumps(payload).encode()
        hdr = ChannelHeader(event, self.neg.session, CTRL_CHANNEL, 0, len(body))
        self._queue_out(CTRL_CHANNEL, hdr.pack() + body)

    # -- CTRL state --------------------------------------------------------

    def _pump_ctrl(self, sock: socket.socket) -> None:
        while self.state == ST_CTRL and not self.closed:
            try:
                hdr, meta = self._parser.read_one(sock)
            except BlockingIOError:
                return
            except (ConnectionError, OSError):
                # client vanished between operations; channels die with it
                # (the blocking path's clean `break`)
                self._close()
                return
            self._dispatch(hdr, meta)

    def _dispatch(self, hdr: ChannelHeader, meta: dict) -> None:
        if self.reject_kind is not None:
            self._dispatch_reject(hdr)
            return
        if self._verify_ctx is not None:
            self._finish_verify(meta)
            return
        ev = hdr.event
        if ev == ChannelEvent.EOFT:
            self.stats.eoft_frames += 1
            self.fsm.step("eoft")
            assert self.fsm.done, (
                f"conformance: session FSM ended in {self.fsm.state}"
            )
            self._close()
            return
        try:
            if self.server._draining:
                # graceful drain refuses NEW work with a typed answer
                self._send_ctrl_frame(
                    ChannelEvent.EXCEPTION,
                    {"error": "server draining", "kind": ERR_DRAINING})
                self._end_close = True
                self._maybe_finish_close()
                return
            if ev == ChannelEvent.xFTSMU:
                self._start_put(meta)
            elif ev == ChannelEvent.xFTSMD:
                self._start_get(meta)
            elif ev == ChannelEvent.RESUME:
                self._start_resume(meta)
            else:
                self._send_ctrl_frame(
                    ChannelEvent.EXCEPTION,
                    {"error": f"unexpected control event {ev!r}"})
        except SessionError as e:
            payload = {"error": str(e)}
            if e.kind is not None:
                payload["kind"] = e.kind
            self._send_ctrl_frame(ChannelEvent.EXCEPTION, payload)

    def _dispatch_reject(self, hdr: ChannelHeader) -> None:
        if hdr.event == ChannelEvent.EOFT:
            self._close()
            return
        self._send_ctrl_frame(
            ChannelEvent.EXCEPTION,
            {"error": f"server refused session ({self.reject_kind})",
             "kind": self.reject_kind})

    def _start_resume(self, meta: dict) -> None:
        if not self.integrity:
            raise SessionError(
                "RESUME requires an integrity session (negotiate integrity=True)")
        mode = meta.get("mode")
        if mode == "put":
            self._start_put(meta, resume=True)
        elif mode == "get":
            self._start_get(meta, resume=True)
        else:
            raise SessionError(f"unknown resume mode {mode!r}")

    # -- RECV (put) --------------------------------------------------------

    def _start_put(self, meta: dict, resume: bool = False) -> None:
        size = int(meta["size"])
        block_size = int(meta.get("block_size", self.neg.block_size))
        if size and self.root is not None:
            free = store_free_bytes(self.root, self.capacity_bytes)
            if size > free:
                raise DiskFullError(
                    f"store has {free} bytes free; refusing {size}-byte put")
        # a resume-put fills holes of the final file in place, so atomic
        # degrades to fsync for that one operation (session.py idiom)
        durability = (min(self.durability, DURABILITY_FSYNC) if resume
                      else self.durability)
        atomic = durability >= DURABILITY_ATOMIC
        try:
            path = resolve_path(self.root, meta.get("remote"), for_write=True)
            sink = Sink(path, size, durability=durability)
        except OSError as e:
            if e.errno == errno.ENOSPC:
                raise DiskFullError(f"cannot open {meta.get('remote')!r}: {e}")
            raise SessionError(f"cannot open {meta.get('remote')!r}: {e}")
        sidecar = (ResumeSidecar(path)
                   if self.integrity and path is not None else None)
        crc_acc: Optional[CrcManifest] = None
        if self.integrity:
            # no mid-transfer autosave under atomic: resume state would
            # describe blocks living in a temp file an abort discards
            crc_acc = CrcManifest(
                autosave=throttled_autosave(sidecar, size, block_size)
                if sidecar is not None and not atomic else None)
        reply = {"ok": True}
        if resume:
            prev = sidecar.load(size, block_size) if sidecar is not None else None
            if prev is not None:
                crc_acc.merge(prev)
            reply["have"] = {str(off): crc
                             for off, (_ln, crc) in crc_acc.blocks.items()}
        elif sidecar is not None:
            sidecar.clear()
        self._send_ctrl_frame(ChannelEvent.CONM, reply)
        self.fsm.step("resume" if resume else "opened")
        from repro.core.ringbuf import SlabSet

        span = slab_span(self.batch, block_size)
        if self._slabs is None or self._slabs.slab_bytes != span:
            self._slabs = SlabSet(self.n, span)
        self._sink = sink
        self._sidecar = sidecar
        self._crc_acc = crc_acc
        self._path = path
        self._file_size = size
        self._block_size = block_size
        self._chans = [SlabChannel(self._slabs.slab(i), block_size)
                       for i in range(self.n)]
        self._eof = [False] * self.n
        self.progress = 0
        self.state = ST_RECV
        self._apply_all_masks()

    def _fsm_steps(self, *events: str) -> None:
        for e in events:
            self.fsm.step(e)

    def _flush_chan(self, sc: SlabChannel, final: bool = False) -> None:
        batch = sc.take_pending()
        if batch or final:
            self.stats.writev_calls += self._sink.writev_views(batch)
        for rec in sc.take_verified():
            if self._crc_acc is not None:
                self._crc_acc.add(*rec)
        sc.compact()
        if final:
            return
        if self.fsm.state == "10_dispatch":
            self._fsm_steps("flush", "flushed")

    def _serve_recv(self, ch: int, limit: int) -> Tuple[int, bool]:
        sc = self._chans[ch]
        sock = self.socks[ch]
        moved = 0
        while moved < limit:
            if sc.end_event is not None:
                return moved, False
            if sc.free_space() == 0:
                self._flush_chan(sc)
            try:
                done = sc.receive_once(sock, max_bytes=limit - moved)
            except BlockingIOError:
                return moved, False
            moved += sc.last_recv
            self.progress += sc.last_recv
            for _ in range(done):
                self._fsm_steps("read_ready", "block", "buffered")
            if sc.end_event is not None:
                if sc.end_event == ChannelEvent.EOFR:
                    self.stats.eofr_frames += 1
                else:
                    self.stats.eoft_frames += 1
                self._eof[ch] = True
                self._fsm_steps("read_ready", "eof_header",
                                "all_eof" if all(self._eof) else "channels_open")
                if all(self._eof):
                    self._finish_recv()
                else:
                    # the LAST channel's tail rides the final flush (the
                    # FSM is already in 13_flush by then)
                    self._flush_chan(sc)
                return moved, False
        return moved, True

    def _finish_recv(self) -> None:
        for sc in self._chans:  # terminal flush of every channel's tail
            self._flush_chan(sc, final=True)
            self.stats.bytes += sc.bytes
            self.stats.recv_calls += sc.recv_calls
            self.stats.crc_mismatches += sc.crc_mismatches
        self.fsm.step("eofr_flush")
        self.stats.files += 1
        sink, self._sink = self._sink, None
        # durability barrier: the negotiated policy lands the bytes (fsync,
        # or temp fsync + rename + dir fsync) BEFORE the ACK is queued
        sink.commit()
        sink.close()
        if self.integrity:
            self._verify_ctx = (self._crc_acc, self._sidecar,
                                self._file_size, self._block_size, self._path)
        self._chans = None
        self._eof = None
        self.state = ST_CTRL
        for ch in range(self.n):
            self._queue_out(ch, ACK)
        if not self.integrity and self.server._draining:
            self._end_close = True
        self._apply_all_masks()
        self._maybe_finish_close()

    def _finish_verify(self, fin: dict) -> None:
        crc_acc, sidecar, size, block_size, path = self._verify_ctx
        self._verify_ctx = None
        if sidecar is not None:
            sidecar.save(size, block_size, crc_acc)
        try:
            mine = crc_acc.file_crc(size)
        except IntegrityError as e:
            self._send_ctrl_frame(ChannelEvent.EXCEPTION,
                                  {"error": str(e), "kind": "integrity"})
            mine = None
        if mine is not None:
            theirs = fin.get("file_crc")
            if theirs is not None and int(theirs) != mine:
                self._send_ctrl_frame(
                    ChannelEvent.EXCEPTION,
                    {"error": f"file CRC mismatch: client 0x{int(theirs):08x} "
                              f"!= server 0x{mine:08x}",
                     "kind": "integrity"})
            else:
                if path is not None:
                    # at-rest truth next to the committed bytes, for the
                    # scrubber to verify against (session.py idiom)
                    ManifestSidecar(path).save(size, block_size, crc_acc)
                self._send_ctrl_frame(ChannelEvent.CONM,
                                      {"ok": True, "file_crc": mine})
        self._crc_acc = None
        self._sidecar = None
        if self.server._draining:
            self._end_close = True
            self._maybe_finish_close()

    # -- SEND (get) --------------------------------------------------------

    def _start_get(self, meta: dict, resume: bool = False) -> None:
        block_size = int(meta.get("block_size", self.neg.block_size))
        remote = meta.get("remote")
        if remote is None:  # mem-to-mem mode: serve zeros
            size = int(meta["size"])
            source = Source(None, size, block_size)
        else:
            try:
                path = resolve_path(self.root, remote)
                size = os.path.getsize(path)
                source = Source(path, size, block_size)
            except OSError as e:
                raise SessionError(f"cannot read {remote!r}: {e}")
        blocks = None
        payload = size
        if resume:
            want = meta.get("want") or []
            blocks = sorted({int(off) // block_size for off in want
                             if 0 <= int(off) < size})
            payload = sum(source.block_len(b) for b in blocks)
        self._send_ctrl_frame(ChannelEvent.CONM, {"ok": True, "size": size})
        cap = self.batch
        self._source = source
        self._frames = FrameBuilder(self.neg.session, self.n, depth=cap + 1)
        self._tuners = ([ChannelTuner(cap=cap) for _ in range(self.n)]
                        if cap > 1 else None)
        plan = (list(range(source.n_blocks)) if blocks is None else blocks)
        self._queues = [plan[i::self.n] for i in range(self.n)]
        self._qpos = [0] * self.n
        self._pend = [None] * self.n
        self._done = [False] * self.n
        self._acked = [False] * self.n
        self._payload = payload
        self.progress = 0
        self.state = ST_SEND
        self._apply_all_masks()

    def _make_batch(self, ch: int) -> list:
        depth = self._tuners[ch].depth if self._tuners is not None else 1
        iov: list = []
        q = self._queues[ch]
        source = self._source
        data_flags = FLAG_BLOCK_CRC if self.integrity else 0
        for _ in range(depth):
            if self._qpos[ch] >= len(q):
                iov.append(self._frames.header(ch, ChannelEvent.EOFR, 0, 0))
                self._done[ch] = True
                break
            blk = q[self._qpos[ch]]
            self._qpos[ch] += 1
            ln = source.block_len(blk)
            iov.append(self._frames.header(ch, ChannelEvent.xFTSMU,
                                           blk * source.block_size, ln,
                                           flags=data_flags))
            iov.append(source.block_view(blk))
            if self.integrity:
                iov.append(self._frames.trailer(ch, source.block_crc(blk)))
        return iov

    def _serve_send(self, ch: int, limit: int) -> Tuple[int, bool]:
        sock = self.socks[ch]
        moved = 0
        while moved < limit:
            iov = self._pend[ch]
            if iov is None:
                if self._done[ch]:
                    return moved, False  # stripe done; awaiting the ack
                iov = self._make_batch(ch)
                self._pend[ch] = iov
            try:
                w = sock.sendmsg(iov)
            except BlockingIOError:
                return moved, False
            moved += w
            self.progress += w
            if self._tuners is not None:
                self._tuners[ch].note(w)
            if advance_iovec(iov, w):
                continue  # partial batch still pending on this channel
            self._pend[ch] = None
        return moved, True

    def _read_ack(self, ch: int, sock: socket.socket) -> None:
        try:
            b = sock.recv(1)
        except BlockingIOError:
            return
        if not b:
            raise ConnectionError("peer closed before transfer ack")
        self._acked[ch] = True
        if all(self._acked):
            self._finish_send()

    def _finish_send(self) -> None:
        self.stats.files += 1
        self.stats.bytes += self._payload
        source, self._source = self._source, None
        source.close()
        self._frames = None
        self._tuners = None
        self._queues = None
        self._pend = None
        self._done = None
        self._acked = None
        self.state = ST_CTRL
        if self.server._draining:
            self._end_close = True
        self._apply_all_masks()
        self._maybe_finish_close()

    # -- lifecycle ---------------------------------------------------------

    def idle_in_ctrl(self) -> bool:
        """Idle = between operations: no transfer, no pending verify."""
        return (self.state == ST_CTRL and self._verify_ctx is None
                and not self._end_close)

    def evict(self, kind: str = ERR_IDLE) -> None:
        """Best-effort typed notice, then close once the notice flushes."""
        if self.closed:
            return
        try:
            self._send_ctrl_frame(
                ChannelEvent.EXCEPTION,
                {"error": f"session evicted ({kind})", "kind": kind})
        except BaseException:  # noqa: BLE001
            pass
        self._end_close = True
        self._maybe_finish_close()

    def _maybe_finish_close(self) -> None:
        if (self._end_close and not self.closed and self.state == ST_CTRL
                and self._verify_ctx is None
                and all(not q for q in self._outq)):
            self._close()

    def _fail(self, e: BaseException) -> None:
        if self.closed:
            return
        if self.state == ST_RECV and self._sink is not None:
            if self._sink.durability >= DURABILITY_ATOMIC:
                # the uncommitted temp is discarded with the sink: clear
                # any resume state claiming its blocks
                if self._sidecar is not None:
                    try:
                        self._sidecar.clear()
                    except OSError:
                        pass
            # the stream died mid-file: persist what WAS verified so the
            # client can RESUME over a fresh connection
            elif (self._sidecar is not None and self._crc_acc is not None
                    and len(self._crc_acc)):
                try:
                    self._sidecar.save(self._file_size, self._block_size,
                                       self._crc_acc)
                except OSError:
                    pass
            try:
                self._sink.close()
            except OSError:
                pass
            self._sink = None
        if self._source is not None:
            try:
                self._source.close()
            except OSError:
                pass
            self._source = None
        self._close(error=e)

    def _close(self, error: Optional[BaseException] = None) -> None:
        if self.closed:
            return
        self.closed = True
        for ch, s in enumerate(self.socks):
            if self._masks[ch]:
                try:
                    self.shard.sel.unregister(s)
                except (KeyError, ValueError, OSError):
                    pass
                self._masks[ch] = 0
            try:
                s.close()
            except OSError:
                pass
        if self._sink is not None:
            try:
                self._sink.close()
            except OSError:
                pass
            self._sink = None
        if self._source is not None:
            try:
                self._source.close()
            except OSError:
                pass
            self._source = None
        self.shard.sessions.discard(self)
        self.server._loop_session_closed(self, error)


class EventLoopShard(threading.Thread):
    """One event-loop thread: a selector, a task queue (with a socketpair
    self-pipe so cross-thread submits interrupt ``select``), the DRR
    ready queue, and the housekeeping tick."""

    def __init__(self, server, idx: int):
        super().__init__(name=f"xdfs-shard-{idx}", daemon=True)
        self.server = server
        self.idx = idx
        self.sel = selectors.DefaultSelector()
        self.handshakes: Dict[socket.socket, HandshakeConn] = {}
        self.sessions: set = set()
        self.ready: deque = deque()
        self._tasks: deque = deque()
        self._tasks_lock = threading.Lock()
        self._halt = False
        self._wake_r, self._wake_w = socket.socketpair()
        self._wake_r.setblocking(False)
        self._wake_w.setblocking(False)
        self.sel.register(self._wake_r, selectors.EVENT_READ, self._on_wake)
        self._lsock: Optional[socket.socket] = None
        self._next_tick = 0.0

    # -- cross-thread API --------------------------------------------------

    def attach_listener(self, lsock: socket.socket) -> None:
        self._lsock = lsock
        self.sel.register(lsock, selectors.EVENT_READ, self._on_accept)

    def submit(self, fn) -> None:
        with self._tasks_lock:
            self._tasks.append(fn)
        self.wake()

    def wake(self) -> None:
        try:
            self._wake_w.send(b"\x00")
        except (BlockingIOError, OSError):
            pass

    def halt(self) -> None:
        self._halt = True
        self.wake()

    # -- loop --------------------------------------------------------------

    def run(self) -> None:
        try:
            while not self._halt:
                try:
                    events = self.sel.select(TICK)
                except OSError:
                    # a socket was force-closed under us (abort); per-object
                    # error paths clean up on their next callback
                    events = []
                for key, mask in events:
                    if self._halt:
                        break
                    try:
                        key.data(key.fileobj, mask)
                    except Exception as e:  # noqa: BLE001 - defensive: the
                        # per-object handlers catch their own failures
                        self.server.errors.append(e)
                self._drain_tasks()
                self._serve_ready()
                now = time.monotonic()
                if now >= self._next_tick:
                    self._next_tick = now + TICK
                    self._tick()
        finally:
            self._cleanup()

    def _on_wake(self, sock, mask) -> None:
        try:
            while sock.recv(4096):
                pass
        except (BlockingIOError, OSError):
            pass

    def _drain_tasks(self) -> None:
        while True:
            with self._tasks_lock:
                if not self._tasks:
                    return
                fn = self._tasks.popleft()
            try:
                fn()
            except Exception as e:  # noqa: BLE001
                self.server.errors.append(e)

    def _on_accept(self, lsock, mask) -> None:
        srv = self.server
        while True:
            try:
                conn, _ = lsock.accept()
            except BlockingIOError:
                return  # another shard won this wakeup's race
            except OSError:
                try:
                    self.sel.unregister(lsock)
                except (KeyError, ValueError, OSError):
                    pass
                return
            if srv._stopping or srv._draining:
                try:
                    conn.close()
                except OSError:
                    pass
                continue
            if (srv.max_pending is not None
                    and srv._pending_load() >= srv.max_pending):
                with srv._lock:
                    srv.stats["rejected_pending"] += 1
                try:
                    conn.close()
                except OSError:
                    pass
                continue
            try:
                conn.setblocking(False)
                conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            except OSError:
                try:
                    conn.close()
                except OSError:
                    pass
                continue
            hs = HandshakeConn(self, conn)
            self.handshakes[conn] = hs
            self.sel.register(conn, selectors.EVENT_READ, hs.on_io)

    def _serve_ready(self) -> None:
        """Deficit round robin over ready channels. Budget exhaustion
        leaves unserved items AT THE FRONT (starved work ages forward);
        served-but-still-hungry items re-queue at the back; blocked items
        drop out and the level-triggered selector re-arms them."""
        srv = self.server
        budget = srv.turn_budget
        while self.ready and budget > 0:
            sess, ch = self.ready.popleft()
            sess.queued.discard(ch)
            if sess.closed:
                continue
            if sess.deficit <= 0:
                sess.deficit = min(sess.deficit + srv.drr_quantum,
                                   srv.drr_quantum)
            limit = min(sess.deficit, budget)
            moved, more = sess.service(ch, limit)
            sess.deficit -= moved
            budget -= moved
            if more and not sess.closed and ch not in sess.queued:
                sess.queued.add(ch)
                self.ready.append((sess, ch))

    def _tick(self) -> None:
        srv = self.server
        now = srv._clock()
        for sess in list(self.sessions):
            if sess.closed:
                continue
            if sess.reject_kind is not None:
                # reject shells live only long enough to answer; bound by
                # the handshake timeout so a silent client can't pin one
                if now - sess.last_activity > srv.handshake_timeout:
                    sess._close()
                continue
            idle = now - sess.last_activity
            if sess.idle_in_ctrl():
                if srv._draining:
                    sess._end_close = True
                    sess._maybe_finish_close()
                elif (srv.idle_timeout is not None
                      and idle > srv.idle_timeout):
                    with srv._lock:
                        srv.stats["evicted"] += 1
                    sess.evict(ERR_IDLE)
            elif srv.io_timeout is not None and idle > srv.io_timeout:
                # a peer that stops moving bytes mid-transfer surfaces as
                # a typed TimeoutError in that session, not a pinned shard
                sess._fail(TimeoutError(
                    f"session stalled > {srv.io_timeout}s mid-transfer"))
        for hs in list(self.handshakes.values()):
            if now - hs.t0 > srv.handshake_timeout:
                srv.handshake_errors.append(
                    TimeoutError("handshake timed out"))
                hs.close()
        if self.idx == 0:
            srv._prune_stale_handshakes()

    def _cleanup(self) -> None:
        for hs in list(self.handshakes.values()):
            hs.close()
        for sess in list(self.sessions):
            try:
                sess._close()
            except Exception as e:  # noqa: BLE001
                self.server.errors.append(e)
        try:
            self.sel.close()
        except OSError:
            pass
        for s in (self._wake_r, self._wake_w):
            try:
                s.close()
            except OSError:
                pass
