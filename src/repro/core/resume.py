"""Interrupted-transfer resume sidecars (the RESUME flow's durable state).

A sidecar is a small JSON file next to the data file
(``<path>.xdfs-resume``) recording which blocks of the file are already
present AND verified::

    {"size": 1048576, "block_size": 65536,
     "blocks": {"0": [65536, 3735928559], ...}}   # offset -> [length, crc]

Writers: the server saves one whenever an integrity put dies mid-stream
(and autosaves every N verified blocks, so a hard crash also leaves one);
the client saves one when an integrity get dies or fails verification.
Readers: the RESUME handshake (``core/session.py`` / ``core/api.py``)
loads it to compute the missing/corrupt block set, so only those blocks
cross the wire again.

Writes are atomic (temp file + ``os.replace``) and loads are paranoid: a
missing, corrupt, or geometry-mismatched sidecar simply means "no resume
state" — the transfer restarts from byte 0, never from bad state.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Optional, Tuple

from repro.core.integrity import CrcManifest

SIDECAR_SUFFIX = ".xdfs-resume"
MANIFEST_SUFFIX = ".xdfs-manifest"

# floor between two autosaves of the same transfer: each autosave dumps
# the WHOLE growing manifest, so a pure per-N-blocks cadence costs
# O(blocks^2) over a long transfer; crash durability only needs a
# "recent" sidecar (the exception paths save the final state anyway)
AUTOSAVE_MIN_INTERVAL = 0.25


def throttled_autosave(sidecar: "ResumeSidecar", size: int, block_size: int,
                       min_interval: float = AUTOSAVE_MIN_INTERVAL,
                       ) -> Callable[[CrcManifest], None]:
    """The ``CrcManifest.autosave`` hook both transfer directions install:
    saves ``sidecar`` at most once per ``min_interval`` seconds."""
    last = [float("-inf")]

    def save(manifest: CrcManifest) -> None:
        now = time.monotonic()
        if now - last[0] >= min_interval:
            last[0] = now
            sidecar.save(size, block_size, manifest)

    return save


class ResumeSidecar:
    """Atomic load/save of one file's verified-block manifest."""

    __slots__ = ("path",)

    SUFFIX = SIDECAR_SUFFIX

    def __init__(self, data_path: str):
        self.path = str(data_path) + self.SUFFIX

    def exists(self) -> bool:
        return os.path.exists(self.path)

    def save(self, size: int, block_size: int, manifest: CrcManifest) -> None:
        doc = {
            "size": int(size),
            "block_size": int(block_size),
            "blocks": {str(off): [length, crc]
                       for off, (length, crc) in manifest.blocks.items()},
        }
        tmp = f"{self.path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(doc, f)
        os.replace(tmp, self.path)

    def load_any(self) -> Optional[Tuple[int, int, CrcManifest]]:
        """``(size, block_size, manifest)`` from disk, or None if the
        sidecar is missing or unusable in any way."""
        try:
            with open(self.path) as f:
                doc = json.load(f)
            size = int(doc["size"])
            block_size = int(doc["block_size"])
            if size < 0 or block_size <= 0:
                return None
            manifest = CrcManifest()
            for off, (length, crc) in doc["blocks"].items():
                manifest.blocks[int(off)] = (int(length), int(crc) & 0xFFFFFFFF)
        except (OSError, ValueError, KeyError, TypeError):
            return None
        return size, block_size, manifest

    def load(self, size: int, block_size: int) -> Optional[CrcManifest]:
        """The manifest, but only if the recorded geometry matches the
        transfer being resumed — otherwise the state is for some OTHER
        version of the file and resuming from it would corrupt it."""
        got = self.load_any()
        if got is None:
            return None
        got_size, got_block, manifest = got
        if got_size != size or got_block != block_size:
            return None
        return manifest

    def clear(self) -> None:
        try:
            os.unlink(self.path)
        except FileNotFoundError:
            pass


class ManifestSidecar(ResumeSidecar):
    """The at-rest truth for a COMMITTED file (``<path>.xdfs-manifest``).

    Same JSON schema and atomic-replace discipline as the resume sidecar,
    but the lifecycle is inverted: a resume sidecar describes a transfer
    that DIDN'T finish (and is cleared on success), while a manifest is
    written only after a successful integrity put commits, and stays next
    to the data file so the scrubber (``cluster/scrub.py``) can re-verify
    the bytes long after the writing session is gone.
    """

    __slots__ = ()

    SUFFIX = MANIFEST_SUFFIX


def sweep_sidecars(root: str) -> list:
    """GC orphaned transfer state under ``root``: sidecars whose data file
    is gone and abandoned atomic-commit temp files (``*.xdfs-tmp.<pid>``
    left by a transfer that died before its ``os.replace``). Returns the
    list of removed paths; IO errors skip the entry (a live transfer may
    own it)."""
    from repro.core.engines.base import TMP_INFIX

    removed = []
    for dirpath, _dirs, files in os.walk(root):
        names = set(files)
        for name in files:
            full = os.path.join(dirpath, name)
            stale = False
            for suffix in (SIDECAR_SUFFIX, MANIFEST_SUFFIX):
                if name.endswith(suffix):
                    stale = name[: -len(suffix)] not in names
            if TMP_INFIX in name:
                stale = True
            if stale:
                try:
                    os.unlink(full)
                    removed.append(full)
                except OSError:
                    pass
    return removed
