"""xDFS public API: persistent servers, multi-file client sessions, futures.

The paper's throughput wins come from amortizing protocol overhead across
a long-lived session (§2.5.3): negotiate once, keep n channels open, and
stream many files through them with ``EOFR`` (channel reusable) frames.
This module is the object model for that:

* :class:`XdfsServer` — a persistent in-process server that accepts many
  concurrent sessions and dispatches each through a registry engine
  (``mtedp`` / ``mt`` / ``mp`` or anything registered at runtime);
* :class:`XdfsClient` — ``connect()`` negotiates once; ``put`` / ``get`` /
  ``put_many`` / ``get_many`` reuse the same n channels for every file;
* :class:`TransferResult` — a future per file, so callers pipeline
  requests without blocking on each transfer.

Quickstart::

    with XdfsServer(engine="mtedp", root="/srv/data") as srv:
        with XdfsClient.connect(srv.address, n_channels=8) as cli:
            results = cli.put_many([(f, f"in/{os.path.basename(f)}")
                                    for f in local_files])
            total = sum(r.result().bytes for r in results)

``run_transfer`` in ``core/transfer.py`` remains as a one-shot
compatibility shim over these objects.
"""
from __future__ import annotations

import os
import queue
import socket
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.core.engines import Engine, Sink, Source, get_engine
from repro.core.faults import Deadline
from repro.core.header import (
    ChannelEvent,
    Negotiation,
    ProtocolError,
    new_session_id,
)
from repro.core.integrity import CrcManifest, IntegrityError, crc32_combine
from repro.core.resume import ResumeSidecar, throttled_autosave
from repro.core.session import (
    CTRL_CHANNEL,
    DEFAULT_BLOCK,
    MAX_BATCH_FRAMES,
    IntegrityFailure,
    ServerSession,
    SessionError,
    SessionStats,
    SocketTuning,
    recv_ctrl,
    recv_hello,
    recv_negotiation,
    send_ctrl,
    send_hello,
    send_negotiation,
)

HANDSHAKE_TIMEOUT = 15.0


def _connect_tuned(address: Tuple[str, int], timeout: float,
                   tuning: SocketTuning) -> socket.socket:
    """``socket.create_connection`` with the tuning applied BEFORE the TCP
    handshake — SO_RCVBUF must be set pre-connect for the kernel to pick a
    matching window-scale factor."""
    host, port = address
    err: Optional[OSError] = None
    for af, kind, proto, _, sa in socket.getaddrinfo(
        host, port, 0, socket.SOCK_STREAM
    ):
        s = socket.socket(af, kind, proto)
        try:
            tuning.apply(s)
            s.settimeout(timeout)
            s.connect(sa)
            return s
        except OSError as e:
            err = e
            s.close()
    raise err if err is not None else OSError(f"cannot resolve {address}")


@dataclass(frozen=True)
class FileResult:
    """Outcome of one file transfer inside a session."""

    remote: Optional[str]
    bytes: int
    wall_s: float
    data: Optional[bytes] = None  # populated by get_bytes

    @property
    def throughput_mbps(self) -> float:
        return self.bytes * 8 / self.wall_s / 1e6 if self.wall_s else 0.0


class TransferResult:
    """Future handle for one queued transfer. ``result()`` blocks until the
    session worker finishes the file and returns a :class:`FileResult`."""

    def __init__(self):
        self._future: Future = Future()

    def result(self, timeout: Optional[float] = None) -> FileResult:
        return self._future.result(timeout)

    def exception(self, timeout: Optional[float] = None):
        return self._future.exception(timeout)

    def done(self) -> bool:
        return self._future.done()

    def add_done_callback(self, fn) -> None:
        self._future.add_done_callback(lambda f: fn(self))


# ---------------------------------------------------------------------------
# server
# ---------------------------------------------------------------------------


class XdfsServer:
    """Persistent xDFS server: accepts many concurrent sessions, each a
    long-lived set of n channels carrying many files (EOFR reuse)."""

    def __init__(self, engine: Union[str, Engine] = "mtedp",
                 root: Optional[str] = None, host: str = "127.0.0.1",
                 port: int = 0, pool_slots: int = 32, backlog: int = 128,
                 tuning: Optional[SocketTuning] = None,
                 splice: bool = False, io_timeout: Optional[float] = None,
                 loop: Union[bool, int] = False,
                 max_sessions: Optional[int] = None,
                 max_pending: Optional[int] = None,
                 idle_timeout: Optional[float] = None,
                 clock=time.monotonic,
                 drr_quantum: Optional[int] = None,
                 turn_budget: Optional[int] = None,
                 durability: Union[int, str] = 0,
                 capacity_bytes: Optional[int] = None):
        from repro.core import evloop
        from repro.core.engines.base import durability_byte

        self.engine = get_engine(engine)  # fail fast on unknown engines
        self.root = root
        # server-side durability FLOOR: every put commits with at least
        # this policy, whatever the client negotiated ("none"/"fsync"/
        # "atomic" or the wire byte)
        self.durability = durability_byte(durability)
        # synthetic store capacity (bytes) for disk-pressure tests and
        # quota-limited stores; None = trust statvfs
        self.capacity_bytes = capacity_bytes
        self.host = host
        self._port = port
        self.pool_slots = pool_slots
        self.backlog = backlog
        # opt-in kernel-side receive (os.splice) for engines that support it
        self.splice = splice
        # per-operation stall bound applied while a transfer is in flight
        # (a client that stops moving bytes mid-file surfaces as a
        # TimeoutError in that session instead of pinning it forever)
        self.io_timeout = io_timeout
        # server-side default tuning; buffer sizes land on the LISTENING
        # socket so accepted channels inherit them before the TCP
        # handshake fixes the window scale
        self.tuning = tuning or SocketTuning()
        # ``loop`` selects the sharded event-loop core (core/evloop.py):
        # True = default shard count, an int = that many shards, False =
        # the thread-per-session path (still the default while engines
        # with server-side thread affinity — mp splice — need it)
        if isinstance(loop, bool):
            self.loop_shards = evloop.DEFAULT_SHARDS if loop else 0
        else:
            self.loop_shards = max(1, int(loop))
        # admission + scheduling knobs (loop mode)
        self.max_sessions = max_sessions
        self.max_pending = max_pending
        self.idle_timeout = idle_timeout
        self.handshake_timeout = HANDSHAKE_TIMEOUT
        self.drr_quantum = drr_quantum or evloop.DRR_QUANTUM
        self.turn_budget = turn_budget or evloop.TURN_BUDGET
        self._clock = clock  # injectable for eviction/stall tests
        self._shards: List["evloop.EventLoopShard"] = []
        self._loop_live = 0  # admitted, not-yet-closed loop sessions
        self._lsock: Optional[socket.socket] = None
        self._accept_thread: Optional[threading.Thread] = None
        self._session_threads: List[threading.Thread] = []
        self._live_socks: Dict[threading.Thread, list] = {}
        self._pending: Dict[bytes, Dict[int, socket.socket]] = {}
        self._pending_neg: Dict[bytes, Negotiation] = {}
        self._pending_since: Dict[bytes, float] = {}
        self._lock = threading.Lock()
        self._closed_cv = threading.Condition(self._lock)
        self._stopping = False
        self._draining = False
        self.errors: List[BaseException] = []  # session failures
        self.handshake_errors: List[BaseException] = []  # stray/bad connects
        self.last_tuning: Optional[SocketTuning] = None  # most recent session
        self.stats: Dict[str, int] = {
            "sessions": 0, "sessions_closed": 0, "negotiations": 0,
            "files": 0, "bytes": 0, "eofr_frames": 0, "eoft_frames": 0,
            "writev_calls": 0, "splice_bytes": 0, "recv_calls": 0,
            "splice_autodisables": 0, "crc_mismatches": 0,
            "rejected": 0, "rejected_pending": 0, "evicted": 0,
        }

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "XdfsServer":
        from repro.core.evloop import EventLoopShard

        lsock = socket.socket()
        lsock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self.tuning.apply_buffers(lsock)
        lsock.bind((self.host, self._port))
        lsock.listen(self.backlog)
        self._lsock = lsock
        if self.loop_shards:
            # sharded event-loop core: every shard registers the listener
            # for accept fan-out; no accept thread, no session threads
            lsock.setblocking(False)
            self._shards = [EventLoopShard(self, i)
                            for i in range(self.loop_shards)]
            for sh in self._shards:
                sh.attach_listener(lsock)
                sh.start()
            return self
        # a timeout so the accept loop notices _stopping: close() alone does
        # not wake a thread blocked in accept()
        lsock.settimeout(0.25)
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="xdfs-accept", daemon=True
        )
        self._accept_thread.start()
        return self

    @property
    def address(self) -> Tuple[str, int]:
        assert self._lsock is not None, "server not started"
        return self._lsock.getsockname()[:2]

    def stop(self, timeout: float = 10.0) -> None:
        """Graceful shutdown bounded by ONE global deadline (joining each
        session with the full timeout made worst-case stop time
        ``timeout x n_sessions``). Loop mode drains: in-flight files (and
        their verify exchange) complete, new work is refused with a typed
        ``draining`` answer, idle sessions close immediately."""
        deadline = time.monotonic() + timeout
        self._draining = True
        self._stopping = self._stopping or not self._shards
        if self._lsock is not None:
            try:
                self._lsock.close()
            except OSError:
                pass
        if self._shards:
            # unblock clients stuck mid-connect: a half-assembled session
            # will never complete once the listener is gone
            with self._lock:
                parked = [s for chans in self._pending.values()
                          for s in chans.values()]
                self._pending.clear()
                self._pending_neg.clear()
                self._pending_since.clear()
            for s in parked:
                try:
                    s.close()
                except OSError:
                    pass
            for sh in self._shards:
                sh.wake()
            while time.monotonic() < deadline:
                if all(not sh.sessions and not sh.handshakes
                       for sh in self._shards):
                    break
                time.sleep(0.01)
            self._stopping = True
            for sh in self._shards:
                sh.halt()
            for sh in self._shards:
                sh.join(max(0.2, deadline - time.monotonic()))
            return
        if self._accept_thread is not None:
            self._accept_thread.join(max(0.0, deadline - time.monotonic()))
        with self._lock:
            live = list(self._session_threads)
        for t in live:
            t.join(max(0.0, deadline - time.monotonic()))

    def abort(self) -> None:
        """Crash the server: close the listener AND every live session's
        channel sockets without draining, so in-flight transfers fail on
        the peer immediately. This is the fault-injection hook the
        cluster's node-kill uses (:meth:`stop` is the graceful path —
        it waits for open sessions, which a crash must not)."""
        self._stopping = True
        self._draining = True
        if self._lsock is not None:
            try:
                self._lsock.close()
            except OSError:
                pass
        with self._lock:
            socks = [s for lst in self._live_socks.values() for s in lst]
            socks.extend(s for chans in self._pending.values()
                         for s in chans.values())
        for sh in self._shards:
            socks.extend(hs.sock for hs in list(sh.handshakes.values()))
            for sess in list(sh.sessions):
                socks.extend(sess.socks)
        for s in socks:
            try:
                s.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                s.close()
            except OSError:
                pass
        for sh in self._shards:
            sh.halt()
        for sh in self._shards:
            sh.join(2.0)
        if self._accept_thread is not None:
            self._accept_thread.join(2.0)

    def wait_closed_sessions(self, n: int = 1, timeout: float = 600.0) -> bool:
        """Block until ``n`` sessions have completed (shim + tests)."""
        deadline = time.monotonic() + timeout
        with self._closed_cv:
            while self.stats["sessions_closed"] < n:
                left = deadline - time.monotonic()
                if left <= 0:
                    return False
                self._closed_cv.wait(left)
        return True

    def __enter__(self) -> "XdfsServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- accept / handshake ------------------------------------------------

    def _accept_loop(self) -> None:
        while not self._stopping:
            try:
                conn, _ = self._lsock.accept()
            except socket.timeout:
                self._prune_stale_handshakes()
                continue
            except OSError:
                break  # listener closed by stop()
            threading.Thread(
                target=self._handshake, args=(conn,), daemon=True
            ).start()

    def _prune_stale_handshakes(self) -> None:
        """Drop sessions whose remaining channels never arrived (client died
        mid-connect) so parked sockets and negotiations don't leak."""
        now = self._clock()
        with self._lock:
            stale = [sid for sid, t0 in self._pending_since.items()
                     if now - t0 > self.handshake_timeout]
            dropped = []
            for sid in stale:
                dropped.extend(self._pending.pop(sid, {}).values())
                self._pending_neg.pop(sid, None)
                self._pending_since.pop(sid, None)
        for s in dropped:
            try:
                s.close()
            except OSError:
                pass

    def _handshake(self, conn: socket.socket) -> None:
        """Read the channel hello (+ negotiation on the control channel),
        park the socket under its session id, and launch the session once
        all n channels have arrived. Channels of concurrent sessions may
        interleave arbitrarily."""
        try:
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            conn.settimeout(HANDSHAKE_TIMEOUT)
            hello = recv_hello(conn)
            if hello.channel == CTRL_CHANNEL:
                neg = recv_negotiation(conn)
                with self._lock:
                    self._pending_neg[hello.session] = neg
                    self.stats["negotiations"] += 1
            conn.settimeout(None)
            with self._lock:
                chans = self._pending.setdefault(hello.session, {})
                stale = chans.get(hello.channel)
                chans[hello.channel] = conn
                self._pending_since.setdefault(hello.session, self._clock())
            if stale is not None:
                # a reconnect/duplicate hello for the same channel: the
                # newer socket wins, the old one must not leak
                try:
                    stale.close()
                except OSError:
                    pass
            self._maybe_start_session(hello.session)
        except Exception as e:  # noqa: BLE001 - a bad/stray connection must
            # not take the server down, and is NOT a session failure
            self.handshake_errors.append(e)
            try:
                conn.close()
            except OSError:
                pass

    def _maybe_start_session(self, session_id: bytes) -> None:
        with self._lock:
            neg = self._pending_neg.get(session_id)
            chans = self._pending.get(session_id, {})
            if neg is None or len(chans) < neg.n_channels:
                return
            socks = [chans.get(i) for i in range(neg.n_channels)]
            if any(s is None for s in socks):
                # enough hellos arrived but with out-of-range/garbled
                # channel indices — not a startable session; leave the
                # state for the expected channels (or stale pruning)
                return
            extras = [s for ch, s in chans.items() if ch >= neg.n_channels]
            del self._pending_neg[session_id]
            del self._pending[session_id]
            self._pending_since.pop(session_id, None)
            self.stats["sessions"] += 1
            # apply the client-negotiated socket tuning to the server side
            # of every channel, so both ends of the session agree
            tuning = SocketTuning.from_negotiation(neg)
            for s in socks:
                tuning.apply(s)
            self.last_tuning = tuning
            t = threading.Thread(
                target=self._run_session, args=(socks, neg),
                name="xdfs-session", daemon=True,
            )
            self._session_threads.append(t)
            self._live_socks[t] = list(socks)
        for s in extras:  # garbled out-of-range channel hellos must not leak
            try:
                s.close()
            except OSError:
                pass
        t.start()

    def _run_session(self, socks, neg: Negotiation) -> None:
        sess = None
        try:
            # construction can refuse the session (e.g. a livelock-prone
            # pool_slots/n_channels combination) — that must still close
            # the channels and count the session as closed
            sess = ServerSession(socks, neg, self.engine, self.root,
                                 self.pool_slots, splice=self.splice,
                                 io_timeout=self.io_timeout,
                                 durability=self.durability,
                                 capacity_bytes=self.capacity_bytes)
            sess.run()
        except BaseException as e:  # noqa: BLE001 - keep the server alive
            self.errors.append(e)
        finally:
            for s in socks:
                try:
                    s.close()
                except OSError:
                    pass
            with self._closed_cv:
                st = sess.stats if sess is not None else SessionStats()
                self.stats["files"] += st.files
                self.stats["bytes"] += st.bytes
                self.stats["eofr_frames"] += st.eofr_frames
                self.stats["eoft_frames"] += st.eoft_frames
                self.stats["writev_calls"] += st.writev_calls
                self.stats["splice_bytes"] += st.splice_bytes
                self.stats["recv_calls"] += st.recv_calls
                self.stats["splice_autodisables"] += st.splice_autodisables
                self.stats["crc_mismatches"] += st.crc_mismatches
                self.stats["sessions_closed"] += 1
                # prune finished threads so a long-lived server stays bounded
                me = threading.current_thread()
                self._live_socks.pop(me, None)
                self._session_threads = [
                    t for t in self._session_threads
                    if t is not me and t.is_alive()
                ]
                self._closed_cv.notify_all()

    # -- loop-mode session assembly (called from shard threads) ------------

    def _pending_load(self) -> int:
        """In-flight handshake work: demuxing connections plus parked
        channels of half-assembled sessions (approximate across shards —
        admission is a load-shedding valve, not an exact semaphore)."""
        load = sum(len(sh.handshakes) for sh in self._shards)
        with self._lock:
            load += sum(len(chans) for chans in self._pending.values())
        return load

    def _park_from_loop(self, shard, hello, neg, sock) -> None:
        """Loop-mode twin of :meth:`_handshake`'s parking step: record the
        negotiation, park the channel under its session id (newer socket
        wins a duplicate hello), then try to assemble the session."""
        with self._lock:
            if neg is not None:
                self._pending_neg[hello.session] = neg
                self.stats["negotiations"] += 1
            chans = self._pending.setdefault(hello.session, {})
            stale = chans.get(hello.channel)
            chans[hello.channel] = sock
            self._pending_since.setdefault(hello.session, self._clock())
        if stale is not None:
            try:
                stale.close()
            except OSError:
                pass
        self._maybe_start_loop_session(shard, hello.session)

    def _maybe_start_loop_session(self, shard, session_id: bytes) -> None:
        from repro.core.evloop import ERR_BUSY, ERR_DRAINING, LoopSession

        with self._lock:
            neg = self._pending_neg.get(session_id)
            chans = self._pending.get(session_id, {})
            if neg is None or len(chans) < neg.n_channels:
                return
            socks = [chans.get(i) for i in range(neg.n_channels)]
            if any(s is None for s in socks):
                return  # out-of-range/garbled indices — wait or prune
            extras = [s for ch, s in chans.items() if ch >= neg.n_channels]
            del self._pending_neg[session_id]
            del self._pending[session_id]
            self._pending_since.pop(session_id, None)
            reject = None
            if self._draining or self._stopping:
                reject = ERR_DRAINING
            elif (self.max_sessions is not None
                  and self._loop_live >= self.max_sessions):
                reject = ERR_BUSY
            if reject is None:
                self.stats["sessions"] += 1
                self._loop_live += 1
                tuning = SocketTuning.from_negotiation(neg)
                for s in socks:
                    tuning.apply(s)
                self.last_tuning = tuning
            else:
                self.stats["rejected"] += 1
        for s in extras:  # garbled out-of-range channel hellos must not leak
            try:
                s.close()
            except OSError:
                pass
        # an admitted session lands on the least-loaded shard; a reject
        # shell stays where the last handshake finished (it only answers)
        target = (shard if reject is not None
                  else min(self._shards, key=lambda sh: len(sh.sessions)))
        sess = LoopSession(self, target, socks, neg, reject_kind=reject)
        target.submit(sess.attach)

    def _loop_session_closed(self, sess, error) -> None:
        if sess.reject_kind is not None:
            return  # never admitted: no stats, no closed count
        st = sess.stats
        with self._closed_cv:
            self.stats["files"] += st.files
            self.stats["bytes"] += st.bytes
            self.stats["eofr_frames"] += st.eofr_frames
            self.stats["eoft_frames"] += st.eoft_frames
            self.stats["writev_calls"] += st.writev_calls
            self.stats["splice_bytes"] += st.splice_bytes
            self.stats["recv_calls"] += st.recv_calls
            self.stats["splice_autodisables"] += st.splice_autodisables
            self.stats["crc_mismatches"] += st.crc_mismatches
            self.stats["sessions_closed"] += 1
            self._loop_live -= 1
            if error is not None:
                self.errors.append(error)
            self._closed_cv.notify_all()

    def loop_sessions(self) -> list:
        """Snapshot of live loop-mode sessions (observability + tests)."""
        return [sess for sh in self._shards for sess in list(sh.sessions)
                if sess.reject_kind is None]


# ---------------------------------------------------------------------------
# client
# ---------------------------------------------------------------------------


class XdfsClient:
    """One persistent session: negotiate once, stream many files over the
    same n channels. Operations are queued to a session worker thread and
    return :class:`TransferResult` futures, so callers can pipeline."""

    def __init__(self, socks: List[socket.socket], session_id: bytes,
                 engine: Engine, n_channels: int, block_size: int,
                 tuning: Optional[SocketTuning] = None,
                 splice: bool = False, batch_frames: int = 1,
                 integrity: bool = False,
                 io_timeout: Optional[float] = None):
        self.socks = socks
        self.session_id = session_id
        self.engine = engine
        self.n_channels = n_channels
        self.block_size = block_size
        self.tuning = tuning or SocketTuning()
        self.integrity = integrity  # negotiated end-to-end CRC datapath
        # splice cannot see payload bytes (no CRC verify) and cannot run on
        # a timeout-mode (non-blocking) fd, so either feature disables it
        self.splice = splice and not integrity and io_timeout is None
        self.io_timeout = io_timeout  # per-operation stall bound
        # negotiated syscall-batching ceiling, both directions
        self.batch_frames = max(1, min(int(batch_frames), MAX_BATCH_FRAMES))
        self.stats: Dict[str, int] = {
            "negotiations": 1, "files": 0, "bytes": 0, "eofr_sent": 0,
        }
        self._ops: "queue.Queue" = queue.Queue()
        self._submit_lock = threading.Lock()
        self._closed = False
        self._broken: Optional[BaseException] = None
        self._recv_pool = None  # RecvBufferPool reused across session gets
        self._recv_slabs = None  # SlabSet reused across session gets
        self._worker = threading.Thread(
            target=self._drain_ops, name="xdfs-client", daemon=True
        )
        self._worker.start()

    # -- connection --------------------------------------------------------

    @classmethod
    def connect(cls, address: Tuple[str, int], n_channels: int = 4,
                engine: Union[str, Engine] = "mtedp",
                block_size: int = DEFAULT_BLOCK,
                timeout: float = HANDSHAKE_TIMEOUT,
                tuning: Optional[SocketTuning] = None,
                splice: bool = False, batch_frames: int = 1,
                integrity: bool = False,
                io_timeout: Optional[float] = None,
                connect_deadline: Optional[float] = None,
                durability: Union[int, str] = 0) -> "XdfsClient":
        """``tuning`` — negotiated socket knobs (TCP_NODELAY + SO_SNDBUF /
        SO_RCVBUF); carried in the Negotiation so the server applies the
        same values to its side of every channel. ``splice`` — opt this
        client's downloads into the kernel-side receive fast path (the
        autotuner may still switch it off when it measures slower).
        ``batch_frames`` — negotiated ceiling on frames per scatter-gather
        syscall batch, BOTH directions (1 = per-frame datapath; actual
        depth is hill-climbed per channel). ``integrity`` — negotiate the
        end-to-end CRC datapath (per-block trailers + file manifest), a
        prerequisite for ``put/get(resume=True)``. ``io_timeout`` — stall
        bound applied to every in-flight operation (typed ``TimeoutError``
        instead of a hang). ``connect_deadline`` — wall-clock budget for
        the WHOLE multi-channel handshake, on top of the per-socket
        ``timeout``. ``durability`` — requested at-rest policy for puts
        ("none"/"fsync"/"atomic" or the wire byte); the server commits
        with the STRONGER of this and its own configured floor before
        the final ACK."""
        from repro.core.engines.base import durability_byte

        eng = get_engine(engine)
        tuning = tuning or SocketTuning()
        durability = durability_byte(durability)
        batch_frames = max(1, min(int(batch_frames), MAX_BATCH_FRAMES))
        deadline = (Deadline(connect_deadline)
                    if connect_deadline is not None else None)
        session_id = new_session_id()
        socks: List[socket.socket] = []
        try:
            for i in range(n_channels):
                dial_timeout = timeout
                if deadline is not None:
                    deadline.check(f"connect channel {i} to {address}")
                    dial_timeout = deadline.budget(timeout)
                s = _connect_tuned(address, dial_timeout, tuning)
                socks.append(s)  # before the hello: a failed write must
                # still find the socket in the cleanup loop below
                send_hello(s, session_id, i)
                if i == CTRL_CHANNEL:
                    send_negotiation(s, Negotiation(
                        session_id, n_channels, block_size, 1 << 20,
                        "", "", file_size=0,
                        so_sndbuf=tuning.sndbuf, so_rcvbuf=tuning.rcvbuf,
                        so_nodelay=tuning.nodelay, batch_frames=batch_frames,
                        integrity=integrity, durability=durability,
                    ))
        except BaseException:
            for s in socks:
                s.close()
            raise
        for s in socks:
            s.settimeout(io_timeout)  # None = plain blocking mode
        return cls(socks, session_id, eng, n_channels, block_size,
                   tuning=tuning, splice=splice, batch_frames=batch_frames,
                   integrity=integrity, io_timeout=io_timeout)

    # -- public operations (pipelined) -------------------------------------

    def put(self, src: Optional[str], dst: Optional[str] = None,
            size: Optional[int] = None,
            data: Optional[bytes] = None,
            resume: bool = False) -> TransferResult:
        """Upload ``src`` (or in-memory ``data``; or ``size`` zero bytes in
        mem-to-mem mode) to remote name ``dst`` (None discards server-side).
        An explicit ``size`` bounds how much of ``src``/``data`` is sent.
        ``resume=True`` asks the server which verified blocks it already
        holds for ``dst`` and re-sends ONLY the missing/stale ones
        (requires an integrity session)."""
        if resume and not self.integrity:
            raise ValueError("resume requires an integrity session "
                             "(connect with integrity=True)")
        if resume and dst is None:
            raise ValueError("resume needs a remote name to resume onto")
        if size is None:
            if data is not None:
                size = len(data)
            elif src is not None:
                size = os.path.getsize(src)
            else:
                raise ValueError("mem-mode put needs an explicit size")
        elif data is not None and size > len(data):
            # an oversized frame would stall the receiver waiting for
            # payload bytes that never come — fail before touching the wire
            raise ValueError(f"size {size} exceeds len(data) {len(data)}")
        elif src is not None and size > os.path.getsize(src):
            raise ValueError(f"size {size} exceeds file size of {src!r}")
        return self._submit(self._do_put, src, dst, size, data, resume)

    def get(self, src: Optional[str], dst: Optional[str] = None,
            size: Optional[int] = None,
            resume: bool = False) -> TransferResult:
        """Download remote ``src`` into local path ``dst`` (None discards).
        ``src=None`` is mem-to-mem mode and needs ``size``.
        ``resume=True`` reads the local ``.xdfs-resume`` sidecar and
        requests ONLY the blocks it is missing (requires an integrity
        session; falls back to a full get when no usable sidecar exists)."""
        if resume and not self.integrity:
            raise ValueError("resume requires an integrity session "
                             "(connect with integrity=True)")
        if resume and (src is None or dst is None):
            raise ValueError("resume needs both a remote and a local path")
        if src is None and size is None:
            raise ValueError("mem-mode get needs an explicit size")
        return self._submit(self._do_get, src, dst, size, False, resume)

    def get_bytes(self, src: str) -> TransferResult:
        """Download remote ``src`` into memory; the FileResult carries it
        in ``.data``."""
        return self._submit(self._do_get, src, None, None, True, False)

    def put_many(self, items: Sequence) -> List[TransferResult]:
        """Queue many uploads over the SAME channels: one negotiation total,
        EOFR between files. Items are ``(src, dst)`` tuples or dicts with
        ``src``/``dst``/``size``/``data`` keys."""
        out = []
        for item in items:
            if isinstance(item, dict):
                out.append(self.put(item.get("src"), item.get("dst"),
                                    item.get("size"), item.get("data")))
            else:
                src, dst = item
                out.append(self.put(src, dst))
        return out

    def get_many(self, items: Sequence) -> List[TransferResult]:
        """Queue many downloads; items are ``(src, dst)`` tuples or dicts."""
        out = []
        for item in items:
            if isinstance(item, dict):
                out.append(self.get(item.get("src"), item.get("dst"),
                                    item.get("size")))
            else:
                src, dst = item
                out.append(self.get(src, dst))
        return out

    @property
    def broken(self) -> bool:
        """True once the transport failed: every further op fails fast.
        Pool users (:class:`SessionPool`) check this to replace the
        session instead of leasing it out again."""
        return self._broken is not None

    @property
    def closed(self) -> bool:
        return self._closed

    def close(self) -> None:
        """Drain queued operations, send the terminal EOFT, close channels."""
        with self._submit_lock:
            if self._closed:
                return
            self._closed = True
            fin = TransferResult()
            self._ops.put((self._do_close, (), fin))
            self._ops.put(None)
        self._worker.join()
        for s in self.socks:
            try:
                s.close()
            except OSError:
                pass
        exc = fin.exception()
        if exc is not None and self._broken is None:
            raise exc

    def __enter__(self) -> "XdfsClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- worker ------------------------------------------------------------

    def _submit(self, fn, *args) -> TransferResult:
        # the lock orders submits against close(): nothing can land in the
        # queue after close() has enqueued the worker-stopping sentinel
        with self._submit_lock:
            if self._closed:
                raise SessionError("session is closed")
            res = TransferResult()
            self._ops.put((fn, args, res))
            return res

    def _drain_ops(self) -> None:
        while True:
            item = self._ops.get()
            if item is None:
                return
            fn, args, res = item
            if self._broken is not None:
                res._future.set_exception(self._broken)
                continue
            try:
                res._future.set_result(fn(*args))
            except BaseException as e:  # noqa: BLE001
                if not isinstance(e, SessionError):
                    self._broken = e  # transport is gone; fail the rest fast
                res._future.set_exception(e)

    def _do_put(self, src, dst, size, data, resume=False) -> FileResult:
        ctrl = self.socks[CTRL_CHANNEL]
        t0 = time.perf_counter()
        meta = {"remote": dst, "size": size, "block_size": self.block_size}
        if resume:
            meta["mode"] = "put"
            send_ctrl(ctrl, ChannelEvent.RESUME, self.session_id, meta)
        else:
            send_ctrl(ctrl, ChannelEvent.xFTSMU, self.session_id, meta)
        _, resp = recv_ctrl(ctrl)  # OK, or raises SessionError on EXCEPTION
        source = Source(src, size, self.block_size, data=data)
        try:
            blocks = None
            sent = size
            crcs: Optional[Dict[int, int]] = {} if self.integrity else None
            if resume:
                # diff the server's verified blocks against OUR block CRCs:
                # re-send whatever is missing or stale on the far side (the
                # diff pass covers every block, so it also completes `crcs`)
                have = resp.get("have", {})
                blocks = []
                for b in range(source.n_blocks):
                    c = source.block_crc(b)
                    crcs[b] = c
                    if have.get(str(b * self.block_size)) != c:
                        blocks.append(b)
                sent = sum(source.block_len(b) for b in blocks)
            self.engine.send(self.socks, source, self.session_id,
                             reusable=True, batch_frames=self.batch_frames,
                             integrity=self.integrity, blocks=blocks,
                             io_timeout=self.io_timeout, crc_out=crcs)
            if self.integrity:
                # end-to-end manifest exchange: the server folds the CRCs
                # of what LANDED and must match our whole-file CRC. Fold
                # the per-block CRCs the send path already computed (fork
                # engines can't report them back -> serial fallback pass).
                if len(crcs) == source.n_blocks:
                    file_crc = 0
                    for b in range(source.n_blocks):
                        file_crc = crc32_combine(file_crc, crcs[b],
                                                 source.block_len(b))
                else:
                    file_crc = source.file_crc()
                send_ctrl(ctrl, ChannelEvent.CONM, self.session_id,
                          {"file_crc": file_crc})
                recv_ctrl(ctrl)  # ok, or raises IntegrityFailure
        finally:
            source.close()
        self.stats["files"] += 1
        self.stats["bytes"] += sent
        self.stats["eofr_sent"] += self.n_channels
        return FileResult(dst, sent, time.perf_counter() - t0)

    def _do_get(self, src, dst, size, capture, resume=False) -> FileResult:
        ctrl = self.socks[CTRL_CHANNEL]
        t0 = time.perf_counter()
        sidecar = (ResumeSidecar(dst)
                   if self.integrity and dst is not None else None)
        prev: Optional[CrcManifest] = None
        want: Optional[List[int]] = None
        if resume and sidecar is not None:
            got = sidecar.load_any()  # size is unknown until the reply
            if got is not None and got[1] == self.block_size:
                prev_size, _bs, prev = got
                want = prev.missing(prev_size, self.block_size)
            # no usable sidecar -> silently degrade to a full get
        if prev is None:
            resume = False
        meta = {"remote": src, "size": size, "block_size": self.block_size}
        if resume:
            meta["mode"] = "get"
            meta["want"] = want
            send_ctrl(ctrl, ChannelEvent.RESUME, self.session_id, meta)
        else:
            if sidecar is not None:
                sidecar.clear()  # a fresh get invalidates old resume state
            send_ctrl(ctrl, ChannelEvent.xFTSMD, self.session_id, meta)
        _, resp = recv_ctrl(ctrl)
        size = int(resp["size"])
        if resume and size != prev_size:
            # the remote file changed size: the sidecar describes some other
            # version. The server is already streaming the requested blocks,
            # so this session cannot be cleanly reused — surface a transport
            # (not session-level) error and restart on a fresh connection.
            sidecar.clear()
            raise ProtocolError(
                f"cannot resume {src!r}: remote size {size} != "
                f"sidecar size {prev_size}")
        expected = (sum(min(self.block_size, size - off) for off in want)
                    if resume else size)
        sink = Sink(dst, size, capture=capture)
        if self.engine.uses_pool and self.batch_frames <= 1 and (
            self._recv_pool is None
            or self._recv_pool.block_size != self.block_size
        ):
            from repro.core.ringbuf import RecvBufferPool

            # sized past n_channels so the receiver's livelock guard
            # (pool.slots > n_channels) holds for any channel count
            self._recv_pool = RecvBufferPool(max(32, self.n_channels + 1),
                                             self.block_size)
        if self.engine.uses_pool and self.batch_frames > 1:
            from repro.core.engines.base import slab_span
            from repro.core.ringbuf import SlabSet

            span = slab_span(self.batch_frames, self.block_size)
            if self._recv_slabs is None or self._recv_slabs.slab_bytes != span:
                self._recv_slabs = SlabSet(self.n_channels, span)
        crc_acc: Optional[CrcManifest] = None
        if self.integrity:
            crc_acc = CrcManifest(
                autosave=throttled_autosave(sidecar, size, self.block_size)
                if sidecar is not None else None)
            if prev is not None:
                crc_acc.merge(prev)
        try:
            self.engine.receive(
                self.socks, sink, self.block_size, reusable=True,
                pool=self._recv_pool, splice=self.splice,
                batch_frames=self.batch_frames, slabs=self._recv_slabs,
                crc_acc=crc_acc, io_timeout=self.io_timeout,
            )
            payload = sink.data if capture else None
        except BaseException:
            # the stream died mid-file: persist what WAS verified so a
            # later get(resume=True) re-fetches only the rest
            if sidecar is not None and crc_acc is not None and len(crc_acc):
                sidecar.save(size, self.block_size, crc_acc)
            raise
        finally:
            sink.close()
        if crc_acc is not None and dst is not None:
            try:
                crc_acc.file_crc(size)  # raises on any unverified gap
            except IntegrityError as e:
                if sidecar is not None:
                    sidecar.save(size, self.block_size, crc_acc)
                raise IntegrityFailure(
                    f"download of {src!r} is incomplete: {e}")
            if sidecar is not None:
                sidecar.clear()  # fully verified: no resume state to keep
        self.stats["files"] += 1
        self.stats["bytes"] += expected
        return FileResult(src, expected, time.perf_counter() - t0,
                          data=payload)

    def _do_close(self) -> FileResult:
        send_ctrl(self.socks[CTRL_CHANNEL], ChannelEvent.EOFT, self.session_id)
        return FileResult(None, 0, 0.0)


# ---------------------------------------------------------------------------
# session pool (the cluster layer's node-to-node transport hook)
# ---------------------------------------------------------------------------


class SessionPool:
    """Reusable :class:`XdfsClient` sessions keyed by peer address.

    The cluster layer multiplies session peers: a striped put talks to
    every data node, and re-replication copies blocks node-to-node. Each
    of those transfers must still amortize negotiation the way a single
    session does, so the pool keeps ONE negotiated multi-channel session
    per peer and every block ``put``/``get`` rides it (EOFR reuse, the
    batched zero-copy datapath unchanged). A session that broke (peer
    died) or was closed is replaced on the next :meth:`lease`.
    """

    def __init__(self, n_channels: int = 2,
                 engine: Union[str, Engine] = "mtedp",
                 block_size: int = DEFAULT_BLOCK,
                 batch_frames: int = 1,
                 tuning: Optional[SocketTuning] = None,
                 timeout: float = HANDSHAKE_TIMEOUT,
                 integrity: bool = False,
                 io_timeout: Optional[float] = None,
                 durability: Union[int, str] = 0):
        self.n_channels = n_channels
        self.engine = engine
        self.block_size = block_size
        self.batch_frames = batch_frames
        self.tuning = tuning
        self.timeout = timeout
        self.integrity = integrity
        self.io_timeout = io_timeout
        self.durability = durability
        self._lock = threading.Lock()
        self._sessions: Dict[Tuple[str, int], XdfsClient] = {}
        self.stats: Dict[str, int] = {"connects": 0, "reuses": 0,
                                      "stale_redials": 0}

    def lease(self, address: Tuple[str, int]) -> XdfsClient:
        """The pooled session for ``address``, dialing one if needed.
        Leases are shared, not exclusive: ``XdfsClient`` serializes its
        operations through one worker, so concurrent leaseholders simply
        pipeline onto the same channels."""
        address = (address[0], int(address[1]))
        with self._lock:
            cli = self._sessions.get(address)
            if cli is not None and not (cli.broken or cli.closed):
                self.stats["reuses"] += 1
                return cli
            if cli is not None:
                self._discard(cli)
            cli = XdfsClient.connect(
                address, n_channels=self.n_channels, engine=self.engine,
                block_size=self.block_size, timeout=self.timeout,
                tuning=self.tuning, batch_frames=self.batch_frames,
                integrity=self.integrity, io_timeout=self.io_timeout,
                durability=self.durability,
            )
            self._sessions[address] = cli
            self.stats["connects"] += 1
            return cli

    def execute(self, address: Tuple[str, int], fn):
        """Run ``fn(client)`` on the pooled session for ``address``,
        absorbing ONE stale-session failure. A peer that restarted at the
        same address leaves the pooled session looking healthy until its
        first use raises a connection-level error — invalidate, redial
        once, and re-run; a second failure propagates (the peer is
        actually down, not just restarted)."""
        cli = self.lease(address)
        try:
            return fn(cli)
        except (ConnectionError, TimeoutError, OSError):
            self.invalidate(address)
            self.stats["stale_redials"] += 1
            return fn(self.lease(address))

    def invalidate(self, address: Tuple[str, int]) -> None:
        """Drop the pooled session for a peer (e.g. after a transfer
        error) so the next lease re-dials."""
        address = (address[0], int(address[1]))
        with self._lock:
            cli = self._sessions.pop(address, None)
        if cli is not None:
            self._discard(cli)

    @staticmethod
    def _discard(cli: XdfsClient) -> None:
        try:
            cli.close()
        except Exception:  # noqa: BLE001 - already-broken peers raise
            pass

    def close(self) -> None:
        with self._lock:
            sessions = list(self._sessions.values())
            self._sessions.clear()
        for cli in sessions:
            self._discard(cli)

    def __enter__(self) -> "SessionPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
