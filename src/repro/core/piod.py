"""PIOD — Parallel I/O Dispatcher (paper §4.1, Fig. 7).

The event-dispatching core of the MTEDP architecture: one thread multiplexes
all n channels of a session through a readiness loop (``selectors`` — the
cross-platform select()/epoll/kqueue abstraction, matching the paper's choice
of select() for portability). Channel handlers are small state machines fed
with readiness events; the dispatcher never blocks on any single channel.
"""
from __future__ import annotations

import selectors
import socket
import time
from typing import Callable, Dict, Optional

from repro.core.faults import DeadlineExceeded


class PIOD:
    def __init__(self):
        self.sel = selectors.DefaultSelector()
        self._n = 0
        self.idle_callback: Optional[Callable[[], None]] = None

    def register(self, sock: socket.socket, events: int, callback) -> None:
        sock.setblocking(False)
        self.sel.register(sock, events, callback)
        self._n += 1

    def modify(self, sock: socket.socket, events: int, callback) -> None:
        self.sel.modify(sock, events, callback)

    def unregister(self, sock: socket.socket) -> None:
        self.sel.unregister(sock)
        self._n -= 1

    @property
    def active(self) -> int:
        return self._n

    def run(self, until: Callable[[], bool], timeout: float = 0.05,
            stall_timeout: Optional[float] = None) -> None:
        """Dispatch readiness events until ``until()`` is true.

        ``stall_timeout`` bounds how long the loop tolerates ZERO
        readiness events across all channels: a peer that stops moving
        bytes surfaces as a typed ``TimeoutError`` (DeadlineExceeded)
        instead of hanging the dispatcher forever.
        """
        last_progress = time.monotonic()
        while not until():
            events = self.sel.select(timeout)
            for key, mask in events:
                key.data(key.fileobj, mask)
            if events:
                last_progress = time.monotonic()
            elif (stall_timeout is not None
                    and time.monotonic() - last_progress > stall_timeout):
                raise DeadlineExceeded(
                    f"no channel readiness for {stall_timeout:.1f}s")
            if self.idle_callback is not None:
                self.idle_callback()

    def close(self) -> None:
        self.sel.close()
