"""Shared transfer-engine plumbing: wire helpers, Source/Sink, RecvStats.

Engines (engines/{mtedp,mt,mp}.py) move blocks between a ``Source`` and a
``Sink`` over framed TCP channels. Sources can be backed by a file, an
in-memory buffer (checkpoint leaves), or zeros (the paper's /dev/zero
mem-to-mem mode); sinks by a file, a capture buffer, or /dev/null-style
discard.

Both halves of the datapath are zero-copy:

* **send** — file-backed sources are mmapped and ``block_view(i)`` hands
  out views into the map, ``FrameBuilder`` packs headers into per-channel
  reusable buffers, and senders hand both straight to ``socket.sendmsg``
  (scatter-gather) or ``os.sendfile`` — no per-block heap copy between
  the page cache and the socket.
* **receive** — frames land directly in a registered
  ``RecvBufferPool`` (core/ringbuf.py): receivers pass pool slot views to
  ``socket.recv_into``, parse headers in place from reusable buffers, and
  the drain side hands trimmed views of the SAME pool memory to
  ``Sink.writev_views`` (coalesced ``os.pwritev``). Slot lifecycle:
  ``acquire -> recv_into -> commit -> pwritev -> release``. On Linux the
  blocking receivers can additionally opt into :class:`SpliceReceiver`
  (socket -> pipe -> file ``os.splice``), which keeps the payload
  kernel-side entirely; a :class:`SpliceUnsupported` first-call failure
  falls back to the pool path, mirroring the ``sendfile`` pattern.

Both directions additionally batch syscalls when the session negotiates
``batch_frames > 1``: senders coalesce up to that many frames into one
scatter-gather ``sendmsg`` (:func:`sendmsg_batched`, exact per-frame
delivery accounting under partial sends), and receivers drain the socket
with large slab reads parsed in place by :class:`SlabChannel` — many
frames per ``recv_into``, committed as ``(offset, view)`` pairs of the
same slab memory. Actual batch depth is hill-climbed at runtime by
``core/autotune.py``; the splice opt-in is likewise arbitrated against
the pool path by measured goodput instead of being static.
"""
from __future__ import annotations

import errno
import mmap
import os
import socket
from dataclasses import dataclass
from typing import List, Optional, Tuple

from repro.core.header import (
    CRC_TRAILER,
    FLAG_BLOCK_CRC,
    HEADER_SIZE,
    TRAILER_SIZE,
    ChannelEvent,
    ChannelHeader,
    ProtocolError,
    pack_header_into,
)
from repro.core.integrity import (
    HAVE_NATIVE_CRC,
    buffer_address,
    crc32_update,
    crc32_update_at,
)

ACK = b"\x06"
IOV_MAX = 512
SENDFILE = hasattr(os, "sendfile")

# At-rest durability policy for received files, negotiated as the final
# Negotiation tail byte (header.Negotiation.durability). Wire bytes are
# ordered by strength so the server can apply max(client, server floor).
DURABILITY_NONE = 0  # close + ACK; the page cache owns the bytes
DURABILITY_FSYNC = 1  # fsync the sink before the final ACK
DURABILITY_ATOMIC = 2  # temp file + fsync + os.replace + dir fsync pre-ACK
DURABILITY_NAMES = ("none", "fsync", "atomic")
# receive-side temp files of atomic-mode sinks: <path>.xdfs-tmp.<pid>
TMP_INFIX = ".xdfs-tmp."


def durability_byte(policy) -> int:
    """Normalize a durability policy (name or wire byte) to its byte."""
    if isinstance(policy, str):
        try:
            return DURABILITY_NAMES.index(policy)
        except ValueError:
            raise ValueError(
                f"unknown durability policy {policy!r}; "
                f"expected one of {DURABILITY_NAMES}") from None
    b = int(policy)
    if not 0 <= b < len(DURABILITY_NAMES):
        raise ValueError(f"unknown durability byte {b}")
    return b


def fsync_dir(path: str) -> None:
    """fsync a directory so a just-landed ``os.replace`` survives power
    loss (best-effort: some filesystems refuse O_RDONLY dir fsync)."""
    try:
        fd = os.open(path or ".", os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def store_free_bytes(root: str, capacity_bytes: Optional[int] = None) -> int:
    """Bytes available for new data under ``root``. With a configured
    ``capacity_bytes`` (quota'd stores, deterministic tests) it is the
    capacity minus bytes currently stored under the root; otherwise the
    filesystem's own free space (``statvfs``)."""
    if capacity_bytes is not None:
        used = 0
        for dirpath, _dirs, files in os.walk(root):
            for name in files:
                try:
                    used += os.lstat(os.path.join(dirpath, name)).st_size
                except OSError:
                    pass
        return max(0, capacity_bytes - used)
    try:
        st = os.statvfs(root)
    except OSError:
        return 1 << 62  # unprobeable store: never refuse on a guess
    return st.f_bavail * st.f_frsize

# the one definition of which frame events end a channel's file stream
END_EVENTS = (ChannelEvent.EOFR, ChannelEvent.EOFT)


# ---------------------------------------------------------------------------
# wire helpers
# ---------------------------------------------------------------------------


MSG_MORE = getattr(socket, "MSG_MORE", 0)  # Linux: coalesce with next send


def send_all(sock: socket.socket, data, flags: int = 0) -> None:
    view = memoryview(data)
    while view:
        n = sock.send(view, flags)
        view = view[n:]


def recv_exact(sock: socket.socket, n: int, buf: Optional[memoryview] = None):
    out = memoryview(bytearray(n)) if buf is None else buf[:n]
    got = 0
    while got < n:
        r = sock.recv_into(out[got:], n - got)
        if r == 0:
            raise ConnectionError("peer closed")
        got += r
    return out


def pwrite_all(fd: int, data, offset: int) -> None:
    """``os.pwrite`` until every byte of ``data`` lands (short writes —
    near-full disk, quotas — must surface as progress or an error, never
    as a silent hole in the file)."""
    view = memoryview(data)
    while view:
        n = os.pwrite(fd, view, offset)
        if n <= 0:
            raise OSError(errno.EIO, "pwrite: short write")
        offset += n
        view = view[n:]


def advance_iovec(iov: List[memoryview], n: int) -> List[memoryview]:
    """Account ``n`` sent bytes against the head of an iovec IN PLACE —
    partial ``sendmsg`` resumes by re-slicing the vector instead of
    rebuilding the frame."""
    while n:
        head = iov[0]
        if n < len(head):
            iov[0] = head[n:]
            break
        n -= len(head)
        iov.pop(0)
    return iov


def sendmsg_all(sock: socket.socket, views) -> int:
    """Scatter-gather send of [header_view, payload_view, ...] on a blocking
    socket; partial sends re-slice the iovec until everything is on the
    wire. Returns total bytes sent."""
    iov = [v if isinstance(v, memoryview) else memoryview(v) for v in views]
    iov = [v for v in iov if len(v)]
    total = 0
    while iov:
        n = sock.sendmsg(iov)
        total += n
        advance_iovec(iov, n)
    return total


class SendfileUnsupported(OSError):
    """First ``sendfile`` call failed before any byte hit the wire — the
    fd/socket combination doesn't support it; caller falls back."""


_KERNEL_COPY_FALLBACK_ERRNOS = frozenset(
    getattr(errno, name) for name in
    ("EINVAL", "ENOSYS", "EOPNOTSUPP", "ENOTSOCK", "ENOTSUP")
    if hasattr(errno, name)
)


def sendfile_all(sock: socket.socket, fd: int, offset: int, count: int) -> int:
    """Kernel-side copy of ``count`` bytes of ``fd`` at ``offset`` into the
    socket (the uncompressed file-backed fast path). Raises
    :class:`SendfileUnsupported` only if the FIRST call fails with an
    unsupported-operation errno (nothing on the wire yet, safe to fall
    back); a mid-stream error is a real transport failure and re-raises."""
    sent = 0
    while sent < count:
        try:
            n = os.sendfile(sock.fileno(), fd, offset + sent, count - sent)
        except OSError as e:
            if sent == 0 and e.errno in _KERNEL_COPY_FALLBACK_ERRNOS:
                raise SendfileUnsupported(e.errno, "sendfile unsupported") from e
            raise
        if n == 0:
            raise ConnectionError("sendfile: peer closed")
        sent += n
    return sent


SPLICE = hasattr(os, "splice")


class SpliceUnsupported(OSError):
    """First ``splice`` call failed before any byte left the socket — the
    socket/pipe/file combination doesn't support it; caller falls back to
    the registered-buffer pool path."""


class SpliceReceiver:
    """Kernel-side socket->file block receive: ``os.splice`` through a
    private pipe (sockets cannot splice straight into a file offset), the
    receive-side mirror of the ``sendfile`` fast path. The payload never
    surfaces to user space.

    One instance per receiving worker; :meth:`splice_block` moves exactly
    one frame's payload from a BLOCKING socket into ``fd`` at ``offset``.
    Fallback contract, mirroring :func:`sendfile_all`:

    * if the FIRST socket->pipe splice of a block fails with an
      unsupported-operation errno, nothing was consumed from the socket —
      :class:`SpliceUnsupported` is raised and the caller receives the
      whole block on the generic pool path;
    * if splice dies mid-block (bytes already off the socket), the block
      is COMPLETED with a recovery copy (charged to
      ``RecvBufferPool.materializations``) and ``self.ok`` drops to False
      so the caller switches paths from the next frame — data is never
      lost to a late fallback;
    * any other mid-stream error is a real transport failure and re-raises.
    """

    PIPE_CHUNK = 1 << 16  # default Linux pipe capacity

    def __init__(self):
        if not SPLICE:
            raise SpliceUnsupported(0, "os.splice unavailable")
        self._r, self._w = os.pipe()
        self._scratch: Optional[memoryview] = None
        self.ok = True  # drops to False after a mid-block recovery

    def close(self) -> None:
        for fd in (self._r, self._w):
            try:
                os.close(fd)
            except OSError:
                pass

    def splice_block(self, sock: socket.socket, fd: int, offset: int,
                     count: int) -> int:
        """Move ``count`` payload bytes socket->pipe->file. Returns the
        number of bytes that stayed kernel-side (== ``count`` unless a
        mid-block recovery copied part of the chunk)."""
        moved = spliced = 0
        while moved < count:
            want = min(self.PIPE_CHUNK, count - moved)
            try:
                n_in = os.splice(sock.fileno(), self._w, want)
            except OSError as e:
                if e.errno not in _KERNEL_COPY_FALLBACK_ERRNOS:
                    raise
                if moved == 0:
                    raise SpliceUnsupported(
                        e.errno, "splice unsupported") from e
                self.ok = False  # finish the block in user space
                self._copy_from_socket(sock, fd, offset + moved,
                                       count - moved)
                return spliced
            if n_in == 0:
                raise ConnectionError("splice: peer closed mid-block")
            # _pipe_to_file recovers its own mid-drain fallback (dropping
            # self.ok); the whole chunk is on disk either way
            spliced += self._pipe_to_file(fd, offset + moved, n_in)
            moved += n_in
            if not self.ok:
                # finish the rest of the block from the socket, then the
                # caller drops to the pool path for later frames
                self._copy_from_socket(sock, fd, offset + moved,
                                       count - moved)
                return spliced
        return spliced

    def _pipe_to_file(self, fd: int, offset: int, n_in: int) -> int:
        """Drain ``n_in`` pipe bytes into ``fd`` at ``offset``. Returns how
        many moved kernel-side; an unsupported-errno failure mid-drain
        recovers ONLY the still-undrained remainder (at its correct
        offset) with a counted copy and drops ``self.ok``."""
        drained = 0
        while drained < n_in:
            try:
                n_out = os.splice(self._r, fd, n_in - drained,
                                  offset_dst=offset + drained)
            except OSError as e:
                if e.errno not in _KERNEL_COPY_FALLBACK_ERRNOS:
                    raise
                self.ok = False
                self._copy_from_pipe(fd, offset + drained, n_in - drained)
                return drained
            if n_out == 0:
                raise OSError(errno.EIO, "splice: pipe->file stalled")
            drained += n_out
        return drained

    def _scratch_view(self) -> memoryview:
        if self._scratch is None:
            self._scratch = memoryview(bytearray(self.PIPE_CHUNK))
        return self._scratch

    def _copy_from_pipe(self, fd: int, offset: int, n: int) -> None:
        from repro.core.ringbuf import RecvBufferPool

        RecvBufferPool.materializations += 1
        scratch = self._scratch_view()
        done = 0
        while done < n:
            got = os.readv(self._r, [scratch[: n - done]])
            if got == 0:
                raise OSError(errno.EIO, "splice recovery: pipe drained early")
            pwrite_all(fd, scratch[:got], offset + done)
            done += got

    def _copy_from_socket(self, sock: socket.socket, fd: int, offset: int,
                          n: int) -> None:
        if n <= 0:
            return
        from repro.core.ringbuf import RecvBufferPool

        RecvBufferPool.materializations += 1
        scratch = self._scratch_view()
        done = 0
        while done < n:
            got = sock.recv_into(scratch[: min(len(scratch), n - done)])
            if got == 0:
                raise ConnectionError("peer closed mid-block")
            pwrite_all(fd, scratch[:got], offset + done)
            done += got


class FrameBuilder:
    """Packs channel headers into per-channel REUSABLE buffers.

    ``depth`` is the number of header buffers per channel: a channel may
    have at most ``depth`` frames in flight (one for the legacy per-frame
    senders; the negotiated batch ceiling plus the end frame for the
    batched ones), and :meth:`header` hands the buffers out round-robin —
    the next reuse of a buffer only happens after the batch it belonged
    to fully drained. Eliminates the two per-block allocations of the
    legacy ``hdr.pack() + payload`` path (header bytes + concatenated
    frame)."""

    __slots__ = ("session", "depth", "_bufs", "_views", "_next",
                 "_tbufs", "_tviews", "_tnext")

    def __init__(self, session: bytes, n_channels: int, depth: int = 1):
        self.session = session
        self.depth = max(1, depth)
        self._bufs = [[bytearray(HEADER_SIZE) for _ in range(self.depth)]
                      for _ in range(n_channels)]
        self._views = [[memoryview(b) for b in row] for row in self._bufs]
        self._next = [0] * n_channels
        # integrity-mode CRC trailers ride the same reuse discipline: one
        # 4-byte buffer per in-flight frame, handed out round-robin
        self._tbufs = [[bytearray(TRAILER_SIZE) for _ in range(self.depth)]
                       for _ in range(n_channels)]
        self._tviews = [[memoryview(b) for b in row] for row in self._tbufs]
        self._tnext = [0] * n_channels

    def header(self, channel: int, event: ChannelEvent, offset: int,
               length: int, flags: int = 0) -> memoryview:
        slot = self._next[channel]
        self._next[channel] = (slot + 1) % self.depth
        pack_header_into(self._bufs[channel][slot], event, self.session,
                         channel, offset, length, flags)
        return self._views[channel][slot]

    def trailer(self, channel: int, crc: int) -> memoryview:
        """A packed CRC32 trailer view for the channel's next data frame."""
        slot = self._tnext[channel]
        self._tnext[channel] = (slot + 1) % self.depth
        CRC_TRAILER.pack_into(self._tbufs[channel][slot], 0, crc & 0xFFFFFFFF)
        return self._tviews[channel][slot]


@dataclass
class SendStats:
    """Delivery accounting for the batched send path. ``bytes`` counts
    bytes the kernel actually accepted (partial ``sendmsg`` returns
    included as-is); ``frames`` counts frames whose LAST byte has been
    delivered — never the raw iovec sum of an in-flight batch."""

    bytes: int = 0
    syscalls: int = 0  # sendmsg calls issued
    frames: int = 0  # frames fully delivered
    batches: int = 0  # batched sendmsg groups completed


def sendmsg_batched(sock: socket.socket, views, frame_sizes,
                    stats: Optional[SendStats] = None) -> int:
    """Scatter-gather send of MANY frames in one iovec
    (``[hdr0, blk0, hdr1, blk1, ...]``) on a blocking socket; partial
    sends resume by re-slicing (:func:`advance_iovec`). ``frame_sizes``
    holds each frame's on-wire size (header + payload); per-frame stats
    credit a frame only once the cumulative delivered byte count crosses
    its end boundary, so a short ``sendmsg`` under a tiny SO_SNDBUF never
    over-reports delivery. Returns total bytes sent."""
    iov = [v if isinstance(v, memoryview) else memoryview(v) for v in views]
    iov = [v for v in iov if len(v)]
    sent = 0
    boundary = 0  # cumulative wire size up to the next uncredited frame
    fi = 0
    while iov:
        n = sock.sendmsg(iov)
        sent += n
        if stats is not None:
            stats.syscalls += 1
            stats.bytes += n
            while fi < len(frame_sizes) and sent >= boundary + frame_sizes[fi]:
                boundary += frame_sizes[fi]
                fi += 1
                stats.frames += 1
        advance_iovec(iov, n)
    if stats is not None:
        stats.batches += 1
    return sent


# ---------------------------------------------------------------------------
# batched (slab) receive machinery
# ---------------------------------------------------------------------------


MAX_SLAB_BYTES = 8 << 20  # per-channel slab memory ceiling


def slab_span(batch_frames: int, block_size: int) -> int:
    """Slab size for a channel receiving up to ``batch_frames``-deep
    batches of ``block_size`` blocks: ideally one full batch plus a
    trailing header fits, clamped to a sane memory ceiling (a smaller
    slab stays CORRECT — frames spanning the slab edge are committed as
    partial payload views — it just flushes more often)."""
    # TRAILER_SIZE is budgeted unconditionally: integrity frames carry a
    # 4-byte CRC trailer, and a slab sized without it fills 4*batch_frames
    # bytes short of a full batch — every batch then splits its last frame
    # across an extra flush/compact round-trip
    want = batch_frames * (HEADER_SIZE + block_size + TRAILER_SIZE) + HEADER_SIZE
    return max(4 * HEADER_SIZE, min(want, MAX_SLAB_BYTES))


class SlabChannel:
    """Batched receive parser for one channel: ONE large ``recv_into``
    may land MANY frames in the slab; headers are parsed in place and
    payload ``(file_offset, view)`` pairs — views of the SAME slab
    memory — accumulate in ``pending`` for a vectored write-out.

    Frame boundaries land anywhere relative to reads: a read may end
    mid-header (the fragment waits for more bytes) or mid-payload (the
    prefix is committed immediately as a partial ``(offset, view)`` pair
    and the remainder continues in later reads, possibly after a slab
    reset). The zero-materialization invariant holds because payload
    bytes are consumed the moment they are parsed — the only bytes ever
    moved by :meth:`compact` are a sub-header tail (< 48 bytes), which is
    not a payload-sized copy.

    Caller contract: when ``free_space()`` hits 0 (or on any flush
    policy), write ``take_pending()`` out, then :meth:`compact` — views
    in ``pending`` reference slab memory and must land before the slab
    is reused. ``end_event`` is set when the channel's EOFR/EOFT frame
    is parsed; no stream bytes follow it (the ACK exchange gates the
    session's next file).
    """

    __slots__ = ("mem", "block_size", "filled", "parsed", "pending",
                 "pending_bytes", "hdr", "payload_left", "payload_off",
                 "end_event", "recv_calls", "bytes", "blocks",
                 "_crc_on", "_crc", "_trl_left", "_trl_buf",
                 "_addr", "verified", "crc_mismatches", "last_recv")

    def __init__(self, slab, block_size: int):
        # ``slab`` is a ringbuf.RecvSlab (or anything with a ``mem`` view)
        self.mem: memoryview = slab.mem
        # slab memory is fixed for the channel's lifetime, so the native
        # CRC can run from a base address computed once (the per-call
        # ctypes extraction otherwise costs ~3µs per parsed chunk)
        self._addr = buffer_address(self.mem) if HAVE_NATIVE_CRC else None
        self.block_size = block_size
        self.filled = 0
        self.parsed = 0
        self.pending: List[Tuple[int, memoryview]] = []
        self.pending_bytes = 0
        self.hdr: Optional[ChannelHeader] = None
        self.payload_left = 0
        self.payload_off = 0
        self.end_event: Optional[ChannelEvent] = None
        self.recv_calls = 0
        self.last_recv = 0
        self.bytes = 0  # payload bytes landed
        self.blocks = 0  # frames fully landed
        # integrity mode (FLAG_BLOCK_CRC frames): running payload CRC, the
        # 4-byte trailer assembled across reads, and the per-frame verdicts.
        # ``verified`` holds (offset, length, crc) of CRC-clean frames; the
        # flush path drains it into the manifest only AFTER the frame's
        # pending views are on disk (take_verified).
        self._crc_on = False
        self._crc = 0
        self._trl_left = 0
        self._trl_buf = bytearray(TRAILER_SIZE)
        self.verified: List[Tuple[int, int, int]] = []
        self.crc_mismatches = 0

    def free_space(self) -> int:
        return len(self.mem) - self.filled

    def receive_once(self, sock: socket.socket, max_bytes: int = None) -> int:
        """One ``recv_into`` into the slab's free tail, then parse
        everything that landed. Returns the number of frames COMPLETED by
        this read (the caller's FSM/stat hook). Raises ``ConnectionError``
        on EOF and propagates ``BlockingIOError`` untouched (nonblocking
        callers use it to yield).

        ``max_bytes`` caps the read below the slab's free space so a
        fair-share scheduler (the server event loop's DRR queue) can bound
        how much one channel drains per service turn. The raw byte count
        of the last read is exposed as ``last_recv``."""
        want = len(self.mem) - self.filled
        if max_bytes is not None and max_bytes < want:
            want = max_bytes
        r = sock.recv_into(self.mem[self.filled:self.filled + want])
        if r == 0:
            raise ConnectionError("peer closed mid-stream")
        self.recv_calls += 1
        self.filled += r
        self.last_recv = r
        return self._parse()

    def _parse(self) -> int:
        done = 0
        while self.end_event is None:
            if self.payload_left:
                avail = self.filled - self.parsed
                if not avail:
                    break
                take = min(self.payload_left, avail)
                chunk = self.mem[self.parsed:self.parsed + take]
                self.pending.append((self.payload_off, chunk))
                if self._crc_on:
                    if self._addr is not None:
                        self._crc = crc32_update_at(
                            self._crc, self._addr + self.parsed, take)
                    else:
                        self._crc = crc32_update(self._crc, chunk)
                self.pending_bytes += take
                self.parsed += take
                self.payload_off += take
                self.payload_left -= take
                self.bytes += take
                if self.payload_left:
                    break  # rest of this frame arrives in a later read
                if self._crc_on:
                    self._trl_left = TRAILER_SIZE  # trailer follows payload
                    continue
                self.hdr = None
                self.blocks += 1
                done += 1
                continue
            if self._trl_left:
                avail = self.filled - self.parsed
                if not avail:
                    break
                take = min(self._trl_left, avail)
                at = TRAILER_SIZE - self._trl_left
                self._trl_buf[at:at + take] = self.mem[
                    self.parsed:self.parsed + take]
                self.parsed += take
                self._trl_left -= take
                if self._trl_left:
                    break  # trailer split across reads
                (want,) = CRC_TRAILER.unpack(self._trl_buf)
                hdr = self.hdr
                if (self._crc & 0xFFFFFFFF) == want:
                    self.verified.append((hdr.offset, hdr.length, want))
                else:
                    # keep the stream synced; the manifest check at EOF
                    # reports the gap and RESUME re-fetches the block
                    self.crc_mismatches += 1
                self._crc_on = False
                self._crc = 0
                self.hdr = None
                self.blocks += 1
                done += 1
                continue
            if self.filled - self.parsed < HEADER_SIZE:
                break  # partial header: wait for more bytes
            hdr = ChannelHeader.unpack(
                self.mem[self.parsed:self.parsed + HEADER_SIZE])
            self.parsed += HEADER_SIZE
            if hdr.event in END_EVENTS:
                self.end_event = hdr.event
                break
            if hdr.length > self.block_size:
                raise ProtocolError(
                    f"block of {hdr.length} bytes exceeds negotiated "
                    f"block_size {self.block_size}"
                )
            self.hdr = hdr
            self.payload_left = hdr.length
            self.payload_off = hdr.offset
            self._crc_on = bool(hdr.flags & FLAG_BLOCK_CRC)
            self._crc = 0
        return done

    def take_pending(self) -> List[Tuple[int, memoryview]]:
        out = self.pending
        self.pending = []
        self.pending_bytes = 0
        return out

    def take_verified(self) -> List[Tuple[int, int, int]]:
        """CRC-clean ``(offset, length, crc)`` frames accumulated since
        the last call. Callers drain this into the manifest AFTER writing
        ``take_pending`` out — a frame's trailer always parses after its
        last payload chunk entered ``pending``, so at flush time every
        verified frame's bytes are on disk."""
        out = self.verified
        self.verified = []
        return out

    def compact(self) -> None:
        """Reclaim the parsed region. Only legal once ``pending`` has been
        taken AND written out (its views alias slab memory). The unparsed
        tail is always sub-header sized — payload bytes never sit
        unparsed — so this move is never a payload copy."""
        assert not self.pending, "flush pending views before compacting"
        tail = self.filled - self.parsed
        assert tail < HEADER_SIZE
        if tail:
            self.mem[0:tail] = self.mem[self.parsed:self.filled]
        self.filled = tail
        self.parsed = 0

    def seed(self, header_tail: bytes = b"", payload_off: int = 0,
             payload_left: int = 0) -> None:
        """Enter slab mode mid-stream (the mirror of :meth:`handoff`):
        ``header_tail`` pre-loads a sub-header fragment already read on
        another path; a nonzero ``payload_left`` resumes a frame whose
        prefix landed elsewhere (the remainder continues at file offset
        ``payload_off``). The two are mutually exclusive — a parser mid-
        payload never holds header bytes."""
        assert self.filled == 0 and self.payload_left == 0
        assert self._trl_left == 0
        assert not (header_tail and payload_left)
        if header_tail:
            self.mem[:len(header_tail)] = header_tail
            self.filled = len(header_tail)
        self.payload_off = payload_off
        self.payload_left = payload_left

    def handoff(self) -> Tuple[bytes, Optional[ChannelHeader], int, int]:
        """Leave slab mode at the current parse position (a datapath
        switch, e.g. the splice arbiter choosing splice back): returns
        ``(header_tail, in_progress_hdr, payload_off, payload_left)``.
        ``header_tail`` is the sub-header fragment already read (seed the
        per-frame header buffer with it); a non-None header means the
        current frame still owes ``payload_left`` bytes at file offset
        ``payload_off``. Pending must have been taken/flushed first."""
        assert not self.pending, "flush pending views before handoff"
        # datapath switches never happen mid-trailer: the splice arbiter
        # (the only handoff caller) is disabled on integrity sessions
        assert self._trl_left == 0
        tail = bytes(self.mem[self.parsed:self.filled])
        hdr, off, left = self.hdr, self.payload_off, self.payload_left
        self.hdr = None
        self.payload_left = 0
        self.filled = self.parsed = 0
        return tail, hdr, off, left


# ---------------------------------------------------------------------------
# sources and sinks
# ---------------------------------------------------------------------------


class Source:
    """Reads blocks from a file, an in-memory buffer, or serves zeros.

    File-backed sources are mmapped: :meth:`block_view` returns a
    ``memoryview`` straight into the map (zero heap copies on the send
    path), with ``os.pread`` as the fallback when the map cannot be built.
    :meth:`read_block` is the legacy materializing read; every fresh
    per-block heap copy it makes is counted in the class-level
    ``materializations`` so tests can assert the hot path stays at zero.
    """

    materializations = 0  # class-level: fresh per-block heap copies

    def __init__(self, path: Optional[str], size: int, block_size: int,
                 data: Optional[bytes] = None, use_mmap: bool = True):
        self.size = size
        self.block_size = block_size
        self.n_blocks = (size + block_size - 1) // block_size
        self.path = path
        self.data = data
        self.use_mmap = use_mmap
        self._fd = os.open(path, os.O_RDONLY) if path else -1
        self._mem = memoryview(data) if (path is None and data is not None) else None
        self._zeros = bytes(block_size) if (path is None and data is None) else None
        self._zeros_view = (memoryview(self._zeros)
                            if self._zeros is not None else None)
        self._map: Optional[mmap.mmap] = None
        self._map_view: Optional[memoryview] = None
        self._crc_addr = False  # lazily resolved base address (False=unset)
        if self._fd >= 0 and use_mmap and size > 0:
            try:
                self._map = mmap.mmap(self._fd, 0, access=mmap.ACCESS_READ)
                self._map_view = memoryview(self._map)
            except (OSError, ValueError):
                self._map = None  # pread fallback (pipes, odd filesystems)

    @property
    def file_backed(self) -> bool:
        return self._fd >= 0

    def fileno(self) -> int:
        return self._fd

    def open_worker(self) -> "Source":
        """A worker-private handle (MP/MT senders use one fd per worker)."""
        return Source(self.path, self.size, self.block_size, data=self.data,
                      use_mmap=self.use_mmap)

    def block_len(self, i: int) -> int:
        return min(self.block_size, self.size - i * self.block_size)

    def block_view(self, i: int) -> memoryview:
        """Zero-copy view of block ``i`` (mmap / in-memory / zeros); only
        the pread fallback materializes a fresh buffer."""
        ln = self.block_len(i)
        off = i * self.block_size
        if self._map_view is not None:
            return self._map_view[off : off + ln]
        if self._mem is not None:
            return self._mem[off : off + ln]
        if self._zeros_view is not None:
            return self._zeros_view[:ln]
        Source.materializations += 1
        return memoryview(os.pread(self._fd, ln, off))

    def _crc_base(self) -> Optional[int]:
        """Base address of the source's fixed backing memory (mmap or
        in-memory buffer), computed once — the map/buffer outlives the
        Source, so per-block CRCs can run straight from offsets."""
        if self._crc_addr is False:
            backing = (self._map_view if self._map_view is not None
                       else self._mem)
            self._crc_addr = (buffer_address(backing)
                              if HAVE_NATIVE_CRC and backing is not None
                              else None)
        return self._crc_addr

    def block_crc(self, i: int) -> int:
        """CRC32 of block ``i`` (integrity senders pack it into the frame
        trailer; the RESUME flow compares it against the peer's sidecar)."""
        addr = self._crc_base()
        if addr is not None:
            return crc32_update_at(0, addr + i * self.block_size,
                                   self.block_len(i))
        return crc32_update(0, self.block_view(i))

    def file_crc(self) -> int:
        """CRC32 of the whole source, computed as one sequential pass over
        the block views (mmap/in-memory — no per-block heap copies)."""
        addr = self._crc_base()
        if addr is not None:
            return crc32_update_at(0, addr, self.size)
        crc = 0
        for i in range(self.n_blocks):
            crc = crc32_update(crc, self.block_view(i))
        return crc

    def read_block(self, i: int) -> bytes:
        """Legacy materializing read (the copy path senders no longer use)."""
        ln = self.block_len(i)
        if self._fd >= 0:
            Source.materializations += 1
            return os.pread(self._fd, ln, i * self.block_size)
        if self._mem is not None:
            off = i * self.block_size
            return self._mem[off : off + ln]
        return self._zeros[:ln]

    def close(self):
        if self._map_view is not None:
            self._map_view.release()
            self._map_view = None
        if self._map is not None:
            try:
                self._map.close()
            except BufferError:
                pass  # exported block views still referenced; GC reaps later
            self._map = None
        if self._fd >= 0:
            os.close(self._fd)
            self._fd = -1


class Sink:
    """Writes blocks to a file (pwrite / coalesced pwritev), captures them
    into memory, or discards them. The zero-copy write-out is
    :meth:`writev_views`: trimmed views of registered pool memory go
    straight into ``os.pwritev`` — the pool slots they reference are
    released by the caller only after the write lands.

    ``durability`` is the negotiated at-rest policy. Engines call
    :meth:`commit` after their final flush and BEFORE the final ACK:
    ``fsync`` syncs the file, ``atomic`` lands every block in a private
    temp file (``<path>.xdfs-tmp.<pid>``) that commit fsyncs and
    ``os.replace``s over the final path (+ directory fsync) — an acked
    file can never be half-present after power loss, and a crash before
    commit leaves any previous complete version untouched. ``close``
    without commit unlinks an atomic sink's temp file."""

    def __init__(self, path: Optional[str], size: int, capture: bool = False,
                 durability=DURABILITY_NONE):
        self.path = path
        self.size = size
        self.capture = capture
        self.durability = durability_byte(durability)
        self.committed = False
        if path and self.durability >= DURABILITY_ATOMIC:
            self._write_path = f"{path}{TMP_INFIX}{os.getpid()}"
        else:
            self._write_path = path
        if path:
            self._fd = os.open(self._write_path,
                               os.O_WRONLY | os.O_CREAT, 0o644)
            os.ftruncate(self._fd, size)
            self._cap = None
        else:
            self._fd = -1
            self._cap = memoryview(bytearray(size)) if capture else None

    @property
    def data(self) -> bytes:
        """The captured payload (capture sinks only)."""
        if self._cap is None:
            raise ValueError("not a capture sink")
        return bytes(self._cap)

    @property
    def file_backed(self) -> bool:
        """True when write-out goes to a real fd (splice needs one)."""
        return self._fd >= 0

    def fileno(self) -> int:
        return self._fd

    def open_worker(self) -> "Sink":
        if self.capture:
            raise ValueError("capture sinks cannot be shared with forked workers")
        # workers write the PARENT's write path (the temp file in atomic
        # mode — never a per-worker temp) and carry no commit/cleanup
        # duty: the owning sink alone fsyncs/renames after every worker
        # is reaped
        return Sink(self._write_path, self.size)

    def write_at(self, offset: int, data) -> None:
        if self._fd >= 0:
            pwrite_all(self._fd, data, offset)
        elif self._cap is not None:
            self._cap[offset : offset + len(data)] = data

    def writev_views(self, blocks: List[Tuple[int, memoryview]]) -> int:
        """Vectored write-out of pre-trimmed ``(offset, view)`` pairs: sort
        by offset, group contiguous runs, one ``pwritev`` per run — the
        views (registered pool memory) go into the syscall untouched.

        Returns the number of vectored syscalls issued (the seek-reduction
        metric from the paper)."""
        if not blocks or (self._fd < 0 and self._cap is None):
            return 0
        if self._cap is not None:
            for off, mv in blocks:
                self._cap[off : off + len(mv)] = mv
            return 1
        blocks.sort(key=lambda b: b[0])
        calls = 0
        run: List[memoryview] = []
        run_start = run_end = -1
        for off, mv in blocks:
            if off == run_end and len(run) < IOV_MAX:
                run.append(mv)
                run_end += len(mv)
            else:
                if run:
                    calls += self._pwritev_all(run, run_start)
                run = [mv]
                run_start, run_end = off, off + len(mv)
        if run:
            calls += self._pwritev_all(run, run_start)
        return calls

    def _pwritev_all(self, run: List[memoryview], offset: int) -> int:
        """One run, fully written: a short ``pwritev`` (near-full disk,
        RLIMIT_FSIZE) resumes by re-slicing the iovec — a partial run must
        never silently drop its tail. Returns syscalls issued."""
        calls = 0
        while run:
            n = os.pwritev(self._fd, run, offset)
            calls += 1
            if n <= 0:
                raise OSError(errno.EIO, "pwritev: short write")
            offset += n
            advance_iovec(run, n)
        return calls

    def writev_coalesced(self, blocks: List[Tuple[int, int, bytes]]) -> int:
        """Legacy ``(offset, length, buffer)`` write-out; trims each buffer
        and delegates to :meth:`writev_views`."""
        return self.writev_views(
            [(off, memoryview(blk)[:ln]) for off, ln, blk in blocks]
        )

    def commit(self) -> None:
        """Make the received bytes durable per the sink's policy — engines
        call this after the final flush and before the final ACK, so the
        ACK is a durability promise, not just a delivery one."""
        if self._fd < 0 or self.durability == DURABILITY_NONE:
            self.committed = True
            return
        os.fsync(self._fd)
        if self.durability >= DURABILITY_ATOMIC and self._write_path != self.path:
            os.close(self._fd)
            self._fd = -1
            os.replace(self._write_path, self.path)
            fsync_dir(os.path.dirname(os.path.abspath(self.path)))
        self.committed = True

    def close(self):
        if self._fd >= 0:
            os.close(self._fd)
            self._fd = -1
        if (self.durability >= DURABILITY_ATOMIC and not self.committed
                and self._write_path != self.path):
            # aborted transfer: discard the temp file; a previous complete
            # version at the final path survives untouched
            try:
                os.unlink(self._write_path)
            except OSError:
                pass


@dataclass
class RecvStats:
    bytes: int = 0
    writev_calls: int = 0
    flushes: int = 0
    eofr_frames: int = 0  # EOFR end-frames seen (channel stays reusable)
    eoft_frames: int = 0  # EOFT end-frames seen (session terminates)
    splice_bytes: int = 0  # payload bytes that stayed kernel-side (splice)
    recv_calls: int = 0  # slab-path recv_into syscalls (0 on legacy paths)
    # times the autotuner switched a WORKING splice path off because it
    # measured slower than the pool path (mechanical fallbacks not counted)
    splice_autodisables: int = 0
    # integrity mode: data frames whose CRC32 trailer did not match the
    # payload — skipped (never written/manifested), re-fetched via RESUME
    crc_mismatches: int = 0
