"""Shared transfer-engine plumbing: wire helpers, Source/Sink, RecvStats.

Engines (engines/{mtedp,mt,mp}.py) move blocks between a ``Source`` and a
``Sink`` over framed TCP channels. Sources can be backed by a file, an
in-memory buffer (checkpoint leaves), or zeros (the paper's /dev/zero
mem-to-mem mode); sinks by a file, a capture buffer, or /dev/null-style
discard.
"""
from __future__ import annotations

import os
import socket
from dataclasses import dataclass
from typing import List, Optional, Tuple

from repro.core.header import ChannelEvent

ACK = b"\x06"
IOV_MAX = 512

# the one definition of which frame events end a channel's file stream
END_EVENTS = (ChannelEvent.EOFR, ChannelEvent.EOFT)


# ---------------------------------------------------------------------------
# wire helpers
# ---------------------------------------------------------------------------


def send_all(sock: socket.socket, data) -> None:
    view = memoryview(data)
    while view:
        n = sock.send(view)
        view = view[n:]


def recv_exact(sock: socket.socket, n: int, buf: Optional[memoryview] = None):
    out = memoryview(bytearray(n)) if buf is None else buf[:n]
    got = 0
    while got < n:
        r = sock.recv_into(out[got:], n - got)
        if r == 0:
            raise ConnectionError("peer closed")
        got += r
    return out


# ---------------------------------------------------------------------------
# sources and sinks
# ---------------------------------------------------------------------------


class Source:
    """Reads blocks from a file, an in-memory buffer, or serves zeros."""

    def __init__(self, path: Optional[str], size: int, block_size: int,
                 data: Optional[bytes] = None):
        self.size = size
        self.block_size = block_size
        self.n_blocks = (size + block_size - 1) // block_size
        self.path = path
        self.data = data
        self._fd = os.open(path, os.O_RDONLY) if path else -1
        self._mem = memoryview(data) if (path is None and data is not None) else None
        self._zeros = bytes(block_size) if (path is None and data is None) else None

    def open_worker(self) -> "Source":
        """A worker-private handle (MP/MT senders use one fd per worker)."""
        return Source(self.path, self.size, self.block_size, data=self.data)

    def block_len(self, i: int) -> int:
        return min(self.block_size, self.size - i * self.block_size)

    def read_block(self, i: int) -> bytes:
        ln = self.block_len(i)
        if self._fd >= 0:
            return os.pread(self._fd, ln, i * self.block_size)
        if self._mem is not None:
            off = i * self.block_size
            return self._mem[off : off + ln]
        return self._zeros[:ln]

    def close(self):
        if self._fd >= 0:
            os.close(self._fd)


class Sink:
    """Writes blocks to a file (pwrite / coalesced pwritev), captures them
    into memory, or discards them."""

    def __init__(self, path: Optional[str], size: int, capture: bool = False):
        self.path = path
        self.size = size
        self.capture = capture
        if path:
            self._fd = os.open(path, os.O_WRONLY | os.O_CREAT, 0o644)
            os.ftruncate(self._fd, size)
            self._cap = None
        else:
            self._fd = -1
            self._cap = memoryview(bytearray(size)) if capture else None

    @property
    def data(self) -> bytes:
        """The captured payload (capture sinks only)."""
        if self._cap is None:
            raise ValueError("not a capture sink")
        return bytes(self._cap)

    def open_worker(self) -> "Sink":
        if self.capture:
            raise ValueError("capture sinks cannot be shared with forked workers")
        return Sink(self.path, self.size)

    def write_at(self, offset: int, data) -> None:
        if self._fd >= 0:
            os.pwrite(self._fd, data, offset)
        elif self._cap is not None:
            self._cap[offset : offset + len(data)] = data

    def writev_coalesced(self, blocks: List[Tuple[int, int, bytearray]]) -> int:
        """Sort by offset, group contiguous runs, one pwritev per run.

        Returns the number of vectored syscalls issued (the seek-reduction
        metric from the paper)."""
        if not blocks or (self._fd < 0 and self._cap is None):
            return 0
        if self._cap is not None:
            for off, ln, blk in blocks:
                self._cap[off : off + ln] = memoryview(blk)[:ln]
            return 1
        blocks.sort(key=lambda b: b[0])
        calls = 0
        run: List[memoryview] = []
        run_start = run_end = -1
        for off, ln, blk in blocks:
            if off == run_end and len(run) < IOV_MAX:
                run.append(memoryview(blk)[:ln])
                run_end += ln
            else:
                if run:
                    os.pwritev(self._fd, run, run_start)
                    calls += 1
                run = [memoryview(blk)[:ln]]
                run_start, run_end = off, off + ln
        if run:
            os.pwritev(self._fd, run, run_start)
            calls += 1
        return calls

    def close(self):
        if self._fd >= 0:
            os.close(self._fd)


@dataclass
class RecvStats:
    bytes: int = 0
    writev_calls: int = 0
    flushes: int = 0
    eofr_frames: int = 0  # EOFR end-frames seen (channel stays reusable)
    eoft_frames: int = 0  # EOFT end-frames seen (session terminates)
