"""Shared transfer-engine plumbing: wire helpers, Source/Sink, RecvStats.

Engines (engines/{mtedp,mt,mp}.py) move blocks between a ``Source`` and a
``Sink`` over framed TCP channels. Sources can be backed by a file, an
in-memory buffer (checkpoint leaves), or zeros (the paper's /dev/zero
mem-to-mem mode); sinks by a file, a capture buffer, or /dev/null-style
discard.

Both halves of the datapath are zero-copy:

* **send** — file-backed sources are mmapped and ``block_view(i)`` hands
  out views into the map, ``FrameBuilder`` packs headers into per-channel
  reusable buffers, and senders hand both straight to ``socket.sendmsg``
  (scatter-gather) or ``os.sendfile`` — no per-block heap copy between
  the page cache and the socket.
* **receive** — frames land directly in a registered
  ``RecvBufferPool`` (core/ringbuf.py): receivers pass pool slot views to
  ``socket.recv_into``, parse headers in place from reusable buffers, and
  the drain side hands trimmed views of the SAME pool memory to
  ``Sink.writev_views`` (coalesced ``os.pwritev``). Slot lifecycle:
  ``acquire -> recv_into -> commit -> pwritev -> release``. On Linux the
  blocking receivers can additionally opt into :class:`SpliceReceiver`
  (socket -> pipe -> file ``os.splice``), which keeps the payload
  kernel-side entirely; a :class:`SpliceUnsupported` first-call failure
  falls back to the pool path, mirroring the ``sendfile`` pattern.
"""
from __future__ import annotations

import errno
import mmap
import os
import socket
from dataclasses import dataclass
from typing import List, Optional, Tuple

from repro.core.header import HEADER_SIZE, ChannelEvent, pack_header_into

ACK = b"\x06"
IOV_MAX = 512
SENDFILE = hasattr(os, "sendfile")

# the one definition of which frame events end a channel's file stream
END_EVENTS = (ChannelEvent.EOFR, ChannelEvent.EOFT)


# ---------------------------------------------------------------------------
# wire helpers
# ---------------------------------------------------------------------------


MSG_MORE = getattr(socket, "MSG_MORE", 0)  # Linux: coalesce with next send


def send_all(sock: socket.socket, data, flags: int = 0) -> None:
    view = memoryview(data)
    while view:
        n = sock.send(view, flags)
        view = view[n:]


def recv_exact(sock: socket.socket, n: int, buf: Optional[memoryview] = None):
    out = memoryview(bytearray(n)) if buf is None else buf[:n]
    got = 0
    while got < n:
        r = sock.recv_into(out[got:], n - got)
        if r == 0:
            raise ConnectionError("peer closed")
        got += r
    return out


def pwrite_all(fd: int, data, offset: int) -> None:
    """``os.pwrite`` until every byte of ``data`` lands (short writes —
    near-full disk, quotas — must surface as progress or an error, never
    as a silent hole in the file)."""
    view = memoryview(data)
    while view:
        n = os.pwrite(fd, view, offset)
        if n <= 0:
            raise OSError(errno.EIO, "pwrite: short write")
        offset += n
        view = view[n:]


def advance_iovec(iov: List[memoryview], n: int) -> List[memoryview]:
    """Account ``n`` sent bytes against the head of an iovec IN PLACE —
    partial ``sendmsg`` resumes by re-slicing the vector instead of
    rebuilding the frame."""
    while n:
        head = iov[0]
        if n < len(head):
            iov[0] = head[n:]
            break
        n -= len(head)
        iov.pop(0)
    return iov


def sendmsg_all(sock: socket.socket, views) -> int:
    """Scatter-gather send of [header_view, payload_view, ...] on a blocking
    socket; partial sends re-slice the iovec until everything is on the
    wire. Returns total bytes sent."""
    iov = [v if isinstance(v, memoryview) else memoryview(v) for v in views]
    iov = [v for v in iov if len(v)]
    total = 0
    while iov:
        n = sock.sendmsg(iov)
        total += n
        advance_iovec(iov, n)
    return total


class SendfileUnsupported(OSError):
    """First ``sendfile`` call failed before any byte hit the wire — the
    fd/socket combination doesn't support it; caller falls back."""


_KERNEL_COPY_FALLBACK_ERRNOS = frozenset(
    getattr(errno, name) for name in
    ("EINVAL", "ENOSYS", "EOPNOTSUPP", "ENOTSOCK", "ENOTSUP")
    if hasattr(errno, name)
)


def sendfile_all(sock: socket.socket, fd: int, offset: int, count: int) -> int:
    """Kernel-side copy of ``count`` bytes of ``fd`` at ``offset`` into the
    socket (the uncompressed file-backed fast path). Raises
    :class:`SendfileUnsupported` only if the FIRST call fails with an
    unsupported-operation errno (nothing on the wire yet, safe to fall
    back); a mid-stream error is a real transport failure and re-raises."""
    sent = 0
    while sent < count:
        try:
            n = os.sendfile(sock.fileno(), fd, offset + sent, count - sent)
        except OSError as e:
            if sent == 0 and e.errno in _KERNEL_COPY_FALLBACK_ERRNOS:
                raise SendfileUnsupported(e.errno, "sendfile unsupported") from e
            raise
        if n == 0:
            raise ConnectionError("sendfile: peer closed")
        sent += n
    return sent


SPLICE = hasattr(os, "splice")


class SpliceUnsupported(OSError):
    """First ``splice`` call failed before any byte left the socket — the
    socket/pipe/file combination doesn't support it; caller falls back to
    the registered-buffer pool path."""


class SpliceReceiver:
    """Kernel-side socket->file block receive: ``os.splice`` through a
    private pipe (sockets cannot splice straight into a file offset), the
    receive-side mirror of the ``sendfile`` fast path. The payload never
    surfaces to user space.

    One instance per receiving worker; :meth:`splice_block` moves exactly
    one frame's payload from a BLOCKING socket into ``fd`` at ``offset``.
    Fallback contract, mirroring :func:`sendfile_all`:

    * if the FIRST socket->pipe splice of a block fails with an
      unsupported-operation errno, nothing was consumed from the socket —
      :class:`SpliceUnsupported` is raised and the caller receives the
      whole block on the generic pool path;
    * if splice dies mid-block (bytes already off the socket), the block
      is COMPLETED with a recovery copy (charged to
      ``RecvBufferPool.materializations``) and ``self.ok`` drops to False
      so the caller switches paths from the next frame — data is never
      lost to a late fallback;
    * any other mid-stream error is a real transport failure and re-raises.
    """

    PIPE_CHUNK = 1 << 16  # default Linux pipe capacity

    def __init__(self):
        if not SPLICE:
            raise SpliceUnsupported(0, "os.splice unavailable")
        self._r, self._w = os.pipe()
        self._scratch: Optional[memoryview] = None
        self.ok = True  # drops to False after a mid-block recovery

    def close(self) -> None:
        for fd in (self._r, self._w):
            try:
                os.close(fd)
            except OSError:
                pass

    def splice_block(self, sock: socket.socket, fd: int, offset: int,
                     count: int) -> int:
        """Move ``count`` payload bytes socket->pipe->file. Returns the
        number of bytes that stayed kernel-side (== ``count`` unless a
        mid-block recovery copied part of the chunk)."""
        moved = spliced = 0
        while moved < count:
            want = min(self.PIPE_CHUNK, count - moved)
            try:
                n_in = os.splice(sock.fileno(), self._w, want)
            except OSError as e:
                if e.errno not in _KERNEL_COPY_FALLBACK_ERRNOS:
                    raise
                if moved == 0:
                    raise SpliceUnsupported(
                        e.errno, "splice unsupported") from e
                self.ok = False  # finish the block in user space
                self._copy_from_socket(sock, fd, offset + moved,
                                       count - moved)
                return spliced
            if n_in == 0:
                raise ConnectionError("splice: peer closed mid-block")
            # _pipe_to_file recovers its own mid-drain fallback (dropping
            # self.ok); the whole chunk is on disk either way
            spliced += self._pipe_to_file(fd, offset + moved, n_in)
            moved += n_in
            if not self.ok:
                # finish the rest of the block from the socket, then the
                # caller drops to the pool path for later frames
                self._copy_from_socket(sock, fd, offset + moved,
                                       count - moved)
                return spliced
        return spliced

    def _pipe_to_file(self, fd: int, offset: int, n_in: int) -> int:
        """Drain ``n_in`` pipe bytes into ``fd`` at ``offset``. Returns how
        many moved kernel-side; an unsupported-errno failure mid-drain
        recovers ONLY the still-undrained remainder (at its correct
        offset) with a counted copy and drops ``self.ok``."""
        drained = 0
        while drained < n_in:
            try:
                n_out = os.splice(self._r, fd, n_in - drained,
                                  offset_dst=offset + drained)
            except OSError as e:
                if e.errno not in _KERNEL_COPY_FALLBACK_ERRNOS:
                    raise
                self.ok = False
                self._copy_from_pipe(fd, offset + drained, n_in - drained)
                return drained
            if n_out == 0:
                raise OSError(errno.EIO, "splice: pipe->file stalled")
            drained += n_out
        return drained

    def _scratch_view(self) -> memoryview:
        if self._scratch is None:
            self._scratch = memoryview(bytearray(self.PIPE_CHUNK))
        return self._scratch

    def _copy_from_pipe(self, fd: int, offset: int, n: int) -> None:
        from repro.core.ringbuf import RecvBufferPool

        RecvBufferPool.materializations += 1
        scratch = self._scratch_view()
        done = 0
        while done < n:
            got = os.readv(self._r, [scratch[: n - done]])
            if got == 0:
                raise OSError(errno.EIO, "splice recovery: pipe drained early")
            pwrite_all(fd, scratch[:got], offset + done)
            done += got

    def _copy_from_socket(self, sock: socket.socket, fd: int, offset: int,
                          n: int) -> None:
        if n <= 0:
            return
        from repro.core.ringbuf import RecvBufferPool

        RecvBufferPool.materializations += 1
        scratch = self._scratch_view()
        done = 0
        while done < n:
            got = sock.recv_into(scratch[: min(len(scratch), n - done)])
            if got == 0:
                raise ConnectionError("peer closed mid-block")
            pwrite_all(fd, scratch[:got], offset + done)
            done += got


class FrameBuilder:
    """Packs channel headers into per-channel REUSABLE buffers.

    Safe because a channel has at most one frame in flight: the next header
    is only packed after the previous frame fully drained. Eliminates the
    two per-block allocations of the legacy ``hdr.pack() + payload`` path
    (header bytes + concatenated frame)."""

    __slots__ = ("session", "_bufs", "_views")

    def __init__(self, session: bytes, n_channels: int):
        self.session = session
        self._bufs = [bytearray(HEADER_SIZE) for _ in range(n_channels)]
        self._views = [memoryview(b) for b in self._bufs]

    def header(self, channel: int, event: ChannelEvent, offset: int,
               length: int, flags: int = 0) -> memoryview:
        pack_header_into(self._bufs[channel], event, self.session, channel,
                         offset, length, flags)
        return self._views[channel]


# ---------------------------------------------------------------------------
# sources and sinks
# ---------------------------------------------------------------------------


class Source:
    """Reads blocks from a file, an in-memory buffer, or serves zeros.

    File-backed sources are mmapped: :meth:`block_view` returns a
    ``memoryview`` straight into the map (zero heap copies on the send
    path), with ``os.pread`` as the fallback when the map cannot be built.
    :meth:`read_block` is the legacy materializing read; every fresh
    per-block heap copy it makes is counted in the class-level
    ``materializations`` so tests can assert the hot path stays at zero.
    """

    materializations = 0  # class-level: fresh per-block heap copies

    def __init__(self, path: Optional[str], size: int, block_size: int,
                 data: Optional[bytes] = None, use_mmap: bool = True):
        self.size = size
        self.block_size = block_size
        self.n_blocks = (size + block_size - 1) // block_size
        self.path = path
        self.data = data
        self.use_mmap = use_mmap
        self._fd = os.open(path, os.O_RDONLY) if path else -1
        self._mem = memoryview(data) if (path is None and data is not None) else None
        self._zeros = bytes(block_size) if (path is None and data is None) else None
        self._zeros_view = (memoryview(self._zeros)
                            if self._zeros is not None else None)
        self._map: Optional[mmap.mmap] = None
        self._map_view: Optional[memoryview] = None
        if self._fd >= 0 and use_mmap and size > 0:
            try:
                self._map = mmap.mmap(self._fd, 0, access=mmap.ACCESS_READ)
                self._map_view = memoryview(self._map)
            except (OSError, ValueError):
                self._map = None  # pread fallback (pipes, odd filesystems)

    @property
    def file_backed(self) -> bool:
        return self._fd >= 0

    def fileno(self) -> int:
        return self._fd

    def open_worker(self) -> "Source":
        """A worker-private handle (MP/MT senders use one fd per worker)."""
        return Source(self.path, self.size, self.block_size, data=self.data,
                      use_mmap=self.use_mmap)

    def block_len(self, i: int) -> int:
        return min(self.block_size, self.size - i * self.block_size)

    def block_view(self, i: int) -> memoryview:
        """Zero-copy view of block ``i`` (mmap / in-memory / zeros); only
        the pread fallback materializes a fresh buffer."""
        ln = self.block_len(i)
        off = i * self.block_size
        if self._map_view is not None:
            return self._map_view[off : off + ln]
        if self._mem is not None:
            return self._mem[off : off + ln]
        if self._zeros_view is not None:
            return self._zeros_view[:ln]
        Source.materializations += 1
        return memoryview(os.pread(self._fd, ln, off))

    def read_block(self, i: int) -> bytes:
        """Legacy materializing read (the copy path senders no longer use)."""
        ln = self.block_len(i)
        if self._fd >= 0:
            Source.materializations += 1
            return os.pread(self._fd, ln, i * self.block_size)
        if self._mem is not None:
            off = i * self.block_size
            return self._mem[off : off + ln]
        return self._zeros[:ln]

    def close(self):
        if self._map_view is not None:
            self._map_view.release()
            self._map_view = None
        if self._map is not None:
            try:
                self._map.close()
            except BufferError:
                pass  # exported block views still referenced; GC reaps later
            self._map = None
        if self._fd >= 0:
            os.close(self._fd)
            self._fd = -1


class Sink:
    """Writes blocks to a file (pwrite / coalesced pwritev), captures them
    into memory, or discards them. The zero-copy write-out is
    :meth:`writev_views`: trimmed views of registered pool memory go
    straight into ``os.pwritev`` — the pool slots they reference are
    released by the caller only after the write lands."""

    def __init__(self, path: Optional[str], size: int, capture: bool = False):
        self.path = path
        self.size = size
        self.capture = capture
        if path:
            self._fd = os.open(path, os.O_WRONLY | os.O_CREAT, 0o644)
            os.ftruncate(self._fd, size)
            self._cap = None
        else:
            self._fd = -1
            self._cap = memoryview(bytearray(size)) if capture else None

    @property
    def data(self) -> bytes:
        """The captured payload (capture sinks only)."""
        if self._cap is None:
            raise ValueError("not a capture sink")
        return bytes(self._cap)

    @property
    def file_backed(self) -> bool:
        """True when write-out goes to a real fd (splice needs one)."""
        return self._fd >= 0

    def fileno(self) -> int:
        return self._fd

    def open_worker(self) -> "Sink":
        if self.capture:
            raise ValueError("capture sinks cannot be shared with forked workers")
        return Sink(self.path, self.size)

    def write_at(self, offset: int, data) -> None:
        if self._fd >= 0:
            pwrite_all(self._fd, data, offset)
        elif self._cap is not None:
            self._cap[offset : offset + len(data)] = data

    def writev_views(self, blocks: List[Tuple[int, memoryview]]) -> int:
        """Vectored write-out of pre-trimmed ``(offset, view)`` pairs: sort
        by offset, group contiguous runs, one ``pwritev`` per run — the
        views (registered pool memory) go into the syscall untouched.

        Returns the number of vectored syscalls issued (the seek-reduction
        metric from the paper)."""
        if not blocks or (self._fd < 0 and self._cap is None):
            return 0
        if self._cap is not None:
            for off, mv in blocks:
                self._cap[off : off + len(mv)] = mv
            return 1
        blocks.sort(key=lambda b: b[0])
        calls = 0
        run: List[memoryview] = []
        run_start = run_end = -1
        for off, mv in blocks:
            if off == run_end and len(run) < IOV_MAX:
                run.append(mv)
                run_end += len(mv)
            else:
                if run:
                    calls += self._pwritev_all(run, run_start)
                run = [mv]
                run_start, run_end = off, off + len(mv)
        if run:
            calls += self._pwritev_all(run, run_start)
        return calls

    def _pwritev_all(self, run: List[memoryview], offset: int) -> int:
        """One run, fully written: a short ``pwritev`` (near-full disk,
        RLIMIT_FSIZE) resumes by re-slicing the iovec — a partial run must
        never silently drop its tail. Returns syscalls issued."""
        calls = 0
        while run:
            n = os.pwritev(self._fd, run, offset)
            calls += 1
            if n <= 0:
                raise OSError(errno.EIO, "pwritev: short write")
            offset += n
            advance_iovec(run, n)
        return calls

    def writev_coalesced(self, blocks: List[Tuple[int, int, bytes]]) -> int:
        """Legacy ``(offset, length, buffer)`` write-out; trims each buffer
        and delegates to :meth:`writev_views`."""
        return self.writev_views(
            [(off, memoryview(blk)[:ln]) for off, ln, blk in blocks]
        )

    def close(self):
        if self._fd >= 0:
            os.close(self._fd)


@dataclass
class RecvStats:
    bytes: int = 0
    writev_calls: int = 0
    flushes: int = 0
    eofr_frames: int = 0  # EOFR end-frames seen (channel stays reusable)
    eoft_frames: int = 0  # EOFT end-frames seen (session terminates)
    splice_bytes: int = 0  # payload bytes that stayed kernel-side (splice)
