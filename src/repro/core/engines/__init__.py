"""xDFS transfer engines behind a pluggable registry.

The three server architectures from the paper register themselves on
import; ``get_engine(name)`` is the single dispatch point used by the
session layer, ``run_transfer``, and the benchmarks. Third-party engines
register with::

    from repro.core.engines import Engine, register_engine
    register_engine(Engine("myengine", my_receive, my_send, "..."))
"""
from repro.core.engines.base import (  # noqa: F401
    ACK,
    IOV_MAX,
    SENDFILE,
    SPLICE,
    FrameBuilder,
    RecvStats,
    SendfileUnsupported,
    SendStats,
    Sink,
    SlabChannel,
    Source,
    SpliceReceiver,
    SpliceUnsupported,
    advance_iovec,
    recv_exact,
    send_all,
    sendfile_all,
    sendmsg_all,
    sendmsg_batched,
    slab_span,
)
from repro.core.engines.registry import (  # noqa: F401
    Engine,
    UnknownEngineError,
    available_engines,
    get_engine,
    register_engine,
)

# importing the engine modules populates the registry
from repro.core.engines import mtedp, mt, mp  # noqa: F401, E402
from repro.core.engines.mtedp import event_send, mtedp_receive  # noqa: F401
from repro.core.engines.mt import mt_receive, worker_send  # noqa: F401
from repro.core.engines.mp import mp_receive  # noqa: F401

__all__ = [
    "ACK", "IOV_MAX", "SENDFILE", "SPLICE", "FrameBuilder", "RecvStats",
    "SendfileUnsupported", "SendStats", "Sink", "SlabChannel", "Source",
    "SpliceReceiver", "SpliceUnsupported", "advance_iovec", "recv_exact",
    "send_all", "sendfile_all", "sendmsg_all", "sendmsg_batched",
    "slab_span",
    "Engine", "UnknownEngineError", "available_engines", "get_engine",
    "register_engine", "mtedp_receive", "event_send", "mt_receive",
    "worker_send", "mp_receive",
]
