"""MTEDP — multi-threaded event-driven pipelined engine (paper §2.5.3).

Concurrency model: ONE thread multiplexes all n channels via PIOD
(selectors) — no locks anywhere on the datapath, because nothing is
shared between threads. The sender is the mirror image: one thread,
write-readiness multiplexing, scatter-gather ``sendmsg`` frames.

Pool-slot lifecycle (receive): each channel's state machine ``acquire``s
a slot from the registered ``RecvBufferPool`` when a data header arrives,
``recv_into``s the slot view across however many readiness callbacks the
payload needs, ``commit``s the filled slot, and the flush step hands the
committed views to one coalesced ``os.pwritev`` (single file handle,
single writer, minimal seeks) before ``release``-ing them. Pool
exhaustion back-pressures the event loop by flushing inline; headers are
parsed in place from per-channel reusable buffers. No payload byte is
copied in user space between the socket and the disk.
"""
from __future__ import annotations

import selectors
import socket
from typing import Dict, List, Optional

from repro.core.engines.base import (
    ACK,
    END_EVENTS,
    FrameBuilder,
    RecvStats,
    Sink,
    Source,
    advance_iovec,
    recv_exact,
    send_all,
)
from repro.core.engines.registry import Engine, register_engine
from repro.core.fsm import FSM_BUILDERS, Machine
from repro.core.header import (
    HEADER_SIZE,
    ChannelEvent,
    ChannelHeader,
    ProtocolError,
)
from repro.core.piod import PIOD


def mtedp_receive(
    socks: List[socket.socket],
    sink: Sink,
    block_size: int,
    pool_slots: int = 32,
    conformance: bool = True,
    fsm: Optional[Machine] = None,
    reusable: bool = False,
    pool=None,
) -> RecvStats:
    """The xDFS MTEDP receiver: PIOD event loop + registered
    ``RecvBufferPool`` + vectored I/O.

    ``fsm`` — a persistent ``server_upload`` conformance machine owned by the
    session layer (multi-file sessions thread ONE machine through every file).
    When ``None`` and ``conformance`` is set, a fresh machine is built and
    fast-forwarded through the connection stages (one-shot mode).
    ``reusable`` — file streams end with EOFR (channels stay open; the FSM
    loops back to ``9_open_file``) instead of EOFT (terminal flush).
    ``pool`` — a caller-owned ``RecvBufferPool`` reused across the files of a
    session (every slot is released by the final flush, so reuse is safe);
    when ``None`` a file-private pool is allocated.
    """
    from repro.core.ringbuf import RecvBufferPool

    stats = RecvStats()
    n = len(socks)
    if pool is None or pool.block_size != block_size:
        pool = RecvBufferPool(pool_slots, block_size)
    if pool.slots <= n:
        # with <= n slots every slot can be held by a partially-filled
        # block (one per channel) and the backpressure flush below would
        # spin forever draining zero committed blocks
        raise ValueError(
            f"pool_slots ({pool.slots}) must exceed n_channels ({n}): "
            "an all-uncommitted pool cannot make progress"
        )
    piod = PIOD()
    eof = [False] * n
    own_fsm = False
    if fsm is None and conformance:
        fsm = FSM_BUILDERS["server_upload"]()
        own_fsm = True
        # connection/negotiation stages already completed by the session layer
        for ev in ("conn", "auth_ok", "ftsm", "params_ok", "new_session",
                   "registered", "all_channels", "opened"):
            fsm.step(ev)

    class Chan:
        __slots__ = ("sock", "idx", "hdr_buf", "hdr_got", "hdr", "slot",
                     "view", "got")

        def __init__(self, sock, idx):
            self.sock = sock
            self.idx = idx
            self.hdr_buf = memoryview(bytearray(HEADER_SIZE))
            self.hdr_got = 0
            self.hdr = None
            self.slot = None  # claimed pool slot handle
            self.view = None  # its registered buffer view
            self.got = 0

    def fsm_steps(*events):
        if fsm is not None:
            for e in events:
                fsm.step(e)

    def flush(final=False):
        blocks = pool.drain()
        if blocks or final:
            stats.writev_calls += sink.writev_views(
                [(off, pool.view(slot)[:ln]) for off, ln, slot in blocks]
            )
            stats.flushes += 1
            for _, _, slot in blocks:
                pool.release(slot)
        if fsm is None:
            return
        if final:
            # conformance: must be in 13_flush; EOFR keeps the session alive
            fsm.step("eofr_flush" if reusable else "final_flush")
        elif fsm.state == "10_dispatch":
            fsm_steps("flush", "flushed")
        # (a drain tick after all_eof, state 13, needs no transition)

    def on_readable(sock, mask):
        """Greedy drain: keep consuming until the socket would block —
        one selector wakeup then services many blocks (minimizes dispatch
        overhead, the §2.3 context-switch factor applied to the event loop).
        """
        c = chans[sock]
        try:
            while True:
                if c.hdr is None:
                    r = sock.recv_into(
                        c.hdr_buf[c.hdr_got:], HEADER_SIZE - c.hdr_got
                    )
                    if r == 0:
                        raise ConnectionError("peer closed mid-header")
                    c.hdr_got += r
                    if c.hdr_got < HEADER_SIZE:
                        continue
                    c.hdr = ChannelHeader.unpack(c.hdr_buf)
                    c.hdr_got = 0
                    if c.hdr.event in END_EVENTS:
                        # milestone: 10 -> 11 -> 14 -> (10 | 13)
                        if c.hdr.event == ChannelEvent.EOFR:
                            stats.eofr_frames += 1
                        else:
                            stats.eoft_frames += 1
                        eof[c.idx] = True
                        piod.unregister(sock)
                        c.hdr = None
                        fsm_steps("read_ready", "eof_header",
                                  "all_eof" if all(eof) else "channels_open")
                        return
                    if c.hdr.length > block_size:
                        raise ProtocolError(
                            f"block of {c.hdr.length} bytes exceeds "
                            f"negotiated block_size {block_size}"
                        )
                    c.slot = pool.acquire()
                    while c.slot is None:  # backpressure: drain to disk
                        if pool.n_committed == 0:
                            # every slot is held by a partially-filled block
                            # of some channel: flushing drains nothing and
                            # the loop would livelock (guarded against by
                            # the pool_slots > n_channels check above)
                            raise RuntimeError(
                                "receiver livelock: all pool slots held by "
                                "uncommitted blocks; raise pool_slots above "
                                "the channel count"
                            )
                        flush()
                        c.slot = pool.acquire()
                    c.view = pool.view(c.slot)
                    c.got = 0
                    continue
                # payload lands straight in the registered slot view
                want = c.hdr.length - c.got
                r = sock.recv_into(c.view[c.got : c.hdr.length], want)
                if r == 0:
                    raise ConnectionError("peer closed mid-block")
                c.got += r
                stats.bytes += r
                if c.got == c.hdr.length:
                    pool.commit(c.slot, c.hdr.offset, c.hdr.length)
                    # milestone: full block moved through 10 -> 11 -> 12 -> 10
                    fsm_steps("read_ready", "block", "buffered")
                    c.hdr = None
                    c.slot = None
                    c.view = None
                    if pool.n_free == 0:
                        flush()
        except BlockingIOError:
            return

    chans: Dict[socket.socket, Chan] = {}
    for i, s in enumerate(socks):
        chans[s] = Chan(s, i)
        piod.register(s, selectors.EVENT_READ, on_readable)

    def drained_if_idle():
        if pool.n_committed >= pool_slots // 2:
            flush()

    piod.idle_callback = drained_if_idle
    piod.run(until=lambda: all(eof))
    flush(final=True)
    piod.close()
    if own_fsm:
        if reusable:
            assert fsm.state == "9_open_file", (
                f"conformance: receiver FSM ended in {fsm.state}"
            )
        else:
            assert fsm.done, f"conformance: receiver FSM ended in {fsm.state}"
    for s in socks:
        s.setblocking(True)
        send_all(s, ACK)
    return stats


def event_send(
    socks: List[socket.socket],
    source: Source,
    session: bytes,
    mode_event: ChannelEvent = ChannelEvent.xFTSMU,
    reusable: bool = False,
) -> int:
    """xDFS event-driven sender: one thread, write-readiness multiplexing.

    Zero-copy: frames are scatter-gather iovecs ``[header_view,
    block_view]`` — the header lives in a per-channel reusable buffer
    (:class:`FrameBuilder`), the payload is a view into the source mmap —
    and partial ``sendmsg`` resumes by re-slicing the iovec
    (:func:`advance_iovec`) instead of rebuilding the frame.
    """
    n = len(socks)
    piod = PIOD()
    frames = FrameBuilder(session, n)
    next_block = [c for c in range(n)]  # block index each channel sends next
    pending: Dict[socket.socket, List[memoryview]] = {}  # in-flight iovecs
    done = [False] * n
    sent = 0
    end_event = ChannelEvent.EOFR if reusable else ChannelEvent.EOFT

    def make_frame(i_chan: int, i_block: int) -> List[memoryview]:
        if i_block >= source.n_blocks:
            return [frames.header(i_chan, end_event, 0, 0)]
        ln = source.block_len(i_block)
        return [
            frames.header(i_chan, mode_event, i_block * source.block_size, ln),
            source.block_view(i_block),
        ]

    idx = {s: i for i, s in enumerate(socks)}

    def on_writable(sock, mask):
        nonlocal sent
        i = idx[sock]
        try:
            while True:  # greedy: fill the socket until it would block
                iov = pending.get(sock)
                if iov is None:
                    blk = next_block[i]
                    next_block[i] += n
                    iov = make_frame(i, blk)
                    pending[sock] = iov
                    if blk >= source.n_blocks:
                        done[i] = True
                w = sock.sendmsg(iov)
                sent += w
                if advance_iovec(iov, w):
                    continue  # partial frame still pending on this channel
                pending.pop(sock)
                if done[i]:
                    piod.unregister(sock)
                    return
        except BlockingIOError:
            return

    for s in socks:
        piod.register(s, selectors.EVENT_WRITE, on_writable)
    piod.run(until=lambda: all(done) and not pending)
    piod.close()
    for s in socks:
        s.setblocking(True)
        recv_exact(s, 1)  # final ack (exception-header channel)
    return sent


def _receive(socks, sink, block_size, *, pool_slots=32, fsm=None,
             conformance=True, reusable=False, pool=None, splice=False):
    # ``splice`` is accepted for signature uniformity but ignored: the
    # blocking socket->pipe splice would stall the nonblocking event loop
    # (the same reason the mtedp sender has no sendfile path).
    return mtedp_receive(socks, sink, block_size, pool_slots,
                         conformance=conformance, fsm=fsm, reusable=reusable,
                         pool=pool)


def _send(socks, source, session, *, reusable=False):
    return event_send(socks, source, session, reusable=reusable)


ENGINE = register_engine(Engine(
    "mtedp", _receive, _send,
    "multi-threaded event-driven pipelined (the paper's xDFS design): one "
    "event loop, registered zero-copy recv pool, single-writer vectored "
    "disk I/O",
    uses_pool=True,
    pool_livelock_guard=True,
))
