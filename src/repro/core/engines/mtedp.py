"""MTEDP — multi-threaded event-driven pipelined engine (paper §2.5.3).

Concurrency model: ONE thread multiplexes all n channels via PIOD
(selectors) — no locks anywhere on the datapath, because nothing is
shared between threads. The sender is the mirror image: one thread,
write-readiness multiplexing, scatter-gather ``sendmsg`` frames.

Pool-slot lifecycle (receive): each channel's state machine ``acquire``s
a slot from the registered ``RecvBufferPool`` when a data header arrives,
``recv_into``s the slot view across however many readiness callbacks the
payload needs, ``commit``s the filled slot, and the flush step hands the
committed views to one coalesced ``os.pwritev`` (single file handle,
single writer, minimal seeks) before ``release``-ing them. Pool
exhaustion back-pressures the event loop by flushing inline; headers are
parsed in place from per-channel reusable buffers. No payload byte is
copied in user space between the socket and the disk.

Batched mode (``batch_frames > 1``): each channel owns a registered
``RecvSlab`` instead of sharing the pool — one ``recv_into`` spans MANY
frames, ``SlabChannel`` parses headers in place and commits payload
views of the slab, and the flush step ``pwritev``s those views before
the slab compacts (backpressure = flush when the slab fills). The
sender's mirror: up to ``batch_frames`` frames per pending iovec, depth
hill-climbed per channel by ``autotune.ChannelTuner``.
"""
from __future__ import annotations

import selectors
import socket
from typing import Dict, List, Optional

from repro.core.autotune import ChannelTuner
from repro.core.engines.base import (
    ACK,
    END_EVENTS,
    FrameBuilder,
    RecvStats,
    Sink,
    SlabChannel,
    Source,
    advance_iovec,
    recv_exact,
    send_all,
    slab_span,
)
from repro.core.engines.registry import Engine, register_engine
from repro.core.fsm import FSM_BUILDERS, Machine
from repro.core.integrity import block_crc
from repro.core.header import (
    CRC_TRAILER,
    FLAG_BLOCK_CRC,
    HEADER_SIZE,
    TRAILER_SIZE,
    ChannelEvent,
    ChannelHeader,
    ProtocolError,
)
from repro.core.piod import PIOD


def _session_fsm():
    """A fresh ``server_upload`` machine fast-forwarded through the
    connection stages (one-shot mode)."""
    fsm = FSM_BUILDERS["server_upload"]()
    for ev in ("conn", "auth_ok", "ftsm", "params_ok", "new_session",
               "registered", "all_channels", "opened"):
        fsm.step(ev)
    return fsm


def mtedp_receive(
    socks: List[socket.socket],
    sink: Sink,
    block_size: int,
    pool_slots: int = 32,
    conformance: bool = True,
    fsm: Optional[Machine] = None,
    reusable: bool = False,
    pool=None,
    batch_frames: int = 1,
    slabs=None,
    crc_acc=None,
    io_timeout: Optional[float] = None,
) -> RecvStats:
    """The xDFS MTEDP receiver: PIOD event loop + registered
    ``RecvBufferPool`` + vectored I/O.

    ``fsm`` — a persistent ``server_upload`` conformance machine owned by the
    session layer (multi-file sessions thread ONE machine through every file).
    When ``None`` and ``conformance`` is set, a fresh machine is built and
    fast-forwarded through the connection stages (one-shot mode).
    ``reusable`` — file streams end with EOFR (channels stay open; the FSM
    loops back to ``9_open_file``) instead of EOFT (terminal flush).
    ``pool`` — a caller-owned ``RecvBufferPool`` reused across the files of a
    session (every slot is released by the final flush, so reuse is safe);
    when ``None`` a file-private pool is allocated.
    ``batch_frames`` — the negotiated batch ceiling; above 1 the receiver
    runs the slab datapath (``slabs`` optionally carries a caller-owned
    ``SlabSet`` reused across the session's files).
    ``crc_acc`` — integrity manifest (``integrity.CrcManifest``): verified
    blocks are recorded only AFTER their bytes land on disk.
    ``io_timeout`` — event-loop stall bound + ACK-write timeout; a peer
    that stops moving bytes surfaces as a typed ``TimeoutError``.
    """
    own_fsm = fsm is None and conformance
    if own_fsm:
        fsm = _session_fsm()
    if batch_frames > 1:
        stats = _receive_batched(socks, sink, block_size, fsm, reusable,
                                 batch_frames, slabs, crc_acc, io_timeout)
    else:
        stats = _receive_pooled(socks, sink, block_size, pool_slots, fsm,
                                reusable, pool, crc_acc, io_timeout)
    if own_fsm:
        if reusable:
            assert fsm.state == "9_open_file", (
                f"conformance: receiver FSM ended in {fsm.state}"
            )
        else:
            assert fsm.done, f"conformance: receiver FSM ended in {fsm.state}"
    sink.commit()  # durability barrier: bytes are safe BEFORE the ACK
    for s in socks:
        s.settimeout(io_timeout)  # None = blocking without a deadline
        send_all(s, ACK)
    return stats


def _receive_pooled(socks, sink, block_size, pool_slots, fsm, reusable,
                    pool, crc_acc=None, io_timeout=None) -> RecvStats:
    """The per-frame registered-pool datapath (batch_frames == 1)."""
    from repro.core.ringbuf import RecvBufferPool

    stats = RecvStats()
    n = len(socks)
    # verified-but-unflushed blocks: slot -> (offset, length, crc); the
    # manifest only learns about a block once its pwritev landed
    pending_crcs: Dict[int, tuple] = {}
    if pool is None or pool.block_size != block_size:
        pool = RecvBufferPool(pool_slots, block_size)
    if pool.slots <= n:
        # with <= n slots every slot can be held by a partially-filled
        # block (one per channel) and the backpressure flush below would
        # spin forever draining zero committed blocks
        raise ValueError(
            f"pool_slots ({pool.slots}) must exceed n_channels ({n}): "
            "an all-uncommitted pool cannot make progress"
        )
    piod = PIOD()
    eof = [False] * n

    class Chan:
        __slots__ = ("sock", "idx", "hdr_buf", "hdr_got", "hdr", "slot",
                     "view", "got", "need_trl", "trl_got", "trl_buf")

        def __init__(self, sock, idx):
            self.sock = sock
            self.idx = idx
            self.hdr_buf = memoryview(bytearray(HEADER_SIZE))
            self.hdr_got = 0
            self.hdr = None
            self.slot = None  # claimed pool slot handle
            self.view = None  # its registered buffer view
            self.got = 0
            self.need_trl = False  # payload done, CRC trailer pending
            self.trl_got = 0
            self.trl_buf = memoryview(bytearray(TRAILER_SIZE))

    def fsm_steps(*events):
        if fsm is not None:
            for e in events:
                fsm.step(e)

    def flush(final=False):
        blocks = pool.drain()
        if blocks or final:
            stats.writev_calls += sink.writev_views(
                [(off, pool.view(slot)[:ln]) for off, ln, slot in blocks]
            )
            stats.flushes += 1
            for _, _, slot in blocks:
                if crc_acc is not None:
                    rec = pending_crcs.pop(slot, None)
                    if rec is not None:
                        crc_acc.add(*rec)  # bytes are on disk now
                pool.release(slot)
        if fsm is None:
            return
        if final:
            # conformance: must be in 13_flush; EOFR keeps the session alive
            fsm.step("eofr_flush" if reusable else "final_flush")
        elif fsm.state == "10_dispatch":
            fsm_steps("flush", "flushed")
        # (a drain tick after all_eof, state 13, needs no transition)

    def on_readable(sock, mask):
        """Greedy drain: keep consuming until the socket would block —
        one selector wakeup then services many blocks (minimizes dispatch
        overhead, the §2.3 context-switch factor applied to the event loop).
        """
        c = chans[sock]
        try:
            while True:
                if c.hdr is None:
                    r = sock.recv_into(
                        c.hdr_buf[c.hdr_got:], HEADER_SIZE - c.hdr_got
                    )
                    if r == 0:
                        raise ConnectionError("peer closed mid-header")
                    c.hdr_got += r
                    if c.hdr_got < HEADER_SIZE:
                        continue
                    c.hdr = ChannelHeader.unpack(c.hdr_buf)
                    c.hdr_got = 0
                    if c.hdr.event in END_EVENTS:
                        # milestone: 10 -> 11 -> 14 -> (10 | 13)
                        if c.hdr.event == ChannelEvent.EOFR:
                            stats.eofr_frames += 1
                        else:
                            stats.eoft_frames += 1
                        eof[c.idx] = True
                        piod.unregister(sock)
                        c.hdr = None
                        fsm_steps("read_ready", "eof_header",
                                  "all_eof" if all(eof) else "channels_open")
                        return
                    if c.hdr.length > block_size:
                        raise ProtocolError(
                            f"block of {c.hdr.length} bytes exceeds "
                            f"negotiated block_size {block_size}"
                        )
                    c.slot = pool.acquire()
                    while c.slot is None:  # backpressure: drain to disk
                        if pool.n_committed == 0:
                            # every slot is held by a partially-filled block
                            # of some channel: flushing drains nothing and
                            # the loop would livelock (guarded against by
                            # the pool_slots > n_channels check above)
                            raise RuntimeError(
                                "receiver livelock: all pool slots held by "
                                "uncommitted blocks; raise pool_slots above "
                                "the channel count"
                            )
                        flush()
                        c.slot = pool.acquire()
                    c.view = pool.view(c.slot)
                    c.got = 0
                    continue
                if c.need_trl:
                    # integrity mode: the 4-byte CRC32 trailer after the
                    # payload; verify BEFORE commit, so a corrupt block
                    # never reaches the pool (let alone the disk)
                    r = sock.recv_into(c.trl_buf[c.trl_got:],
                                       TRAILER_SIZE - c.trl_got)
                    if r == 0:
                        raise ConnectionError("peer closed mid-trailer")
                    c.trl_got += r
                    if c.trl_got < TRAILER_SIZE:
                        continue
                    (want_crc,) = CRC_TRAILER.unpack(c.trl_buf)
                    if block_crc(c.view[:c.hdr.length]) == want_crc:
                        pool.commit(c.slot, c.hdr.offset, c.hdr.length)
                        if crc_acc is not None:
                            pending_crcs[c.slot] = (
                                c.hdr.offset, c.hdr.length, want_crc)
                    else:
                        # stream stays synced (trailer is length-framed);
                        # skip the block — RESUME re-fetches it
                        stats.crc_mismatches += 1
                        pool.release(c.slot)
                    fsm_steps("read_ready", "block", "buffered")
                    c.hdr = None
                    c.slot = None
                    c.view = None
                    c.need_trl = False
                    c.trl_got = 0
                    if pool.n_free == 0:
                        flush()
                    continue
                # payload lands straight in the registered slot view
                want = c.hdr.length - c.got
                r = sock.recv_into(c.view[c.got : c.hdr.length], want)
                if r == 0:
                    raise ConnectionError("peer closed mid-block")
                c.got += r
                stats.bytes += r
                if c.got == c.hdr.length:
                    if c.hdr.flags & FLAG_BLOCK_CRC:
                        c.need_trl = True
                        c.trl_got = 0
                        continue
                    pool.commit(c.slot, c.hdr.offset, c.hdr.length)
                    # milestone: full block moved through 10 -> 11 -> 12 -> 10
                    fsm_steps("read_ready", "block", "buffered")
                    c.hdr = None
                    c.slot = None
                    c.view = None
                    if pool.n_free == 0:
                        flush()
        except BlockingIOError:
            return

    chans: Dict[socket.socket, Chan] = {}
    for i, s in enumerate(socks):
        chans[s] = Chan(s, i)
        piod.register(s, selectors.EVENT_READ, on_readable)

    def drained_if_idle():
        if pool.n_committed >= pool_slots // 2:
            flush()

    piod.idle_callback = drained_if_idle
    piod.run(until=lambda: all(eof), stall_timeout=io_timeout)
    flush(final=True)
    piod.close()
    return stats


def _receive_batched(socks, sink, block_size, fsm, reusable, batch_frames,
                     slabs, crc_acc=None, io_timeout=None) -> RecvStats:
    """The slab datapath: per-channel registered slabs, many frames per
    ``recv_into``, flush = pwritev of the slab views + compact."""
    from repro.core.ringbuf import SlabSet

    stats = RecvStats()
    n = len(socks)
    span = slab_span(batch_frames, block_size)
    if slabs is None or slabs.n_channels < n or slabs.slab_bytes != span:
        slabs = SlabSet(n, span)
    piod = PIOD()
    eof = [False] * n
    chans: Dict[socket.socket, SlabChannel] = {}
    idx: Dict[socket.socket, int] = {}

    def fsm_steps(*events):
        if fsm is not None:
            for e in events:
                fsm.step(e)

    def flush_chan(sc: SlabChannel, final=False):
        batch = sc.take_pending()
        if batch or final:
            stats.writev_calls += sink.writev_views(batch)
            stats.flushes += 1
        # a verified frame's chunks always precede its trailer in the
        # stream, so after this write they are ALL on disk — safe to
        # manifest now
        for rec in sc.take_verified():
            if crc_acc is not None:
                crc_acc.add(*rec)
        sc.compact()
        if fsm is None or final:
            return
        if fsm.state == "10_dispatch":
            fsm_steps("flush", "flushed")

    def on_readable(sock, mask):
        sc = chans[sock]
        try:
            while True:
                if sc.free_space() == 0:
                    flush_chan(sc)
                done = sc.receive_once(sock)
                for _ in range(done):
                    # milestone per landed frame: 10 -> 11 -> 12 -> 10
                    fsm_steps("read_ready", "block", "buffered")
                if sc.end_event is not None:
                    i = idx[sock]
                    if sc.end_event == ChannelEvent.EOFR:
                        stats.eofr_frames += 1
                    else:
                        stats.eoft_frames += 1
                    eof[i] = True
                    piod.unregister(sock)
                    fsm_steps("read_ready", "eof_header",
                              "all_eof" if all(eof) else "channels_open")
                    if not all(eof):
                        # the LAST channel's tail rides the final flush
                        # (FSM is already in 13_flush by then)
                        flush_chan(sc)
                    return
        except BlockingIOError:
            return

    for i, s in enumerate(socks):
        chans[s] = SlabChannel(slabs.slab(i), block_size)
        idx[s] = i
        piod.register(s, selectors.EVENT_READ, on_readable)

    def drained_if_idle():
        for sc in chans.values():
            if sc.pending_bytes and sc.end_event is None:
                flush_chan(sc)

    piod.idle_callback = drained_if_idle
    piod.run(until=lambda: all(eof), stall_timeout=io_timeout)
    for sc in chans.values():  # terminal flush of every channel's tail
        flush_chan(sc, final=True)
        stats.bytes += sc.bytes
        stats.recv_calls += sc.recv_calls
        stats.crc_mismatches += sc.crc_mismatches
    if fsm is not None:
        fsm.step("eofr_flush" if reusable else "final_flush")
    piod.close()
    return stats


def event_send(
    socks: List[socket.socket],
    source: Source,
    session: bytes,
    mode_event: ChannelEvent = ChannelEvent.xFTSMU,
    reusable: bool = False,
    batch_frames: int = 1,
    integrity: bool = False,
    blocks: Optional[List[int]] = None,
    io_timeout: Optional[float] = None,
    crc_out: Optional[Dict[int, int]] = None,
) -> int:
    """xDFS event-driven sender: one thread, write-readiness multiplexing.

    Zero-copy: frames are scatter-gather iovecs ``[header_view,
    block_view, ...]`` — headers live in per-channel reusable buffers
    (:class:`FrameBuilder`), payloads are views into the source mmap —
    and partial ``sendmsg`` resumes by re-slicing the iovec
    (:func:`advance_iovec`) instead of rebuilding the frame.

    ``batch_frames`` caps how many frames one pending iovec coalesces;
    above 1, each channel's actual depth is hill-climbed by a
    ``ChannelTuner`` from measured goodput.

    ``integrity`` appends a CRC32 trailer to every data frame (the
    FLAG_BLOCK_CRC wire contract); ``blocks`` restricts the transfer to
    a sorted subset of block indices (the RESUME flow's missing set —
    each channel strips the PLAN, not the whole file); ``io_timeout``
    bounds event-loop stalls and the final ACK wait. ``crc_out`` collects
    the per-block trailer CRCs (single-threaded loop, no lock needed) so
    callers can fold the whole-file CRC without a second serial pass.
    """
    n = len(socks)
    cap = max(1, batch_frames)
    piod = PIOD()
    frames = FrameBuilder(session, n, depth=cap + 1)  # batch + end frame
    tuners = ([ChannelTuner(cap=cap) for _ in range(n)] if cap > 1 else None)
    plan = (list(range(source.n_blocks)) if blocks is None
            else sorted(set(blocks)))
    queues = [plan[i::n] for i in range(n)]  # channel i sends plan[i::n]
    qpos = [0] * n
    pending: Dict[socket.socket, List[memoryview]] = {}  # in-flight iovecs
    done = [False] * n
    sent = 0
    end_event = ChannelEvent.EOFR if reusable else ChannelEvent.EOFT
    data_flags = FLAG_BLOCK_CRC if integrity else 0

    def make_batch(i_chan: int) -> List[memoryview]:
        """Up to the tuned depth of frames for this channel; the end
        frame rides the batch that exhausts the stripe."""
        depth = tuners[i_chan].depth if tuners is not None else 1
        iov: List[memoryview] = []
        q = queues[i_chan]
        for _ in range(depth):
            if qpos[i_chan] >= len(q):
                iov.append(frames.header(i_chan, end_event, 0, 0))
                done[i_chan] = True
                break
            blk = q[qpos[i_chan]]
            qpos[i_chan] += 1
            ln = source.block_len(blk)
            iov.append(frames.header(i_chan, mode_event,
                                     blk * source.block_size, ln,
                                     flags=data_flags))
            iov.append(source.block_view(blk))
            if integrity:
                c = source.block_crc(blk)
                if crc_out is not None:
                    crc_out[blk] = c
                iov.append(frames.trailer(i_chan, c))
        return iov

    idx = {s: i for i, s in enumerate(socks)}

    def on_writable(sock, mask):
        nonlocal sent
        i = idx[sock]
        try:
            while True:  # greedy: fill the socket until it would block
                iov = pending.get(sock)
                if iov is None:
                    iov = make_batch(i)
                    pending[sock] = iov
                w = sock.sendmsg(iov)
                sent += w
                if tuners is not None:
                    tuners[i].note(w)
                if advance_iovec(iov, w):
                    continue  # partial batch still pending on this channel
                pending.pop(sock)
                if done[i]:
                    piod.unregister(sock)
                    return
        except BlockingIOError:
            return

    for s in socks:
        piod.register(s, selectors.EVENT_WRITE, on_writable)
    piod.run(until=lambda: all(done) and not pending,
             stall_timeout=io_timeout)
    piod.close()
    for s in socks:
        s.settimeout(io_timeout)  # None = blocking without a deadline
        recv_exact(s, 1)  # final ack (exception-header channel)
    return sent


def _receive(socks, sink, block_size, *, pool_slots=32, fsm=None,
             conformance=True, reusable=False, pool=None, splice=False,
             batch_frames=1, slabs=None, crc_acc=None, io_timeout=None):
    # ``splice`` is accepted for signature uniformity but ignored: the
    # blocking socket->pipe splice would stall the nonblocking event loop
    # (the same reason the mtedp sender has no sendfile path).
    return mtedp_receive(socks, sink, block_size, pool_slots,
                         conformance=conformance, fsm=fsm, reusable=reusable,
                         pool=pool, batch_frames=batch_frames, slabs=slabs,
                         crc_acc=crc_acc, io_timeout=io_timeout)


def _send(socks, source, session, *, reusable=False, batch_frames=1,
          integrity=False, blocks=None, io_timeout=None, crc_out=None):
    return event_send(socks, source, session, reusable=reusable,
                      batch_frames=batch_frames, integrity=integrity,
                      blocks=blocks, io_timeout=io_timeout, crc_out=crc_out)


ENGINE = register_engine(Engine(
    "mtedp", _receive, _send,
    "multi-threaded event-driven pipelined (the paper's xDFS design): one "
    "event loop, registered zero-copy recv pool or batched slabs, "
    "single-writer vectored disk I/O",
    uses_pool=True,
    pool_livelock_guard=True,
))
