"""MT — multi-threaded engine (paper §2.5.2).

Thread per channel + pessimistically locked shared ring + one disk thread
(single handle). The sender is a blocking worker thread per channel, each
with a private fd reading its stripe.
"""
from __future__ import annotations

import socket
import threading
from typing import List

from repro.core.engines.base import (
    ACK,
    END_EVENTS,
    RecvStats,
    Sink,
    Source,
    recv_exact,
    send_all,
)
from repro.core.engines.registry import Engine, register_engine
from repro.core.header import HEADER_SIZE, ChannelEvent, ChannelHeader


def mt_receive(
    socks: List[socket.socket],
    sink: Sink,
    block_size: int,
    ring_slots: int = 32,
    reusable: bool = False,
) -> RecvStats:
    """MT model: thread per channel + locked shared ring + disk thread."""
    from repro.core.ringbuf import LockedRing

    stats = RecvStats()
    ring = LockedRing(ring_slots, block_size)
    lock = threading.Lock()

    def rx(sock):
        hdr_buf = memoryview(bytearray(HEADER_SIZE))
        while True:
            recv_exact(sock, HEADER_SIZE, hdr_buf)
            hdr = ChannelHeader.unpack(bytes(hdr_buf))
            if hdr.event in END_EVENTS:
                with lock:
                    if hdr.event == ChannelEvent.EOFR:
                        stats.eofr_frames += 1
                    else:
                        stats.eoft_frames += 1
                return
            payload = recv_exact(sock, hdr.length)
            ring.put(payload, hdr.offset)
            with lock:
                stats.bytes += hdr.length

    def disk():
        while True:
            batch = ring.get_batch()
            if batch:
                blocks = [(off, len(d), bytearray(d)) for off, d in batch]
                stats.writev_calls += sink.writev_coalesced(blocks)
                stats.flushes += 1
            elif ring.closed:
                return

    dt = threading.Thread(target=disk)
    dt.start()
    threads = [threading.Thread(target=rx, args=(s,)) for s in socks]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    ring.close()
    dt.join()
    for s in socks:
        send_all(s, ACK)
    return stats


def worker_send(
    socks: List[socket.socket],
    source: Source,
    session: bytes,
    use_processes: bool,
    mode_event: ChannelEvent = ChannelEvent.xFTSMU,
    reusable: bool = False,
) -> int:
    """Baseline sender: blocking worker (thread or fork) per channel, each
    with a PRIVATE fd reading its stripe (seek-heavy, GridFTP-like)."""
    import os

    n = len(socks)
    end_event = ChannelEvent.EOFR if reusable else ChannelEvent.EOFT

    def tx(i: int, sock: socket.socket):
        src = source.open_worker()
        b = i
        while b < src.n_blocks:
            ln = src.block_len(b)
            hdr = ChannelHeader(mode_event, session, i, b * src.block_size, ln)
            send_all(sock, hdr.pack() + src.read_block(b))
            b += n
        send_all(sock, ChannelHeader(end_event, session, i, 0, 0).pack())
        sock.setblocking(True)
        recv_exact(sock, 1)
        src.close()

    if use_processes:
        pids = []
        for i, s in enumerate(socks):
            pid = os.fork()
            if pid == 0:
                try:
                    tx(i, s)
                    os._exit(0)
                except BaseException:
                    os._exit(1)
            pids.append(pid)
        for pid in pids:
            _, status = os.waitpid(pid, 0)
            if os.waitstatus_to_exitcode(status) != 0:
                raise RuntimeError("sender child failed")
    else:
        threads = [
            threading.Thread(target=tx, args=(i, s)) for i, s in enumerate(socks)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    return source.size


def _receive(socks, sink, block_size, *, pool_slots=32, fsm=None,
             conformance=True, reusable=False, pool=None):
    return mt_receive(socks, sink, block_size, pool_slots, reusable=reusable)


def _send(socks, source, session, *, reusable=False):
    return worker_send(socks, source, session, use_processes=False,
                       reusable=reusable)


ENGINE = register_engine(Engine(
    "mt", _receive, _send,
    "multi-threaded: thread per channel, pessimistically locked shared "
    "ring, one disk thread",
))
