"""MT — multi-threaded engine (paper §2.5.2).

Concurrency model: one blocking thread per channel plus one disk thread,
all sharing a pessimistically locked receive structure (the paper's MT
synchronization cost lives in those lock handoffs). The sender is a
blocking worker thread per channel, each with a private fd reading its
stripe.

Pool-slot lifecycle (receive, ``batch_frames == 1``): each channel
thread parses headers in place from its reusable buffer, ``acquire``s a
slot from the shared ``LockedRecvPool`` (blocking when the pool is
exhausted — backpressure), ``recv_into``s the slot view, and
``commit``s; the single disk thread ``drain_wait``s the committed
backlog, hands the trimmed pool views to one coalesced ``os.pwritev``,
and ``release``s the slots.

Batched mode (``batch_frames > 1``): each channel thread owns a
registered ``RecvSlab`` and drains its socket with large multi-frame
``recv_into`` reads (``SlabChannel`` parses in place); full slabs are
handed to the disk thread through a ``LockedBatchRelay`` — the channel
thread blocks until the batch is written, so slab memory is never
reused under an in-flight ``pwritev`` (the batched descendant of the
per-block lock handoff).

Splice is ADAPTIVE: ``use_splice`` starts the kernel-side
socket->pipe->file path, but a ``SpliceArbiter`` (core/autotune.py)
measures one splice window against one pool/slab window and the faster
path wins the rest of the stream; a measured switch off a working
splice is counted in ``RecvStats.splice_autodisables``. Mechanical
failures (``SpliceUnsupported``, mid-block recovery) still fall back
exactly as before.
"""
from __future__ import annotations

import socket
import threading
from typing import Dict, List, Optional

from repro.core.autotune import ChannelTuner, SpliceArbiter
from repro.core.engines.base import (
    ACK,
    END_EVENTS,
    MSG_MORE,
    SENDFILE,
    SPLICE,
    FrameBuilder,
    RecvStats,
    SendfileUnsupported,
    Sink,
    SlabChannel,
    Source,
    SpliceReceiver,
    SpliceUnsupported,
    recv_exact,
    send_all,
    sendfile_all,
    sendmsg_all,
    sendmsg_batched,
    slab_span,
)
from repro.core.engines.registry import Engine, register_engine
from repro.core.integrity import block_crc
from repro.core.header import (
    CRC_TRAILER,
    FLAG_BLOCK_CRC,
    HEADER_SIZE,
    TRAILER_SIZE,
    ChannelEvent,
    ChannelHeader,
    ProtocolError,
)

# sentinel results of one receive phase (see _rx_channel)
_END = "end"  # the channel's end frame landed; stream done
_TO_POOL = "pool"  # arbiter moved off splice; continue on the pool path
_TO_SPLICE = "splice"  # arbiter chose splice back; resume per-frame


def mt_receive(
    socks: List[socket.socket],
    sink: Sink,
    block_size: int,
    ring_slots: int = 32,
    reusable: bool = False,
    pool=None,
    use_splice: bool = False,
    batch_frames: int = 1,
    slabs=None,
    arbiter_factory=None,
    crc_acc=None,
    io_timeout: Optional[float] = None,
) -> RecvStats:
    """MT model: thread per channel + locked shared handoff + disk thread.

    Zero-copy receive either way: per-frame mode lands payloads in
    shared ``RecvBufferPool`` slots, batched mode in per-channel slabs;
    the disk thread writes the SAME memory out with coalesced
    ``pwritev``. ``use_splice`` opts into the kernel-side path under the
    goodput arbiter; ``arbiter_factory`` overrides arbiter construction
    (tests script deterministic decisions through it). Channel-thread
    failures are re-raised in the caller, not swallowed.

    ``crc_acc`` (a ``CrcManifest``) collects verified blocks from
    CRC-flagged frames — a block is only manifested AFTER its pwritev
    landed, so the manifest never claims bytes that aren't on disk.
    ``io_timeout`` bounds every blocking socket wait; a stalled peer
    surfaces as ``TimeoutError`` instead of a hung channel thread."""
    from repro.core.ringbuf import (
        LockedBatchRelay,
        LockedRecvPool,
        RecvBufferPool,
        SlabSet,
    )

    stats = RecvStats()
    batched = batch_frames > 1
    n = len(socks)
    shared = relay = None
    if batched:
        span = slab_span(batch_frames, block_size)
        if slabs is None or slabs.n_channels < n or slabs.slab_bytes != span:
            slabs = SlabSet(n, span)
        relay = LockedBatchRelay()
    else:
        if pool is None or pool.block_size != block_size:
            pool = RecvBufferPool(ring_slots, block_size)
        shared = LockedRecvPool(pool)
    lock = threading.Lock()
    errors: List[BaseException] = []
    splice_ok = use_splice and SPLICE and sink.file_backed
    # slot -> (offset, length, crc) for verified-but-not-yet-written blocks;
    # the disk thread pops entries into crc_acc after their pwritev lands
    pending_crcs = {}
    if io_timeout is not None and not splice_ok:
        # settimeout puts the fd in non-blocking mode, which os.splice
        # cannot tolerate — deadlines apply to the recv/sendmsg paths only
        for s in socks:
            s.settimeout(io_timeout)

    def manifest_verified(records) -> None:
        if crc_acc is not None:
            with lock:
                for rec in records:
                    crc_acc.add(*rec)

    def fail(e: BaseException) -> None:
        with lock:
            errors.append(e)
        if shared is not None:
            shared.close()  # unblock siblings parked in acquire
        if relay is not None:
            relay.close()  # unblock siblings parked in submit_wait
        for s in socks:  # unblock sibling channel threads mid-recv
            try:
                s.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass

    def note_arbiter(arb: Optional[SpliceArbiter], spl, nbytes: int) -> None:
        """Feed the arbiter and count a measured autodisable exactly once."""
        if arb is not None and arb.note(nbytes):
            if arb.measured_switch and spl is not None and spl.ok:
                with lock:
                    stats.splice_autodisables += 1

    def splice_phase(sock, spl, arb, hdr_buf, resume):
        """Per-frame kernel-side receive while the arbiter favors splice.
        Returns (_END, None) or (_TO_POOL, resume') where resume' is a
        frame whose payload still needs ``(offset, length)`` on the pool
        path (a first-call SpliceUnsupported consumed nothing)."""
        if resume is not None:  # finish a frame handed over mid-payload
            off, left = resume
            n_k = spl.splice_block(sock, sink.fileno(), off, left)
            with lock:
                stats.bytes += left
                stats.splice_bytes += n_k
            note_arbiter(arb, spl, left)
            if not spl.ok:
                arb.force_pool()
        while arb.use_splice:
            recv_exact(sock, HEADER_SIZE, hdr_buf)
            hdr = ChannelHeader.unpack(hdr_buf)
            if hdr.event in END_EVENTS:
                with lock:
                    if hdr.event == ChannelEvent.EOFR:
                        stats.eofr_frames += 1
                    else:
                        stats.eoft_frames += 1
                return _END, None
            if hdr.length > block_size:
                raise ProtocolError(
                    f"block of {hdr.length} bytes exceeds negotiated "
                    f"block_size {block_size}"
                )
            try:
                n_k = spl.splice_block(sock, sink.fileno(), hdr.offset,
                                       hdr.length)
            except SpliceUnsupported:
                # nothing consumed: the whole payload moves to the pool path
                arb.force_pool()
                return _TO_POOL, (hdr.offset, hdr.length)
            if hdr.flags & FLAG_BLOCK_CRC:
                # splice moved the payload kernel-side, so there is nothing
                # to checksum — drain the trailer to stay framed (the
                # session layer disables splice under integrity; this is
                # belt-and-braces for mixed peers)
                recv_exact(sock, TRAILER_SIZE)
            with lock:
                stats.bytes += hdr.length
                stats.splice_bytes += n_k
            note_arbiter(arb, spl, hdr.length)
            if not spl.ok:  # mid-block recovery: stop splicing
                arb.force_pool()
        return _TO_POOL, None

    def pool_phase(sock, arb, spl, hdr_buf, resume):
        """Per-frame shared-pool receive (``batch_frames == 1``). Runs to
        the end frame unless the arbiter picks splice back mid-trial."""
        trl_buf = memoryview(bytearray(TRAILER_SIZE))
        if resume is not None:
            off, left = resume
            slot = shared.acquire()
            recv_exact(sock, left, shared.view(slot))
            shared.commit(slot, off, left)
            with lock:
                stats.bytes += left
            note_arbiter(arb, spl, left)
        while True:
            if arb is not None and arb.use_splice:
                return _TO_SPLICE, None
            recv_exact(sock, HEADER_SIZE, hdr_buf)
            hdr = ChannelHeader.unpack(hdr_buf)
            if hdr.event in END_EVENTS:
                with lock:
                    if hdr.event == ChannelEvent.EOFR:
                        stats.eofr_frames += 1
                    else:
                        stats.eoft_frames += 1
                return _END, None
            if hdr.length > block_size:
                raise ProtocolError(
                    f"block of {hdr.length} bytes exceeds negotiated "
                    f"block_size {block_size}"
                )
            slot = shared.acquire()  # blocks when exhausted: backpressure
            recv_exact(sock, hdr.length, shared.view(slot))
            if hdr.flags & FLAG_BLOCK_CRC:
                recv_exact(sock, TRAILER_SIZE, trl_buf)
                want = CRC_TRAILER.unpack(trl_buf)[0]
                got = block_crc(shared.view(slot)[:hdr.length])
                if got != want:
                    # corrupt block: never commit it — the manifest gap
                    # drives a RESUME re-fetch; the stream itself stays
                    # framed (trailer is length-delimited) and alive
                    shared.release_all([slot])
                    with lock:
                        stats.bytes += hdr.length
                        stats.crc_mismatches += 1
                    note_arbiter(arb, spl, hdr.length)
                    continue
                with lock:
                    pending_crcs[slot] = (hdr.offset, hdr.length, want)
            shared.commit(slot, hdr.offset, hdr.length)
            with lock:
                stats.bytes += hdr.length
            note_arbiter(arb, spl, hdr.length)

    def slab_phase(sock, sc: SlabChannel, arb, spl, carry, resume):
        """Batched slab receive: large multi-frame reads, full slabs
        relayed to the disk thread. Runs to the end frame unless the
        arbiter picks splice back mid-trial (slab state is then handed
        off at the current parse position)."""
        sc.seed(carry, *(resume or (0, 0)))
        last_bytes = sc.bytes
        while True:
            if sc.free_space() == 0:
                relay.submit_wait(sc.take_pending())
                # submit_wait returns only after the disk thread's pwritev,
                # so every chunk of a verified frame is on disk by now
                manifest_verified(sc.take_verified())
                sc.compact()
            sc.receive_once(sock)
            note_arbiter(arb, spl, sc.bytes - last_bytes)
            last_bytes = sc.bytes
            if sc.end_event is not None:
                relay.submit_wait(sc.take_pending())
                manifest_verified(sc.take_verified())
                with lock:
                    if sc.end_event == ChannelEvent.EOFR:
                        stats.eofr_frames += 1
                    else:
                        stats.eoft_frames += 1
                return _END, b"", None
            if arb is not None and arb.decided and arb.chose_splice:
                relay.submit_wait(sc.take_pending())
                manifest_verified(sc.take_verified())
                tail, hdr, off, left = sc.handoff()
                return _TO_SPLICE, tail, ((off, left) if left else None)

    def rx(i: int, sock):
        spl = None
        try:
            arb = None
            if splice_ok:
                try:
                    spl = SpliceReceiver()
                    arb = (arbiter_factory() if arbiter_factory is not None
                           else SpliceArbiter())
                except SpliceUnsupported:
                    spl = None
            hdr_buf = memoryview(bytearray(HEADER_SIZE))
            sc = SlabChannel(slabs.slab(i), block_size) if batched else None
            carry, resume = b"", None
            while True:
                if arb is not None and arb.use_splice:
                    if carry:  # sub-header fragment from a slab handoff
                        hdr_buf[:len(carry)] = carry
                        recv_exact(sock, HEADER_SIZE - len(carry),
                                   hdr_buf[len(carry):])
                        hdr = ChannelHeader.unpack(hdr_buf)
                        carry = b""
                        if hdr.event in END_EVENTS:
                            with lock:
                                if hdr.event == ChannelEvent.EOFR:
                                    stats.eofr_frames += 1
                                else:
                                    stats.eoft_frames += 1
                            break
                        if hdr.length > block_size:
                            raise ProtocolError(
                                f"block of {hdr.length} bytes exceeds "
                                f"negotiated block_size {block_size}"
                            )
                        resume = (hdr.offset, hdr.length)
                    sig, resume = splice_phase(sock, spl, arb, hdr_buf,
                                               resume)
                elif batched:
                    sig, carry, resume = slab_phase(sock, sc, arb, spl,
                                                    carry, resume)
                else:
                    sig, resume = pool_phase(sock, arb, spl, hdr_buf, resume)
                if sig == _END:
                    break
            if sc is not None:
                with lock:
                    stats.bytes += sc.bytes
                    stats.recv_calls += sc.recv_calls
                    stats.crc_mismatches += sc.crc_mismatches
        except BaseException as e:  # noqa: BLE001 - surfaced after join
            fail(e)
        finally:
            if spl is not None:
                spl.close()

    def disk_pooled():
        try:
            while True:
                batch = shared.drain_wait()
                if batch:
                    # trimmed views of the registered pool memory go into
                    # pwritev untouched; slots free only after the write
                    stats.writev_calls += sink.writev_views(
                        [(off, shared.view(slot)[:ln])
                         for off, ln, slot in batch]
                    )
                    stats.flushes += 1
                    if pending_crcs:
                        # the batch is on disk: its blocks may enter the
                        # manifest (slots pop even without crc_acc so a
                        # reused slot never inherits a stale record)
                        with lock:
                            for _, _, slot in batch:
                                rec = pending_crcs.pop(slot, None)
                                if rec is not None and crc_acc is not None:
                                    crc_acc.add(*rec)
                    shared.release_all(slot for _, _, slot in batch)
                elif shared.closed:
                    return
        except BaseException as e:  # noqa: BLE001 - e.g. sink ENOSPC
            fail(e)

    def disk_batched():
        try:
            while True:
                ticket = relay.next_ticket()
                if ticket is None:
                    if relay.closed:
                        return
                    continue
                stats.writev_calls += sink.writev_views(ticket[0])
                stats.flushes += 1
                relay.mark_done(ticket)
        except BaseException as e:  # noqa: BLE001 - e.g. sink ENOSPC
            fail(e)

    dt = threading.Thread(target=disk_batched if batched else disk_pooled)
    dt.start()
    threads = [threading.Thread(target=rx, args=(i, s))
               for i, s in enumerate(socks)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if shared is not None:
        shared.close()
    if relay is not None:
        relay.close()
    dt.join()
    if errors:
        raise errors[0]  # don't ACK a broken stream
    sink.commit()  # durability barrier: bytes are safe BEFORE the ACK
    for s in socks:
        send_all(s, ACK)
    return stats


def worker_send(
    socks: List[socket.socket],
    source: Source,
    session: bytes,
    use_processes: bool,
    mode_event: ChannelEvent = ChannelEvent.xFTSMU,
    reusable: bool = False,
    allow_sendfile: bool = True,
    batch_frames: int = 1,
    integrity: bool = False,
    blocks: Optional[List[int]] = None,
    io_timeout: Optional[float] = None,
    crc_out: Optional[Dict[int, int]] = None,
) -> int:
    """Baseline sender: blocking worker (thread or fork) per channel, each
    with a PRIVATE fd reading its stripe (seek-heavy, GridFTP-like).

    Zero-copy datapath: uncompressed file-backed sources go through
    ``os.sendfile`` (kernel-side page-cache -> socket copy); everything
    else is scatter-gather ``sendmsg``. With ``batch_frames > 1`` the
    sendfile path steps aside and each worker coalesces a hill-climbed
    number of frames into one ``sendmsg_batched`` call (headers cycle
    through a ring of reusable per-worker buffers).

    ``integrity`` flags every data frame and appends its CRC32 trailer.
    ``blocks`` restricts the transfer to those block indices (the RESUME
    re-send plan); channels stripe over the PLAN, not the file, so a
    short plan still spreads across all channels. ``io_timeout`` bounds
    every blocking send/ACK wait. ``crc_out`` (thread mode only) collects
    the per-block CRCs the workers compute for the trailers, so callers
    can fold the whole-file CRC without a second serial pass."""
    import os

    n = len(socks)
    end_event = ChannelEvent.EOFR if reusable else ChannelEvent.EOFT
    cap = max(1, batch_frames)
    plan = (list(range(source.n_blocks)) if blocks is None
            else sorted(set(blocks)))
    data_flags = FLAG_BLOCK_CRC if integrity else 0
    # reusable header buffers per channel: one per potentially in-flight
    # frame (the batch ceiling plus the end frame)
    frames = FrameBuilder(session, n, depth=cap + 1)
    # fork-mode children can't write back to the parent; crc_out stays
    # incomplete there and callers fall back to a serial file pass
    collect = integrity and crc_out is not None and not use_processes
    crc_lock = threading.Lock()

    def tx(i: int, sock: socket.socket):
        src = source.open_worker()
        local_crcs: Optional[Dict[int, int]] = {} if collect else None
        if io_timeout is not None:
            sock.settimeout(io_timeout)

        def hdr(event, off, ln, flags=0):
            return frames.header(i, event, off, ln, flags)

        def bcrc(b: int) -> int:
            c = src.block_crc(b)
            if local_crcs is not None:
                local_crcs[b] = c
            return c

        # sendfile precludes gathering many frames into one syscall, so
        # the batched mode always takes the scatter-gather path
        use_sf = (allow_sendfile and SENDFILE and src.file_backed
                  and cap == 1)
        tuner = ChannelTuner(cap=cap) if cap > 1 else None
        mine = plan[i::n]  # this channel's stripe of the send plan
        k = 0
        while k < len(mine):
            b = mine[k]
            if tuner is None:
                ln = src.block_len(b)
                off = b * src.block_size
                if use_sf:
                    # MSG_MORE keeps the tiny header out of its own NODELAY
                    # segment: it coalesces with the first sendfile payload
                    send_all(sock, hdr(mode_event, off, ln, data_flags),
                             MSG_MORE)
                    try:
                        sendfile_all(sock, src.fileno(), off, ln)
                    except SendfileUnsupported:
                        # nothing of this block hit the wire: finish it from
                        # the mmap view and stay on the generic path
                        use_sf = False
                        send_all(sock, src.block_view(b))
                    if integrity:
                        # MSG_MORE again: the 4-byte trailer must not ride
                        # its own segment — it coalesces with the next
                        # frame's header (or the end frame flushes it)
                        send_all(sock, frames.trailer(i, bcrc(b)),
                                 MSG_MORE)
                else:
                    iov = [hdr(mode_event, off, ln, data_flags),
                           src.block_view(b)]
                    if integrity:
                        iov.append(frames.trailer(i, bcrc(b)))
                    sendmsg_all(sock, iov)
                k += 1
                continue
            iov = []
            sizes = []
            while len(sizes) < tuner.depth and k < len(mine):
                b = mine[k]
                ln = src.block_len(b)
                iov.append(hdr(mode_event, b * src.block_size, ln,
                               data_flags))
                iov.append(src.block_view(b))
                fsz = HEADER_SIZE + ln
                if integrity:
                    iov.append(frames.trailer(i, bcrc(b)))
                    fsz += TRAILER_SIZE
                sizes.append(fsz)
                k += 1
            sent = sendmsg_batched(sock, iov, sizes)
            tuner.note(sent)
        send_all(sock, hdr(end_event, 0, 0))
        if local_crcs:
            with crc_lock:
                crc_out.update(local_crcs)
        if io_timeout is None:
            sock.setblocking(True)
        recv_exact(sock, 1)
        src.close()

    if use_processes:
        pids = []
        for i, s in enumerate(socks):
            pid = os.fork()
            if pid == 0:
                try:
                    tx(i, s)
                    os._exit(0)
                except BaseException:
                    os._exit(1)
            pids.append(pid)
        for pid in pids:
            _, status = os.waitpid(pid, 0)
            if os.waitstatus_to_exitcode(status) != 0:
                raise RuntimeError("sender child failed")
    else:
        errors: List[BaseException] = []

        def guarded_tx(i, s):
            try:
                tx(i, s)
            except BaseException as e:  # noqa: BLE001 - surfaced after join
                errors.append(e)
                for sock in socks:  # unblock siblings awaiting their ACK
                    try:
                        sock.shutdown(socket.SHUT_RDWR)
                    except OSError:
                        pass

        threads = [
            threading.Thread(target=guarded_tx, args=(i, s))
            for i, s in enumerate(socks)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errors:
            # mirror the fork path's exit-code check: a dead channel must
            # fail the transfer, not return success
            raise errors[0]
    if blocks is None:
        return source.size
    return sum(source.block_len(b) for b in plan)


def _receive(socks, sink, block_size, *, pool_slots=32, fsm=None,
             conformance=True, reusable=False, pool=None, splice=False,
             batch_frames=1, slabs=None, crc_acc=None, io_timeout=None):
    return mt_receive(socks, sink, block_size, pool_slots, reusable=reusable,
                      pool=pool, use_splice=splice, batch_frames=batch_frames,
                      slabs=slabs, crc_acc=crc_acc, io_timeout=io_timeout)


def _send(socks, source, session, *, reusable=False, batch_frames=1,
          integrity=False, blocks=None, io_timeout=None, crc_out=None):
    return worker_send(socks, source, session, use_processes=False,
                       reusable=reusable, batch_frames=batch_frames,
                       integrity=integrity, blocks=blocks,
                       io_timeout=io_timeout, crc_out=crc_out)


ENGINE = register_engine(Engine(
    "mt", _receive, _send,
    "multi-threaded: thread per channel, pessimistically locked shared "
    "recv pool (or batched slab relay), one disk thread",
    uses_pool=True,
))
