"""MT — multi-threaded engine (paper §2.5.2).

Concurrency model: one blocking thread per channel plus one disk thread,
all sharing a pessimistically locked receive pool (the paper's MT
synchronization cost lives in those per-block lock handoffs). The sender
is a blocking worker thread per channel, each with a private fd reading
its stripe.

Pool-slot lifecycle (receive): each channel thread parses headers in
place from its reusable buffer, ``acquire``s a slot from the shared
``LockedRecvPool`` (blocking when the pool is exhausted — backpressure),
``recv_into``s the slot view, and ``commit``s; the single disk thread
``drain_wait``s the committed backlog, hands the trimmed pool views to
one coalesced ``os.pwritev``, and ``release``s the slots. With
``use_splice`` and a file-backed sink, channel threads instead move each
payload kernel-side (socket -> pipe -> file ``os.splice``), bypassing the
pool and the disk thread entirely; a first-call ``SpliceUnsupported``
drops that channel back to the pool path.
"""
from __future__ import annotations

import socket
import threading
from typing import List

from repro.core.engines.base import (
    ACK,
    END_EVENTS,
    MSG_MORE,
    SENDFILE,
    SPLICE,
    RecvStats,
    SendfileUnsupported,
    Sink,
    Source,
    SpliceReceiver,
    SpliceUnsupported,
    recv_exact,
    send_all,
    sendfile_all,
    sendmsg_all,
)
from repro.core.engines.registry import Engine, register_engine
from repro.core.header import (
    HEADER_SIZE,
    ChannelEvent,
    ChannelHeader,
    ProtocolError,
    pack_header_into,
)


def mt_receive(
    socks: List[socket.socket],
    sink: Sink,
    block_size: int,
    ring_slots: int = 32,
    reusable: bool = False,
    pool=None,
    use_splice: bool = False,
) -> RecvStats:
    """MT model: thread per channel + locked shared recv pool + disk thread.

    Zero-copy receive: each channel thread parses headers in place from
    its one reusable buffer and ``recv_into``s payloads straight into
    slots of the shared registered ``RecvBufferPool`` (``pool``, reusable
    across a session's files); the disk thread drains committed slots
    with coalesced ``pwritev`` of the SAME pool memory. The per-block
    acquire/commit lock handoffs are the MT model's deliberate
    synchronization cost. ``use_splice`` moves payloads kernel-side
    instead (file-backed sinks on Linux; opt-in). Channel-thread failures
    are re-raised in the caller, not swallowed."""
    from repro.core.ringbuf import LockedRecvPool, RecvBufferPool

    stats = RecvStats()
    if pool is None or pool.block_size != block_size:
        pool = RecvBufferPool(ring_slots, block_size)
    shared = LockedRecvPool(pool)
    lock = threading.Lock()
    errors: List[BaseException] = []

    def rx(sock):
        spl = None
        try:
            use_spl = use_splice and SPLICE and sink.file_backed
            if use_spl:
                try:
                    spl = SpliceReceiver()
                except SpliceUnsupported:
                    use_spl = False
            hdr_buf = memoryview(bytearray(HEADER_SIZE))
            while True:
                recv_exact(sock, HEADER_SIZE, hdr_buf)
                hdr = ChannelHeader.unpack(hdr_buf)
                if hdr.event in END_EVENTS:
                    with lock:
                        if hdr.event == ChannelEvent.EOFR:
                            stats.eofr_frames += 1
                        else:
                            stats.eoft_frames += 1
                    return
                if hdr.length > block_size:
                    raise ProtocolError(
                        f"block of {hdr.length} bytes exceeds negotiated "
                        f"block_size {block_size}"
                    )
                if use_spl:
                    try:
                        n_k = spl.splice_block(sock, sink.fileno(),
                                               hdr.offset, hdr.length)
                        with lock:
                            stats.bytes += hdr.length
                            stats.splice_bytes += n_k
                        if not spl.ok:  # mid-block recovery: stop splicing
                            use_spl = False
                        continue
                    except SpliceUnsupported:
                        use_spl = False  # nothing consumed; pool path below
                slot = shared.acquire()  # blocks when exhausted: backpressure
                recv_exact(sock, hdr.length, shared.view(slot))
                shared.commit(slot, hdr.offset, hdr.length)
                with lock:
                    stats.bytes += hdr.length
        except BaseException as e:  # noqa: BLE001 - surfaced after join
            with lock:
                errors.append(e)
            shared.close()  # unblock siblings parked in acquire
            for s in socks:  # unblock sibling channel threads mid-recv
                try:
                    s.shutdown(socket.SHUT_RDWR)
                except OSError:
                    pass
        finally:
            if spl is not None:
                spl.close()

    def disk():
        try:
            while True:
                batch = shared.drain_wait()
                if batch:
                    # trimmed views of the registered pool memory go into
                    # pwritev untouched; slots free only after the write
                    stats.writev_calls += sink.writev_views(
                        [(off, shared.view(slot)[:ln])
                         for off, ln, slot in batch]
                    )
                    stats.flushes += 1
                    shared.release_all(slot for _, _, slot in batch)
                elif shared.closed:
                    return
        except BaseException as e:  # noqa: BLE001 - e.g. sink ENOSPC
            with lock:
                errors.append(e)
            shared.close()  # unblock channel threads waiting in acquire
            for s in socks:
                try:
                    s.shutdown(socket.SHUT_RDWR)
                except OSError:
                    pass

    dt = threading.Thread(target=disk)
    dt.start()
    threads = [threading.Thread(target=rx, args=(s,)) for s in socks]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    shared.close()
    dt.join()
    if errors:
        raise errors[0]  # don't ACK a broken stream
    for s in socks:
        send_all(s, ACK)
    return stats


def worker_send(
    socks: List[socket.socket],
    source: Source,
    session: bytes,
    use_processes: bool,
    mode_event: ChannelEvent = ChannelEvent.xFTSMU,
    reusable: bool = False,
    allow_sendfile: bool = True,
) -> int:
    """Baseline sender: blocking worker (thread or fork) per channel, each
    with a PRIVATE fd reading its stripe (seek-heavy, GridFTP-like).

    Zero-copy datapath: uncompressed file-backed sources go through
    ``os.sendfile`` (kernel-side page-cache -> socket copy); everything
    else is scatter-gather ``sendmsg([header_view, block_view])``. Headers
    are packed into one reusable per-worker buffer."""
    import os

    n = len(socks)
    end_event = ChannelEvent.EOFR if reusable else ChannelEvent.EOFT

    def tx(i: int, sock: socket.socket):
        src = source.open_worker()
        # one reusable header buffer per worker (its single wire channel)
        hdr_buf = bytearray(HEADER_SIZE)
        hdr = memoryview(hdr_buf)
        use_sf = allow_sendfile and SENDFILE and src.file_backed
        b = i
        while b < src.n_blocks:
            ln = src.block_len(b)
            off = b * src.block_size
            pack_header_into(hdr_buf, mode_event, session, i, off, ln)
            if use_sf:
                # MSG_MORE keeps the tiny header out of its own NODELAY
                # segment: it coalesces with the first sendfile payload
                send_all(sock, hdr, MSG_MORE)
                try:
                    sendfile_all(sock, src.fileno(), off, ln)
                except SendfileUnsupported:
                    # nothing of this block hit the wire: finish it from
                    # the mmap view and stay on the generic path
                    use_sf = False
                    send_all(sock, src.block_view(b))
            else:
                sendmsg_all(sock, [hdr, src.block_view(b)])
            b += n
        pack_header_into(hdr_buf, end_event, session, i, 0, 0)
        send_all(sock, hdr)
        sock.setblocking(True)
        recv_exact(sock, 1)
        src.close()

    if use_processes:
        pids = []
        for i, s in enumerate(socks):
            pid = os.fork()
            if pid == 0:
                try:
                    tx(i, s)
                    os._exit(0)
                except BaseException:
                    os._exit(1)
            pids.append(pid)
        for pid in pids:
            _, status = os.waitpid(pid, 0)
            if os.waitstatus_to_exitcode(status) != 0:
                raise RuntimeError("sender child failed")
    else:
        errors: List[BaseException] = []

        def guarded_tx(i, s):
            try:
                tx(i, s)
            except BaseException as e:  # noqa: BLE001 - surfaced after join
                errors.append(e)
                for sock in socks:  # unblock siblings awaiting their ACK
                    try:
                        sock.shutdown(socket.SHUT_RDWR)
                    except OSError:
                        pass

        threads = [
            threading.Thread(target=guarded_tx, args=(i, s))
            for i, s in enumerate(socks)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errors:
            # mirror the fork path's exit-code check: a dead channel must
            # fail the transfer, not return success
            raise errors[0]
    return source.size


def _receive(socks, sink, block_size, *, pool_slots=32, fsm=None,
             conformance=True, reusable=False, pool=None, splice=False):
    return mt_receive(socks, sink, block_size, pool_slots, reusable=reusable,
                      pool=pool, use_splice=splice)


def _send(socks, source, session, *, reusable=False):
    return worker_send(socks, source, session, use_processes=False,
                       reusable=reusable)


ENGINE = register_engine(Engine(
    "mt", _receive, _send,
    "multi-threaded: thread per channel, pessimistically locked shared "
    "recv pool, one disk thread",
    uses_pool=True,
))
