"""MP — multi-processed engine (paper §2.5.1, the GridFTP model).

Concurrency model: fork per channel, n independent file handles, per-block
pwrite at scattered offsets — no coalescing, no shared state. Each forked
child pipes its byte/end-frame counts back to the parent so ``RecvStats``
is accurate across the process boundary.

Pool-slot lifecycle (receive): each child owns a small private
``RecvBufferPool`` (pools cannot be shared across forks); per frame it
``acquire``s a slot, ``recv_into``s the slot view, ``pwrite``s the
trimmed view at the frame's scattered offset — the GridFTP baseline keeps
its one-write-per-block seek behavior deliberately — and ``release``s
the slot. ``use_splice`` moves payloads kernel-side instead
(socket -> pipe -> file), with the standard first-call fallback.
"""
from __future__ import annotations

import json
import os
import socket
from typing import List

from repro.core.engines.base import (
    ACK,
    END_EVENTS,
    SPLICE,
    RecvStats,
    Sink,
    Source,
    SpliceReceiver,
    SpliceUnsupported,
    recv_exact,
    send_all,
)
from repro.core.engines.mt import worker_send
from repro.core.engines.registry import Engine, register_engine
from repro.core.header import (
    HEADER_SIZE,
    ChannelEvent,
    ChannelHeader,
    ProtocolError,
)


def mp_receive(
    socks: List[socket.socket],
    sink: Sink,
    block_size: int,
    reusable: bool = False,
    use_splice: bool = False,
) -> RecvStats:
    """MP model (GridFTP-like): fork per channel, n file handles, per-block
    pwrite at scattered offsets — no coalescing, no shared state. Per-child
    counters travel back over a pipe and are summed into the parent stats.

    Each child receives into slots of a small private ``RecvBufferPool``
    (header parsed in place, payload ``recv_into`` the slot view, trimmed
    view handed to ``pwrite``); ``use_splice`` keeps payloads kernel-side
    entirely via socket -> pipe -> file ``os.splice``."""
    from repro.core.ringbuf import RecvBufferPool

    if sink.capture:
        raise ValueError("mp engine cannot receive into a capture sink "
                         "(forked children do not share parent memory)")
    stats = RecvStats()
    procs = []
    for s in socks:
        r_cnt, w_cnt = os.pipe()
        pid = os.fork()
        if pid == 0:  # child
            os.close(r_cnt)
            try:
                wsink = sink.open_worker()
                # one header buffer + a tiny private recv pool per child,
                # reused for every frame (zero per-frame allocation)
                hdr_buf = memoryview(bytearray(HEADER_SIZE))
                pool = RecvBufferPool(2, block_size)
                spl = None
                use_spl = use_splice and SPLICE and wsink.file_backed
                if use_spl:
                    try:
                        spl = SpliceReceiver()
                    except SpliceUnsupported:
                        use_spl = False
                child = {"bytes": 0, "eofr": 0, "eoft": 0, "splice": 0}
                while True:
                    recv_exact(s, HEADER_SIZE, hdr_buf)
                    hdr = ChannelHeader.unpack(hdr_buf)
                    if hdr.event in END_EVENTS:
                        key = "eofr" if hdr.event == ChannelEvent.EOFR else "eoft"
                        child[key] += 1
                        break
                    if hdr.length > block_size:
                        raise ProtocolError(
                            f"block of {hdr.length} bytes exceeds "
                            f"negotiated block_size {block_size}"
                        )
                    if use_spl:
                        try:
                            child["splice"] += spl.splice_block(
                                s, wsink.fileno(), hdr.offset, hdr.length)
                            child["bytes"] += hdr.length
                            if not spl.ok:
                                use_spl = False
                            continue
                        except SpliceUnsupported:
                            use_spl = False
                    slot = pool.acquire()
                    recv_exact(s, hdr.length, pool.view(slot))
                    wsink.write_at(hdr.offset, pool.view(slot)[: hdr.length])
                    pool.release(slot)
                    child["bytes"] += hdr.length
                wsink.close()
                os.write(w_cnt, json.dumps(child).encode())
                os.close(w_cnt)
                send_all(s, ACK)
                os._exit(0)
            except BaseException:
                os._exit(1)
        os.close(w_cnt)
        procs.append((pid, r_cnt))
    for pid, r_cnt in procs:
        raw = os.read(r_cnt, 4096)
        os.close(r_cnt)
        _, status = os.waitpid(pid, 0)
        if os.waitstatus_to_exitcode(status) != 0:
            raise RuntimeError("mp receiver child failed")
        child = json.loads(raw.decode())
        stats.bytes += child["bytes"]
        stats.eofr_frames += child["eofr"]
        stats.eoft_frames += child["eoft"]
        stats.splice_bytes += child.get("splice", 0)
    return stats


def _receive(socks, sink, block_size, *, pool_slots=32, fsm=None,
             conformance=True, reusable=False, pool=None, splice=False):
    return mp_receive(socks, sink, block_size, reusable=reusable,
                      use_splice=splice)


def _send(socks, source, session, *, reusable=False):
    return worker_send(socks, source, session, use_processes=True,
                       reusable=reusable)


ENGINE = register_engine(Engine(
    "mp", _receive, _send,
    "multi-processed (GridFTP-like baseline): fork per channel, private "
    "file handles, scattered per-block pwrite",
))
