"""MP — multi-processed engine (paper §2.5.1, the GridFTP model).

Concurrency model: fork per channel, n independent file handles, per-block
pwrite at scattered offsets — no coalescing, no shared state. Each forked
child pipes its byte/end-frame counts back to the parent so ``RecvStats``
is accurate across the process boundary.

Pool-slot lifecycle (receive, ``batch_frames == 1``): each child owns a
small private ``RecvBufferPool`` (pools cannot be shared across forks);
per frame it ``acquire``s a slot, ``recv_into``s the slot view,
``pwrite``s the trimmed view at the frame's scattered offset — the
GridFTP baseline keeps its one-write-per-block seek behavior
deliberately — and ``release``s the slot. Batched mode gives each child
a private ``RecvSlab`` instead: one ``recv_into`` spans many frames and
every parsed ``(offset, view)`` fragment still goes out through its own
scattered ``pwrite``.

``use_splice`` starts the kernel-side socket -> pipe -> file path; like
the MT engine it is ADAPTIVE — a per-child ``SpliceArbiter`` measures a
splice window against a pool window and the faster path keeps the
stream (a measured switch is counted in ``splice_autodisables``).
"""
from __future__ import annotations

import json
import os
import socket
import struct
from typing import List, Optional

from repro.core.autotune import SpliceArbiter
from repro.core.engines.base import (
    ACK,
    END_EVENTS,
    SPLICE,
    RecvStats,
    Sink,
    SlabChannel,
    Source,
    SpliceReceiver,
    SpliceUnsupported,
    recv_exact,
    send_all,
    slab_span,
)
from repro.core.engines.mt import worker_send
from repro.core.engines.registry import Engine, register_engine
from repro.core.integrity import block_crc
from repro.core.header import (
    CRC_TRAILER,
    FLAG_BLOCK_CRC,
    HEADER_SIZE,
    TRAILER_SIZE,
    ChannelEvent,
    ChannelHeader,
    ProtocolError,
)


def _child_receive(s, wsink: Sink, block_size: int, use_splice: bool,
                   batch_frames: int, arbiter_factory,
                   io_timeout: Optional[float] = None) -> dict:
    """One forked channel's receive loop; returns its counters (including
    the verified ``crcs`` records, since the manifest lives in the parent)."""
    from repro.core.ringbuf import RecvBufferPool, RecvSlab

    child = {"bytes": 0, "eofr": 0, "eoft": 0, "splice": 0,
             "recv_calls": 0, "autodisables": 0, "crcs": [],
             "crc_mismatches": 0}
    hdr_buf = memoryview(bytearray(HEADER_SIZE))
    trl_buf = memoryview(bytearray(TRAILER_SIZE))
    batched = batch_frames > 1
    sc = (SlabChannel(RecvSlab(slab_span(batch_frames, block_size)),
                      block_size) if batched else None)
    pool = None if batched else RecvBufferPool(2, block_size)
    spl = arb = None
    if use_splice and SPLICE and wsink.file_backed:
        try:
            spl = SpliceReceiver()
            arb = (arbiter_factory() if arbiter_factory is not None
                   else SpliceArbiter())
        except SpliceUnsupported:
            spl = None
    if io_timeout is not None and spl is None:
        # settimeout makes the fd non-blocking, which os.splice cannot
        # tolerate — deadlines only cover the recv paths
        s.settimeout(io_timeout)

    def note(nbytes):
        if arb is not None and arb.note(nbytes):
            if arb.measured_switch and spl is not None and spl.ok:
                child["autodisables"] += 1

    def end_frame(event) -> None:
        child["eofr" if event == ChannelEvent.EOFR else "eoft"] += 1

    def flush_slab():
        for off, mv in sc.take_pending():
            # GridFTP-faithful: every fragment is its own scattered pwrite
            wsink.write_at(off, mv)
        # a frame's chunks always precede its trailer, so every verified
        # frame is fully on disk once the pending list drained
        child["crcs"].extend(sc.take_verified())
        sc.compact()

    try:
        carry, resume = b"", None
        while True:
            if arb is not None and arb.use_splice:
                # ---- per-frame kernel-side phase ----
                if resume is not None:
                    off, left = resume
                    child["splice"] += spl.splice_block(
                        s, wsink.fileno(), off, left)
                    child["bytes"] += left
                    note(left)
                    resume = None
                    if not spl.ok:
                        arb.force_pool()
                        continue
                if carry:
                    hdr_buf[:len(carry)] = carry
                    recv_exact(s, HEADER_SIZE - len(carry),
                               hdr_buf[len(carry):])
                    carry = b""
                else:
                    recv_exact(s, HEADER_SIZE, hdr_buf)
                hdr = ChannelHeader.unpack(hdr_buf)
                if hdr.event in END_EVENTS:
                    end_frame(hdr.event)
                    return child
                if hdr.length > block_size:
                    raise ProtocolError(
                        f"block of {hdr.length} bytes exceeds negotiated "
                        f"block_size {block_size}"
                    )
                try:
                    child["splice"] += spl.splice_block(
                        s, wsink.fileno(), hdr.offset, hdr.length)
                except SpliceUnsupported:
                    arb.force_pool()  # nothing consumed; pool path resumes
                    resume = (hdr.offset, hdr.length)
                    continue
                if hdr.flags & FLAG_BLOCK_CRC:
                    # payload moved kernel-side: nothing to checksum, just
                    # drain the trailer to stay framed
                    recv_exact(s, TRAILER_SIZE, trl_buf)
                child["bytes"] += hdr.length
                note(hdr.length)
                if not spl.ok:
                    arb.force_pool()
            elif batched:
                # ---- slab phase: many frames per recv_into ----
                sc.seed(carry, *(resume or (0, 0)))
                carry, resume = b"", None
                last = sc.bytes
                while True:
                    if sc.free_space() == 0:
                        flush_slab()
                    sc.receive_once(s)
                    note(sc.bytes - last)
                    last = sc.bytes
                    if sc.end_event is not None:
                        flush_slab()
                        end_frame(sc.end_event)
                        child["bytes"] += sc.bytes
                        child["recv_calls"] += sc.recv_calls
                        child["crc_mismatches"] += sc.crc_mismatches
                        return child
                    if arb is not None and arb.decided and arb.chose_splice:
                        flush_slab()
                        tail, _hdr, off, left = sc.handoff()
                        carry = tail
                        resume = (off, left) if left else None
                        child["bytes"] += sc.bytes
                        child["recv_calls"] += sc.recv_calls
                        child["crc_mismatches"] += sc.crc_mismatches
                        sc.bytes = sc.recv_calls = sc.crc_mismatches = 0
                        break
            else:
                # ---- per-frame private-pool phase ----
                if resume is not None:
                    off, left = resume
                    slot = pool.acquire()
                    recv_exact(s, left, pool.view(slot))
                    wsink.write_at(off, pool.view(slot)[:left])
                    pool.release(slot)
                    child["bytes"] += left
                    note(left)
                    resume = None
                recv_exact(s, HEADER_SIZE, hdr_buf)
                hdr = ChannelHeader.unpack(hdr_buf)
                if hdr.event in END_EVENTS:
                    end_frame(hdr.event)
                    return child
                if hdr.length > block_size:
                    raise ProtocolError(
                        f"block of {hdr.length} bytes exceeds negotiated "
                        f"block_size {block_size}"
                    )
                if arb is not None and arb.use_splice:
                    resume = (hdr.offset, hdr.length)
                    continue  # arbiter flipped back mid-stream
                slot = pool.acquire()
                recv_exact(s, hdr.length, pool.view(slot))
                if hdr.flags & FLAG_BLOCK_CRC:
                    recv_exact(s, TRAILER_SIZE, trl_buf)
                    want = CRC_TRAILER.unpack(trl_buf)[0]
                    got = block_crc(pool.view(slot)[: hdr.length])
                    if got != want:
                        # corrupt block: drop it (the manifest hole drives
                        # a RESUME re-fetch); the stream itself stays framed
                        pool.release(slot)
                        child["bytes"] += hdr.length
                        child["crc_mismatches"] += 1
                        note(hdr.length)
                        continue
                    wsink.write_at(hdr.offset, pool.view(slot)[: hdr.length])
                    child["crcs"].append((hdr.offset, hdr.length, want))
                    pool.release(slot)
                    child["bytes"] += hdr.length
                    note(hdr.length)
                    continue
                wsink.write_at(hdr.offset, pool.view(slot)[: hdr.length])
                pool.release(slot)
                child["bytes"] += hdr.length
                note(hdr.length)
    finally:
        if spl is not None:
            spl.close()


def _write_msg(fd: int, payload: bytes) -> None:
    """Length-prefixed write (loops: a big crcs list exceeds PIPE_BUF)."""
    data = struct.pack("<Q", len(payload)) + payload
    off = 0
    while off < len(data):
        off += os.write(fd, data[off:])


def _read_msg(fd: int) -> bytes:
    """Read one length-prefixed message. Exact-count framing, NOT
    read-to-EOF: other threads of this process fork too (the in-process
    server's mp sender children), and their children inherit this pipe's
    write end — an EOF wait would deadlock against a sender child that is
    itself blocked waiting for the ACK this read gates."""
    chunks: List[bytes] = []
    need = 8
    while need:
        part = os.read(fd, need)
        if not part:
            return b""  # child died before reporting
        chunks.append(part)
        need -= len(part)
    (length,) = struct.unpack("<Q", b"".join(chunks))
    chunks, need = [], length
    while need:
        part = os.read(fd, min(need, 65536))
        if not part:
            return b""
        chunks.append(part)
        need -= len(part)
    return b"".join(chunks)


def mp_receive(
    socks: List[socket.socket],
    sink: Sink,
    block_size: int,
    reusable: bool = False,
    use_splice: bool = False,
    batch_frames: int = 1,
    arbiter_factory=None,
    crc_acc=None,
    io_timeout: Optional[float] = None,
) -> RecvStats:
    """MP model (GridFTP-like): fork per channel, n file handles, per-block
    pwrite at scattered offsets — no coalescing, no shared state. Per-child
    counters (and verified CRC records, merged into ``crc_acc``) travel back
    over a pipe and are summed into the parent stats. A failed child reports
    a typed error record so timeouts surface as ``TimeoutError`` in the
    parent, not a bare exit code."""
    if sink.capture:
        raise ValueError("mp engine cannot receive into a capture sink "
                         "(forked children do not share parent memory)")
    stats = RecvStats()
    procs = []
    for s in socks:
        r_cnt, w_cnt = os.pipe()
        pid = os.fork()
        if pid == 0:  # child
            os.close(r_cnt)
            try:
                wsink = sink.open_worker()
                child = _child_receive(s, wsink, block_size, use_splice,
                                       batch_frames, arbiter_factory,
                                       io_timeout)
                wsink.close()
                _write_msg(w_cnt, json.dumps(child).encode())
                os.close(w_cnt)
                # the PARENT acks after reaping every child and committing
                # the sink — a child acking its own stripe could promise
                # durability for bytes a sibling then fails to land
                os._exit(0)
            except BaseException as e:  # noqa: BLE001 - reported over pipe
                kind = ("timeout" if isinstance(e, TimeoutError)
                        else "protocol" if isinstance(e, ProtocolError)
                        else "other")
                try:
                    _write_msg(w_cnt, json.dumps(
                        {"error": str(e) or type(e).__name__,
                         "kind": kind}).encode())
                    os.close(w_cnt)
                except OSError:
                    pass
                os._exit(1)
        os.close(w_cnt)
        procs.append((pid, r_cnt))
    failure = None
    for pid, r_cnt in procs:
        raw = _read_msg(r_cnt)
        os.close(r_cnt)
        _, status = os.waitpid(pid, 0)
        if os.waitstatus_to_exitcode(status) != 0:
            if failure is None:
                try:
                    err = json.loads(raw.decode())
                except (ValueError, UnicodeDecodeError):
                    err = {}
                msg = err.get("error", "mp receiver child failed")
                kind = err.get("kind", "other")
                failure = (TimeoutError(msg) if kind == "timeout"
                           else ProtocolError(msg) if kind == "protocol"
                           else RuntimeError(msg))
            continue  # keep reaping siblings before raising
        child = json.loads(raw.decode())
        stats.bytes += child["bytes"]
        stats.eofr_frames += child["eofr"]
        stats.eoft_frames += child["eoft"]
        stats.splice_bytes += child.get("splice", 0)
        stats.recv_calls += child.get("recv_calls", 0)
        stats.splice_autodisables += child.get("autodisables", 0)
        stats.crc_mismatches += child.get("crc_mismatches", 0)
        if crc_acc is not None:
            for off, ln, crc in child.get("crcs", ()):
                crc_acc.add(off, ln, crc)
    if failure is not None:
        raise failure
    # fsync(fd) flushes the whole inode, so the parent's commit covers
    # every child's writes to the shared (temp) path
    sink.commit()
    for s in socks:
        s.settimeout(io_timeout)
        send_all(s, ACK)
    return stats


def _receive(socks, sink, block_size, *, pool_slots=32, fsm=None,
             conformance=True, reusable=False, pool=None, splice=False,
             batch_frames=1, slabs=None, crc_acc=None, io_timeout=None):
    return mp_receive(socks, sink, block_size, reusable=reusable,
                      use_splice=splice, batch_frames=batch_frames,
                      crc_acc=crc_acc, io_timeout=io_timeout)


def _send(socks, source, session, *, reusable=False, batch_frames=1,
          integrity=False, blocks=None, io_timeout=None, crc_out=None):
    # fork-mode workers can't report their trailer CRCs back to the
    # parent: crc_out is accepted for signature uniformity but stays
    # empty, and callers fall back to a serial whole-file pass
    return worker_send(socks, source, session, use_processes=True,
                       reusable=reusable, batch_frames=batch_frames,
                       integrity=integrity, blocks=blocks,
                       io_timeout=io_timeout)


ENGINE = register_engine(Engine(
    "mp", _receive, _send,
    "multi-processed (GridFTP-like baseline): fork per channel, private "
    "file handles, scattered per-block pwrite",
))
