"""Pluggable engine registry.

An ``Engine`` bundles the receive + send halves of one server architecture
(the paper's §2.5 MTEDP / MT / MP designs). Engines self-register at import
time via :func:`register_engine`; the session layer dispatches by name, so
new architectures (e.g. a hybrid xThread/xDFS server, Table 4) plug in
without touching the protocol code.

Uniform callable signatures:

  receive(socks, sink, block_size, *, pool_slots=32, fsm=None,
          conformance=True, reusable=False, pool=None, splice=False,
          batch_frames=1, slabs=None) -> RecvStats
  send(socks, source, session, *, reusable=False, batch_frames=1,
       integrity=False, blocks=None, io_timeout=None,
       crc_out=None) -> int  (bytes on the wire)

``crc_out`` is an optional caller-owned dict the sender fills with the
``block_index -> crc32`` trailer values it computes under ``integrity``
(fork-based senders leave it incomplete; callers fall back to a serial
whole-file pass).

``pool`` is an optional caller-owned registered ``RecvBufferPool`` reused
across a session's files (engines that don't pool blocks ignore it).

``reusable=True`` ends each channel's file stream with ``EOFR`` (channel
stays open for the next file of the session) instead of ``EOFT``.

``splice=True`` opts the receive side into the kernel-side
socket->pipe->file ``os.splice`` fast path where the engine supports it
(blocking receivers, file-backed sinks); engines that can't splice accept
and ignore the flag. The opt-in is ADAPTIVE: a goodput arbiter
(core/autotune.py) measures splice against the pool path mid-session and
keeps the faster one.

``batch_frames`` is the session-negotiated ceiling on frames per
scatter-gather syscall batch (1 = the per-frame legacy datapath); above 1
senders hill-climb their actual depth and receivers run the slab
datapath. ``slabs`` optionally carries the session-owned ``SlabSet``
(per-channel registered slabs reused across files); engines that don't
batch ignore both.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List


class UnknownEngineError(ValueError):
    """Raised when a transfer engine name is not in the registry."""


@dataclass(frozen=True)
class Engine:
    name: str
    receive: Callable[..., "RecvStats"]  # noqa: F821 - see base.RecvStats
    send: Callable[..., int]
    description: str = ""
    uses_pool: bool = False  # receive() consumes the caller-owned recv pool
    # receive() livelocks unless pool_slots > n_channels (a nonblocking
    # event loop whose every slot can be pinned by a partial block); the
    # session layer refuses such configurations up front
    pool_livelock_guard: bool = False


_REGISTRY: Dict[str, Engine] = {}


def register_engine(engine: Engine, *aliases: str) -> Engine:
    """Register ``engine`` under its name (and any aliases). Re-registering
    a name replaces the previous engine (lets tests/users override)."""
    for name in (engine.name, *aliases):
        _REGISTRY[name] = engine
    return engine


def get_engine(name: str) -> Engine:
    if isinstance(name, Engine):
        return name
    try:
        return _REGISTRY[name]
    except KeyError:
        raise UnknownEngineError(
            f"unknown transfer engine {name!r}; "
            f"available engines: {', '.join(sorted(_REGISTRY))}"
        ) from None


def available_engines() -> List[str]:
    return sorted(_REGISTRY)
