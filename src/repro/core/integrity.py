"""End-to-end wire integrity (DotDFS-style per-block CRC + file manifest).

The integrity datapath is negotiated per session (``Negotiation.integrity``)
and rides the existing frame format without a new event:

* every DATA frame sets ``FLAG_BLOCK_CRC`` in the header flag byte and
  appends a 4-byte little-endian CRC32 trailer of the payload — frames are
  self-describing, so receivers verify whenever the bit is set;
* receivers accumulate verified ``(offset, length, crc)`` triples into a
  :class:`CrcManifest` **after the block's bytes land on disk** (flush
  time, not parse time — a crash must never leave the manifest claiming
  bytes that were still buffered);
* at end of file the two sides compare whole-file CRCs:
  :meth:`CrcManifest.file_crc` folds the per-block CRCs with
  :func:`crc32_combine` (the GF(2) matrix trick, so the fold equals
  ``zlib.crc32`` over the concatenated file) and raises
  :class:`IntegrityError` on any hole or overlap.

A trailer mismatch is NOT fatal to the session: the receiver skips the
block (it never reaches the manifest), keeps the stream synced — the
trailer is length-framed like the payload — and the end-of-file manifest
check reports the gap, which the RESUME flow then re-fetches.
"""
from __future__ import annotations

import ctypes
import ctypes.util
import functools
import zlib
from typing import Callable, Dict, Iterable, List, Optional, Tuple

from repro.core.header import ProtocolError

CRC_POLY = 0xEDB88320  # reflected CRC-32 (IEEE 802.3), zlib's polynomial

# ``zlib.crc32`` computes at ~1 GB/s while HOLDING the GIL for
# block-sized buffers — on the wire that is the whole transfer budget
# spent twice (once per endpoint). Both libdeflate and libz export the
# same reflected CRC-32 with zlib's continuation semantics; calling them
# through ctypes releases the GIL for the duration, and libdeflate's
# PCLMUL/SSE kernels run an order of magnitude faster than zlib's
# table walk. Preference: libdeflate > libz > pure zlib fallback.


def _load_native_crc32():
    """``(gil_holding, gil_releasing)`` handles to the same native CRC.

    Block-sized calls (~6µs of compute at libdeflate speed) go through
    the PyDLL handle, which keeps the GIL: releasing it for a call that
    short costs far more than it saves — with other runnable threads the
    reacquire waits out their timeslices, and measured per-call latency
    ballooned from ~7µs to ~36µs in the live datapath. The CDLL handle
    releases the GIL and is reserved for long whole-file passes where
    overlap actually pays."""
    for name, sym, argtypes in (
        ("libdeflate.so.0", "libdeflate_crc32",
         (ctypes.c_uint32, ctypes.c_void_p, ctypes.c_size_t)),
        (ctypes.util.find_library("deflate"), "libdeflate_crc32",
         (ctypes.c_uint32, ctypes.c_void_p, ctypes.c_size_t)),
        (ctypes.util.find_library("z") or "libz.so.1", "crc32",
         (ctypes.c_ulong, ctypes.c_void_p, ctypes.c_uint)),
    ):
        if not name:
            continue
        try:
            fns = []
            for loader in (ctypes.PyDLL, ctypes.CDLL):
                fn = getattr(loader(name), sym)
                fn.restype = argtypes[0]
                fn.argtypes = argtypes
                if fn(0, b"123456789", 9) & 0xFFFFFFFF != 0xCBF43926:
                    raise AttributeError(f"{sym} check value mismatch")
                fns.append(fn)
            return tuple(fns)
        except (OSError, AttributeError):
            continue
    return None, None


_native_crc32, _native_crc32_nogil = _load_native_crc32()

# release the GIL only for passes at least this long (whole-file CRCs);
# block-sized calls hold it — see _load_native_crc32
_GIL_RELEASE_MIN = 1 << 20

try:
    import numpy as _np  # zero-copy address of READONLY views (mmap sources)
except ImportError:  # pragma: no cover - numpy ships with the toolchain
    _np = None

# below this the ctypes call overhead beats the native win; zlib handles it
_MIN_NATIVE = 1 << 12


HAVE_NATIVE_CRC = _native_crc32 is not None


def buffer_address(view) -> Optional[int]:
    """Base address of a contiguous buffer, or ``None`` when it can't be
    extracted. The address is only valid while the OWNER keeps the backing
    memory alive and unmoved — use for long-lived fixed buffers (receive
    slabs, mmaps) where computing it ONCE amortizes the ~3µs/call ctypes
    extraction that :func:`crc32_update` otherwise pays per block."""
    buf = view if isinstance(view, memoryview) else memoryview(view)
    if buf.nbytes == 0 or not buf.contiguous:
        return None
    if not buf.readonly:
        try:
            return ctypes.addressof(
                (ctypes.c_char * buf.nbytes).from_buffer(buf))
        except (TypeError, ValueError):
            pass
    if _np is not None:
        try:
            # ~2us cheaper per call than the arr.ctypes accessor
            return _np.frombuffer(buf, _np.uint8).__array_interface__["data"][0]
        except (TypeError, ValueError):
            pass
    return None


def crc32_update_at(crc: int, addr: int, n: int) -> int:
    """Native CRC straight from a raw address (no per-call buffer
    bookkeeping). Caller guarantees ``HAVE_NATIVE_CRC`` and that
    ``[addr, addr+n)`` stays alive across the call."""
    fn = _native_crc32_nogil if n >= _GIL_RELEASE_MIN else _native_crc32
    return fn(crc & 0xFFFFFFFF, addr, n) & 0xFFFFFFFF


def crc32_update(crc: int, view) -> int:
    """``zlib.crc32(view, crc)``, via the fast native path for
    block-sized buffers (GIL-releasing only for whole-file passes)."""
    buf = view if isinstance(view, memoryview) else memoryview(view)
    n = buf.nbytes
    if _native_crc32 is not None and n >= _MIN_NATIVE and buf.contiguous:
        addr = buffer_address(buf)
        if addr is not None:
            # buf pins the memory across the call
            return crc32_update_at(crc, addr, n)
    return zlib.crc32(buf, crc) & 0xFFFFFFFF


class IntegrityError(ProtocolError):
    """Verified-data mismatch: a CRC trailer or the file manifest failed."""


def _gf2_matrix_times(mat: List[int], vec: int) -> int:
    out = 0
    i = 0
    while vec:
        if vec & 1:
            out ^= mat[i]
        vec >>= 1
        i += 1
    return out


def _gf2_matrix_square(square: List[int], mat: List[int]) -> None:
    for i in range(32):
        square[i] = _gf2_matrix_times(mat, mat[i])


def _gf2_matrix_mult(a: List[int], b) -> List[int]:
    """Compose two operators: column ``i`` of the product is ``a`` applied
    to column ``i`` of ``b`` (zlib's column-vector matrix convention)."""
    return [_gf2_matrix_times(a, b[i]) for i in range(32)]


@functools.lru_cache(maxsize=256)
def _zero_operator(len2: int) -> Tuple[int, ...]:
    """The GF(2) operator that advances a CRC through ``len2`` zero bytes,
    built once by repeated matrix squaring and memoized.

    Manifest folds combine hundreds of equal-length blocks, so caching per
    distinct length turns each fold step from ~34 pure-Python 32x32 matrix
    squarings into one 32-op matrix-vector product — without the cache the
    fold dominated the whole transfer (a ~20x throughput collapse)."""
    even = [0] * 32  # operator for 2^k zero bytes (even k)
    odd = [0] * 32   # ... and odd k
    odd[0] = CRC_POLY
    row = 1
    for i in range(1, 32):
        odd[i] = row
        row <<= 1
    _gf2_matrix_square(even, odd)   # odd  -> 2 zero bytes
    _gf2_matrix_square(odd, even)   # even -> 4 zero bytes
    op: Optional[List[int]] = None
    while True:
        _gf2_matrix_square(even, odd)
        if len2 & 1:
            # powers of one base matrix commute, so accumulation order
            # doesn't matter
            op = even[:] if op is None else _gf2_matrix_mult(even, op)
        len2 >>= 1
        if not len2:
            break
        _gf2_matrix_square(odd, even)
        if len2 & 1:
            op = odd[:] if op is None else _gf2_matrix_mult(odd, op)
        len2 >>= 1
        if not len2:
            break
    return tuple(op)


@functools.lru_cache(maxsize=64)
def _zero_tables(len2: int) -> Tuple[Tuple[int, ...], ...]:
    """Byte-indexed lookup tables of :func:`_zero_operator`: applying the
    operator becomes 4 table hits + XOR (sub-microsecond) instead of a
    32-step matrix-vector product — manifest folds run one application
    per block, so this is the fold's inner loop."""
    op = _zero_operator(len2)
    return tuple(
        tuple(_gf2_matrix_times(op, v << (8 * j)) for v in range(256))
        for j in range(4)
    )


def crc32_combine(crc1: int, crc2: int, len2: int) -> int:
    """CRC32 of ``A + B`` given ``crc32(A)``, ``crc32(B)`` and ``len(B)``.

    Port of zlib's ``crc32_combine``: advancing a CRC through ``len2``
    zero bytes is a linear operator over GF(2) — O(log len2) instead of
    hashing ``len2`` bytes, with byte-indexed tables cached per length.
    """
    if len2 <= 0:
        return crc1 & 0xFFFFFFFF
    t0, t1, t2, t3 = _zero_tables(len2)
    crc1 &= 0xFFFFFFFF
    return (t0[crc1 & 0xFF] ^ t1[(crc1 >> 8) & 0xFF]
            ^ t2[(crc1 >> 16) & 0xFF] ^ t3[crc1 >> 24] ^ crc2) & 0xFFFFFFFF


def block_crc(view) -> int:
    """CRC32 of one block's bytes (buffer/memoryview safe, GIL-releasing
    for block-sized buffers — see :func:`crc32_update`)."""
    return crc32_update(0, view)


class CrcManifest:
    """Verified block map of one file: ``offset -> (length, crc32)``.

    ``add`` is called by receive engines once a verified block's bytes are
    durable (post-``pwritev``); ``autosave`` (if set) fires every
    ``autosave_every`` additions so a crash leaves a recent sidecar behind.
    """

    __slots__ = ("blocks", "autosave", "autosave_every", "_since_save")

    def __init__(self, autosave: Optional[Callable[["CrcManifest"], None]] = None,
                 autosave_every: int = 64):
        self.blocks: Dict[int, Tuple[int, int]] = {}
        self.autosave = autosave
        self.autosave_every = autosave_every
        self._since_save = 0

    def __len__(self) -> int:
        return len(self.blocks)

    def __contains__(self, offset: int) -> bool:
        return offset in self.blocks

    def add(self, offset: int, length: int, crc: int) -> None:
        self.blocks[offset] = (length, crc & 0xFFFFFFFF)
        if self.autosave is not None:
            self._since_save += 1
            if self._since_save >= self.autosave_every:
                self._since_save = 0
                self.autosave(self)

    def add_many(self, triples: Iterable[Tuple[int, int, int]]) -> None:
        for off, length, crc in triples:
            self.add(off, length, crc)

    def merge(self, other: "CrcManifest") -> None:
        """Fold ``other``'s blocks in without clobbering newer entries."""
        for off, (length, crc) in other.blocks.items():
            self.blocks.setdefault(off, (length, crc))

    def missing(self, size: int, block_size: int) -> List[int]:
        """Block offsets of ``size`` bytes NOT covered by the manifest
        (covered = present with the exact expected length)."""
        out = []
        for off in range(0, size, block_size):
            want = min(block_size, size - off)
            got = self.blocks.get(off)
            if got is None or got[0] != want:
                out.append(off)
        if size == 0 and not self.blocks:
            return []
        return out

    def file_crc(self, size: int) -> int:
        """Whole-file CRC32 folded from the per-block CRCs.

        Raises :class:`IntegrityError` unless the blocks tile
        ``[0, size)`` exactly — any hole, overlap, or overhang means the
        file on disk is NOT fully verified.
        """
        pos = 0
        crc = 0
        for off in sorted(self.blocks):
            length, bcrc = self.blocks[off]
            if off != pos:
                raise IntegrityError(
                    f"manifest hole: verified up to {pos}, next block at {off}")
            crc = crc32_combine(crc, bcrc, length)
            pos += length
        if pos != size:
            raise IntegrityError(
                f"manifest covers {pos} of {size} bytes")
        return crc
