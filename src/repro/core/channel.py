"""Device-side xDFS channels: chunked, pipelined ring collectives.

The paper's session/channel schedule mapped onto ICI:

  * a transfer session = one collective over a mesh axis;
  * n parallel channels = concurrent chunk streams — on a TPU torus the
    physical parallelism is the two ring directions, so ``bidirectional=True``
    runs two counter-rotating rings (2 channels) whose ppermutes XLA
    schedules concurrently;
  * block headers (offset, length) = static chunk indices in the unrolled
    ring schedule;
  * ZxDFS compressed channels = int8 payload codec per hop (core/compress);
  * MTEDP pipelining = chunk k+1's ppermute overlaps chunk k's local
    reduction under XLA async scheduling.

All functions are called INSIDE shard_map over ``axis_name``. Equivalence
against lax.psum / lax.all_gather is property-tested (tests/test_channel.py).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.compress import Int8Codec, NullCodec, Quantized


def _ring_perm(n: int, step_dir: int):
    return [(i, (i + step_dir) % n) for i in range(n)]


def _permute_payload(acc, axis_name, perm, codec):
    """One channel hop: encode -> ppermute -> decode."""
    if codec is None or codec is NullCodec:
        return lax.ppermute(acc, axis_name, perm)
    z = codec.encode(acc)
    q = lax.ppermute(z.q, axis_name, perm)
    s = lax.ppermute(z.scale, axis_name, perm)
    return codec.decode(Quantized(q, s, z.orig_size, z.orig_shape)).astype(acc.dtype)


def ring_reduce_scatter(x, axis_name: str, *, reverse: bool = False, codec=None):
    """Ring reduce-scatter. x: local (N, ...), N divisible by axis size n.

    n-1 hops; each hop moves one block (one xDFS frame: header = chunk id)
    to the ring neighbour and folds in the local chunk. Device i ends with
    the fully-reduced chunk (i + dir) mod n.
    """
    n = lax.axis_size(axis_name)
    if n == 1:
        return x
    idx = lax.axis_index(axis_name)
    chunks = x.reshape((n, x.shape[0] // n) + x.shape[1:])
    d = -1 if reverse else 1
    perm = _ring_perm(n, d)

    def hop(acc, s):
        recv = _permute_payload(acc, axis_name, perm, codec)
        nxt = (idx - d * (s + 1)) % n
        acc = (
            recv.astype(jnp.float32)
            + jnp.take(chunks, nxt, axis=0).astype(jnp.float32)
        ).astype(x.dtype)
        return acc, None

    acc0 = jnp.take(chunks, idx % n, axis=0)
    acc, _ = lax.scan(hop, acc0, jnp.arange(n - 1))
    return acc


def ring_all_gather(shard, axis_name: str, *, reverse: bool = False,
                    chunk_of=None):
    """Ring all-gather of reduced shards back into chunk order.

    ``chunk_of(idx)`` maps a device to the chunk id it holds (defaults to the
    reduce-scatter convention (idx + dir) mod n). Returns (n*M, ...) in
    chunk order 0..n-1.
    """
    n = lax.axis_size(axis_name)
    if n == 1:
        return shard
    idx = lax.axis_index(axis_name)
    d = -1 if reverse else 1
    perm = _ring_perm(n, d)
    if chunk_of is None:
        chunk_of = lambda dev: (dev + d) % n
    out = jnp.zeros((n,) + shard.shape, shard.dtype)

    def hop(carry, s):
        out_acc, blk = carry
        # at step s my block originated at device (idx - d*s)
        src_chunk = chunk_of((idx - d * s) % n) % n
        out_acc = jax.lax.dynamic_update_index_in_dim(
            out_acc, blk, src_chunk, axis=0
        )
        blk = lax.ppermute(blk, axis_name, perm)
        return (out_acc, blk), None

    (out, _), _ = lax.scan(hop, (out, shard), jnp.arange(n))
    return out.reshape((n * shard.shape[0],) + shard.shape[1:])


def ring_all_reduce(x, axis_name: str, *, codec=None, bidirectional: bool = True):
    """Chunked ring all-reduce (reduce-scatter + all-gather).

    bidirectional=True splits the payload across two counter-rotating rings
    (two parallel channels, saturating both torus link directions).
    """
    n = lax.axis_size(axis_name)
    if n == 1:
        return x
    shape, size = x.shape, x.size
    flat = x.reshape(-1)
    lanes = 2 if bidirectional else 1
    pad = (-size) % (lanes * n)
    flat = jnp.pad(flat, (0, pad))

    def one_ring(part, reverse):
        rs = ring_reduce_scatter(part, axis_name, reverse=reverse, codec=codec)
        return ring_all_gather(rs, axis_name, reverse=reverse)

    if bidirectional:
        half = flat.size // 2
        out = jnp.concatenate(
            [one_ring(flat[:half], False), one_ring(flat[half:], True)]
        )
    else:
        out = one_ring(flat, False)
    return out[:size].reshape(shape)


def stream_broadcast(x, axis_name: str, *, src: int = 0):
    """Pipelined one-to-all relay broadcast (xFTSM download mode): the
    payload travels hop-by-hop around the ring; each device keeps a copy as
    it passes through. n-1 hops, each link carries the payload once —
    bandwidth-optimal on a ring."""
    n = lax.axis_size(axis_name)
    if n == 1:
        return x
    idx = lax.axis_index(axis_name)
    perm = _ring_perm(n, 1)
    have = jnp.where(idx == src, x, jnp.zeros_like(x))

    def hop(carry, s):
        recv = lax.ppermute(carry, axis_name, perm)
        just_arrived = idx == (src + s + 1) % n
        keep = jnp.where(just_arrived, recv, carry)
        return keep, None

    out, _ = lax.scan(hop, have, jnp.arange(n - 1))
    return out


def xdfs_psum_tree(tree, axis_name: str, *, compress: bool = False):
    """Gradient-push channel (FTSM upload) over a pytree."""
    codec = Int8Codec if compress else None
    return jax.tree.map(lambda g: ring_all_reduce(g, axis_name, codec=codec), tree)
