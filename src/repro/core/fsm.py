"""Communicating finite state machines for the xDFS protocol (Figs. 8-11).

The paper specifies xDFS with CFSMs: a protocol = a set of FSMs exchanging
messages over FIFO channels; validation / synthesis / conformance testing all
hang off the explicit transition relation. Here the machines are EXECUTABLE:
the transfer engines drive them for every channel and any illegal transition
raises — i.e. runtime conformance checking — and the same tables power the
property tests (tests/test_fsm.py) and the fault-tolerance supervisor
(runtime/fault.py reuses the Machine class).

States follow the paper's server/client download/upload CFSMs, with the
read-readiness bookkeeping (Done / NotDone / FirstTime) modeled as socket
tags exactly as described in §4.1.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Callable, Dict, FrozenSet, Iterable, Optional, Tuple


class FSMError(RuntimeError):
    pass


@dataclass
class Machine:
    """A finite state machine with an explicit transition relation."""

    name: str
    states: FrozenSet[str]
    initial: str
    finals: FrozenSet[str]
    # (state, event) -> next state
    transitions: Dict[Tuple[str, str], str]
    state: str = ""
    trace: list = field(default_factory=list)

    def __post_init__(self):
        self.state = self.state or self.initial
        for (s, _e), t in self.transitions.items():
            if s not in self.states or t not in self.states:
                raise FSMError(f"{self.name}: transition {s}->{t} uses unknown state")

    def step(self, event: str) -> str:
        key = (self.state, event)
        if key not in self.transitions:
            raise FSMError(
                f"{self.name}: illegal event {event!r} in state {self.state!r}"
            )
        self.trace.append((self.state, event))
        self.state = self.transitions[key]
        return self.state

    def can(self, event: str) -> bool:
        return (self.state, event) in self.transitions

    @property
    def done(self) -> bool:
        return self.state in self.finals

    def events_from(self, state: Optional[str] = None) -> Iterable[str]:
        s = state or self.state
        return [e for (st, e) in self.transitions if st == s]

    def reset(self):
        self.state = self.initial
        self.trace.clear()


# ---------------------------------------------------------------------------
# Socket readiness tags (paper §4.1: Done / NotDone / FirstTime)
# ---------------------------------------------------------------------------


class ReadyTag(enum.Enum):
    FIRST_TIME = "FirstTime"
    DONE = "Done"
    NOT_DONE = "NotDone"


# ---------------------------------------------------------------------------
# xFTSM machines (paper Figs. 8-11). State names mirror the figures:
# numbered stages with descriptive suffixes.
# ---------------------------------------------------------------------------


def server_download_fsm() -> Machine:
    """Fig. 8 — server side, download (server reads disk, sends to client)."""
    states = frozenset({
        "1_accept", "2_auth", "3_mode", "4_params", "5_session_lookup",
        "6_register_channel", "7_await_channels", "9_open_file",
        "10_dispatch", "12_send_blocks", "15_eof_check", "16_send_eof",
        "17_drain", "18_end", "err",
    })
    t = {
        ("1_accept", "conn"): "2_auth",
        ("2_auth", "auth_ok"): "3_mode",
        ("3_mode", "ftsm"): "4_params",
        ("4_params", "params_ok"): "5_session_lookup",
        ("5_session_lookup", "new_session"): "6_register_channel",
        ("5_session_lookup", "known_session"): "6_register_channel",
        ("6_register_channel", "registered"): "7_await_channels",
        ("7_await_channels", "more_channels"): "1_accept",
        ("7_await_channels", "all_channels"): "9_open_file",
        ("9_open_file", "opened"): "10_dispatch",
        # RESUME (interrupted-transfer recovery): re-open the file and
        # dispatch only the blocks the requester is missing
        ("9_open_file", "resume"): "10_dispatch",
        ("10_dispatch", "write_ready"): "12_send_blocks",
        ("12_send_blocks", "block_sent"): "10_dispatch",
        ("10_dispatch", "eof_reached"): "15_eof_check",
        ("15_eof_check", "pending_data"): "10_dispatch",
        ("15_eof_check", "all_sent"): "16_send_eof",
        ("16_send_eof", "eof_headers_sent"): "17_drain",
        ("17_drain", "drained"): "18_end",
        # multi-file session loop (EOFR, Table 3): the drained channel set
        # stays open and the machine re-arms for the next file of the session
        ("17_drain", "drained_reusable"): "9_open_file",
        ("9_open_file", "eoft"): "18_end",  # client terminates the session
    }
    for s in list(states - {"18_end", "err"}):
        t[(s, "error")] = "err"
    t[("err", "handled")] = "18_end"
    return Machine("server_download", states, "1_accept", frozenset({"18_end"}), t)


def client_download_fsm() -> Machine:
    """Fig. 9 — client side, download (client receives, writes local disk)."""
    states = frozenset({
        "1_connect", "2_auth", "3_request", "5_await_channels", "6_dispatch",
        "7_recv_block", "8_eof_check", "10_write_disk", "12_end", "err",
    })
    t = {
        ("1_connect", "connected"): "2_auth",
        ("2_auth", "auth_ok"): "3_request",
        ("3_request", "request_sent"): "5_await_channels",
        ("5_await_channels", "more_channels"): "1_connect",
        ("5_await_channels", "all_channels"): "6_dispatch",
        ("6_dispatch", "read_ready"): "7_recv_block",
        ("7_recv_block", "block"): "10_write_disk",
        ("7_recv_block", "eof_header"): "8_eof_check",
        ("10_write_disk", "written"): "6_dispatch",
        ("8_eof_check", "channels_open"): "6_dispatch",
        ("8_eof_check", "all_eof"): "12_end",
        # multi-file session loop (EOFR): all channels saw EOFR, so the file
        # is complete but the session persists — request the next file over
        # the already-open channels, or close the session with EOFT
        ("8_eof_check", "all_eofr"): "3_request",
        ("3_request", "request_sent_reuse"): "6_dispatch",
        # RESUME: request only the blocks missing from the local sidecar
        ("3_request", "resume_sent"): "6_dispatch",
        ("3_request", "session_close"): "12_end",
    }
    for s in list(states - {"12_end", "err"}):
        t[(s, "error")] = "err"
    t[("err", "handled")] = "12_end"
    return Machine("client_download", states, "1_connect", frozenset({"12_end"}), t)


def server_upload_fsm() -> Machine:
    """Fig. 10 — server side, upload (server receives, writes disk)."""
    states = frozenset({
        "1_accept", "2_auth", "3_mode", "4_params", "5_session_lookup",
        "6_register_channel", "7_await_channels", "9_open_file",
        "10_dispatch", "11_recv_block", "12_buffer", "13_flush",
        "14_eof_check", "18_end", "err",
    })
    t = {
        ("1_accept", "conn"): "2_auth",
        ("2_auth", "auth_ok"): "3_mode",
        ("3_mode", "ftsm"): "4_params",
        ("4_params", "params_ok"): "5_session_lookup",
        ("5_session_lookup", "new_session"): "6_register_channel",
        ("5_session_lookup", "known_session"): "6_register_channel",
        ("6_register_channel", "registered"): "7_await_channels",
        ("7_await_channels", "more_channels"): "1_accept",
        ("7_await_channels", "all_channels"): "9_open_file",
        ("9_open_file", "opened"): "10_dispatch",
        # RESUME (interrupted upload): the file re-opens with its verified
        # blocks intact; only the missing/corrupt blocks arrive
        ("9_open_file", "resume"): "10_dispatch",
        ("10_dispatch", "read_ready"): "11_recv_block",
        ("10_dispatch", "flush"): "13_flush",  # backpressure / idle drain
        ("11_recv_block", "block"): "12_buffer",
        ("11_recv_block", "eof_header"): "14_eof_check",
        ("12_buffer", "buffered"): "10_dispatch",
        ("12_buffer", "ring_full"): "13_flush",
        ("13_flush", "flushed"): "10_dispatch",
        ("14_eof_check", "channels_open"): "10_dispatch",
        ("14_eof_check", "all_eof"): "13_flush",
        ("13_flush", "final_flush"): "18_end",
        # multi-file session loop (EOFR, Table 3): the final flush of a file
        # that ended with EOFR re-arms the machine for the session's next
        # file instead of terminating; EOFT while idle ends the session
        ("13_flush", "eofr_flush"): "9_open_file",
        ("9_open_file", "eoft"): "18_end",
    }
    for s in list(states - {"18_end", "err"}):
        t[(s, "error")] = "err"
    t[("err", "handled")] = "18_end"
    return Machine("server_upload", states, "1_accept", frozenset({"18_end"}), t)


def client_upload_fsm() -> Machine:
    """Fig. 11 — client side, upload (client reads disk, sends)."""
    states = frozenset({
        "1_connect", "2_auth", "3_request", "5_await_channels",
        "6_dispatch", "7_read_disk", "8_send_block", "9_eof",
        "10_await_acks", "12_end", "err",
    })
    t = {
        ("1_connect", "connected"): "2_auth",
        ("2_auth", "auth_ok"): "3_request",
        ("3_request", "request_sent"): "5_await_channels",
        ("5_await_channels", "more_channels"): "1_connect",
        ("5_await_channels", "all_channels"): "6_dispatch",
        ("6_dispatch", "write_ready"): "7_read_disk",
        ("7_read_disk", "block"): "8_send_block",
        ("7_read_disk", "eof"): "9_eof",
        ("8_send_block", "sent"): "6_dispatch",
        ("9_eof", "eof_sent"): "10_await_acks",
        ("10_await_acks", "acked"): "12_end",
        # multi-file session loop (EOFR): acks for an EOFR-terminated file
        # return to the request state; the open channels carry the next file
        ("10_await_acks", "acked_reusable"): "3_request",
        ("3_request", "request_sent_reuse"): "6_dispatch",
        # RESUME: re-send only the blocks the server's sidecar is missing
        ("3_request", "resume_sent"): "6_dispatch",
        ("3_request", "session_close"): "12_end",
    }
    for s in list(states - {"12_end", "err"}):
        t[(s, "error")] = "err"
    t[("err", "handled")] = "12_end"
    return Machine("client_upload", states, "1_connect", frozenset({"12_end"}), t)


FSM_BUILDERS: Dict[str, Callable[[], Machine]] = {
    "server_download": server_download_fsm,
    "client_download": client_download_fsm,
    "server_upload": server_upload_fsm,
    "client_upload": client_upload_fsm,
}


def dual_pairs() -> list:
    """The paper's duality observation: the send side of one mode mirrors the
    receive side of the other. Used by tests/test_fsm.py."""
    return [
        ("server_download", "client_upload"),
        ("server_upload", "client_download"),
    ]
