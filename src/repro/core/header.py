"""xDFS binary channel headers (paper Fig. 5, Tables 2-3).

Every block moving through an xDFS channel is framed by a fixed-size binary
header carrying the channel event type, session id, and the (offset, length)
of the file block. The same framing is reused verbatim by the device-side
tensor channels (chunk offset/length over ICI) — see core/channel.py.
"""
from __future__ import annotations

import enum
import struct
import uuid
from dataclasses import dataclass

MAGIC = 0x78444653  # 'xDFS'
VERSION = 2  # xDFS extends DotDFS (v1)


class ChannelEvent(enum.IntEnum):
    """Channel event types (paper Table 3)."""

    NOOP = 0
    xFTSMU = 1  # initiate/change to upload mode
    xFTSMD = 2  # initiate/change to download mode
    xPathM = 3  # path-mode (out of paper scope; reserved)
    EOFR = 4  # end-of-file on this channel; channel becomes reusable
    EOFT = 5  # end-of-file; terminate session, close all channels
    CONM = 6  # continue/maintain last channel event state
    ZxDFS = 7  # compressed (zero-copy) channel negotiation
    EXCEPTION = 8  # exception header (error propagation)
    RESUME = 9  # resume an interrupted transfer: only missing blocks move


# per-frame flag bits (byte 3 of the header). FLAG_BLOCK_CRC marks a data
# frame whose payload is followed by a 4-byte little-endian CRC32 trailer;
# frames self-describe, so receivers verify whenever the bit is set.
FLAG_BLOCK_CRC = 0x01

CRC_TRAILER = struct.Struct("<I")
TRAILER_SIZE = CRC_TRAILER.size

# magic, version, event, flags, session(16s), channel, offset, length, crc
_FMT = struct.Struct("<IHBB16sIQQI")
HEADER_SIZE = _FMT.size


def header_checksum(event: int, session: bytes, channel: int,
                    offset: int, length: int) -> int:
    """Cheap integrity word over the header fields (not the payload)."""
    x = (offset * 0x9E3779B97F4A7C15 + length) & 0xFFFFFFFFFFFFFFFF
    x ^= int(event) << 56 | channel
    x ^= int.from_bytes(session[:8], "little")
    return (x ^ (x >> 32)) & 0xFFFFFFFF


def pack_header_into(buf, event: int, session: bytes, channel: int,
                     offset: int, length: int, flags: int = 0) -> None:
    """Pack a channel header into a caller-owned buffer — the zero-copy
    senders reuse one per-channel buffer for every frame instead of
    allocating ``pack()`` bytes per block."""
    _FMT.pack_into(
        buf, 0, MAGIC, VERSION, int(event), flags, session, channel,
        offset, length, header_checksum(event, session, channel, offset, length),
    )


@dataclass(frozen=True)
class ChannelHeader:
    event: ChannelEvent
    session: bytes  # 16-byte GUID
    channel: int
    offset: int
    length: int
    flags: int = 0

    def pack(self) -> bytes:
        crc = self.checksum()
        return _FMT.pack(
            MAGIC, VERSION, int(self.event), self.flags,
            self.session, self.channel, self.offset, self.length, crc,
        )

    def pack_into(self, buf) -> None:
        pack_header_into(buf, self.event, self.session, self.channel,
                         self.offset, self.length, self.flags)

    def checksum(self) -> int:
        return header_checksum(self.event, self.session, self.channel,
                               self.offset, self.length)

    @classmethod
    def unpack(cls, buf) -> "ChannelHeader":
        """Accepts any buffer (bytes, bytearray, memoryview) — receivers
        unpack straight from their reusable header buffers."""
        magic, ver, ev, flags, session, channel, offset, length, crc = (
            _FMT.unpack_from(buf)
        )
        if magic != MAGIC:
            raise ProtocolError(f"bad magic {magic:#x}")
        if ver != VERSION:
            # per-block headers are version-exact; cross-version compat is
            # negotiated at session setup (Negotiation.version)
            raise ProtocolError(f"unsupported version {ver}")
        hdr = cls(ChannelEvent(ev), session, channel, offset, length, flags)
        if hdr.checksum() != crc:
            raise ProtocolError("header checksum mismatch")
        return hdr


class ProtocolError(RuntimeError):
    pass


@dataclass(frozen=True)
class Negotiation:
    """Session negotiation parameters (paper Table 2)."""

    session: bytes
    n_channels: int
    block_size: int
    tcp_window: int
    remote_name: str
    local_name: str
    version: int = VERSION
    compressed: bool = False  # ZxDFS extended mode
    file_size: int = 0
    credentials: bytes = b""  # xSec is out of scope; carried opaquely
    # negotiated socket tuning: both ends apply the same TCP_NODELAY and
    # SO_SNDBUF/SO_RCVBUF so window sizes agree across the session
    # (0 = kernel default)
    so_sndbuf: int = 0
    so_rcvbuf: int = 0
    so_nodelay: bool = True
    # negotiated CEILING on frames per scatter-gather sendmsg batch (both
    # directions); receivers size their slabs from it and senders
    # hill-climb actual depth below it. 1 (or an absent tail on the
    # wire) = the per-frame legacy datapath.
    batch_frames: int = 1
    # negotiated end-to-end integrity: every data frame carries a CRC32
    # trailer (FLAG_BLOCK_CRC) and the put/get completes with a file-level
    # manifest check. False (or an absent tail) = the unchecked datapath.
    integrity: bool = False
    # negotiated at-rest durability policy for received files: 0 = none,
    # 1 = fsync before ACK, 2 = fsync + atomic rename (engines/base.py
    # DURABILITY_* constants). The receiving server applies the MAX of
    # this request and its own configured floor; 0 (or an absent tail)
    # = the unsynced datapath.
    durability: int = 0

    def pack(self) -> bytes:
        rn = self.remote_name.encode()
        ln = self.local_name.encode()
        head = struct.pack(
            "<16sHIIQQB??HH",
            self.session, self.version, self.n_channels, self.block_size,
            self.tcp_window, self.file_size, 0, self.compressed, False,
            len(rn), len(ln),
        )
        return (head + rn + ln
                + struct.pack("<H", len(self.credentials)) + self.credentials
                + struct.pack("<II?", self.so_sndbuf, self.so_rcvbuf,
                              self.so_nodelay)
                + struct.pack("<H", self.batch_frames)
                + struct.pack("<B", 1 if self.integrity else 0)
                + struct.pack("<B", self.durability))

    @classmethod
    def unpack(cls, buf) -> "Negotiation":
        """Accepts any buffer (bytes, bytearray, memoryview) — the session
        layer parses the negotiation straight from its recv buffer;
        ``str(view, "utf-8")`` and ``unpack_from`` read in place, and only
        the (stored) credentials blob is materialized."""
        head = struct.Struct("<16sHIIQQB??HH")
        (session, ver, n, bs, win, fsize, _r, comp, _r2, lrn, lln) = (
            head.unpack_from(buf)
        )
        p = head.size
        rn = str(buf[p : p + lrn], "utf-8")
        p += lrn
        ln = str(buf[p : p + lln], "utf-8")
        p += lln
        (lc,) = struct.unpack_from("<H", buf, p)
        creds = bytes(buf[p + 2 : p + 2 + lc])
        p += 2 + lc
        # v1 negotiation blobs end at the credentials; tuning tail optional
        sndbuf = rcvbuf = 0
        nodelay = True
        batch = 1
        if len(buf) >= p + 8:
            sndbuf, rcvbuf = struct.unpack_from("<II", buf, p)
            if len(buf) >= p + 9:
                nodelay = bool(buf[p + 8])
        # batch tail optional too: pre-batching blobs (and a wire value of
        # 0) mean the per-frame datapath
        if len(buf) >= p + 11:
            (batch,) = struct.unpack_from("<H", buf, p + 9)
            batch = max(1, batch)
        # integrity tail optional: pre-integrity blobs mean no trailers
        integrity = len(buf) >= p + 12 and bool(buf[p + 11])
        # durability tail optional: pre-durability blobs mean unsynced
        durability = buf[p + 12] if len(buf) >= p + 13 else 0
        return cls(session, n, bs, win, rn, ln, ver, comp, fsize, creds,
                   sndbuf, rcvbuf, nodelay, batch, integrity, durability)


def new_session_id() -> bytes:
    return uuid.uuid4().bytes
