"""Adaptive datapath autotuning (the ROADMAP "adaptive splice / autotuning"
item): measured-goodput controllers for the batched frame datapath.

Two knobs are tuned at runtime, both per channel/worker, both from the
same primitive (compare goodput across measurement windows):

* **batch depth** — how many frames a sender coalesces into one
  scatter-gather ``sendmsg`` (:class:`ChannelTuner`): a hill-climbing
  loop over the discrete ``LADDER`` ``(1, 4, 16, 64)`` keeps the depth
  that measures fastest on THIS path (deep batches win on syscall-bound
  links, shallow ones when the socket buffer is the bottleneck);
* **splice vs pool** — whether a receive worker keeps the kernel-side
  ``os.splice`` path (:class:`SpliceArbiter`): one splice window and one
  pool window are measured back to back and the faster path wins for the
  remainder of the session. This replaces the static ``splice=True``
  always-on behavior — on hosts where splice is slower than the
  registered-buffer path (gVisor's syscall virtualization is the known
  case) the session falls back mid-stream instead of paying for the
  whole transfer.

Controllers take an injectable ``clock`` so tests drive convergence
deterministically with a fake clock; engines use the default
``time.perf_counter``.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional, Sequence, Tuple

# The discrete batch-depth ladder senders climb. Depths beyond 64 frames
# push the iovec toward IOV_MAX (2 entries per frame) for no measured
# gain; the negotiated batch_frames cap truncates the ladder from above.
LADDER: Tuple[int, ...] = (1, 4, 16, 64)

# SpliceArbiter phase names (documented in docs/ARCHITECTURE.md; the
# docs test machine-checks them against these constants)
SPLICE_TRIAL = "splice_trial"
POOL_TRIAL = "pool_trial"
DECIDED = "decided"


class HillClimber:
    """1-D hill climb over a discrete ladder of settings.

    One ``observe(score)`` call per measurement epoch (higher score is
    better). The climber first walks the ladder to score every
    neighbor of its path, then settles on the local maximum: each
    observation refreshes the current rung's exponentially-weighted
    score and the next position is the best-scoring of {down, stay, up},
    preferring any still-unexplored neighbor. On a noiseless peaked
    score function this converges to the peak and stays there.
    """

    __slots__ = ("ladder", "i", "scores", "_alpha")

    def __init__(self, ladder: Sequence, start_index: Optional[int] = None,
                 alpha: float = 0.5):
        assert len(ladder) > 0
        self.ladder = tuple(ladder)
        self.i = len(self.ladder) - 1 if start_index is None else start_index
        self.scores: Dict[int, float] = {}  # rung index -> EWMA score
        self._alpha = alpha

    @property
    def value(self):
        return self.ladder[self.i]

    @property
    def settled(self) -> bool:
        """True once every neighbor of the current rung has a score and
        the current rung is the best of them."""
        cand = self._candidates()
        return all(j in self.scores for j in cand) and self._argmax() == self.i

    def _candidates(self):
        return [j for j in (self.i - 1, self.i, self.i + 1)
                if 0 <= j < len(self.ladder)]

    def _argmax(self) -> int:
        return max(self._candidates(), key=lambda j: self.scores[j])

    def observe(self, score: float) -> None:
        prev = self.scores.get(self.i)
        self.scores[self.i] = (score if prev is None
                               else prev + self._alpha * (score - prev))
        for j in self._candidates():  # explore unscored neighbors first
            if j not in self.scores:
                self.i = j
                return
        self.i = self._argmax()


class ChannelTuner:
    """Batch-depth controller for one send channel.

    ``depth`` is the number of frames the caller should coalesce into
    its next ``sendmsg``; ``note(nbytes)`` reports delivered bytes after
    each batch. Bytes are accumulated into fixed-size measurement
    windows; each closed window's goodput feeds the hill climb. The
    ladder is truncated at the negotiated ``batch_frames`` cap, and the
    climb starts at the cap (the caller asked for batching; the tuner's
    job is to back off when shallower measures faster).
    """

    __slots__ = ("window_bytes", "_clock", "_climber", "_t0", "_bytes",
                 "windows")

    def __init__(self, cap: int = LADDER[-1], window_bytes: int = 2 << 20,
                 clock: Callable[[], float] = time.perf_counter):
        # the cap itself is always a rung: a negotiated ceiling between
        # ladder rungs (e.g. 2, 8, 32) must still be reachable, not
        # silently rounded down to the next rung (which would disable
        # batching entirely for caps of 2 and 3)
        cap = max(1, min(cap, LADDER[-1]))
        ladder = tuple(d for d in LADDER if d < cap) + (cap,)
        self.window_bytes = window_bytes
        self._clock = clock
        self._climber = HillClimber(ladder)
        self._t0: Optional[float] = None
        self._bytes = 0
        self.windows = 0  # closed measurement windows (observability)

    @property
    def depth(self) -> int:
        return self._climber.value

    @property
    def settled(self) -> bool:
        return self._climber.settled

    def note(self, nbytes: int) -> None:
        now = self._clock()
        if self._t0 is None:  # first note opens the window
            self._t0 = now
            self._bytes = nbytes
            return
        self._bytes += nbytes
        if self._bytes < self.window_bytes:
            return
        elapsed = max(now - self._t0, 1e-9)
        self._climber.observe(self._bytes / elapsed)
        self.windows += 1
        self._t0 = now
        self._bytes = 0


class SpliceArbiter:
    """Decides whether a receive worker keeps the kernel-side splice path.

    Phase machine (state in ``.phase``)::

        splice_trial --window--> pool_trial --window--> decided

    Each trial measures goodput over ``window_bytes`` of payload on one
    path; after both windows the faster path (with ``margin`` hysteresis
    in splice's favor, so a tie keeps the path the caller opted into)
    wins for the rest of the session. ``use_splice`` tells the caller
    which path to run the NEXT block on; ``note(nbytes)`` reports each
    landed block and returns ``True`` exactly once, on the observation
    that completes the decision (the caller's hook for counting
    ``RecvStats.splice_autodisables`` and switching datapaths).
    ``force_pool()`` records a mechanical splice failure (unsupported /
    mid-block fallback) — that is a failure, not a measured switch, so
    it decides without flagging an autodisable.
    """

    __slots__ = ("window_bytes", "margin", "_clock", "phase", "_t0",
                 "_bytes", "_splice_goodput", "chose_splice", "measured_switch")

    def __init__(self, window_bytes: int = 4 << 20, margin: float = 0.10,
                 clock: Callable[[], float] = time.perf_counter):
        self.window_bytes = window_bytes
        self.margin = margin
        self._clock = clock
        self.phase = SPLICE_TRIAL
        self._t0: Optional[float] = None
        self._bytes = 0
        self._splice_goodput = 0.0
        self.chose_splice = False
        self.measured_switch = False  # decided pool over a WORKING splice

    @property
    def use_splice(self) -> bool:
        if self.phase == DECIDED:
            return self.chose_splice
        return self.phase == SPLICE_TRIAL

    @property
    def decided(self) -> bool:
        return self.phase == DECIDED

    def force_pool(self) -> None:
        self.phase = DECIDED
        self.chose_splice = False

    def note(self, nbytes: int) -> bool:
        """Report one landed block. Returns True on the note that makes
        the decision; False otherwise."""
        if self.phase == DECIDED:
            return False
        now = self._clock()
        if self._t0 is None:
            self._t0 = now
            self._bytes = nbytes
            return False
        self._bytes += nbytes
        if self._bytes < self.window_bytes:
            return False
        goodput = self._bytes / max(now - self._t0, 1e-9)
        self._t0 = None
        self._bytes = 0
        if self.phase == SPLICE_TRIAL:
            self._splice_goodput = goodput
            self.phase = POOL_TRIAL
            return False
        # pool window closed: pick the winner, with hysteresis toward
        # the splice path the caller explicitly opted into
        self.phase = DECIDED
        self.chose_splice = self._splice_goodput * (1.0 + self.margin) >= goodput
        self.measured_switch = not self.chose_splice
        return True
