"""Circular block buffer (paper §2.5.2-2.5.3 and §4.1).

Two variants, matching the two server architectures that use one:

* ``RingBuffer`` — single-producer/single-consumer, index-based, LOCK-FREE
  (the MTEDP engine: one event loop produces, the disk drain consumes in the
  same thread or a dedicated disk thread). Slots are preallocated bytearrays
  (the paper's memory-allocation factor: zero per-block allocation in steady
  state).
* ``LockedRing`` — the MT model's pessimistically-locked shared buffer
  (threading.Condition), kept deliberately faithful to the paper's
  description so the benchmark reproduces its synchronization overhead.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import List, Optional, Tuple


class RingBuffer:
    """SPSC ring of (offset, length) tagged preallocated block slots."""

    def __init__(self, slots: int, block_size: int):
        assert slots > 0 and (slots & (slots - 1)) == 0, "slots must be 2^k"
        self.slots = slots
        self.block_size = block_size
        self._buf: List[bytearray] = [bytearray(block_size) for _ in range(slots)]
        self._meta: List[Tuple[int, int]] = [(0, 0)] * slots
        self._head = 0  # next write (producer)
        self._tail = 0  # next read (consumer)

    def __len__(self) -> int:
        return self._head - self._tail

    @property
    def free(self) -> int:
        return self.slots - len(self)

    def full(self) -> bool:
        return len(self) == self.slots

    def empty(self) -> bool:
        return self._head == self._tail

    def produce_view(self) -> Optional[memoryview]:
        """Borrow the next free slot's buffer for a zero-copy recv_into."""
        if self.full():
            return None
        return memoryview(self._buf[self._head % self.slots])

    def commit(self, offset: int, length: int) -> None:
        assert not self.full()
        self._meta[self._head % self.slots] = (offset, length)
        self._head += 1

    def push(self, data, offset: int) -> bool:
        """Copy-push (convenience; the hot path uses produce_view+commit)."""
        mv = self.produce_view()
        if mv is None:
            return False
        n = len(data)
        mv[:n] = data
        self.commit(offset, n)
        return True

    def peek(self) -> Optional[Tuple[int, memoryview]]:
        if self.empty():
            return None
        i = self._tail % self.slots
        off, ln = self._meta[i]
        return off, memoryview(self._buf[i])[:ln]

    def pop(self) -> None:
        assert not self.empty()
        self._tail += 1

    def drain_contiguous(self) -> List[Tuple[int, memoryview]]:
        """Pop ALL queued blocks (offset order as queued) for vectored I/O."""
        out = []
        while not self.empty():
            i = self._tail % self.slots
            off, ln = self._meta[i]
            out.append((off, memoryview(self._buf[i])[:ln]))
            self._tail += 1
        return out


class BlockPool:
    """Preallocated block pool (region allocator, paper §2.2): the MTEDP
    engine claims blocks for in-flight channel receives (zero-copy
    ``recv_into``) and commits them to a FIFO for the disk drain — multiple
    channels can hold claimed blocks concurrently, unlike the strict SPSC
    ring."""

    def __init__(self, slots: int, block_size: int):
        self.slots = slots
        self.block_size = block_size
        self._free: List[bytearray] = [bytearray(block_size) for _ in range(slots)]
        self._committed: List[Tuple[int, int, bytearray]] = []  # (offset, len, blk)

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_committed(self) -> int:
        return len(self._committed)

    def acquire(self) -> Optional[bytearray]:
        return self._free.pop() if self._free else None

    def release(self, blk: bytearray) -> None:
        self._free.append(blk)

    def commit(self, blk: bytearray, offset: int, length: int) -> None:
        self._committed.append((offset, length, blk))

    def drain(self) -> List[Tuple[int, int, bytearray]]:
        out = self._committed
        self._committed = []
        return out


class LockedRing:
    """The MT model's shared circular buffer with pessimistic locking."""

    def __init__(self, slots: int, block_size: int):
        self._ring = RingBuffer(slots, block_size)
        self._cv = threading.Condition()
        self.closed = False

    def put(self, data, offset: int) -> None:
        with self._cv:
            while self._ring.full() and not self.closed:
                self._cv.wait()
            if self.closed:
                raise RuntimeError("ring closed")
            ok = self._ring.push(data, offset)
            assert ok
            self._cv.notify_all()

    def get_batch(self, timeout: float = 0.1) -> List[Tuple[int, bytes]]:
        with self._cv:
            if self._ring.empty() and not self.closed:
                self._cv.wait(timeout)
            out = [(off, bytes(mv)) for off, mv in self._ring.drain_contiguous()]
            self._cv.notify_all()
            return out

    def close(self) -> None:
        with self._cv:
            self.closed = True
            self._cv.notify_all()
