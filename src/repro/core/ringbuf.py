"""Receive-side block buffers (paper §2.5.2-2.5.3 and §4.1).

The registered-buffer receive datapath lives here:

* ``RecvBufferPool`` — ONE preallocated backing buffer carved into
  block-size slot views. Receivers hand slot views straight to
  ``socket.recv_into`` so frames land in pool memory, and the drain side
  hands trimmed views of the same memory to ``os.pwritev`` — zero
  payload copies between the socket and the disk. Slot lifecycle:
  ``acquire -> recv_into(view) -> commit -> pwritev -> release``.
* ``LockedRecvPool`` — the MT model's pessimistically-locked shared pool
  (threading.Condition around a ``RecvBufferPool``): channel threads
  block in ``acquire`` when the pool is exhausted (backpressure), the
  disk thread blocks in ``drain_wait``; the per-block lock handoffs keep
  the paper's MT synchronization cost observable.
* ``RecvSlab`` / ``SlabSet`` — the batched datapath's per-channel slabs:
  one large ``recv_into`` may land MANY frames in the slab, parsed in
  place by ``SlabChannel`` (engines/base.py); a session-owned ``SlabSet``
  reuses the registered memory across files.
* ``LockedBatchRelay`` — the MT model's batched disk handoff (channel
  threads block until the disk thread wrote their slab views out).

Legacy structures kept for the benchmarks and model-checking tests:

* ``RingBuffer`` — single-producer/single-consumer, index-based,
  lock-free copy-in ring.
* ``LockedRing`` — the seed's MT shared buffer; both its ``put`` copy-in
  and its ``get_batch`` snapshot are charged to
  ``RecvBufferPool.materializations``, so the copying receive path is
  measurably non-zero-copy.
* ``BlockPool`` — the pre-registered-buffer MTEDP pool (per-slot
  bytearrays; superseded by ``RecvBufferPool``).
"""
from __future__ import annotations

import threading
from typing import Iterable, List, Optional, Tuple


class RecvBufferPool:
    """Registered-buffer pool: the receive-side mirror of the mmap send path.

    One contiguous backing ``bytearray`` is registered up front and carved
    into ``slots`` fixed views. ``acquire`` hands out an integer slot
    handle; ``view(slot)`` is the preallocated memoryview receivers pass to
    ``recv_into``; ``commit`` tags a filled slot with its file
    ``(offset, length)``; ``drain`` returns the committed backlog for a
    coalesced ``pwritev`` of the SAME memory; ``release`` returns slots to
    the free list. Nothing on that path allocates or copies payload bytes.

    ``materializations`` is a class-level counter of payload-sized heap
    copies made anywhere on the receive path (legacy ring snapshots, splice
    recovery reads, ...). The zero-copy hot loop must leave it untouched —
    tests assert it reads 0 after a full transfer.
    """

    materializations = 0  # class-level: payload-sized receive-path copies

    __slots__ = ("slots", "block_size", "_backing", "_views", "_free",
                 "_committed")

    def __init__(self, slots: int, block_size: int):
        assert slots > 0 and block_size > 0
        self.slots = slots
        self.block_size = block_size
        self._backing = bytearray(slots * block_size)
        mem = memoryview(self._backing)
        self._views = [mem[i * block_size : (i + 1) * block_size]
                       for i in range(slots)]
        self._free: List[int] = list(range(slots))
        self._committed: List[Tuple[int, int, int]] = []  # (offset, len, slot)

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_committed(self) -> int:
        return len(self._committed)

    def acquire(self) -> Optional[int]:
        """Claim a free slot handle (None when exhausted — the caller's
        backpressure point)."""
        return self._free.pop() if self._free else None

    def view(self, slot: int) -> memoryview:
        """The slot's full-block view into the registered backing buffer."""
        return self._views[slot]

    def commit(self, slot: int, offset: int, length: int) -> None:
        """Tag a filled slot for write-out at file ``offset``."""
        self._committed.append((offset, length, slot))

    def drain(self) -> List[Tuple[int, int, int]]:
        """Take the committed backlog (offset, length, slot) for vectored
        write-out; the caller releases each slot after the write lands."""
        out = self._committed
        self._committed = []
        return out

    def release(self, slot: int) -> None:
        self._free.append(slot)

    def release_all(self, slots: Iterable[int]) -> None:
        self._free.extend(slots)


class RecvSlab:
    """One registered receive slab for the batched datapath: a contiguous
    buffer that LARGE ``recv_into`` reads fill with many frames at once.
    ``SlabChannel`` (engines/base.py) parses headers in place from it and
    commits payload ``(offset, view)`` pairs of the SAME memory for
    vectored write-out — the multi-frame generalization of a
    :class:`RecvBufferPool` slot. One slab per channel; a session-owned
    :class:`SlabSet` reuses the memory across files."""

    __slots__ = ("nbytes", "_backing", "mem")

    def __init__(self, nbytes: int):
        assert nbytes > 0
        self.nbytes = nbytes
        self._backing = bytearray(nbytes)
        self.mem = memoryview(self._backing)


class SlabSet:
    """Per-channel receive slabs owned by a session and lent to every
    ``engine.receive`` call (the batched twin of the session's
    :class:`RecvBufferPool`): slab memory is registered once and reused
    across all the files of the session."""

    __slots__ = ("n_channels", "slab_bytes", "_slabs")

    def __init__(self, n_channels: int, slab_bytes: int):
        self.n_channels = n_channels
        self.slab_bytes = slab_bytes
        self._slabs = [RecvSlab(slab_bytes) for _ in range(n_channels)]

    def slab(self, i: int) -> RecvSlab:
        return self._slabs[i]


class LockedBatchRelay:
    """The MT model's batched disk handoff: channel threads submit whole
    ``(offset, view)`` batches (views into their slabs) and BLOCK until
    the disk thread reports them written — the slab memory is only reused
    after the write lands. The per-batch lock handoffs are the batched
    descendant of ``LockedRecvPool``'s per-block synchronization cost."""

    def __init__(self):
        self._cv = threading.Condition()
        self._queue: List[list] = []  # [batch, done] tickets
        self.closed = False

    def submit_wait(self, batch) -> None:
        if not batch:
            return
        ticket = [batch, False]
        with self._cv:
            if self.closed:
                raise RuntimeError("batch relay closed")
            self._queue.append(ticket)
            self._cv.notify_all()
            while not ticket[1]:
                if self.closed:
                    raise RuntimeError("batch relay closed")
                self._cv.wait()

    def next_ticket(self, timeout: float = 0.1):
        """Disk thread: the oldest unwritten batch ticket (None on
        timeout/closed). Pass the ticket back to :meth:`mark_done`."""
        with self._cv:
            if not self._queue and not self.closed:
                self._cv.wait(timeout)
            return self._queue.pop(0) if self._queue else None

    def mark_done(self, ticket) -> None:
        with self._cv:
            ticket[1] = True
            self._cv.notify_all()

    def close(self) -> None:
        with self._cv:
            self.closed = True
            self._cv.notify_all()


class LockedRecvPool:
    """The MT model's shared receive pool: a ``RecvBufferPool`` behind one
    pessimistic lock. Channel threads ``acquire`` (blocking when the pool
    is exhausted — backpressure), fill the slot view, ``commit``; the disk
    thread ``drain_wait``s, writes the views out, and ``release``s."""

    def __init__(self, pool: RecvBufferPool):
        self.pool = pool
        self._cv = threading.Condition()
        self.closed = False

    def acquire(self) -> int:
        with self._cv:
            while not self.closed:
                slot = self.pool.acquire()
                if slot is not None:
                    return slot
                self._cv.wait()
            raise RuntimeError("recv pool closed")

    def view(self, slot: int) -> memoryview:
        return self.pool.view(slot)

    def commit(self, slot: int, offset: int, length: int) -> None:
        with self._cv:
            self.pool.commit(slot, offset, length)
            self._cv.notify_all()

    def drain_wait(self, timeout: float = 0.1) -> List[Tuple[int, int, int]]:
        with self._cv:
            if self.pool.n_committed == 0 and not self.closed:
                self._cv.wait(timeout)
            return self.pool.drain()

    def release_all(self, slots: Iterable[int]) -> None:
        with self._cv:
            self.pool.release_all(slots)
            self._cv.notify_all()

    def close(self) -> None:
        with self._cv:
            self.closed = True
            self._cv.notify_all()


class RingBuffer:
    """SPSC ring of (offset, length) tagged preallocated block slots."""

    def __init__(self, slots: int, block_size: int):
        assert slots > 0 and (slots & (slots - 1)) == 0, "slots must be 2^k"
        self.slots = slots
        self.block_size = block_size
        self._buf: List[bytearray] = [bytearray(block_size) for _ in range(slots)]
        self._meta: List[Tuple[int, int]] = [(0, 0)] * slots
        self._head = 0  # next write (producer)
        self._tail = 0  # next read (consumer)

    def __len__(self) -> int:
        return self._head - self._tail

    @property
    def free(self) -> int:
        return self.slots - len(self)

    def full(self) -> bool:
        return len(self) == self.slots

    def empty(self) -> bool:
        return self._head == self._tail

    def produce_view(self) -> Optional[memoryview]:
        """Borrow the next free slot's buffer for a zero-copy recv_into."""
        if self.full():
            return None
        return memoryview(self._buf[self._head % self.slots])

    def commit(self, offset: int, length: int) -> None:
        assert not self.full()
        self._meta[self._head % self.slots] = (offset, length)
        self._head += 1

    def push(self, data, offset: int) -> bool:
        """Copy-push (convenience; the hot path uses produce_view+commit)."""
        mv = self.produce_view()
        if mv is None:
            return False
        n = len(data)
        mv[:n] = data
        self.commit(offset, n)
        return True

    def peek(self) -> Optional[Tuple[int, memoryview]]:
        if self.empty():
            return None
        i = self._tail % self.slots
        off, ln = self._meta[i]
        return off, memoryview(self._buf[i])[:ln]

    def pop(self) -> None:
        assert not self.empty()
        self._tail += 1

    def drain_contiguous(self) -> List[Tuple[int, memoryview]]:
        """Pop ALL queued blocks (offset order as queued) for vectored I/O."""
        out = []
        while not self.empty():
            i = self._tail % self.slots
            off, ln = self._meta[i]
            out.append((off, memoryview(self._buf[i])[:ln]))
            self._tail += 1
        return out


class BlockPool:
    """Preallocated block pool (region allocator, paper §2.2): per-slot
    bytearray blocks claimed for in-flight channel receives and committed
    to a FIFO for the disk drain. Superseded on the engine receive path by
    :class:`RecvBufferPool` (one registered backing buffer, slot handles);
    kept for the model-checking tests and as the simplest pool shape."""

    def __init__(self, slots: int, block_size: int):
        self.slots = slots
        self.block_size = block_size
        self._free: List[bytearray] = [bytearray(block_size) for _ in range(slots)]
        self._committed: List[Tuple[int, int, bytearray]] = []  # (offset, len, blk)

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_committed(self) -> int:
        return len(self._committed)

    def acquire(self) -> Optional[bytearray]:
        return self._free.pop() if self._free else None

    def release(self, blk: bytearray) -> None:
        self._free.append(blk)

    def commit(self, blk: bytearray, offset: int, length: int) -> None:
        self._committed.append((offset, length, blk))

    def drain(self) -> List[Tuple[int, int, bytearray]]:
        out = self._committed
        self._committed = []
        return out


class LockedRing:
    """The seed MT model's shared circular buffer with pessimistic locking.

    Every block is COPIED twice on its way through (``put`` copies into the
    ring slot, ``get_batch`` snapshots it back out); both copies are charged
    to ``RecvBufferPool.materializations`` so the legacy datapath is
    measurably non-zero-copy. The live MT engine uses
    :class:`LockedRecvPool` instead; this stays as the copying baseline for
    ``benchmarks/zero_copy.py`` and the threaded-integrity tests."""

    def __init__(self, slots: int, block_size: int):
        self._ring = RingBuffer(slots, block_size)
        self._cv = threading.Condition()
        self.closed = False

    def put(self, data, offset: int) -> None:
        with self._cv:
            while self._ring.full() and not self.closed:
                self._cv.wait()
            if self.closed:
                raise RuntimeError("ring closed")
            RecvBufferPool.materializations += 1  # copy-in to the ring slot
            ok = self._ring.push(data, offset)
            assert ok
            self._cv.notify_all()

    def get_batch(self, timeout: float = 0.1) -> List[Tuple[int, bytes]]:
        with self._cv:
            if self._ring.empty() and not self.closed:
                self._cv.wait(timeout)
            drained = self._ring.drain_contiguous()
            RecvBufferPool.materializations += len(drained)  # snapshots
            out = [(off, bytes(mv)) for off, mv in drained]
            self._cv.notify_all()
            return out

    def close(self) -> None:
        with self._cv:
            self.closed = True
            self._cv.notify_all()
