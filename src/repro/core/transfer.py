"""One-shot transfer compatibility shim over the xDFS session API.

Historically this module WAS the engines (652 lines of MTEDP/MT/MP
receivers and senders). Those now live behind the pluggable registry in
``core/engines/`` and the persistent-session objects in ``core/api.py``
(``XdfsServer`` / ``XdfsClient``). What remains here:

* ``TransferSpec`` / ``TransferStats`` — the original one-shot dataclasses;
* ``run_transfer(spec)`` — DEPRECATED single-file entry point, now a thin
  shim that forks an ``XdfsServer`` process and an ``XdfsClient`` process
  (per-side CPU/RSS attribution, paper Figs. 13/16/17/19) and moves one
  file through a one-negotiation session. New code should hold an
  ``XdfsClient`` session open and amortize negotiation across files.
* re-exports of the engine helpers (``Source``, ``Sink``, ``mtedp_receive``
  etc.) for backward compatibility.
"""
from __future__ import annotations

import json
import os
import resource
import socket
from dataclasses import dataclass
from typing import Optional

# Backward-compatible re-exports: the engines moved to repro.core.engines.
from repro.core.engines import (  # noqa: F401
    ACK,
    IOV_MAX,
    FrameBuilder,
    RecvStats,
    Sink,
    Source,
    event_send,
    get_engine,
    mp_receive,
    mt_receive,
    mtedp_receive,
    recv_exact,
    send_all,
    sendfile_all,
    sendmsg_all,
    worker_send,
)


@dataclass
class TransferSpec:
    engine: str = "mtedp"  # any name in the engine registry
    mode: str = "upload"  # upload | download
    n_channels: int = 4
    block_size: int = 1 << 20
    size: int = 64 << 20
    src_path: Optional[str] = None  # None -> mem source (zeros)
    dst_path: Optional[str] = None  # None -> mem sink (discard)
    pool_slots: int = 32
    port: int = 0
    sndbuf: int = 0  # negotiated SO_SNDBUF (0 = kernel default)
    rcvbuf: int = 0  # negotiated SO_RCVBUF
    batch_frames: int = 1  # negotiated syscall-batching ceiling


@dataclass
class TransferStats:
    wall_s: float
    bytes: int
    throughput_mbps: float  # megabits/s, like the paper's figures
    server_cpu_s: float = 0.0
    client_cpu_s: float = 0.0
    server_rss_mb: float = 0.0
    client_rss_mb: float = 0.0
    writev_calls: int = 0


def _child_metrics() -> dict:
    ru = resource.getrusage(resource.RUSAGE_SELF)
    rc = resource.getrusage(resource.RUSAGE_CHILDREN)
    return {
        "cpu_s": ru.ru_utime + ru.ru_stime + rc.ru_utime + rc.ru_stime,
        "rss_mb": max(ru.ru_maxrss, rc.ru_maxrss) / 1024.0,
    }


def run_transfer(spec: TransferSpec) -> TransferStats:
    """DEPRECATED one-shot shim: run one full upload or download session
    over loopback TCP through the persistent-session API, server and client
    in forked processes so CPU and RSS are attributable per side.

    Every call pays a fork + negotiation + teardown; hold an
    ``XdfsClient`` session open instead to amortize that across files."""
    from repro.core.api import XdfsClient, XdfsServer

    get_engine(spec.engine)  # fail fast in the parent on unknown engines

    r_port, w_port = os.pipe()
    r_srv, w_srv = os.pipe()
    server_pid = os.fork()
    if server_pid == 0:  # ----- server process -----
        os.close(r_port)
        os.close(r_srv)
        try:
            srv = XdfsServer(
                engine=spec.engine, root=None, port=spec.port,
                pool_slots=spec.pool_slots,
            ).start()
            os.write(w_port, json.dumps({"port": srv.address[1]}).encode())
            os.close(w_port)
            if not srv.wait_closed_sessions(1, timeout=600.0):
                raise TimeoutError("no session completed")
            if srv.errors:
                raise srv.errors[0]
            m = _child_metrics()
            m["writev_calls"] = srv.stats["writev_calls"]
            m["server_bytes"] = srv.stats["bytes"]
            srv.stop(timeout=2.0)
            os.write(w_srv, json.dumps(m).encode())
            os._exit(0)
        except BaseException as e:
            os.write(w_srv, json.dumps({"error": repr(e)}).encode())
            os._exit(1)

    # ----- parent: learn the port, then fork the client -----
    os.close(w_port)
    os.close(w_srv)
    port_msg = json.loads(os.read(r_port, 4096).decode() or "{}")
    os.close(r_port)
    if "port" not in port_msg:
        os.waitpid(server_pid, 0)
        srv_err = json.loads(os.read(r_srv, 65536).decode() or "{}")
        os.close(r_srv)
        raise RuntimeError(f"transfer failed: srv={srv_err}")
    port = port_msg["port"]

    r_cli, w_cli = os.pipe()
    client_pid = os.fork()
    if client_pid == 0:  # ----- client process -----
        os.close(r_cli)
        try:
            from repro.core.session import SocketTuning

            cli = XdfsClient.connect(
                ("127.0.0.1", port), n_channels=spec.n_channels,
                engine=spec.engine, block_size=spec.block_size,
                tuning=SocketTuning(sndbuf=spec.sndbuf, rcvbuf=spec.rcvbuf),
                batch_frames=spec.batch_frames,
            )
            if spec.mode == "upload":
                res = cli.put(spec.src_path, spec.dst_path, size=spec.size)
            else:
                res = cli.get(spec.src_path, spec.dst_path, size=spec.size)
            fr = res.result()
            cli.close()
            m = _child_metrics()
            m["wall_s"] = fr.wall_s
            os.write(w_cli, json.dumps(m).encode())
            os._exit(0)
        except BaseException as e:
            os.write(w_cli, json.dumps({"error": repr(e)}).encode())
            os._exit(1)

    os.close(w_cli)
    srv = json.loads(os.read(r_srv, 65536).decode() or "{}")
    cli = json.loads(os.read(r_cli, 65536).decode() or "{}")
    os.close(r_srv)
    os.close(r_cli)
    for pid in (server_pid, client_pid):
        os.waitpid(pid, 0)
    if "error" in srv or "error" in cli:
        raise RuntimeError(f"transfer failed: srv={srv} cli={cli}")
    wall = cli["wall_s"]
    return TransferStats(
        wall_s=wall,
        bytes=spec.size,
        throughput_mbps=spec.size * 8 / wall / 1e6,
        server_cpu_s=srv["cpu_s"],
        client_cpu_s=cli["cpu_s"],
        server_rss_mb=srv["rss_mb"],
        client_rss_mb=cli["rss_mb"],
        writev_calls=srv.get("writev_calls", 0),
    )
