"""xDFS host transfer engines — the paper's three server architectures.

* ``mtedp`` — multi-threaded event-driven pipelined (the paper's xDFS
  design, §2.5.3): ONE thread multiplexes all n channels via PIOD
  (selectors), blocks land zero-copy in a preallocated BlockPool, and a
  single file handle drains them with coalesced VECTORED writes
  (os.pwritev) — single-writer, lock-free, minimal seeks.
* ``mt`` — multi-threaded (§2.5.2): thread per channel + pessimistically
  locked shared ring + one disk thread (single handle).
* ``mp`` — multi-processed (§2.5.1, the GridFTP model): fork per channel,
  n independent file handles, per-block pwrite at scattered offsets.

Senders mirror the receivers (the paper notes client APIs reuse the same
quasi-server architectures): ``event`` (single-thread, selectors) vs
``threaded``/``forked`` (blocking worker per channel, own fd + seeks).

Both transfer directions run over real loopback TCP sockets; disk I/O is
real file I/O; mem-to-mem mode replaces them with zero buffers / no-op
sinks (the paper's /dev/zero -> /dev/null tests).
"""
from __future__ import annotations

import json
import os
import resource
import selectors
import socket
import struct
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.fsm import FSM_BUILDERS
from repro.core.header import (
    HEADER_SIZE,
    ChannelEvent,
    ChannelHeader,
    Negotiation,
    new_session_id,
)
from repro.core.piod import PIOD
from repro.core.ringbuf import BlockPool, LockedRing

ACK = b"\x06"
IOV_MAX = 512


# ---------------------------------------------------------------------------
# wire helpers
# ---------------------------------------------------------------------------


def send_all(sock: socket.socket, data) -> None:
    view = memoryview(data)
    while view:
        n = sock.send(view)
        view = view[n:]


def recv_exact(sock: socket.socket, n: int, buf: Optional[memoryview] = None):
    out = memoryview(bytearray(n)) if buf is None else buf[:n]
    got = 0
    while got < n:
        r = sock.recv_into(out[got:], n - got)
        if r == 0:
            raise ConnectionError("peer closed")
        got += r
    return out


# ---------------------------------------------------------------------------
# sources and sinks
# ---------------------------------------------------------------------------


class Source:
    """Reads blocks from a file, or serves zeros (mem mode)."""

    def __init__(self, path: Optional[str], size: int, block_size: int):
        self.size = size
        self.block_size = block_size
        self.n_blocks = (size + block_size - 1) // block_size
        self.path = path
        self._fd = os.open(path, os.O_RDONLY) if path else -1
        self._zeros = None if path else bytes(block_size)

    def open_worker(self) -> "Source":
        """A worker-private handle (MP/MT senders use one fd per worker)."""
        return Source(self.path, self.size, self.block_size)

    def block_len(self, i: int) -> int:
        return min(self.block_size, self.size - i * self.block_size)

    def read_block(self, i: int) -> bytes:
        ln = self.block_len(i)
        if self._fd < 0:
            return self._zeros[:ln]
        return os.pread(self._fd, ln, i * self.block_size)

    def close(self):
        if self._fd >= 0:
            os.close(self._fd)


class Sink:
    """Writes blocks to a file (pwrite / coalesced pwritev), or discards."""

    def __init__(self, path: Optional[str], size: int):
        self.path = path
        self.size = size
        if path:
            self._fd = os.open(path, os.O_WRONLY | os.O_CREAT, 0o644)
            os.ftruncate(self._fd, size)
        else:
            self._fd = -1

    def open_worker(self) -> "Sink":
        return Sink(self.path, self.size) if self.path else Sink(None, self.size)

    def write_at(self, offset: int, data) -> None:
        if self._fd >= 0:
            os.pwrite(self._fd, data, offset)

    def writev_coalesced(self, blocks: List[Tuple[int, int, bytearray]]) -> int:
        """Sort by offset, group contiguous runs, one pwritev per run.

        Returns the number of vectored syscalls issued (the seek-reduction
        metric from the paper)."""
        if self._fd < 0 or not blocks:
            return 0
        blocks.sort(key=lambda b: b[0])
        calls = 0
        run: List[memoryview] = []
        run_start = run_end = -1
        for off, ln, blk in blocks:
            if off == run_end and len(run) < IOV_MAX:
                run.append(memoryview(blk)[:ln])
                run_end += ln
            else:
                if run:
                    os.pwritev(self._fd, run, run_start)
                    calls += 1
                run = [memoryview(blk)[:ln]]
                run_start, run_end = off, off + ln
        if run:
            os.pwritev(self._fd, run, run_start)
            calls += 1
        return calls

    def close(self):
        if self._fd >= 0:
            os.close(self._fd)


# ---------------------------------------------------------------------------
# receivers
# ---------------------------------------------------------------------------


@dataclass
class RecvStats:
    bytes: int = 0
    writev_calls: int = 0
    flushes: int = 0


def mtedp_receive(
    socks: List[socket.socket],
    sink: Sink,
    block_size: int,
    pool_slots: int = 32,
    conformance: bool = True,
) -> RecvStats:
    """The xDFS MTEDP receiver: PIOD event loop + BlockPool + vectored I/O."""
    stats = RecvStats()
    pool = BlockPool(pool_slots, block_size)
    piod = PIOD()
    n = len(socks)
    eof = [False] * n
    fsm = FSM_BUILDERS["server_upload"]() if conformance else None
    if fsm is not None:
        # connection/negotiation stages already completed by the session layer
        for ev in ("conn", "auth_ok", "ftsm", "params_ok", "new_session",
                   "registered", "all_channels", "opened"):
            fsm.step(ev)

    class Chan:
        __slots__ = ("sock", "idx", "hdr_buf", "hdr_got", "hdr", "blk", "got")

        def __init__(self, sock, idx):
            self.sock = sock
            self.idx = idx
            self.hdr_buf = memoryview(bytearray(HEADER_SIZE))
            self.hdr_got = 0
            self.hdr = None
            self.blk = None
            self.got = 0

    def fsm_steps(*events):
        if fsm is not None:
            for e in events:
                fsm.step(e)

    def flush(final=False):
        blocks = pool.drain()
        if blocks or final:
            stats.writev_calls += sink.writev_coalesced(blocks)
            stats.flushes += 1
            for _, _, blk in blocks:
                pool.release(blk)
        if fsm is None:
            return
        if final:
            fsm.step("final_flush")  # conformance: must be in 13_flush
        elif fsm.state == "10_dispatch":
            fsm_steps("flush", "flushed")
        # (a drain tick after all_eof, state 13, needs no transition)

    def on_readable(sock, mask):
        """Greedy drain: keep consuming until the socket would block —
        one selector wakeup then services many blocks (minimizes dispatch
        overhead, the §2.3 context-switch factor applied to the event loop).
        """
        c = chans[sock]
        try:
            while True:
                if c.hdr is None:
                    r = sock.recv_into(
                        c.hdr_buf[c.hdr_got:], HEADER_SIZE - c.hdr_got
                    )
                    if r == 0:
                        raise ConnectionError("peer closed mid-header")
                    c.hdr_got += r
                    if c.hdr_got < HEADER_SIZE:
                        continue
                    c.hdr = ChannelHeader.unpack(bytes(c.hdr_buf))
                    c.hdr_got = 0
                    if c.hdr.event == ChannelEvent.EOFT:
                        # milestone: 10 -> 11 -> 14 -> (10 | 13)
                        eof[c.idx] = True
                        piod.unregister(sock)
                        c.hdr = None
                        fsm_steps("read_ready", "eof_header",
                                  "all_eof" if all(eof) else "channels_open")
                        return
                    c.blk = pool.acquire()
                    while c.blk is None:  # backpressure: drain to disk
                        flush()
                        c.blk = pool.acquire()
                    c.got = 0
                    continue
                # payload
                want = c.hdr.length - c.got
                r = sock.recv_into(memoryview(c.blk)[c.got : c.hdr.length], want)
                if r == 0:
                    raise ConnectionError("peer closed mid-block")
                c.got += r
                stats.bytes += r
                if c.got == c.hdr.length:
                    pool.commit(c.blk, c.hdr.offset, c.hdr.length)
                    # milestone: full block moved through 10 -> 11 -> 12 -> 10
                    fsm_steps("read_ready", "block", "buffered")
                    c.hdr = None
                    c.blk = None
                    if pool.n_free == 0:
                        flush()
        except BlockingIOError:
            return

    chans: Dict[socket.socket, Chan] = {}
    for i, s in enumerate(socks):
        chans[s] = Chan(s, i)
        piod.register(s, selectors.EVENT_READ, on_readable)

    def drained_if_idle():
        if pool.n_committed >= pool_slots // 2:
            flush()

    piod.idle_callback = drained_if_idle
    piod.run(until=lambda: all(eof))
    flush(final=True)
    piod.close()
    if fsm is not None:
        assert fsm.done, f"conformance: receiver FSM ended in {fsm.state}"
    for s in socks:
        send_all(s, ACK)
    return stats


def mt_receive(
    socks: List[socket.socket],
    sink: Sink,
    block_size: int,
    ring_slots: int = 32,
) -> RecvStats:
    """MT model: thread per channel + locked shared ring + disk thread."""
    stats = RecvStats()
    ring = LockedRing(ring_slots, block_size)
    lock = threading.Lock()

    def rx(sock):
        hdr_buf = memoryview(bytearray(HEADER_SIZE))
        while True:
            recv_exact(sock, HEADER_SIZE, hdr_buf)
            hdr = ChannelHeader.unpack(bytes(hdr_buf))
            if hdr.event == ChannelEvent.EOFT:
                return
            payload = recv_exact(sock, hdr.length)
            ring.put(payload, hdr.offset)
            with lock:
                stats.bytes += hdr.length

    def disk():
        while True:
            batch = ring.get_batch()
            if batch:
                blocks = [(off, len(d), bytearray(d)) for off, d in batch]
                stats.writev_calls += sink.writev_coalesced(blocks)
                stats.flushes += 1
            elif ring.closed:
                return

    dt = threading.Thread(target=disk)
    dt.start()
    threads = [threading.Thread(target=rx, args=(s,)) for s in socks]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    ring.close()
    dt.join()
    for s in socks:
        send_all(s, ACK)
    return stats


def mp_receive(
    socks: List[socket.socket],
    sink: Sink,
    block_size: int,
) -> RecvStats:
    """MP model (GridFTP-like): fork per channel, n file handles, per-block
    pwrite at scattered offsets — no coalescing, no shared state."""
    stats = RecvStats()
    pids = []
    for s in socks:
        pid = os.fork()
        if pid == 0:  # child
            try:
                wsink = sink.open_worker()
                hdr_buf = memoryview(bytearray(HEADER_SIZE))
                while True:
                    recv_exact(s, HEADER_SIZE, hdr_buf)
                    hdr = ChannelHeader.unpack(bytes(hdr_buf))
                    if hdr.event == ChannelEvent.EOFT:
                        break
                    payload = recv_exact(s, hdr.length)
                    wsink.write_at(hdr.offset, payload)
                wsink.close()
                send_all(s, ACK)
                os._exit(0)
            except BaseException:
                os._exit(1)
        pids.append(pid)
    for pid in pids:
        _, status = os.waitpid(pid, 0)
        if os.waitstatus_to_exitcode(status) != 0:
            raise RuntimeError("mp receiver child failed")
    return stats


# ---------------------------------------------------------------------------
# senders
# ---------------------------------------------------------------------------


def event_send(
    socks: List[socket.socket],
    source: Source,
    session: bytes,
    mode_event: ChannelEvent = ChannelEvent.xFTSMU,
) -> int:
    """xDFS event-driven sender: one thread, write-readiness multiplexing."""
    n = len(socks)
    piod = PIOD()
    next_block = [c for c in range(n)]  # block index each channel sends next
    pending: Dict[socket.socket, memoryview] = {}
    done = [False] * n
    sent = 0

    def make_frame(i_chan: int, i_block: int) -> bytes:
        if i_block >= source.n_blocks:
            hdr = ChannelHeader(ChannelEvent.EOFT, session, i_chan, 0, 0)
            return hdr.pack()
        ln = source.block_len(i_block)
        hdr = ChannelHeader(
            mode_event, session, i_chan, i_block * source.block_size, ln
        )
        return hdr.pack() + source.read_block(i_block)

    idx = {s: i for i, s in enumerate(socks)}

    def on_writable(sock, mask):
        nonlocal sent
        i = idx[sock]
        try:
            while True:  # greedy: fill the socket until it would block
                buf = pending.get(sock)
                if buf is None:
                    blk = next_block[i]
                    next_block[i] += n
                    frame = make_frame(i, blk)
                    buf = memoryview(frame)
                    pending[sock] = buf
                    if blk >= source.n_blocks:
                        done[i] = True
                w = sock.send(buf)
                sent += w
                buf = buf[w:]
                if len(buf) == 0:
                    pending.pop(sock)
                    if done[i]:
                        piod.unregister(sock)
                        return
                else:
                    pending[sock] = buf
        except BlockingIOError:
            return

    for s in socks:
        piod.register(s, selectors.EVENT_WRITE, on_writable)
    piod.run(until=lambda: all(done) and not pending)
    piod.close()
    for s in socks:
        s.setblocking(True)
        recv_exact(s, 1)  # final ack (exception-header channel)
    return sent


def worker_send(
    socks: List[socket.socket],
    source: Source,
    session: bytes,
    use_processes: bool,
    mode_event: ChannelEvent = ChannelEvent.xFTSMU,
) -> int:
    """Baseline sender: blocking worker (thread or fork) per channel, each
    with a PRIVATE fd reading its stripe (seek-heavy, GridFTP-like)."""
    n = len(socks)

    def tx(i: int, sock: socket.socket):
        src = source.open_worker()
        b = i
        while b < src.n_blocks:
            ln = src.block_len(b)
            hdr = ChannelHeader(mode_event, session, i, b * src.block_size, ln)
            send_all(sock, hdr.pack() + src.read_block(b))
            b += n
        send_all(sock, ChannelHeader(ChannelEvent.EOFT, session, i, 0, 0).pack())
        sock.setblocking(True)
        recv_exact(sock, 1)
        src.close()

    if use_processes:
        pids = []
        for i, s in enumerate(socks):
            pid = os.fork()
            if pid == 0:
                try:
                    tx(i, s)
                    os._exit(0)
                except BaseException:
                    os._exit(1)
            pids.append(pid)
        for pid in pids:
            _, status = os.waitpid(pid, 0)
            if os.waitstatus_to_exitcode(status) != 0:
                raise RuntimeError("sender child failed")
    else:
        threads = [
            threading.Thread(target=tx, args=(i, s)) for i, s in enumerate(socks)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    return source.size


# ---------------------------------------------------------------------------
# session setup + orchestration
# ---------------------------------------------------------------------------


@dataclass
class TransferSpec:
    engine: str = "mtedp"  # mtedp | mt | mp
    mode: str = "upload"  # upload | download
    n_channels: int = 4
    block_size: int = 1 << 20
    size: int = 64 << 20
    src_path: Optional[str] = None  # None -> mem source (zeros)
    dst_path: Optional[str] = None  # None -> mem sink (discard)
    pool_slots: int = 32
    port: int = 0


@dataclass
class TransferStats:
    wall_s: float
    bytes: int
    throughput_mbps: float  # megabits/s, like the paper's figures
    server_cpu_s: float = 0.0
    client_cpu_s: float = 0.0
    server_rss_mb: float = 0.0
    client_rss_mb: float = 0.0
    writev_calls: int = 0


def _receiver_for(engine: str):
    return {"mtedp": mtedp_receive, "mt": mt_receive, "mp": mp_receive}[engine]


def _run_receiver(engine, socks, sink, block_size, pool_slots):
    if engine == "mtedp":
        return mtedp_receive(socks, sink, block_size, pool_slots)
    if engine == "mt":
        return mt_receive(socks, sink, block_size, pool_slots)
    return mp_receive(socks, sink, block_size)


def _run_sender(engine, socks, source, session):
    if engine == "mtedp":
        return event_send(socks, source, session)
    return worker_send(socks, source, session, use_processes=(engine == "mp"))


def _child_metrics() -> dict:
    ru = resource.getrusage(resource.RUSAGE_SELF)
    rc = resource.getrusage(resource.RUSAGE_CHILDREN)
    return {
        "cpu_s": ru.ru_utime + ru.ru_stime + rc.ru_utime + rc.ru_stime,
        "rss_mb": max(ru.ru_maxrss, rc.ru_maxrss) / 1024.0,
    }


def run_transfer(spec: TransferSpec) -> TransferStats:
    """Run one full client->server (upload) or server->client (download)
    session over loopback TCP, server and client in forked processes so CPU
    and RSS are attributable per side (paper Figs. 13, 16, 17, 19)."""
    lsock = socket.socket()
    lsock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    lsock.bind(("127.0.0.1", spec.port))
    lsock.listen(spec.n_channels + 1)
    port = lsock.getsockname()[1]
    session = new_session_id()

    r_meta, w_meta = os.pipe()
    server_pid = os.fork()
    if server_pid == 0:  # ----- server process -----
        os.close(r_meta)
        try:
            socks = []
            for _ in range(spec.n_channels):
                c, _ = lsock.accept()
                c.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                socks.append(c)
            lsock.close()
            # negotiation arrives on the first-accepted channel
            raw = bytes(recv_exact(socks[0], 4))
            (nlen,) = struct.unpack("<I", raw)
            neg = Negotiation.unpack(bytes(recv_exact(socks[0], nlen)))
            assert neg.n_channels == spec.n_channels
            stats = RecvStats()
            if spec.mode == "upload":
                sink = Sink(spec.dst_path, spec.size)
                stats = _run_receiver(
                    spec.engine, socks, sink, spec.block_size, spec.pool_slots
                )
                sink.close()
            else:  # download: server sends
                source = Source(spec.src_path, spec.size, spec.block_size)
                _run_sender(spec.engine, socks, source, session)
                source.close()
            m = _child_metrics()
            m["writev_calls"] = stats.writev_calls
            os.write(w_meta, json.dumps(m).encode())
            os._exit(0)
        except BaseException as e:
            os.write(w_meta, json.dumps({"error": repr(e)}).encode())
            os._exit(1)

    # ----- client (this process forks again for metric isolation) -----
    os.close(w_meta)
    lsock.close()
    r_cli, w_cli = os.pipe()
    client_pid = os.fork()
    if client_pid == 0:
        os.close(r_cli)
        try:
            socks = []
            for i in range(spec.n_channels):
                c = socket.socket()
                c.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                c.connect(("127.0.0.1", port))
                socks.append(c)
            neg = Negotiation(
                session, spec.n_channels, spec.block_size, 1 << 20,
                "remote.bin", "local.bin", file_size=spec.size,
            ).pack()
            send_all(socks[0], struct.pack("<I", len(neg)) + neg)
            t0 = time.perf_counter()
            if spec.mode == "upload":
                source = Source(spec.src_path, spec.size, spec.block_size)
                _run_sender(spec.engine, socks, source, session)
                source.close()
            else:
                sink = Sink(spec.dst_path, spec.size)
                _run_receiver(
                    spec.engine, socks, sink, spec.block_size, spec.pool_slots
                )
                sink.close()
            wall = time.perf_counter() - t0
            m = _child_metrics()
            m["wall_s"] = wall
            os.write(w_cli, json.dumps(m).encode())
            os._exit(0)
        except BaseException as e:
            os.write(w_cli, json.dumps({"error": repr(e)}).encode())
            os._exit(1)

    os.close(w_cli)
    srv = json.loads(os.read(r_meta, 65536).decode() or "{}")
    cli = json.loads(os.read(r_cli, 65536).decode() or "{}")
    os.close(r_meta)
    os.close(r_cli)
    for pid in (server_pid, client_pid):
        os.waitpid(pid, 0)
    if "error" in srv or "error" in cli:
        raise RuntimeError(f"transfer failed: srv={srv} cli={cli}")
    wall = cli["wall_s"]
    return TransferStats(
        wall_s=wall,
        bytes=spec.size,
        throughput_mbps=spec.size * 8 / wall / 1e6,
        server_cpu_s=srv["cpu_s"],
        client_cpu_s=cli["cpu_s"],
        server_rss_mb=srv["rss_mb"],
        client_rss_mb=cli["rss_mb"],
        writev_calls=srv.get("writev_calls", 0),
    )
