"""Cluster control protocol: length-prefixed JSON messages.

The cluster control plane (client <-> MetaNode, DataNode <-> MetaNode)
speaks a small framed protocol in the spirit of ``core/header.py``: a
fixed little-endian binary header carrying magic, version, message type,
and body length, followed by a UTF-8 JSON body. Control traffic is tiny
and rare compared to block data (which rides the ordinary xDFS session
datapath), so JSON bodies trade a few bytes for debuggability; the
binary header keeps framing unambiguous and version-checked.

The message table in docs/ARCHITECTURE.md ("Cluster control plane") is
normative and machine-checked against :class:`ClusterMsg` and the
command-op constants by ``tests/test_docs.py``.
"""
from __future__ import annotations

import enum
import json
import socket
import struct
import uuid
from typing import Tuple

MAGIC = 0x784D4554  # 'xMET'
VERSION = 1

# header: magic, version, msg type, body length
_FMT = struct.Struct("<IHHI")
MSG_HEADER_SIZE = _FMT.size

# a control body is small metadata (namespace entries, block reports,
# placement plans) — anything bigger is a framing bug, not a message
MAX_BODY = 8 << 20


class ClusterMsg(enum.IntEnum):
    """Cluster control-plane message types (docs/ARCHITECTURE.md table)."""

    REGISTER = 1  # datanode -> meta: join, advertise data address
    HEARTBEAT = 2  # datanode -> meta: liveness + full block report
    PLAN_PUT = 3  # client -> meta: request a striped placement plan
    COMMIT = 4  # client -> meta: record blocks written by a striped put
    LOOKUP = 5  # client -> meta: resolve a name to block locations
    LIST = 6  # client -> meta: namespace listing under a prefix
    DELETE = 7  # client -> meta: drop a file (blocks reclaimed via drop)
    STATE = 8  # client -> meta: cluster health snapshot
    OK = 9  # meta -> any: success reply, JSON result body
    ERR = 10  # meta -> any: failure reply, {"error": ...}
    PING = 11  # any -> meta: identity probe ({epoch, role, seq, meta_id})
    SYNC = 12  # standby -> leader: tail journal records since a sequence


# command ops carried in a HEARTBEAT OK reply ({"commands": [...]}) —
# the MetaNode's only way to make a DataNode act (pull-based, so a
# restarting node picks its work back up on the next beat)
CMD_REPLICATE = "replicate"  # push one block to a peer data node
CMD_DROP = "drop"  # delete one block from the local store

# Every OK reply from a MetaNode carries the sender's leader epoch under
# this key; command batches and commit acks inherit it. Receivers fence:
# a reply whose epoch is below the highest epoch ever observed comes
# from a deposed leader, and its commands are no-ops.
EPOCH_FIELD = "epoch"

# machine-readable ERR codes (carried next to the human-readable
# "error" string) so recovery paths do not have to pattern-match text
ERR_UNREGISTERED = "unregistered"  # heartbeat from a node the meta forgot
#                                    (blank restart): re-REGISTER to recover
ERR_NOT_LEADER = "not_leader"  # mutating request hit a standby; the body
#                                may carry {"leader": [host, port]} as a hint


class ClusterError(RuntimeError):
    """A control request failed (ERR reply or protocol violation).

    ``code`` is the machine-readable ERR code (``ERR_UNREGISTERED``,
    ``ERR_NOT_LEADER``, or None); ``hint`` is the optional leader
    address a standby redirects to."""

    def __init__(self, message: str, code: str = None, hint=None):
        super().__init__(message)
        self.code = code
        self.hint = tuple(hint) if hint else None


def new_block_id() -> str:
    return uuid.uuid4().hex


def block_name(block_id: str) -> str:
    """The remote name one block is stored under in a data node's root."""
    return f"blk_{block_id}.bin"


def send_msg(sock: socket.socket, msg: ClusterMsg, body: dict) -> None:
    raw = json.dumps(body, separators=(",", ":")).encode()
    sock.sendall(_FMT.pack(MAGIC, VERSION, int(msg), len(raw)) + raw)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray(n)
    view = memoryview(buf)
    got = 0
    while got < n:
        r = sock.recv_into(view[got:])
        if r == 0:
            raise ConnectionError("peer closed mid-message")
        got += r
    return bytes(buf)


def recv_msg(sock: socket.socket) -> Tuple[ClusterMsg, dict]:
    magic, ver, msg, length = _FMT.unpack(_recv_exact(sock, MSG_HEADER_SIZE))
    if magic != MAGIC:
        raise ClusterError(f"bad control magic {magic:#x}")
    if ver != VERSION:
        raise ClusterError(f"unsupported control version {ver}")
    if length > MAX_BODY:
        raise ClusterError(f"oversized control body ({length} bytes)")
    body = json.loads(_recv_exact(sock, length)) if length else {}
    return ClusterMsg(msg), body


def request(sock: socket.socket, msg: ClusterMsg, body: dict) -> dict:
    """One control round-trip; raises :class:`ClusterError` on ERR."""
    send_msg(sock, msg, body)
    reply, payload = recv_msg(sock)
    if reply == ClusterMsg.ERR:
        raise ClusterError(payload.get("error", "unknown cluster error"),
                           code=payload.get("code"),
                           hint=payload.get("leader"))
    if reply != ClusterMsg.OK:
        raise ClusterError(f"unexpected reply {reply!r}")
    return payload
