"""MetaNode: the cluster's metadata/placement service (NameNode-style).

One MetaNode fronts a fleet of data nodes (each an ``XdfsServer`` — see
``datanode.py``). It owns the namespace (file -> ordered block list),
the placement policy (``placement.py``), and the failure detector; it
never touches block bytes. Data nodes register, then send periodic
heartbeats carrying a **full block report**; clients ask for placement
plans (put) and block locations (get) and move blocks themselves over
ordinary xDFS sessions, so the MetaNode stays off the datapath.

Control flow is pull-based: the MetaNode commands a data node only by
piggybacking ``replicate`` / ``drop`` commands on its next heartbeat
reply. That makes recovery idempotent — a node that crashes and comes
back simply beats again and picks up fresh commands computed from the
then-current state.

The failure detector and the re-replication planner are driven by an
injectable ``clock`` (same idiom as ``core/autotune.py``'s controllers)
so tests advance time deterministically; ``start()`` additionally runs
a real ticker thread for live clusters.
"""
from __future__ import annotations

import socket
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set, Tuple

from repro.cluster import placement
from repro.cluster.wire import (
    CMD_DROP,
    CMD_REPLICATE,
    ClusterError,
    ClusterMsg,
    new_block_id,
    recv_msg,
    send_msg,
)

DEFAULT_REPLICATION = 2
# a commanded copy that has not shown up in a block report after this
# many timeouts is presumed failed and re-planned
REPLICATION_GRACE_TIMEOUTS = 3.0


class FailureDetector:
    """Heartbeat bookkeeping: a node is alive while its last beat is
    within ``timeout`` of ``clock()``. ``sweep()`` returns the nodes
    that died since the previous sweep; a later beat revives a node."""

    def __init__(self, timeout: float = 2.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout
        self._clock = clock
        self._last: Dict[str, float] = {}
        self._dead: Set[str] = set()

    def beat(self, node_id: str) -> None:
        self._last[node_id] = self._clock()
        self._dead.discard(node_id)

    def is_alive(self, node_id: str) -> bool:
        last = self._last.get(node_id)
        return (last is not None and node_id not in self._dead
                and self._clock() - last <= self.timeout)

    def alive(self) -> Set[str]:
        return {n for n in self._last if self.is_alive(n)}

    def sweep(self) -> List[str]:
        now = self._clock()
        newly_dead = sorted(
            n for n, last in self._last.items()
            if n not in self._dead and now - last > self.timeout
        )
        self._dead.update(newly_dead)
        return newly_dead

    def forget(self, node_id: str) -> None:
        self._last.pop(node_id, None)
        self._dead.discard(node_id)


@dataclass
class NodeInfo:
    node_id: str
    host: str
    port: int
    blocks: Set[str] = field(default_factory=set)

    @property
    def address(self) -> Tuple[str, int]:
        return (self.host, self.port)

    def as_dict(self) -> dict:
        return {"node_id": self.node_id, "host": self.host,
                "port": self.port}


class MetaNode:
    """The metadata/placement service. Thread-safe; all state under one
    lock. Usable fully in-process (handlers are plain methods) or as a
    TCP service via :meth:`start`."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0,
                 replication: int = DEFAULT_REPLICATION,
                 heartbeat_timeout: float = 2.0,
                 tick_interval: Optional[float] = None,
                 auto_rebalance: bool = False,
                 clock: Callable[[], float] = time.monotonic):
        self.host = host
        self._port = port
        self.replication = max(1, int(replication))
        self.heartbeat_timeout = heartbeat_timeout
        self.tick_interval = (heartbeat_timeout / 4.0
                              if tick_interval is None else tick_interval)
        self.auto_rebalance = auto_rebalance
        self._clock = clock
        self.detector = FailureDetector(heartbeat_timeout, clock)
        self._lock = threading.RLock()
        self.nodes: Dict[str, NodeInfo] = {}
        self.files: Dict[str, dict] = {}  # name -> {size, block_size, blocks}
        self.locations: Dict[str, Set[str]] = {}  # block id -> node ids
        self._commands: Dict[str, List[dict]] = {}  # node id -> queued cmds
        self._inflight: Dict[Tuple[str, str], float] = {}  # (blk, dst) -> t
        self._pending_drops: List[Tuple[str, str, str]] = []  # blk, src, dst
        self.lost_blocks: Set[str] = set()
        self.stats: Dict[str, int] = {
            "heartbeats": 0, "plans": 0, "commits": 0, "lookups": 0,
            "re_replications": 0, "rebalance_moves": 0, "nodes_died": 0,
        }
        self._lsock: Optional[socket.socket] = None
        self._threads: List[threading.Thread] = []
        self._stopping = False

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "MetaNode":
        lsock = socket.socket()
        lsock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        lsock.bind((self.host, self._port))
        lsock.listen(64)
        lsock.settimeout(0.25)
        self._lsock = lsock
        acc = threading.Thread(target=self._accept_loop,
                               name="meta-accept", daemon=True)
        acc.start()
        self._threads.append(acc)
        if self.tick_interval > 0:
            tk = threading.Thread(target=self._tick_loop,
                                  name="meta-tick", daemon=True)
            tk.start()
            self._threads.append(tk)
        return self

    @property
    def address(self) -> Tuple[str, int]:
        assert self._lsock is not None, "metanode not started"
        return self._lsock.getsockname()[:2]

    def stop(self, timeout: float = 5.0) -> None:
        self._stopping = True
        if self._lsock is not None:
            try:
                self._lsock.close()
            except OSError:
                pass
        for t in self._threads:
            t.join(timeout)

    def __enter__(self) -> "MetaNode":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def _accept_loop(self) -> None:
        while not self._stopping:
            try:
                conn, _ = self._lsock.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            threading.Thread(target=self._serve_conn, args=(conn,),
                             daemon=True).start()

    def _serve_conn(self, conn: socket.socket) -> None:
        try:
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            while not self._stopping:
                try:
                    msg, body = recv_msg(conn)
                except (ConnectionError, OSError):
                    return
                try:
                    send_msg(conn, ClusterMsg.OK, self.dispatch(msg, body))
                except ClusterError as e:
                    send_msg(conn, ClusterMsg.ERR, {"error": str(e)})
        except OSError:
            pass
        finally:
            try:
                conn.close()
            except OSError:
                pass

    def _tick_loop(self) -> None:
        while not self._stopping:
            time.sleep(self.tick_interval)
            try:
                self.tick()
                if self.auto_rebalance:
                    self.rebalance()
            except Exception:  # noqa: BLE001 - the ticker must survive
                pass

    # -- dispatch ----------------------------------------------------------

    def dispatch(self, msg: ClusterMsg, body: dict) -> dict:
        handlers = {
            ClusterMsg.REGISTER: self.handle_register,
            ClusterMsg.HEARTBEAT: self.handle_heartbeat,
            ClusterMsg.PLAN_PUT: self.handle_plan_put,
            ClusterMsg.COMMIT: self.handle_commit,
            ClusterMsg.LOOKUP: self.handle_lookup,
            ClusterMsg.LIST: self.handle_list,
            ClusterMsg.DELETE: self.handle_delete,
            ClusterMsg.STATE: self.handle_state,
        }
        h = handlers.get(msg)
        if h is None:
            raise ClusterError(f"unhandled control message {msg!r}")
        return h(body)

    # -- node control plane ------------------------------------------------

    def handle_register(self, body: dict) -> dict:
        node_id = str(body["node_id"])
        with self._lock:
            self.nodes[node_id] = NodeInfo(
                node_id, str(body["host"]), int(body["port"]),
                self.nodes.get(node_id, NodeInfo(node_id, "", 0)).blocks,
            )
            self.detector.beat(node_id)
            self._commands.setdefault(node_id, [])
        return {"heartbeat_timeout": self.heartbeat_timeout,
                "replication": self.replication}

    def handle_heartbeat(self, body: dict) -> dict:
        node_id = str(body["node_id"])
        report = {str(b) for b in body.get("blocks", ())}
        with self._lock:
            node = self.nodes.get(node_id)
            if node is None:
                raise ClusterError(f"unregistered node {node_id!r}")
            self.detector.beat(node_id)
            self.stats["heartbeats"] += 1
            # full block report: reconcile the location index by diff
            for blk in node.blocks - report:
                holders = self.locations.get(blk)
                if holders is not None:
                    holders.discard(node_id)
                    if not holders:
                        del self.locations[blk]
            for blk in report - node.blocks:
                self.locations.setdefault(blk, set()).add(node_id)
            node.blocks = report
            for blk in report:
                self._inflight.pop((blk, node_id), None)
                self.lost_blocks.discard(blk)
            self._settle_pending_drops()
            cmds = self._commands.get(node_id, [])
            self._commands[node_id] = []
        return {"commands": cmds}

    def _settle_pending_drops(self) -> None:
        """Rebalance moves drop their source replica only AFTER the
        destination's block report confirms the copy (never reduces
        replication on a failed move); locked by caller."""
        still = []
        for blk, src, dst in self._pending_drops:
            holders = self.locations.get(blk, set())
            if dst in holders and self.detector.is_alive(dst):
                if src in holders:
                    self._enqueue(src, {"op": CMD_DROP, "block_id": blk})
            elif (blk, dst) in self._inflight:
                still.append((blk, src, dst))
            # else: the move expired/failed — abandon the drop entirely
        self._pending_drops = still

    def _enqueue(self, node_id: str, cmd: dict) -> None:
        self._commands.setdefault(node_id, []).append(cmd)

    # -- failure detection + re-replication --------------------------------

    def tick(self) -> List[str]:
        """One failure-detector sweep + re-replication planning pass.
        Returns the nodes that died this tick. Under-replicated blocks
        (for ANY reason: a dead node, a degraded put, an expired copy
        command) get ``replicate`` commands enqueued on live holders,
        with in-flight suppression so repeated ticks do not spam
        duplicate copies."""
        with self._lock:
            newly_dead = self.detector.sweep()
            self.stats["nodes_died"] += len(newly_dead)
            alive = self.detector.alive() & set(self.nodes)
            now = self._clock()
            grace = REPLICATION_GRACE_TIMEOUTS * self.heartbeat_timeout
            self._inflight = {k: t for k, t in self._inflight.items()
                              if now - t <= grace and k[1] in alive}
            replicas = {}
            for meta in self.files.values():
                for blk in meta["blocks"]:
                    holders = self.locations.get(blk["id"], set())
                    live = holders & alive
                    if not live:
                        self.lost_blocks.add(blk["id"])
                        continue
                    if len(live) < self.replication:
                        replicas[blk["id"]] = live
            load = {n: len(self.nodes[n].blocks) for n in alive}
            moves = placement.plan_replication(
                replicas, alive, self.replication, load,
                skip=self._inflight.keys(),
            )
            for mv in moves:
                self._command_copy(mv, now)
                self.stats["re_replications"] += 1
            return newly_dead

    def _command_copy(self, mv: placement.Move, now: float) -> None:
        target = self.nodes[mv.dst]
        self._enqueue(mv.src, {
            "op": CMD_REPLICATE, "block_id": mv.block_id,
            "target": target.as_dict(),
        })
        self._inflight[(mv.block_id, mv.dst)] = now

    def rebalance(self) -> List[placement.Move]:
        """Plan + enqueue moves that even out block counts across live
        nodes; sources are dropped only after the destination confirms
        (see :meth:`_settle_pending_drops`). Returns the planned moves."""
        with self._lock:
            alive = self.detector.alive() & set(self.nodes)
            holdings = {n: set(self.nodes[n].blocks) for n in alive}
            pending_dsts = {(b, d) for b, _s, d in self._pending_drops}
            now = self._clock()
            moves = []
            for mv in placement.plan_rebalance(holdings):
                if ((mv.block_id, mv.dst) in self._inflight
                        or (mv.block_id, mv.dst) in pending_dsts):
                    continue
                self._command_copy(mv, now)
                self._pending_drops.append((mv.block_id, mv.src, mv.dst))
                self.stats["rebalance_moves"] += 1
                moves.append(mv)
            return moves

    # -- client control plane ----------------------------------------------

    def handle_plan_put(self, body: dict) -> dict:
        name = str(body["name"])
        size = int(body["size"])
        block_size = int(body["block_size"])
        if block_size <= 0:
            raise ClusterError(f"bad block_size {block_size}")
        exclude = set(body.get("exclude") or ())
        with self._lock:
            alive = sorted(self.detector.alive() & set(self.nodes))
            if exclude:
                # a re-planning client saw these nodes fail mid-put; steer
                # around them, unless that would leave nothing to place on
                pref = [n for n in alive if n not in exclude]
                if pref:
                    alive = pref
            if not alive:
                raise ClusterError("no live data nodes to place on")
            rf = min(self.replication, len(alive))
            load = {n: len(self.nodes[n].blocks) for n in alive}
            n_blocks = (size + block_size - 1) // block_size
            plan = placement.plan_put(n_blocks, load, rf)
            blocks = []
            for i, nodes in enumerate(plan):
                off = i * block_size
                blocks.append({
                    "id": new_block_id(), "offset": off,
                    "length": min(block_size, size - off),
                    "nodes": [self.nodes[n].as_dict() for n in nodes],
                })
            self.stats["plans"] += 1
        return {"name": name, "size": size, "block_size": block_size,
                "rf": rf, "blocks": blocks}

    def handle_commit(self, body: dict) -> dict:
        name = str(body["name"])
        blocks = body["blocks"]
        with self._lock:
            for blk in blocks:
                if not blk["nodes"]:
                    raise ClusterError(
                        f"block {blk['id']} of {name!r} has no replicas")
            old = self.files.get(name)
            self.files[name] = {
                "size": int(body["size"]),
                "block_size": int(body["block_size"]),
                "blocks": [{"id": str(b["id"]), "offset": int(b["offset"]),
                            "length": int(b["length"]),
                            "crc32": int(b["crc32"])} for b in blocks],
            }
            # optimistic locations so an immediate get works before the
            # writers' next block reports arrive
            for blk in blocks:
                self.locations.setdefault(str(blk["id"]), set()).update(
                    str(n) for n in blk["nodes"])
            if old is not None:  # overwrite: reclaim the old blocks
                self._reclaim(old)
            self.stats["commits"] += 1
        return {"ok": True, "blocks": len(blocks)}

    def handle_lookup(self, body: dict) -> dict:
        name = str(body["name"])
        with self._lock:
            meta = self.files.get(name)
            if meta is None:
                raise ClusterError(f"unknown file {name!r}")
            alive = self.detector.alive()
            blocks = []
            for blk in meta["blocks"]:
                live = sorted(self.locations.get(blk["id"], set()) & alive)
                blocks.append({
                    **blk,
                    "nodes": [self.nodes[n].as_dict() for n in live
                              if n in self.nodes],
                })
            self.stats["lookups"] += 1
            return {"name": name, "size": meta["size"],
                    "block_size": meta["block_size"], "blocks": blocks}

    def handle_list(self, body: dict) -> dict:
        prefix = str(body.get("prefix", ""))
        with self._lock:
            names = sorted(n for n in self.files if n.startswith(prefix))
        return {"names": names}

    def handle_delete(self, body: dict) -> dict:
        name = str(body["name"])
        with self._lock:
            meta = self.files.pop(name, None)
            if meta is None:
                raise ClusterError(f"unknown file {name!r}")
            self._reclaim(meta)
        return {"ok": True}

    def _reclaim(self, meta: dict) -> None:
        """Enqueue drops for every replica of a dereferenced file's
        blocks; locked by caller."""
        for blk in meta["blocks"]:
            for node_id in self.locations.pop(blk["id"], set()):
                if node_id in self.nodes:
                    self._enqueue(node_id,
                                  {"op": CMD_DROP, "block_id": blk["id"]})
            self.lost_blocks.discard(blk["id"])

    def handle_state(self, body: dict) -> dict:
        with self._lock:
            alive = self.detector.alive()
            return {
                "replication": self.replication,
                "nodes": [{**n.as_dict(), "alive": nid in alive,
                           "blocks": len(n.blocks)}
                          for nid, n in sorted(self.nodes.items())],
                "files": len(self.files),
                "under_replicated": sum(
                    1 for c in self._replica_counts() if 0 < c < self.replication),
                "lost": sorted(self.lost_blocks),
            }

    # -- observability (in-process) ----------------------------------------

    def _replica_counts(self) -> List[int]:
        alive = self.detector.alive()
        return [len(self.locations.get(blk["id"], set()) & alive)
                for meta in self.files.values() for blk in meta["blocks"]]

    def replication_of(self, name: str) -> List[int]:
        """Live replica count per block of ``name`` — the block-report
        view tests assert re-replication against."""
        with self._lock:
            meta = self.files.get(name)
            if meta is None:
                raise KeyError(name)
            alive = self.detector.alive()
            return [len(self.locations.get(blk["id"], set()) & alive)
                    for blk in meta["blocks"]]
