"""MetaNode: the cluster's metadata/placement service (NameNode-style).

One MetaNode fronts a fleet of data nodes (each an ``XdfsServer`` — see
``datanode.py``). It owns the namespace (file -> ordered block list),
the placement policy (``placement.py``), and the failure detector; it
never touches block bytes. Data nodes register, then send periodic
heartbeats carrying a **full block report**; clients ask for placement
plans (put) and block locations (get) and move blocks themselves over
ordinary xDFS sessions, so the MetaNode stays off the datapath.

Control flow is pull-based: the MetaNode commands a data node only by
piggybacking ``replicate`` / ``drop`` commands on its next heartbeat
reply. That makes recovery idempotent — a node that crashes and comes
back simply beats again and picks up fresh commands computed from the
then-current state.

Durability (``journal_dir=``): every namespace mutation is a
write-ahead record (``cluster/journal.py``) appended-and-fsynced
BEFORE the reply goes out, with periodic atomic-replace snapshots that
truncate the journal. Restart = load snapshot -> replay journal ->
reconcile against the next round of full block reports: a crashed
MetaNode comes back with every acknowledged commit intact and heals
the soft state (liveness, locations, in-flight copies) from reality
rather than trusting a stale image of it.

Failover (``peers=``): run N metanodes over the same protocol. Exactly
one acts as **leader**; standbys tail the leader's journal via ``SYNC``
polls, reject mutating requests with ``not_leader`` (clients and
datanodes fail over along their address lists), and promote themselves
— bumping the **epoch** — when the leader's lease expires. Every OK
reply carries the leader epoch (``wire.EPOCH_FIELD``); receivers fence
replies from deposed leaders, which is what makes a zombie leader's
stale replicate/drop commands harmless. See ``cluster/leader.py`` and
docs/ARCHITECTURE.md ("Leader epochs and fencing").

The failure detector, re-replication planner, and leader lease are
driven by an injectable ``clock`` (same idiom as ``core/autotune.py``'s
controllers) so tests advance time deterministically; ``start()``
additionally runs a real ticker thread for live clusters.
"""
from __future__ import annotations

import socket
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set, Tuple

from repro.cluster import placement
from repro.cluster.journal import (
    REC_COMMIT,
    REC_DELETE,
    REC_EPOCH,
    REC_MOVE,
    REC_MOVE_DONE,
    REC_REGISTER,
    recover,
)
from repro.cluster.leader import ControlChannel, LeaderLease
from repro.cluster.wire import (
    CMD_DROP,
    CMD_REPLICATE,
    EPOCH_FIELD,
    ERR_NOT_LEADER,
    ERR_UNREGISTERED,
    ClusterError,
    ClusterMsg,
    new_block_id,
    recv_msg,
    send_msg,
)
from repro.core.faults import RetriesExhausted, RetryPolicy

DEFAULT_REPLICATION = 2
# a commanded copy that has not shown up in a block report after this
# many timeouts is presumed failed and re-planned
REPLICATION_GRACE_TIMEOUTS = 3.0
# standbys promote after this many heartbeat timeouts without a
# successful SYNC (rank-staggered; see leader.LeaderLease)
LEASE_TIMEOUTS = 3.0
# snapshot + truncate the journal after this many appended records
SNAPSHOT_EVERY = 256
# journal records buffered in memory for standby SYNC catch-up; a
# standby further behind than this receives a full snapshot instead
SYNC_TAIL_MAX = 4096
# error-buffer bound (standby sync failures, ticker faults); overflow
# increments stats["errors_dropped"] instead of growing the heap
ERROR_BUFFER = 64

ROLE_LEADER = "leader"
ROLE_STANDBY = "standby"


class FailureDetector:
    """Heartbeat bookkeeping: a node is alive while its last beat is
    within ``timeout`` of ``clock()``. ``sweep()`` returns the nodes
    that died since the previous sweep; a later beat revives a node."""

    def __init__(self, timeout: float = 2.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout
        self._clock = clock
        self._last: Dict[str, float] = {}
        self._dead: Set[str] = set()

    def beat(self, node_id: str) -> None:
        self._last[node_id] = self._clock()
        self._dead.discard(node_id)

    def is_alive(self, node_id: str) -> bool:
        last = self._last.get(node_id)
        return (last is not None and node_id not in self._dead
                and self._clock() - last <= self.timeout)

    def alive(self) -> Set[str]:
        return {n for n in self._last if self.is_alive(n)}

    def sweep(self) -> List[str]:
        now = self._clock()
        newly_dead = sorted(
            n for n, last in self._last.items()
            if n not in self._dead and now - last > self.timeout
        )
        self._dead.update(newly_dead)
        return newly_dead

    def forget(self, node_id: str) -> None:
        self._last.pop(node_id, None)
        self._dead.discard(node_id)


@dataclass
class NodeInfo:
    node_id: str
    host: str
    port: int
    blocks: Set[str] = field(default_factory=set)
    # soft state, refreshed by every heartbeat and deliberately NOT
    # journaled: after a recovery it re-derives within one beat
    free_bytes: Optional[int] = None

    @property
    def address(self) -> Tuple[str, int]:
        return (self.host, self.port)

    def as_dict(self) -> dict:
        return {"node_id": self.node_id, "host": self.host,
                "port": self.port}


class MetaNode:
    """The metadata/placement service. Thread-safe; all state under one
    lock. Usable fully in-process (handlers are plain methods) or as a
    TCP service via :meth:`start`."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0,
                 replication: int = DEFAULT_REPLICATION,
                 heartbeat_timeout: float = 2.0,
                 tick_interval: Optional[float] = None,
                 auto_rebalance: bool = False,
                 clock: Callable[[], float] = time.monotonic,
                 journal_dir: Optional[str] = None,
                 journal_fsync: bool = True,
                 snapshot_every: int = SNAPSHOT_EVERY,
                 peers: Tuple[Tuple[str, int], ...] = (),
                 meta_id: Optional[str] = None,
                 lease_timeout: Optional[float] = None,
                 rank: int = 0,
                 policy: Optional[RetryPolicy] = None):
        self.host = host
        self._port = port
        self.replication = max(1, int(replication))
        self.heartbeat_timeout = heartbeat_timeout
        self.tick_interval = (heartbeat_timeout / 4.0
                              if tick_interval is None else tick_interval)
        self.auto_rebalance = auto_rebalance
        self._clock = clock
        self.detector = FailureDetector(heartbeat_timeout, clock)
        self._lock = threading.RLock()
        self.nodes: Dict[str, NodeInfo] = {}
        self.files: Dict[str, dict] = {}  # name -> {size, block_size, blocks}
        self.locations: Dict[str, Set[str]] = {}  # block id -> node ids
        self._commands: Dict[str, List[dict]] = {}  # node id -> queued cmds
        self._inflight: Dict[Tuple[str, str], float] = {}  # (blk, dst) -> t
        self._pending_drops: List[Tuple[str, str, str]] = []  # blk, src, dst
        self.lost_blocks: Set[str] = set()
        self.meta_id = meta_id or f"meta-{id(self) & 0xFFFF:04x}"
        self.stats: Dict[str, int] = {
            "heartbeats": 0, "plans": 0, "commits": 0, "lookups": 0,
            "re_replications": 0, "rebalance_moves": 0, "nodes_died": 0,
            "journal_records": 0, "snapshots": 0, "replayed_records": 0,
            "syncs_served": 0, "syncs_applied": 0, "promotions": 0,
            "errors_dropped": 0, "corrupt_reported": 0,
            "full_nodes_avoided": 0,
        }
        self.errors: deque = deque(maxlen=ERROR_BUFFER)
        # -- durability ------------------------------------------------
        self.seq = 0  # journal sequence of the last applied record
        self.epoch = 0  # current leader epoch (0 = pre-election)
        self.journal = None
        self.snapshot_every = max(1, int(snapshot_every))
        self._records_since_snapshot = 0
        self._tail: deque = deque(maxlen=SYNC_TAIL_MAX)
        if journal_dir is not None:
            self.journal, state, records = recover(journal_dir,
                                                   fsync=journal_fsync)
            if state is not None:
                self._load_state(state)
            for seq, tag, body in records:
                if seq <= self.seq:
                    # already reflected in the snapshot (a crash landed
                    # between write_snapshot's os.replace and the
                    # journal truncate); re-applying would re-reclaim
                    continue
                self._apply(tag, body)
                self.seq = seq
                self.stats["replayed_records"] += 1
            # every recovered node gets a full timeout to re-attach
            # before the detector may declare it dead
            for node_id in self.nodes:
                self.detector.beat(node_id)
        # -- failover --------------------------------------------------
        self.peers = [(p[0], int(p[1])) for p in peers]
        self.role = ROLE_STANDBY if self.peers else ROLE_LEADER
        self.policy = policy or RetryPolicy(
            attempts=1, connect_timeout=2.0,
            io_timeout=max(2.0, heartbeat_timeout))
        self._upstream: Optional[ControlChannel] = None
        self._leader_addr: Optional[Tuple[str, int]] = None
        self.lease = LeaderLease(
            lease_timeout if lease_timeout is not None
            else LEASE_TIMEOUTS * heartbeat_timeout,
            rank=rank, clock=clock)
        self._lsock: Optional[socket.socket] = None
        self._threads: List[threading.Thread] = []
        self._conns: List[socket.socket] = []
        self._stopping = False

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "MetaNode":
        lsock = socket.socket()
        lsock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        lsock.bind((self.host, self._port))
        lsock.listen(64)
        lsock.settimeout(0.25)
        self._lsock = lsock
        self._resolve_role()
        acc = threading.Thread(target=self._accept_loop,
                               name="meta-accept", daemon=True)
        acc.start()
        self._threads.append(acc)
        if self.tick_interval > 0:
            tk = threading.Thread(target=self._tick_loop,
                                  name="meta-tick", daemon=True)
            tk.start()
            self._threads.append(tk)
        return self

    def _resolve_role(self) -> None:
        """Join the metanode group: if any peer currently leads with an
        epoch at least ours, follow it (a restarted deposed leader
        rejoins as standby instead of split-braining); otherwise assume
        leadership with a bumped, journaled epoch."""
        best = None
        for addr in self.peers:
            try:
                ch = ControlChannel([addr], policy=self.policy)
                try:
                    info = ch.call(ClusterMsg.PING, {})
                finally:
                    ch.close()
            except (RetriesExhausted, ClusterError, OSError):
                continue
            if (info.get("role") == ROLE_LEADER
                    and info.get(EPOCH_FIELD, 0) >= self.epoch):
                if best is None or info[EPOCH_FIELD] > best[1]:
                    best = (addr, info[EPOCH_FIELD])
        with self._lock:
            if best is not None:
                self.role = ROLE_STANDBY
                self._leader_addr = best[0]
                self.epoch = max(self.epoch, best[1])
                self.lease.renew()
            else:
                self._assume_leadership(self.epoch + 1)
        if self.role == ROLE_STANDBY and self._upstream is None:
            self._upstream = ControlChannel(self.peers, policy=self.policy,
                                            what="leader")

    @property
    def address(self) -> Tuple[str, int]:
        assert self._lsock is not None, "metanode not started"
        return self._lsock.getsockname()[:2]

    def kill(self) -> None:
        """Crash the metanode: no snapshot, no goodbye — the listener
        and every open control connection are severed. Whatever the
        journal fsynced is all a restart gets (that is the point)."""
        self._stopping = True
        if self._lsock is not None:
            try:
                self._lsock.close()
            except OSError:
                pass
        for conn in list(self._conns):
            try:
                conn.close()
            except OSError:
                pass
        if self._upstream is not None:
            self._upstream.close()
        if self.journal is not None:
            self.journal.close()
        for t in self._threads:
            t.join(5.0)

    def stop(self, timeout: float = 5.0) -> None:
        """Graceful shutdown: checkpoint the journal into a snapshot
        (fast restart), then close."""
        if self.journal is not None and not self._stopping:
            try:
                self.snapshot()
            except OSError:
                pass
        self.kill()

    def __enter__(self) -> "MetaNode":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def _accept_loop(self) -> None:
        while not self._stopping:
            try:
                conn, _ = self._lsock.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            self._conns.append(conn)
            threading.Thread(target=self._serve_conn, args=(conn,),
                             daemon=True).start()

    def _serve_conn(self, conn: socket.socket) -> None:
        try:
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            while not self._stopping:
                try:
                    msg, body = recv_msg(conn)
                except (ConnectionError, OSError):
                    return
                try:
                    send_msg(conn, ClusterMsg.OK, self.dispatch(msg, body))
                except ClusterError as e:
                    err = {"error": str(e)}
                    if e.code:
                        err["code"] = e.code
                    if e.hint:
                        err["leader"] = list(e.hint)
                    send_msg(conn, ClusterMsg.ERR, err)
        except OSError:
            pass
        finally:
            try:
                self._conns.remove(conn)
            except ValueError:
                pass
            try:
                conn.close()
            except OSError:
                pass

    def _tick_loop(self) -> None:
        while not self._stopping:
            time.sleep(self.tick_interval)
            try:
                if self.role == ROLE_LEADER:
                    self.tick()
                    if self.auto_rebalance:
                        self.rebalance()
                    self.maybe_snapshot()
                else:
                    self.standby_poll()
            except Exception as e:  # noqa: BLE001 - the ticker must survive
                self._note_error(e)

    def _note_error(self, e: BaseException) -> None:
        if len(self.errors) == self.errors.maxlen:
            self.stats["errors_dropped"] += 1
        self.errors.append(e)

    # -- durability: journal append / apply / snapshot ---------------------

    def _append(self, tag: str, body: dict) -> None:
        """Write-ahead: the record is on disk (fsynced) before the
        caller applies it or acks the client; locked by caller."""
        self.seq += 1
        if self.journal is not None:
            self.journal.append(self.seq, tag, body)
        self._tail.append((self.seq, tag, body))
        self._records_since_snapshot += 1
        self.stats["journal_records"] += 1

    def _apply(self, tag: str, body: dict) -> None:
        """Apply one journal record to in-memory state. Replay, live
        mutation, and standby SYNC all funnel through here, so the
        three can never drift."""
        if tag == REC_REGISTER:
            node_id = body["node_id"]
            self.nodes[node_id] = NodeInfo(
                node_id, body["host"], int(body["port"]),
                self.nodes.get(node_id, NodeInfo(node_id, "", 0)).blocks,
            )
            self._commands.setdefault(node_id, [])
        elif tag == REC_COMMIT:
            old = self.files.get(body["name"])
            self.files[body["name"]] = {
                "size": int(body["size"]),
                "block_size": int(body["block_size"]),
                "blocks": [{"id": b["id"], "offset": int(b["offset"]),
                            "length": int(b["length"]),
                            "crc32": int(b["crc32"])}
                           for b in body["blocks"]],
            }
            # optimistic locations so an immediate get works before the
            # writers' next block reports arrive (and so a restarted
            # metanode can serve lookups before its first reports)
            for b in body["blocks"]:
                self.locations.setdefault(b["id"], set()).update(b["nodes"])
            if old is not None:
                # overwrite: reclaim only blocks the new version dropped
                # — a duplicated record (replay racing a snapshot) has
                # old == new and must not drop the live blocks
                kept = {b["id"] for b in body["blocks"]}
                stale = [b for b in old["blocks"] if b["id"] not in kept]
                if stale:
                    self._reclaim({"blocks": stale})
        elif tag == REC_DELETE:
            meta = self.files.pop(body["name"], None)
            if meta is not None:
                self._reclaim(meta)
        elif tag == REC_MOVE:
            mv = (body["block_id"], body["src"], body["dst"])
            if mv not in self._pending_drops:
                self._pending_drops.append(mv)
        elif tag == REC_MOVE_DONE:
            self._pending_drops = [
                (b, s, d) for (b, s, d) in self._pending_drops
                if not (b == body["block_id"] and d == body["dst"])]
        elif tag == REC_EPOCH:
            self.epoch = int(body["epoch"])
        else:
            raise ClusterError(f"unknown journal record tag {tag!r}")

    def _state_snapshot(self) -> dict:
        # every container is copied, never aliased: handle_sync's reply
        # is JSON-serialized AFTER the lock is released, racing live
        # commits if the snapshot held references into self.files
        with self._lock:
            return {
                "schema": 1,
                "seq": self.seq,
                "epoch": self.epoch,
                "nodes": [{**n.as_dict(), "blocks": sorted(n.blocks)}
                          for n in self.nodes.values()],
                "files": {name: {"size": m["size"],
                                 "block_size": m["block_size"],
                                 "blocks": [dict(b) for b in m["blocks"]]}
                          for name, m in self.files.items()},
                "locations": {b: sorted(h)
                              for b, h in self.locations.items()},
                "pending_drops": [list(m) for m in self._pending_drops],
            }

    def _load_state(self, state: dict) -> None:
        self.seq = int(state.get("seq", 0))
        self.epoch = int(state.get("epoch", 0))
        self.nodes = {
            n["node_id"]: NodeInfo(n["node_id"], n["host"], int(n["port"]),
                                   set(n.get("blocks", ())))
            for n in state.get("nodes", ())
        }
        self.files = {
            name: {"size": int(m["size"]),
                   "block_size": int(m["block_size"]),
                   "blocks": [dict(b) for b in m["blocks"]]}
            for name, m in (state.get("files") or {}).items()
        }
        self.locations = {b: set(h)
                          for b, h in (state.get("locations") or {}).items()}
        self._pending_drops = [tuple(m)
                               for m in state.get("pending_drops", ())]
        for node_id in self.nodes:
            self._commands.setdefault(node_id, [])

    def snapshot(self) -> None:
        """Atomic-replace snapshot + journal truncation (no-op without
        a journal)."""
        if self.journal is None:
            return
        with self._lock:  # capture + truncate atomically vs. appends
            state = self._state_snapshot()
            self.journal.write_snapshot(state)
            self._records_since_snapshot = 0
            self.stats["snapshots"] += 1

    def maybe_snapshot(self) -> None:
        if (self.journal is not None
                and self._records_since_snapshot >= self.snapshot_every):
            self.snapshot()

    # -- failover: leadership, standby sync --------------------------------

    def _assume_leadership(self, epoch: int) -> None:
        """Become the leader at ``epoch`` (journaled so a restart keeps
        the fencing order); locked by caller or single-threaded start."""
        with self._lock:
            self._append(REC_EPOCH, {"epoch": epoch,
                                     "meta_id": self.meta_id})
            self._apply(REC_EPOCH, {"epoch": epoch})
            self.role = ROLE_LEADER
            # give every known node a full timeout to find us before
            # the detector may declare it dead
            for node_id in self.nodes:
                self.detector.beat(node_id)

    def promote(self) -> None:
        """Standby -> leader: bump past every epoch we have ever seen
        (our own and the deposed leader's)."""
        seen = self.epoch
        if self._upstream is not None:
            seen = max(seen, self._upstream.epoch)
        self._assume_leadership(seen + 1)
        self.stats["promotions"] += 1

    def standby_poll(self) -> None:
        """One SYNC round against the peer list: tail new journal
        records (or a full snapshot when too far behind), renew the
        lease on success, and promote when the lease has expired."""
        if self.role != ROLE_STANDBY:
            return
        try:
            reply = self._upstream.call(ClusterMsg.SYNC, {"since": self.seq})
        except (RetriesExhausted, ClusterError, OSError) as e:
            self._note_error(e)
            if self.lease.expired():
                self.promote()
            return
        self._apply_sync(reply)
        self.lease.renew()
        self._leader_addr = self._upstream.current

    def _apply_sync(self, reply: dict) -> None:
        with self._lock:
            snap = reply.get("snapshot")
            if snap is not None:
                self._load_state(snap)
                if self.journal is not None:
                    self.journal.write_snapshot(snap)
                    self._records_since_snapshot = 0
                self.stats["syncs_applied"] += 1
            for seq, tag, body in reply.get("records", ()):
                if seq <= self.seq:
                    continue  # duplicate tail overlap: already applied
                if self.journal is not None:
                    self.journal.append(seq, tag, body)
                self._apply(tag, body)
                self.seq = seq
                self.stats["syncs_applied"] += 1
            got = reply.get(EPOCH_FIELD)
            if isinstance(got, int) and got > self.epoch:
                self.epoch = got

    def handle_ping(self, body: dict) -> dict:
        return {"meta_id": self.meta_id, "role": self.role,
                "seq": self.seq}

    def handle_sync(self, body: dict) -> dict:
        self._require_leader()
        since = int(body.get("since", 0))
        with self._lock:
            self.stats["syncs_served"] += 1
            if since > self.seq:
                # the poller is ahead of us (it promoted and wrote its
                # own records while we were deposed): full resync
                return {"snapshot": self._state_snapshot(),
                        "seq": self.seq}
            if since == self.seq:
                return {"records": [], "seq": self.seq}
            if self._tail and self._tail[0][0] <= since + 1:
                records = [[s, t, b] for s, t, b in self._tail if s > since]
                return {"records": records, "seq": self.seq}
            return {"snapshot": self._state_snapshot(), "seq": self.seq}

    def _require_leader(self) -> None:
        if self.role != ROLE_LEADER:
            raise ClusterError(
                f"{self.meta_id} is a standby (epoch {self.epoch})",
                code=ERR_NOT_LEADER, hint=self._leader_addr)

    # -- dispatch ----------------------------------------------------------

    def dispatch(self, msg: ClusterMsg, body: dict) -> dict:
        handlers = {
            ClusterMsg.REGISTER: self.handle_register,
            ClusterMsg.HEARTBEAT: self.handle_heartbeat,
            ClusterMsg.PLAN_PUT: self.handle_plan_put,
            ClusterMsg.COMMIT: self.handle_commit,
            ClusterMsg.LOOKUP: self.handle_lookup,
            ClusterMsg.LIST: self.handle_list,
            ClusterMsg.DELETE: self.handle_delete,
            ClusterMsg.STATE: self.handle_state,
            ClusterMsg.PING: self.handle_ping,
            ClusterMsg.SYNC: self.handle_sync,
        }
        h = handlers.get(msg)
        if h is None:
            raise ClusterError(f"unhandled control message {msg!r}")
        if msg not in (ClusterMsg.PING, ClusterMsg.SYNC,
                       ClusterMsg.STATE):
            self._require_leader()
        out = h(body)
        # every reply carries the sender's epoch: commit acks and
        # heartbeat command batches are fenceable at the receiver
        out.setdefault(EPOCH_FIELD, self.epoch)
        return out

    # -- node control plane ------------------------------------------------

    def handle_register(self, body: dict) -> dict:
        node_id = str(body["node_id"])
        rec = {"node_id": node_id, "host": str(body["host"]),
               "port": int(body["port"])}
        with self._lock:
            self._append(REC_REGISTER, rec)
            self._apply(REC_REGISTER, rec)
            self.detector.beat(node_id)
        return {"heartbeat_timeout": self.heartbeat_timeout,
                "replication": self.replication}

    def handle_heartbeat(self, body: dict) -> dict:
        node_id = str(body["node_id"])
        report = {str(b) for b in body.get("blocks", ())}
        with self._lock:
            node = self.nodes.get(node_id)
            if node is None:
                raise ClusterError(f"unregistered node {node_id!r}",
                                   code=ERR_UNREGISTERED)
            self.detector.beat(node_id)
            self.stats["heartbeats"] += 1
            # full block report: reconcile the location index by diff
            for blk in node.blocks - report:
                holders = self.locations.get(blk)
                if holders is not None:
                    holders.discard(node_id)
                    if not holders:
                        del self.locations[blk]
            for blk in report - node.blocks:
                self.locations.setdefault(blk, set()).add(node_id)
            node.blocks = report
            if body.get("free_bytes") is not None:
                node.free_bytes = int(body["free_bytes"])
            for blk in report:
                self._inflight.pop((blk, node_id), None)
                self.lost_blocks.discard(blk)
            # scrub verdicts: evict the condemned replica from the
            # location index explicitly — the block-report diff above
            # cannot be relied on, because a replica the client committed
            # optimistically may never have appeared in ``node.blocks``
            # (put and condemn within one beat interval). Then command
            # the node to reclaim the bad file; the next tick
            # re-replicates from a surviving good holder
            for blk in body.get("corrupt", ()):
                blk = str(blk)
                self.stats["corrupt_reported"] += 1
                node.blocks.discard(blk)
                holders = self.locations.get(blk)
                if holders is not None:
                    holders.discard(node_id)
                    if not holders:
                        del self.locations[blk]
                self._enqueue(node_id, {"op": CMD_DROP, "block_id": blk})
            self._settle_pending_drops()
            cmds = self._commands.get(node_id, [])
            self._commands[node_id] = []
        return {"commands": cmds}

    def _settle_pending_drops(self) -> None:
        """Rebalance moves drop their source replica only AFTER the
        destination's block report confirms the copy (never reduces
        replication on a failed move); locked by caller."""
        still = []
        for blk, src, dst in self._pending_drops:
            holders = self.locations.get(blk, set())
            if dst in holders and self.detector.is_alive(dst):
                if src in holders:
                    self._enqueue(src, {"op": CMD_DROP, "block_id": blk})
                self._append(REC_MOVE_DONE, {"block_id": blk, "dst": dst})
            elif (blk, dst) in self._inflight:
                still.append((blk, src, dst))
            else:
                # the move expired/failed — abandon the drop entirely
                self._append(REC_MOVE_DONE, {"block_id": blk, "dst": dst})
        self._pending_drops = still

    def _enqueue(self, node_id: str, cmd: dict) -> None:
        self._commands.setdefault(node_id, []).append(cmd)

    # -- failure detection + re-replication --------------------------------

    def tick(self) -> List[str]:
        """One failure-detector sweep + re-replication planning pass.
        Returns the nodes that died this tick. Under-replicated blocks
        (for ANY reason: a dead node, a degraded put, an expired copy
        command) get ``replicate`` commands enqueued on live holders,
        with in-flight suppression so repeated ticks do not spam
        duplicate copies."""
        with self._lock:
            newly_dead = self.detector.sweep()
            self.stats["nodes_died"] += len(newly_dead)
            alive = self.detector.alive() & set(self.nodes)
            now = self._clock()
            grace = REPLICATION_GRACE_TIMEOUTS * self.heartbeat_timeout
            self._inflight = {k: t for k, t in self._inflight.items()
                              if now - t <= grace and k[1] in alive}
            replicas, lost = placement.scan_replication(
                self.files, self.locations, alive, self.replication)
            self.lost_blocks |= lost
            load = {n: len(self.nodes[n].blocks) for n in alive}
            moves = placement.plan_replication(
                replicas, alive, self.replication, load,
                skip=self._inflight.keys(),
            )
            for mv in moves:
                self._command_copy(mv, now)
                self.stats["re_replications"] += 1
            return newly_dead

    def _command_copy(self, mv: placement.Move, now: float) -> None:
        target = self.nodes[mv.dst]
        self._enqueue(mv.src, {
            "op": CMD_REPLICATE, "block_id": mv.block_id,
            "target": target.as_dict(),
        })
        self._inflight[(mv.block_id, mv.dst)] = now

    def rebalance(self) -> List[placement.Move]:
        """Plan + enqueue moves that even out block counts across live
        nodes; sources are dropped only after the destination confirms
        (see :meth:`_settle_pending_drops`). Returns the planned moves."""
        with self._lock:
            alive = self.detector.alive() & set(self.nodes)
            holdings = {n: set(self.nodes[n].blocks) for n in alive}
            pending_dsts = {(b, d) for b, _s, d in self._pending_drops}
            now = self._clock()
            moves = []
            for mv in placement.plan_rebalance(holdings):
                if ((mv.block_id, mv.dst) in self._inflight
                        or (mv.block_id, mv.dst) in pending_dsts):
                    continue
                self._command_copy(mv, now)
                rec = {"block_id": mv.block_id, "src": mv.src,
                       "dst": mv.dst}
                self._append(REC_MOVE, rec)
                self._apply(REC_MOVE, rec)
                self.stats["rebalance_moves"] += 1
                moves.append(mv)
            return moves

    # -- client control plane ----------------------------------------------

    def handle_plan_put(self, body: dict) -> dict:
        name = str(body["name"])
        size = int(body["size"])
        block_size = int(body["block_size"])
        if block_size <= 0:
            raise ClusterError(f"bad block_size {block_size}")
        exclude = set(body.get("exclude") or ())
        with self._lock:
            alive = sorted(self.detector.alive() & set(self.nodes))
            if exclude:
                # a re-planning client saw these nodes fail mid-put; steer
                # around them, unless that would leave nothing to place on
                pref = [n for n in alive if n not in exclude]
                if pref:
                    alive = pref
            if not alive:
                raise ClusterError("no live data nodes to place on")
            # disk pressure: steer around nodes that advertised too little
            # free space for even one block of this put, unless that would
            # leave nothing to place on (a degraded plan still lets the
            # other replicas land; the full node refuses with disk_full)
            free = {n: self.nodes[n].free_bytes for n in alive}
            roomy = placement.filter_roomy(alive, free,
                                           min(block_size, max(size, 1)))
            if len(roomy) < len(alive):
                self.stats["full_nodes_avoided"] += len(alive) - len(roomy)
                alive = roomy
            rf = min(self.replication, len(alive))
            load = {n: len(self.nodes[n].blocks) for n in alive}
            n_blocks = (size + block_size - 1) // block_size
            plan = placement.plan_put(n_blocks, load, rf)
            blocks = []
            for i, nodes in enumerate(plan):
                off = i * block_size
                blocks.append({
                    "id": new_block_id(), "offset": off,
                    "length": min(block_size, size - off),
                    "nodes": [self.nodes[n].as_dict() for n in nodes],
                })
            self.stats["plans"] += 1
        return {"name": name, "size": size, "block_size": block_size,
                "rf": rf, "blocks": blocks}

    def handle_commit(self, body: dict) -> dict:
        name = str(body["name"])
        blocks = body["blocks"]
        with self._lock:
            for blk in blocks:
                if not blk["nodes"]:
                    raise ClusterError(
                        f"block {blk['id']} of {name!r} has no replicas")
            rec = {
                "name": name, "size": int(body["size"]),
                "block_size": int(body["block_size"]),
                "blocks": [{"id": str(b["id"]), "offset": int(b["offset"]),
                            "length": int(b["length"]),
                            "crc32": int(b["crc32"]),
                            "nodes": [str(n) for n in b["nodes"]]}
                           for b in blocks],
            }
            # write-ahead: the commit is fsynced before the ack — an
            # acknowledged commit survives kill -9
            self._append(REC_COMMIT, rec)
            self._apply(REC_COMMIT, rec)
            self.stats["commits"] += 1
        return {"ok": True, "blocks": len(blocks)}

    def handle_lookup(self, body: dict) -> dict:
        name = str(body["name"])
        with self._lock:
            meta = self.files.get(name)
            if meta is None:
                raise ClusterError(f"unknown file {name!r}")
            alive = self.detector.alive()
            blocks = []
            for blk in meta["blocks"]:
                live = sorted(self.locations.get(blk["id"], set()) & alive)
                blocks.append({
                    **blk,
                    "nodes": [self.nodes[n].as_dict() for n in live
                              if n in self.nodes],
                })
            self.stats["lookups"] += 1
            return {"name": name, "size": meta["size"],
                    "block_size": meta["block_size"], "blocks": blocks}

    def handle_list(self, body: dict) -> dict:
        prefix = str(body.get("prefix", ""))
        with self._lock:
            names = sorted(n for n in self.files if n.startswith(prefix))
        return {"names": names}

    def handle_delete(self, body: dict) -> dict:
        name = str(body["name"])
        with self._lock:
            if name not in self.files:
                raise ClusterError(f"unknown file {name!r}")
            self._append(REC_DELETE, {"name": name})
            self._apply(REC_DELETE, {"name": name})
        return {"ok": True}

    def _reclaim(self, meta: dict) -> None:
        """Enqueue drops for every replica of a dereferenced file's
        blocks; locked by caller."""
        for blk in meta["blocks"]:
            for node_id in self.locations.pop(blk["id"], set()):
                if node_id in self.nodes:
                    self._enqueue(node_id,
                                  {"op": CMD_DROP, "block_id": blk["id"]})
            self.lost_blocks.discard(blk["id"])

    def handle_state(self, body: dict) -> dict:
        with self._lock:
            alive = self.detector.alive()
            return {
                "replication": self.replication,
                "role": self.role,
                "meta_id": self.meta_id,
                "seq": self.seq,
                "nodes": [{**n.as_dict(), "alive": nid in alive,
                           "blocks": len(n.blocks)}
                          for nid, n in sorted(self.nodes.items())],
                "files": len(self.files),
                "under_replicated": sum(
                    1 for c in self._replica_counts() if 0 < c < self.replication),
                "lost": sorted(self.lost_blocks),
            }

    # -- observability (in-process) ----------------------------------------

    def _replica_counts(self) -> List[int]:
        alive = self.detector.alive()
        return [len(self.locations.get(blk["id"], set()) & alive)
                for meta in self.files.values() for blk in meta["blocks"]]

    def replication_of(self, name: str) -> List[int]:
        """Live replica count per block of ``name`` — the block-report
        view tests assert re-replication against."""
        with self._lock:
            meta = self.files.get(name)
            if meta is None:
                raise KeyError(name)
            alive = self.detector.alive()
            return [len(self.locations.get(blk["id"], set()) & alive)
                    for blk in meta["blocks"]]
