"""ClusterClient: striped, replicated put/get over a fleet of data nodes.

A put asks the MetaNode for a placement plan (``PLAN_PUT``), then writes
every block to each of its ``rf`` planned nodes **in parallel** over
pooled per-node xDFS sessions — one negotiated multi-channel session per
data node, every block a pipelined ``put`` future on it, so the stripe
rides the batched zero-copy datapath unchanged. The client computes a
CRC32 per block and ``COMMIT``\\ s the achieved replica sets: a write
that lost a replica mid-put (node died) still commits as long as every
block landed somewhere, and the MetaNode's re-replication heals it back
to ``rf``.

A get resolves block locations (``LOOKUP``), fans the fetches out across
replicas (block *i* prefers holder ``i mod len(holders)``, spreading
read load), verifies each block's CRC, and **fails over**: a dead node
or a corrupt replica just moves the fetch to the next live holder.

Metanode traffic rides a :class:`~repro.cluster.leader.ControlChannel`:
``meta_address`` may be one ``(host, port)`` or a *list* of metanode
addresses. Transport faults rotate the list with the policy's backoff;
``not_leader`` rejections hop to the hinted leader, so a client created
against the whole metanode group keeps working across a failover.
"""
from __future__ import annotations

import os
from typing import Dict, List, Optional

from repro.cluster.leader import ControlChannel
from repro.cluster.wire import (
    ClusterError,
    ClusterMsg,
    block_name,
)
from repro.core.api import SessionPool
from repro.core.faults import RetryPolicy
from repro.core.integrity import block_crc
from repro.core.session import BusyError, DEFAULT_BLOCK, DiskFullError

DEFAULT_CLUSTER_BLOCK = 4 << 20


def _crc(view) -> int:
    return block_crc(view)


class ClusterClient:
    """Client-side striping/replication over per-node pooled sessions."""

    def __init__(self, meta_address,
                 block_size: int = DEFAULT_CLUSTER_BLOCK,
                 n_channels: int = 2, engine: str = "mtedp",
                 batch_frames: int = 1,
                 session_block: int = DEFAULT_BLOCK,
                 pool: Optional[SessionPool] = None,
                 policy: Optional[RetryPolicy] = None,
                 connect_timeout: float = 10.0,
                 integrity: bool = True,
                 durability=0):
        self.block_size = block_size
        # one policy drives every deadline/retry decision: metanode dials,
        # metanode requests (including failover rotation), and the bounded
        # put re-plan loop
        self.policy = policy or RetryPolicy(connect_timeout=connect_timeout)
        self._ctrl = ControlChannel(meta_address, policy=self.policy)
        # integrity sessions by default: every block put leaves a CRC
        # manifest sidecar at the data node, which is what the scrubber
        # verifies at rest; ``durability`` is the requested commit policy
        # (the node's own floor still applies)
        self.pool = pool or SessionPool(
            n_channels=n_channels, engine=engine,
            block_size=min(session_block, block_size),
            batch_frames=batch_frames, integrity=integrity,
            durability=durability)
        self._owns_pool = pool is None
        self.stats: Dict[str, int] = {
            "puts": 0, "gets": 0, "blocks_written": 0, "blocks_read": 0,
            "replica_failovers": 0, "degraded_blocks": 0, "replans": 0,
            "busy_retries": 0, "disk_full_refusals": 0,
        }

    # -- metanode control --------------------------------------------------

    def _call(self, msg: ClusterMsg, body: dict) -> dict:
        # ClusterError replies pass straight through (a refused request is
        # not a transport fault); dead connections and not_leader redirects
        # fail over along the address list inside the channel
        return self._ctrl.call(msg, body)

    @property
    def meta_address(self):
        """The metanode address currently in use (failover-aware)."""
        return self._ctrl.current

    # -- put ---------------------------------------------------------------

    def put(self, name: str, data: Optional[bytes] = None,
            src: Optional[str] = None) -> dict:
        """Stripe ``data`` (or the contents of file ``src``) across the
        cluster under ``name``. Returns the commit summary."""
        if data is None:
            if src is None:
                raise ValueError("put needs data or src")
            with open(src, "rb") as f:
                data = f.read()
        view = memoryview(data)
        plan = self._call(ClusterMsg.PLAN_PUT, {
            "name": name, "size": len(view), "block_size": self.block_size,
        })
        blocks_plan: List[dict] = list(plan["blocks"])
        achieved: List[List[str]] = [[] for _ in blocks_plan]
        failed_nodes: set = set()

        def write_round(indices: List[int]) -> None:
            # fan out: every (block, replica) is one pipelined put future
            # on that node's pooled session; sessions serialize per node,
            # nodes stream in parallel
            writes = []  # (block index, node dict, future or error)
            for i in indices:
                blk = blocks_plan[i]
                piece = view[blk["offset"]:blk["offset"] + blk["length"]]
                for node in blk["nodes"]:
                    if node["node_id"] in achieved[i]:
                        continue
                    addr = (node["host"], node["port"])
                    try:
                        cli = self.pool.lease(addr)
                        fut = cli.put(None, block_name(blk["id"]), data=piece)
                    except Exception as e:  # noqa: BLE001 - dead node: the
                        # block's other replicas may still land
                        self.pool.invalidate(addr)
                        fut = e
                    writes.append((i, node, fut))
            for i, node, fut in writes:
                if isinstance(fut, Exception):
                    failed_nodes.add(node["node_id"])
                    continue
                try:
                    fut.result()
                    achieved[i].append(node["node_id"])
                    self.stats["blocks_written"] += 1
                except DiskFullError:
                    # typed refusal, not a transport fault: the session
                    # survives, so keep the pooled connection but steer the
                    # re-plan away from the full node
                    failed_nodes.add(node["node_id"])
                    self.stats["disk_full_refusals"] += 1
                except BusyError:
                    # transient admission pushback: the node is healthy, so
                    # do NOT exclude it from the re-plan — the replan loop's
                    # backoff is the retry delay it asked for
                    self.stats["busy_retries"] += 1
                except Exception:  # noqa: BLE001
                    failed_nodes.add(node["node_id"])
                    self.pool.invalidate((node["host"], node["port"]))

        write_round(list(range(len(blocks_plan))))
        pending = [i for i in range(len(blocks_plan)) if not achieved[i]]
        delays = iter(self.policy.delays())
        while pending:
            # every replica of some block failed: back off, then ask the
            # metanode for fresh placements that avoid the nodes we just
            # watched die, and retry only the holeful blocks
            try:
                delay = next(delays)
            except StopIteration:
                raise ClusterError(
                    f"block {pending[0]} of {name!r} failed on every "
                    f"planned node after {self.policy.attempts} rounds"
                    ) from None
            self.policy.sleep(delay)
            replan = self._call(ClusterMsg.PLAN_PUT, {
                "name": name, "size": len(view),
                "block_size": self.block_size,
                "exclude": sorted(failed_nodes),
            })
            self.stats["replans"] += 1
            for i in pending:
                blocks_plan[i] = replan["blocks"][i]
            write_round(pending)
            pending = [i for i in pending if not achieved[i]]
        blocks = []
        for i, blk in enumerate(blocks_plan):
            if len(achieved[i]) < len(blk["nodes"]):
                self.stats["degraded_blocks"] += 1
            piece = view[blk["offset"]:blk["offset"] + blk["length"]]
            blocks.append({
                "id": blk["id"], "offset": blk["offset"],
                "length": blk["length"], "crc32": _crc(piece),
                "nodes": achieved[i],
            })
        out = self._call(ClusterMsg.COMMIT, {
            "name": name, "size": len(view),
            "block_size": plan["block_size"], "blocks": blocks,
        })
        self.stats["puts"] += 1
        return out

    def put_file(self, src: str, name: Optional[str] = None) -> dict:
        return self.put(name or os.path.basename(src), src=src)

    # -- get ---------------------------------------------------------------

    def get(self, name: str) -> bytes:
        """Reassemble ``name`` from block replicas, verifying per-block
        CRCs and failing over dead/corrupt replicas."""
        meta = self._call(ClusterMsg.LOOKUP, {"name": name})
        out = bytearray(meta["size"])
        # first pass: one preferred replica per block, fanned out as
        # pipelined futures grouped by session
        fetches = []  # (block, holders after preferred, future or error)
        for i, blk in enumerate(meta["blocks"]):
            holders = blk["nodes"]
            if not holders:
                raise ClusterError(
                    f"block {i} of {name!r} has no live replica")
            order = holders[i % len(holders):] + holders[:i % len(holders)]
            fetches.append((blk, order[1:], self._start_fetch(order[0], blk)))
        retry = []
        for blk, rest, fut in fetches:
            data = self._finish_fetch(blk, fut)
            if data is None:
                retry.append((blk, rest))
            else:
                out[blk["offset"]:blk["offset"] + blk["length"]] = data
        # failover pass: walk the remaining replicas of each failed block
        for blk, rest in retry:
            data = None
            for node in rest:
                self.stats["replica_failovers"] += 1
                data = self._finish_fetch(blk, self._start_fetch(node, blk))
                if data is not None:
                    break
            if data is None:
                raise ClusterError(
                    f"no intact replica of block {blk['id']} ({name!r})")
            out[blk["offset"]:blk["offset"] + blk["length"]] = data
        self.stats["gets"] += 1
        return bytes(out)

    def get_file(self, name: str, dst: str) -> int:
        data = self.get(name)
        with open(dst, "wb") as f:
            f.write(data)
        return len(data)

    def _start_fetch(self, node: dict, blk: dict):
        addr = (node["host"], node["port"])
        try:
            return self.pool.lease(addr).get_bytes(block_name(blk["id"]))
        except Exception as e:  # noqa: BLE001 - dead node
            self.pool.invalidate(addr)
            return e

    def _finish_fetch(self, blk: dict, fut) -> Optional[bytes]:
        """Resolve one block fetch: None on transport failure or CRC
        mismatch (caller fails over to another replica)."""
        if isinstance(fut, Exception):
            return None
        try:
            data = fut.result().data
        except BusyError:
            self.stats["busy_retries"] += 1
            return None  # failover reads the block from another holder
        except Exception:  # noqa: BLE001
            return None
        if len(data) != blk["length"] or _crc(data) != blk["crc32"]:
            return None  # corrupt replica: as dead as a downed node
        self.stats["blocks_read"] += 1
        return data

    # -- namespace ---------------------------------------------------------

    def delete(self, name: str) -> None:
        self._call(ClusterMsg.DELETE, {"name": name})

    def list(self, prefix: str = "") -> List[str]:
        return self._call(ClusterMsg.LIST, {"prefix": prefix})["names"]

    def state(self) -> dict:
        return self._call(ClusterMsg.STATE, {})

    # -- lifecycle ---------------------------------------------------------

    def close(self) -> None:
        self._ctrl.close()
        if self._owns_pool:
            self.pool.close()

    def __enter__(self) -> "ClusterClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
