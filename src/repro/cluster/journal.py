"""MetaNode write-ahead journal + snapshot (control-plane durability).

The MetaNode's namespace used to be purely in-memory: one ``kill -9``
lost every file->block mapping even though every block survived on the
data nodes' disks. This module makes namespace mutations durable with
the classic WAL + checkpoint pair:

* **Journal** — an append-only log, one record per namespace mutation
  (``register`` / ``commit`` / ``delete`` / rebalance ``move`` and
  ``move_done`` / leader ``epoch`` bumps). Each record is a fixed
  little-endian header (magic, sequence number, tag, body length) plus a
  UTF-8 JSON body, protected by a CRC32 of header-and-body computed with
  the ``core/integrity.py`` helpers. ``append()`` optionally fsyncs
  before returning — a record the caller acked is on disk.
* **Snapshot** — a periodic atomic-replace (`tmp` + ``os.replace``)
  JSON image of the full state. After a snapshot lands, the journal is
  truncated: recovery cost is bounded by ``snapshot_every`` records, not
  by cluster lifetime.
* **Replay** — ``replay()`` is torn-tail tolerant: a crash mid-append
  leaves a short or CRC-broken final record, and replay simply stops at
  the first record that does not verify (everything before it was
  acked-and-fsynced and is applied; everything after was never acked).

Recovery = load snapshot -> replay journal -> let the next round of
full block reports reconcile the location index against reality. The
journal never records soft state (heartbeat liveness, queued commands,
in-flight copy timers): all of that re-derives from heartbeats, which is
what makes a restarted MetaNode converge on the truth instead of
trusting a stale image of it.

The record-tag table in docs/ARCHITECTURE.md ("Control-plane
durability") is normative and machine-checked against :data:`RECORDS`
by ``tests/test_docs.py``.
"""
from __future__ import annotations

import json
import os
import struct
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Tuple

from repro.core.integrity import crc32_update

REC_MAGIC = 0x784A4E4C  # 'xJNL'

# record header: magic, sequence number, tag, body length, CRC32 of the
# packed header-minus-crc concatenated with the body
_REC = struct.Struct("<IQHII")
REC_HEADER_SIZE = _REC.size

# a journal body is one namespace mutation; anything bigger is a torn or
# garbage record, not a message (same cap spirit as wire.MAX_BODY)
MAX_RECORD_BODY = 8 << 20

# Normative record-tag table (docs/ARCHITECTURE.md, machine-checked).
REC_REGISTER = "register"    # a data node joined (id, host, port)
REC_COMMIT = "commit"        # a striped put committed (name -> blocks)
REC_DELETE = "delete"        # a name was unlinked (blocks reclaimed)
REC_MOVE = "move"            # rebalance copy commanded; source drop pending
REC_MOVE_DONE = "move_done"  # the pending source drop settled or expired
REC_EPOCH = "epoch"          # leader epoch bump (election / promotion)

RECORDS: Dict[int, str] = {
    1: REC_REGISTER,
    2: REC_COMMIT,
    3: REC_DELETE,
    4: REC_MOVE,
    5: REC_MOVE_DONE,
    6: REC_EPOCH,
}
_TAG_IDS = {name: tag for tag, name in RECORDS.items()}

JOURNAL_NAME = "journal.log"
SNAPSHOT_NAME = "snapshot.json"


def _record_crc(head: bytes, body: bytes) -> int:
    return crc32_update(crc32_update(0, head), body)


def encode_record(seq: int, tag: str, body: dict) -> bytes:
    raw = json.dumps(body, separators=(",", ":")).encode()
    head = _REC.pack(REC_MAGIC, seq, _TAG_IDS[tag], len(raw), 0)
    crc = _record_crc(head[:-4], raw)
    return _REC.pack(REC_MAGIC, seq, _TAG_IDS[tag], len(raw), crc) + raw


def _scan(path) -> Iterator[Tuple[int, int, str, dict]]:
    """Yield ``(end_offset, seq, tag, body)`` for every intact record,
    stopping silently at the first torn/corrupt one. ``end_offset`` is
    the file offset just past the record — the length of the valid
    prefix so far."""
    path = Path(path)
    if not path.exists():
        return
    offset = 0
    with open(path, "rb") as f:
        while True:
            head = f.read(REC_HEADER_SIZE)
            if len(head) < REC_HEADER_SIZE:
                return  # torn tail: header never fully landed
            magic, seq, tag_id, length, crc = _REC.unpack(head)
            if magic != REC_MAGIC or tag_id not in RECORDS:
                return  # garbage where a record should start
            if length > MAX_RECORD_BODY:
                return
            raw = f.read(length)
            if len(raw) < length:
                return  # torn tail: body never fully landed
            if _record_crc(head[:-4], raw) != crc:
                return  # bit rot or a torn overwrite
            try:
                body = json.loads(raw)
            except ValueError:
                return
            offset += REC_HEADER_SIZE + length
            yield offset, seq, RECORDS[tag_id], body


def replay(path) -> Iterator[Tuple[int, str, dict]]:
    """Yield every intact ``(seq, tag, body)`` record of a journal file,
    stopping silently at the first torn/corrupt record (a crash mid-
    append, a partial disk write, or trailing garbage). Records past a
    bad one are never yielded: without the prefix they continue, their
    meaning cannot be trusted."""
    for _end, seq, tag, body in _scan(path):
        yield seq, tag, body


def valid_length(path) -> int:
    """Byte length of the journal's intact prefix — where replay stops."""
    end = 0
    for end, _seq, _tag, _body in _scan(path):
        pass
    return end


def truncate_torn_tail(path) -> int:
    """Cut the journal back to its last intact record; returns the bytes
    dropped. Without this, appending after a torn tail buries new
    acked-and-fsynced records BEHIND garbage that replay stops at — the
    next restart would silently lose every record written since."""
    path = Path(path)
    if not path.exists():
        return 0
    good = valid_length(path)
    size = path.stat().st_size
    if size <= good:
        return 0
    with open(path, "r+b") as f:
        f.truncate(good)
        f.flush()
        os.fsync(f.fileno())
    return size - good


class Journal:
    """Append-fsync write-ahead log under ``directory``.

    ``fsync=False`` trades durability of the last few records for
    latency (the benchmark's A/B knob); the format and replay path are
    identical either way.
    """

    def __init__(self, directory, fsync: bool = True):
        self.directory = Path(directory)
        self.directory.mkdir(parents=True, exist_ok=True)
        self.fsync = fsync
        self.path = self.directory / JOURNAL_NAME
        self.stats: Dict[str, int] = {
            "appends": 0, "fsyncs": 0, "bytes": 0, "truncations": 0,
            "torn_bytes_dropped": 0,
        }
        # a crash can leave a torn/corrupt tail; cut it off BEFORE any
        # append so new records land on the valid prefix, not after
        # garbage that replay stops at
        self.stats["torn_bytes_dropped"] = truncate_torn_tail(self.path)
        self._f = open(self.path, "ab")

    def append(self, seq: int, tag: str, body: dict) -> None:
        rec = encode_record(seq, tag, body)
        self._f.write(rec)
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())
            self.stats["fsyncs"] += 1
        self.stats["appends"] += 1
        self.stats["bytes"] += len(rec)

    def replay(self) -> List[Tuple[int, str, dict]]:
        return list(replay(self.path))

    def truncate(self) -> None:
        """Drop every record (called right after a snapshot landed: the
        snapshot now carries their effects)."""
        self._f.close()
        self._f = open(self.path, "wb")
        if self.fsync:
            os.fsync(self._f.fileno())
        self.stats["truncations"] += 1

    def close(self) -> None:
        try:
            self._f.close()
        except OSError:
            pass

    # -- snapshot ----------------------------------------------------------

    @property
    def snapshot_path(self) -> Path:
        return self.directory / SNAPSHOT_NAME

    def write_snapshot(self, state: dict) -> None:
        """Atomic-replace snapshot, then truncate the journal. A crash
        between the two steps is safe: replaying the old records onto
        the new snapshot is idempotent (they are already reflected in
        it, and apply functions overwrite rather than accumulate)."""
        write_snapshot(self.snapshot_path, state)
        self.truncate()

    def load_snapshot(self) -> Optional[dict]:
        return load_snapshot(self.snapshot_path)


def write_snapshot(path, state: dict) -> None:
    path = Path(path)
    tmp = path.with_suffix(".tmp")
    with open(tmp, "w") as f:
        json.dump(state, f, separators=(",", ":"))
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    # fsync the directory so the rename itself survives a power cut
    fd = os.open(path.parent, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def load_snapshot(path) -> Optional[dict]:
    """The snapshot state, or None when absent/unreadable (a torn tmp
    never replaces the previous good snapshot, so corruption here means
    no snapshot was ever completed)."""
    path = Path(path)
    if not path.exists():
        return None
    try:
        with open(path) as f:
            state = json.load(f)
    except (ValueError, OSError):
        return None
    return state if isinstance(state, dict) else None


def recover(directory, fsync: bool = True):
    """``(journal, state, records)``: open the journal under
    ``directory``, load the snapshot (None on a cold start), and replay
    the intact journal suffix. The caller applies ``state`` then every
    record in order."""
    journal = Journal(directory, fsync=fsync)
    state = journal.load_snapshot()
    records = journal.replay()
    return journal, state, records
