"""Background scrubber: re-verify data at rest against its manifests.

A put's bytes are CRC-verified as they cross the wire (integrity mode)
and committed with the negotiated durability policy, but nothing ever
re-reads a block after its pwritev lands — silent bit-rot surfaces only
when a client happens to fetch the bad replica. The :class:`Scrubber`
closes that gap: it walks a store directory pairing each data file with
its at-rest manifest (``<path>.xdfs-manifest``, written by a successful
integrity put), re-computes per-block CRC32s via the same libdeflate
path the wire uses (``integrity.block_crc``), and reports what it finds:

* ``corrupt`` — a data file whose bytes no longer match its manifest
  (or whose size drifted from the recorded one);
* ``missing`` — a manifest with no data file (the file vanished out
  from under its at-rest truth);
* ``unverified`` — data files with no manifest (non-integrity puts):
  counted, never flagged.

The scrubber never competes with foreground transfers: reads are capped
at ``rate_limit`` bytes/sec by a token-bucket pause between chunks, with
an injectable ``clock``/``sleep`` pair so tests drive whole passes on a
fake clock. One *pass* is bounded work (one walk of the store); callers
own the cadence — the :class:`~repro.cluster.datanode.DataNode` runs a
pass per scrub interval and folds the verdicts into its heartbeats,
where the MetaNode turns corrupt replicas into drop + re-replicate
repair commands.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

from repro.core.integrity import crc32_update
from repro.core.resume import MANIFEST_SUFFIX, ManifestSidecar

# read unit: big enough to amortize syscalls, small enough that the
# rate-limit pause granularity stays fine-grained
SCRUB_CHUNK = 1 << 20


@dataclass
class ScrubReport:
    """One pass's verdicts (paths are data-file paths, not sidecars)."""

    corrupt: List[str] = field(default_factory=list)
    missing: List[str] = field(default_factory=list)
    verified: int = 0  # files whose every manifest block matched
    unverified: int = 0  # data files with no manifest to check against
    bytes: int = 0  # data bytes actually read and CRC'd


class Scrubber:
    """Rate-limited at-rest verification of one store directory."""

    def __init__(self, root: str, rate_limit: Optional[float] = None,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep,
                 chunk: int = SCRUB_CHUNK):
        self.root = str(root)
        self.rate_limit = rate_limit  # bytes/sec; None = unthrottled
        self._clock = clock
        self._sleep = sleep
        self.chunk = max(1, int(chunk))
        # token-bucket state: the time before which the next read must wait
        self._resume_at = 0.0

    # -- rate limiting -----------------------------------------------------

    def _throttle(self, n_bytes: int) -> None:
        """Charge ``n_bytes`` against the budget; sleep off any debt."""
        if not self.rate_limit or n_bytes <= 0:
            return
        now = self._clock()
        start = max(now, self._resume_at)
        self._resume_at = start + n_bytes / self.rate_limit
        if self._resume_at > now:
            self._sleep(self._resume_at - now)

    # -- verification ------------------------------------------------------

    def verify_file(self, path: str) -> Optional[bool]:
        """``True`` = every manifest block matches, ``False`` = corrupt
        or missing data, ``None`` = no manifest (nothing to check)."""
        loaded = ManifestSidecar(path).load_any()
        if loaded is None:
            return None
        size, _block_size, manifest = loaded
        try:
            if os.path.getsize(path) != size:
                return False
            with open(path, "rb", buffering=0) as f:
                for off in sorted(manifest.blocks):
                    length, want = manifest.blocks[off]
                    f.seek(off)
                    crc = 0
                    left = length
                    while left > 0:
                        piece = f.read(min(self.chunk, left))
                        if not piece:
                            return False  # short read: truncated file
                        crc = crc32_update(crc, piece)
                        left -= len(piece)
                        self._last_pass_bytes += len(piece)
                        self._throttle(len(piece))
                    if crc != want:
                        return False
        except OSError:
            return False
        return True

    def scrub_once(self) -> ScrubReport:
        """One full pass over the store. Deterministic order (sorted
        walk) so fake-clock tests know exactly what a pass reads."""
        report = ScrubReport()
        self._last_pass_bytes = 0
        for dirpath, dirs, files in os.walk(self.root):
            dirs.sort()
            names = set(files)
            for name in sorted(files):
                if not name.endswith(MANIFEST_SUFFIX):
                    continue
                data_name = name[: -len(MANIFEST_SUFFIX)]
                data_path = os.path.join(dirpath, data_name)
                if data_name not in names:
                    report.missing.append(data_path)
                    continue
                ok = self.verify_file(data_path)
                if ok:
                    report.verified += 1
                elif ok is False:
                    report.corrupt.append(data_path)
        # data files with no manifest: present, just not verifiable
        for dirpath, dirs, files in os.walk(self.root):
            names = set(files)
            for name in files:
                if (not name.endswith(MANIFEST_SUFFIX)
                        and f"{name}{MANIFEST_SUFFIX}" not in names
                        and ".xdfs-" not in name):
                    report.unverified += 1
        report.bytes = self._last_pass_bytes
        return report

    _last_pass_bytes = 0
