"""DataNode: one storage node of the cluster = one ``XdfsServer``.

A data node is deliberately thin: the tuned single-host datapath (the
persistent session API with its zero-copy, syscall-batched engines) IS
the block transport, unchanged. This module only adds the control-plane
glue:

* a block store — the wrapped ``XdfsServer``'s root directory, holding
  one ``blk_<id>.bin`` file per block (clients and peers read/write
  them over ordinary xDFS sessions);
* registration + periodic heartbeats to the MetaNode, each carrying a
  **full block report** (scanned from the store, so the report is the
  ground truth even after a crash/restart);
* execution of the commands piggybacked on heartbeat replies:
  ``replicate`` pushes a block to a peer data node over a pooled xDFS
  session (node-to-node copy on the same zero-copy path — file-backed
  ``put`` means mmap/sendfile end to end), ``drop`` unlinks it.

``kill()`` simulates a crash for tests and demos: the server stops
accepting, in-flight sessions die, and heartbeats stop — the MetaNode's
failure detector takes it from there.
"""
from __future__ import annotations

import os
import socket
import threading
import uuid
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.cluster.wire import (
    CMD_DROP,
    CMD_REPLICATE,
    ClusterMsg,
    block_name,
    request,
)
from repro.core.api import SessionPool, XdfsServer
from repro.core.faults import RetryPolicy

BLOCK_PREFIX = "blk_"
BLOCK_SUFFIX = ".bin"


class DataNode:
    """One cluster storage node: an ``XdfsServer`` block store plus the
    MetaNode control loop. ``auto_heartbeat=False`` hands the beat to
    the caller (:meth:`heartbeat_once`) for deterministic tests."""

    def __init__(self, meta_address: Tuple[str, int], root: str,
                 node_id: Optional[str] = None, engine: str = "mtedp",
                 host: str = "127.0.0.1",
                 heartbeat_interval: float = 0.5,
                 auto_heartbeat: bool = True,
                 n_channels: int = 2, batch_frames: int = 1,
                 pool: Optional[SessionPool] = None,
                 connect_timeout: float = 10.0,
                 policy: Optional[RetryPolicy] = None):
        self.meta_address = (meta_address[0], int(meta_address[1]))
        # two attempts preserves the historical redial-once behaviour;
        # pass a policy to trade it for deeper backoff
        self.policy = policy or RetryPolicy(attempts=2,
                                            connect_timeout=connect_timeout)
        self.root = Path(root)
        self.node_id = node_id or f"dn-{uuid.uuid4().hex[:8]}"
        self.heartbeat_interval = heartbeat_interval
        self.auto_heartbeat = auto_heartbeat
        self.server = XdfsServer(engine=engine, root=str(self.root),
                                 host=host)
        # node-to-node transport: one pooled session per peer, so many
        # re-replication copies to the same survivor share a negotiation
        self.pool = pool or SessionPool(n_channels=n_channels,
                                        engine=engine,
                                        batch_frames=batch_frames)
        self._owns_pool = pool is None
        self._ctrl: Optional[socket.socket] = None
        self._ctrl_lock = threading.Lock()
        self._hb_thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self.errors: List[BaseException] = []
        self.stats: Dict[str, int] = {
            "heartbeats": 0, "replicated_out": 0, "dropped": 0,
            "command_errors": 0,
        }

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "DataNode":
        self.root.mkdir(parents=True, exist_ok=True)
        self.server.start()
        self.register()
        if self.auto_heartbeat:
            self._hb_thread = threading.Thread(
                target=self._heartbeat_loop,
                name=f"heartbeat-{self.node_id}", daemon=True)
            self._hb_thread.start()
        return self

    @property
    def address(self) -> Tuple[str, int]:
        return self.server.address

    def kill(self) -> None:
        """Crash the node: sever every open session (clients holding
        pooled sessions see the peer die mid-transfer), stop serving
        blocks, and stop heartbeating. The MetaNode notices via its
        failure detector."""
        self._stop.set()
        with self._ctrl_lock:
            if self._ctrl is not None:
                try:
                    self._ctrl.close()
                except OSError:
                    pass
                self._ctrl = None
        self.server.abort()
        if self._hb_thread is not None:
            self._hb_thread.join(5.0)
        if self._owns_pool:
            self.pool.close()

    def stop(self) -> None:
        self.kill()

    def __enter__(self) -> "DataNode":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- control loop ------------------------------------------------------

    def _meta_request(self, msg: ClusterMsg, body: dict) -> dict:
        """One request on the persistent MetaNode control connection,
        re-dialing (policy-bounded) if the connection went away."""
        def attempt() -> dict:
            if self._ctrl is None:
                self._ctrl = socket.create_connection(
                    self.meta_address, timeout=self.policy.connect_timeout)
                self._ctrl.setsockopt(socket.IPPROTO_TCP,
                                      socket.TCP_NODELAY, 1)
            try:
                return request(self._ctrl, msg, body)
            except (ConnectionError, OSError):
                try:
                    self._ctrl.close()
                except OSError:
                    pass
                self._ctrl = None
                raise

        with self._ctrl_lock:
            return self.policy.run(attempt, what=f"metanode {msg.name}")

    def register(self) -> dict:
        host, port = self.server.address
        return self._meta_request(ClusterMsg.REGISTER, {
            "node_id": self.node_id, "host": host, "port": port,
        })

    def block_ids(self) -> List[str]:
        """The store's ground truth, scanned fresh for every report."""
        out = []
        for p in self.root.glob(f"{BLOCK_PREFIX}*{BLOCK_SUFFIX}"):
            out.append(p.name[len(BLOCK_PREFIX):-len(BLOCK_SUFFIX)])
        return sorted(out)

    def heartbeat_once(self) -> List[dict]:
        """Send one heartbeat + block report; execute every command the
        MetaNode piggybacked on the reply. Returns those commands."""
        reply = self._meta_request(ClusterMsg.HEARTBEAT, {
            "node_id": self.node_id, "blocks": self.block_ids(),
        })
        self.stats["heartbeats"] += 1
        cmds = reply.get("commands", [])
        for cmd in cmds:
            try:
                self._execute(cmd)
            except Exception as e:  # noqa: BLE001 - a failed copy must not
                # kill the beat loop; the MetaNode replans after the grace
                self.stats["command_errors"] += 1
                self.errors.append(e)
        return cmds

    def _heartbeat_loop(self) -> None:
        while not self._stop.wait(self.heartbeat_interval):
            try:
                self.heartbeat_once()
            except Exception as e:  # noqa: BLE001 - meta may be restarting
                self.errors.append(e)

    # -- command execution -------------------------------------------------

    def _execute(self, cmd: dict) -> None:
        op = cmd.get("op")
        if op == CMD_REPLICATE:
            self._replicate(cmd["block_id"], cmd["target"])
        elif op == CMD_DROP:
            self._drop(cmd["block_id"])
        else:
            raise ValueError(f"unknown cluster command {op!r}")

    def _replicate(self, block_id: str, target: dict) -> None:
        """Node-to-node copy: push one block file to a peer data node
        over a pooled xDFS session (file-backed put = the zero-copy
        mmap/sendfile send path, negotiated once per peer)."""
        path = self.root / block_name(block_id)
        addr = (target["host"], int(target["port"]))
        try:
            cli = self.pool.lease(addr)
            cli.put(str(path), block_name(block_id)).result()
            self.stats["replicated_out"] += 1
        except Exception:
            self.pool.invalidate(addr)
            raise

    def _drop(self, block_id: str) -> None:
        try:
            os.unlink(self.root / block_name(block_id))
            self.stats["dropped"] += 1
        except FileNotFoundError:
            pass
