"""DataNode: one storage node of the cluster = one ``XdfsServer``.

A data node is deliberately thin: the tuned single-host datapath (the
persistent session API with its zero-copy, syscall-batched engines) IS
the block transport, unchanged. This module only adds the control-plane
glue:

* a block store — the wrapped ``XdfsServer``'s root directory, holding
  one ``blk_<id>.bin`` file per block (clients and peers read/write
  them over ordinary xDFS sessions);
* registration + periodic heartbeats to the MetaNode, each carrying a
  **full block report** (scanned from the store, so the report is the
  ground truth even after a crash/restart);
* execution of the commands piggybacked on heartbeat replies:
  ``replicate`` pushes a block to a peer data node over a pooled xDFS
  session (node-to-node copy on the same zero-copy path — file-backed
  ``put`` means mmap/sendfile end to end), ``drop`` unlinks it.

Control traffic rides a :class:`~repro.cluster.leader.ControlChannel`:
``meta_address`` may be one ``(host, port)`` or a *list* of metanode
addresses, and the node fails over between them (transport faults back
off and rotate; ``not_leader`` rejections hop to the hinted leader). A
heartbeat answered with the ``unregistered`` error code — the metanode
restarted with a blank namespace, or a fresh standby promoted — makes
the node re-``REGISTER`` and retry, so a control-plane wipe heals
itself on the next beat. Command batches are **epoch-fenced**: a reply
stamped with a lower leader epoch than the channel has ever seen comes
from a deposed leader and its replicate/drop commands are discarded
(``stats["fenced_commands"]``).

``kill()`` simulates a crash for tests and demos: the server stops
accepting, in-flight sessions die, and heartbeats stop — the MetaNode's
failure detector takes it from there.
"""
from __future__ import annotations

import os
import threading
import uuid
from collections import deque
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.cluster.leader import ControlChannel
from repro.cluster.scrub import Scrubber
from repro.cluster.wire import (
    CMD_DROP,
    CMD_REPLICATE,
    ERR_UNREGISTERED,
    ClusterError,
    ClusterMsg,
    block_name,
)
from repro.core.api import SessionPool, XdfsServer
from repro.core.engines.base import store_free_bytes
from repro.core.faults import RetryPolicy
from repro.core.resume import ManifestSidecar, ResumeSidecar, sweep_sidecars

BLOCK_PREFIX = "blk_"
BLOCK_SUFFIX = ".bin"
# recent control/command failures kept for inspection; older ones are
# counted in stats["errors_dropped"] instead of growing without bound
ERROR_BUFFER = 64


class DataNode:
    """One cluster storage node: an ``XdfsServer`` block store plus the
    MetaNode control loop. ``auto_heartbeat=False`` hands the beat to
    the caller (:meth:`heartbeat_once`) for deterministic tests."""

    def __init__(self, meta_address, root: str,
                 node_id: Optional[str] = None, engine: str = "mtedp",
                 host: str = "127.0.0.1",
                 heartbeat_interval: float = 0.5,
                 auto_heartbeat: bool = True,
                 n_channels: int = 2, batch_frames: int = 1,
                 pool: Optional[SessionPool] = None,
                 connect_timeout: float = 10.0,
                 policy: Optional[RetryPolicy] = None,
                 durability: int = 0,
                 capacity_bytes: Optional[int] = None,
                 scrub_rate: Optional[float] = None,
                 scrub_interval: Optional[float] = None,
                 clock=None, scrub_sleep=None):
        # two attempts preserves the historical redial-once behaviour;
        # pass a policy to trade it for deeper backoff
        self.policy = policy or RetryPolicy(attempts=2,
                                            connect_timeout=connect_timeout,
                                            io_timeout=10.0)
        self._ctrl = ControlChannel(meta_address, policy=self.policy)
        self.root = Path(root)
        self.node_id = node_id or f"dn-{uuid.uuid4().hex[:8]}"
        self.heartbeat_interval = heartbeat_interval
        self.auto_heartbeat = auto_heartbeat
        self.server = XdfsServer(engine=engine, root=str(self.root),
                                 host=host, durability=durability,
                                 capacity_bytes=capacity_bytes)
        self.capacity_bytes = capacity_bytes
        # at-rest verification: a rate-limited pass over the store pairing
        # block files with their .xdfs-manifest sidecars; injectable
        # clock/sleep keep chaos tests deterministic
        import time as _time

        self.scrubber = Scrubber(str(self.root), rate_limit=scrub_rate,
                                 clock=clock or _time.monotonic,
                                 sleep=scrub_sleep or _time.sleep)
        self.scrub_interval = scrub_interval
        self._scrub_thread: Optional[threading.Thread] = None
        # blocks the scrubber condemned: excluded from block reports
        # (the MetaNode treats them as gone and re-replicates) and
        # advertised under "corrupt" until the drop command lands
        self._corrupt: set = set()
        # node-to-node transport: one pooled session per peer, so many
        # re-replication copies to the same survivor share a negotiation.
        # integrity=True: a re-replicated block lands with a manifest at
        # the target, so the rebuilt replica is scrubbable too
        self.pool = pool or SessionPool(n_channels=n_channels,
                                        engine=engine,
                                        batch_frames=batch_frames,
                                        integrity=True,
                                        durability=durability)
        self._owns_pool = pool is None
        self._hb_thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self.errors: deque = deque(maxlen=ERROR_BUFFER)
        self.stats: Dict[str, int] = {
            "heartbeats": 0, "replicated_out": 0, "dropped": 0,
            "command_errors": 0, "reregisters": 0, "fenced_commands": 0,
            "errors_dropped": 0, "scrub_passes": 0, "scrub_corrupt": 0,
            "sidecars_swept": 0,
        }

    @property
    def meta_address(self) -> Tuple[str, int]:
        """The metanode address currently in use (failover-aware)."""
        return self._ctrl.current

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "DataNode":
        self.root.mkdir(parents=True, exist_ok=True)
        # a crashed transfer leaves orphan sidecars and atomic-commit temp
        # files; no session is live at startup, so sweeping is safe
        self.stats["sidecars_swept"] += len(sweep_sidecars(str(self.root)))
        self.server.start()
        self.register()
        if self.auto_heartbeat:
            self._hb_thread = threading.Thread(
                target=self._heartbeat_loop,
                name=f"heartbeat-{self.node_id}", daemon=True)
            self._hb_thread.start()
        if self.scrub_interval is not None:
            self._scrub_thread = threading.Thread(
                target=self._scrub_loop,
                name=f"scrub-{self.node_id}", daemon=True)
            self._scrub_thread.start()
        return self

    @property
    def address(self) -> Tuple[str, int]:
        return self.server.address

    def kill(self) -> None:
        """Crash the node: sever every open session (clients holding
        pooled sessions see the peer die mid-transfer), stop serving
        blocks, and stop heartbeating. The MetaNode notices via its
        failure detector."""
        self._stop.set()
        self._ctrl.close()
        self.server.abort()
        if self._hb_thread is not None:
            self._hb_thread.join(5.0)
        if self._scrub_thread is not None:
            self._scrub_thread.join(5.0)
        if self._owns_pool:
            self.pool.close()

    def stop(self) -> None:
        self.kill()

    def __enter__(self) -> "DataNode":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- control loop ------------------------------------------------------

    def _meta_request(self, msg: ClusterMsg, body: dict) -> dict:
        """One request over the failover control channel."""
        return self._ctrl.call(msg, body)

    def register(self) -> dict:
        host, port = self.server.address
        return self._meta_request(ClusterMsg.REGISTER, {
            "node_id": self.node_id, "host": host, "port": port,
        })

    def block_ids(self) -> List[str]:
        """The store's ground truth, scanned fresh for every report.
        Blocks the scrubber condemned are EXCLUDED — the MetaNode must
        not count a corrupt replica as a live copy."""
        out = []
        for p in self.root.glob(f"{BLOCK_PREFIX}*{BLOCK_SUFFIX}"):
            bid = p.name[len(BLOCK_PREFIX):-len(BLOCK_SUFFIX)]
            if bid not in self._corrupt:
                out.append(bid)
        return sorted(out)

    def free_bytes(self) -> int:
        """Advertised store headroom (statvfs, or the synthetic capacity
        minus current usage when ``capacity_bytes`` is set)."""
        return store_free_bytes(str(self.root), self.capacity_bytes)

    # -- scrubbing ---------------------------------------------------------

    def scrub_once(self):
        """One deterministic scrub pass; condemned block ids feed the
        next heartbeat (and stay condemned until the drop lands)."""
        report = self.scrubber.scrub_once()
        self.stats["scrub_passes"] += 1
        for path in report.corrupt + report.missing:
            name = os.path.basename(path)
            if name.startswith(BLOCK_PREFIX) and name.endswith(BLOCK_SUFFIX):
                bid = name[len(BLOCK_PREFIX):-len(BLOCK_SUFFIX)]
                if bid not in self._corrupt:
                    self._corrupt.add(bid)
                    self.stats["scrub_corrupt"] += 1
        return report

    def _scrub_loop(self) -> None:
        while not self._stop.wait(self.scrub_interval):
            try:
                self.scrub_once()
            except Exception as e:  # noqa: BLE001 - scrub must not die
                self._note_error(e)

    def heartbeat_once(self) -> List[dict]:
        """Send one heartbeat + block report; execute every command the
        MetaNode piggybacked on the reply (unless the reply is fenced as
        coming from a deposed leader). A metanode that forgot us —
        restarted blank, or a freshly promoted standby whose journal
        predates our registration — answers ``unregistered``; recover by
        re-registering and beating again. Returns the executed commands."""
        body = {"node_id": self.node_id, "blocks": self.block_ids(),
                "free_bytes": self.free_bytes()}
        if self._corrupt:
            body["corrupt"] = sorted(self._corrupt)
        try:
            reply = self._meta_request(ClusterMsg.HEARTBEAT, body)
        except ClusterError as e:
            if e.code != ERR_UNREGISTERED:
                raise
            self.stats["reregisters"] += 1
            self.register()
            reply = self._meta_request(ClusterMsg.HEARTBEAT, body)
        self.stats["heartbeats"] += 1
        if self._ctrl.stale(reply):
            # a deposed leader answered before noticing its demotion:
            # executing its commands could resurrect deleted blocks or
            # drop live ones, so the whole batch is a no-op
            self.stats["fenced_commands"] += len(reply.get("commands", ()))
            return []
        cmds = reply.get("commands", [])
        for cmd in cmds:
            try:
                self._execute(cmd)
            except Exception as e:  # noqa: BLE001 - a failed copy must not
                # kill the beat loop; the MetaNode replans after the grace
                self.stats["command_errors"] += 1
                self._note_error(e)
        return cmds

    def _heartbeat_loop(self) -> None:
        while not self._stop.wait(self.heartbeat_interval):
            try:
                self.heartbeat_once()
            except Exception as e:  # noqa: BLE001 - meta may be restarting
                self._note_error(e)

    def _note_error(self, e: BaseException) -> None:
        if len(self.errors) == self.errors.maxlen:
            self.stats["errors_dropped"] += 1
        self.errors.append(e)

    # -- command execution -------------------------------------------------

    def _execute(self, cmd: dict) -> None:
        op = cmd.get("op")
        if op == CMD_REPLICATE:
            self._replicate(cmd["block_id"], cmd["target"])
        elif op == CMD_DROP:
            self._drop(cmd["block_id"])
        else:
            raise ValueError(f"unknown cluster command {op!r}")

    def _replicate(self, block_id: str, target: dict) -> None:
        """Node-to-node copy: push one block file to a peer data node
        over a pooled xDFS session (file-backed put = the zero-copy
        mmap/sendfile send path, negotiated once per peer)."""
        path = self.root / block_name(block_id)
        addr = (target["host"], int(target["port"]))
        try:
            cli = self.pool.lease(addr)
            cli.put(str(path), block_name(block_id)).result()
            self.stats["replicated_out"] += 1
        except Exception:
            self.pool.invalidate(addr)
            raise

    def _drop(self, block_id: str) -> None:
        path = self.root / block_name(block_id)
        try:
            os.unlink(path)
            self.stats["dropped"] += 1
        except FileNotFoundError:
            pass
        # GC the block's transfer state with it: a dangling sidecar would
        # make the scrubber report the block as "missing" forever
        ResumeSidecar(str(path)).clear()
        ManifestSidecar(str(path)).clear()
        self._corrupt.discard(block_id)
