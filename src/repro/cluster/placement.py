"""Block placement, re-replication, and rebalance planners.

Pure functions over snapshots of cluster state (who is alive, who holds
what), so the MetaNode's policy is unit-testable without sockets or
clocks. All plans are deterministic: ties break on node id, which keeps
the fake-clock tests exact and makes re-planning idempotent.

The planners deal in :class:`Move` records — ``(block_id, src, dst)`` —
which the MetaNode turns into ``replicate`` commands piggybacked on
heartbeat replies (see ``wire.CMD_REPLICATE``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Mapping, Sequence, Set, Tuple


@dataclass(frozen=True)
class Move:
    """Copy ``block_id`` from data node ``src`` to data node ``dst``."""

    block_id: str
    src: str
    dst: str


def filter_roomy(nodes: Sequence[str], free: Mapping[str, int],
                 need: int) -> List[str]:
    """The nodes with at least ``need`` advertised free bytes.

    ``free`` maps node id -> heartbeat-advertised free space (``None`` =
    the node has not said, which counts as roomy — refusing to place on
    a node for silence would brick a fresh cluster). When EVERY node is
    too full the original list comes back unchanged: a doomed-but-typed
    ``disk_full`` refusal beats an unplaceable put, and the caller's
    stats can tell the difference."""
    roomy = [n for n in nodes if free.get(n) is None or free[n] >= need]
    return roomy if roomy else list(nodes)


def choose_replicas(load: Mapping[str, int], k: int,
                    exclude: Iterable[str] = ()) -> List[str]:
    """The ``k`` least-loaded nodes not in ``exclude`` (load = blocks
    held + blocks already planned onto the node this round, so a striped
    plan spreads instead of piling onto one empty node). Returns fewer
    than ``k`` when the cluster is smaller than the replication factor —
    the caller decides whether a degraded placement is acceptable."""
    banned = set(exclude)
    ranked = sorted((n for n in load if n not in banned),
                    key=lambda n: (load[n], n))
    return ranked[:k]


def plan_put(n_blocks: int, load: Dict[str, int], rf: int) -> List[List[str]]:
    """Placement for a striped put: per block, ``rf`` distinct nodes.
    Mutates ``load`` as it plans so consecutive blocks stripe across the
    fleet instead of all landing on the initially-emptiest node."""
    plan: List[List[str]] = []
    for _ in range(n_blocks):
        nodes = choose_replicas(load, rf)
        for n in nodes:
            load[n] += 1
        plan.append(nodes)
    return plan


def scan_replication(files: Mapping[str, dict],
                     locations: Mapping[str, Set[str]],
                     alive: Set[str], rf: int
                     ) -> Tuple[Dict[str, Set[str]], Set[str]]:
    """One pass over the namespace: ``(under_replicated, lost)``.

    ``under_replicated`` maps block id -> its live holders for every
    block below ``rf`` that still has at least one live copy (the input
    :func:`plan_replication` consumes); ``lost`` is the set of blocks
    with zero live holders. Pure — the MetaNode calls it under its lock
    with snapshots of its state, and recovery reuses it to re-derive
    health from the first post-restart block reports."""
    under: Dict[str, Set[str]] = {}
    lost: Set[str] = set()
    for meta in files.values():
        for blk in meta["blocks"]:
            live = locations.get(blk["id"], set()) & alive
            if not live:
                lost.add(blk["id"])
            elif len(live) < rf:
                under[blk["id"]] = live
    return under, lost


def plan_replication(replicas: Mapping[str, Set[str]], alive: Set[str],
                     rf: int, load: Mapping[str, int],
                     skip: Iterable[Tuple[str, str]] = ()) -> List[Move]:
    """Moves that bring every under-replicated block back to ``rf``.

    ``replicas`` maps block id -> nodes CURRENTLY reporting it; only
    live holders count as sources and only live non-holders as targets.
    ``skip`` is the in-flight suppression set — ``(block_id, dst)``
    pairs already commanded and not yet expired, so re-planning every
    detector tick does not spam duplicate copies. Blocks with zero live
    replicas are unrecoverable and yield no moves (the MetaNode reports
    them as lost instead)."""
    skipset = set(skip)
    budget = dict(load)  # planned targets count toward this round's load
    moves: List[Move] = []
    for block_id in sorted(replicas):
        holders = sorted(replicas[block_id] & alive)
        if not holders:
            continue  # lost: no live source to copy from
        missing = rf - len(holders)
        if missing <= 0:
            continue
        targets = choose_replicas(budget, missing, exclude=holders)
        for i, dst in enumerate(targets):
            if (block_id, dst) in skipset:
                continue
            src = holders[i % len(holders)]  # spread source read load
            budget[dst] += 1
            moves.append(Move(block_id, src, dst))
    return moves


def plan_rebalance(holdings: Mapping[str, Set[str]],
                   max_spread: int = 1) -> List[Move]:
    """Moves that even out block counts across live nodes.

    Repeatedly moves one block from the fullest node to the emptiest
    node that does not already hold it, until the spread (max - min
    blocks per node) is within ``max_spread``. The returned moves are a
    copy plan only — the MetaNode drops the source replica AFTER the
    destination's block report confirms the copy landed, so a crash
    mid-rebalance never reduces replication."""
    if len(holdings) < 2:
        return []
    held = {n: set(b) for n, b in holdings.items()}
    moves: List[Move] = []
    while True:
        ranked = sorted(held, key=lambda n: (len(held[n]), n))
        lo, hi = ranked[0], ranked[-1]
        if len(held[hi]) - len(held[lo]) <= max_spread:
            return moves
        candidates = sorted(held[hi] - held[lo])
        if not candidates:
            return moves  # everything on hi already lives on lo too
        blk = candidates[0]
        held[hi].discard(blk)
        held[lo].add(blk)
        moves.append(Move(blk, hi, lo))


def spread(holdings: Mapping[str, Sequence]) -> int:
    """Max - min blocks per node (0 for empty/single-node clusters)."""
    if not holdings:
        return 0
    counts = [len(b) for b in holdings.values()]
    return max(counts) - min(counts)
