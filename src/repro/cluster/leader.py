"""Leader lease/epoch bookkeeping + the failover control transport.

The control plane runs N metanodes: one **leader** (accepts every
message) and standbys that tail the leader's journal over ``SYNC``
polls (see ``metanode.py``). This module holds the two pieces both
sides of that arrangement share:

* :class:`LeaderLease` — the standby's view of the leader's liveness.
  Every successful ``SYNC`` renews the lease; when the lease has been
  expired for the standby's (rank-staggered) timeout, the standby
  promotes itself and bumps the epoch. An injectable clock keeps the
  election logic unit-testable without sockets (the ``autotune.py``
  controller idiom).
* :class:`ControlChannel` — a metadata connection that takes a *list*
  of metanode addresses and fails over: transport faults advance to the
  next address with ``RetryPolicy`` backoff, ``not_leader`` rejections
  hop immediately (following the standby's leader hint when it has
  one), and the channel tracks the highest leader epoch it has ever
  observed so callers can fence replies from deposed leaders
  (``wire.EPOCH_FIELD``). ``DataNode`` and ``ClusterClient`` both speak
  through one of these instead of hand-rolled redial loops.

Election model (documented in ARCHITECTURE.md "Leader epochs and
fencing"): there is no quorum — correctness does not come from electing
exactly one leader but from **epoch fencing**: every promotion bumps
the epoch, every reply carries it, and any command stamped with a lower
epoch than the receiver has seen is a no-op. A deposed leader can keep
talking; nobody with newer information listens.
"""
from __future__ import annotations

import socket
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.faults import RetriesExhausted, RetryPolicy
from repro.cluster.wire import (
    EPOCH_FIELD,
    ERR_NOT_LEADER,
    ClusterError,
    ClusterMsg,
    request,
)

Address = Tuple[str, int]


def normalize_addresses(meta_address) -> List[Address]:
    """Accept one ``(host, port)`` or a sequence of them; always return
    a non-empty list (the single-metanode call sites stay unchanged)."""
    if (isinstance(meta_address, (tuple, list)) and len(meta_address) == 2
            and isinstance(meta_address[0], str)):
        return [(meta_address[0], int(meta_address[1]))]
    out = [(a[0], int(a[1])) for a in meta_address]
    if not out:
        raise ValueError("need at least one metanode address")
    return out


class LeaderLease:
    """A standby's lease on its belief that the leader is alive.

    ``rank`` staggers promotion: standby *k* waits ``(k + 1) x timeout``
    of silence before promoting, so when several standbys lose the
    leader at once the lowest-ranked one wins the race by default."""

    def __init__(self, timeout: float, rank: int = 0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout * (rank + 1)
        self.rank = rank
        self._clock = clock
        self._last_ok = clock()

    def renew(self) -> None:
        self._last_ok = self._clock()

    def remaining(self) -> float:
        return self.timeout - (self._clock() - self._last_ok)

    def expired(self) -> bool:
        return self.remaining() <= 0


class ControlChannel:
    """One persistent metadata connection over a failover address list.

    ``call()`` is the only entry point: it serializes callers, dials
    lazily, retries transport faults across the address list with the
    policy's backoff, follows ``not_leader`` redirects immediately
    (they spend a hop, not a backoff delay), and records the highest
    ``EPOCH_FIELD`` ever seen in a reply. Callers fence with
    :meth:`stale` BEFORE acting on a reply's commands."""

    def __init__(self, addresses, policy: Optional[RetryPolicy] = None,
                 what: str = "metanode"):
        self.addresses = normalize_addresses(addresses)
        self.policy = policy or RetryPolicy()
        self.what = what
        self.epoch = 0  # highest leader epoch ever observed
        self._idx = 0
        self._sock: Optional[socket.socket] = None
        self._lock = threading.Lock()
        self.stats: Dict[str, int] = {
            "dials": 0, "failovers": 0, "redirects": 0,
        }

    # -- address rotation --------------------------------------------------

    @property
    def current(self) -> Address:
        return self.addresses[self._idx]

    def _advance(self, hint: Optional[Address]) -> None:
        self._close_sock()
        if hint is not None:
            hint = (hint[0], int(hint[1]))
            if hint not in self.addresses:
                self.addresses.append(hint)
            self._idx = self.addresses.index(hint)
        else:
            self._idx = (self._idx + 1) % len(self.addresses)

    # -- transport ---------------------------------------------------------

    def _attempt(self, msg: ClusterMsg, body: dict) -> dict:
        if self._sock is None:
            self._sock = socket.create_connection(
                self.current, timeout=self.policy.connect_timeout)
            self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            # io_timeout=None must not mean block-forever here: a hung
            # or partitioned metanode would wedge every control call, so
            # fall back to the connect timeout
            self._sock.settimeout(self.policy.io_timeout
                                  if self.policy.io_timeout is not None
                                  else self.policy.connect_timeout)
            self.stats["dials"] += 1
        try:
            return request(self._sock, msg, body)
        except (ConnectionError, TimeoutError, OSError):
            self._close_sock()
            raise

    def call(self, msg: ClusterMsg, body: dict) -> dict:
        """One control round-trip with failover. Raises
        :class:`ClusterError` for non-redirect application errors and
        :class:`RetriesExhausted` when every address stayed unreachable
        (or kept answering ``not_leader``) through every attempt."""
        with self._lock:
            last: Optional[BaseException] = None
            for delay in self.policy.delays() + [None]:
                # not_leader hops are free (no backoff) but bounded by
                # the address count so a leaderless interregnum cannot
                # spin the redirect loop forever
                for _ in range(len(self.addresses) + 1):
                    try:
                        payload = self._attempt(msg, body)
                    except ClusterError as e:
                        if e.code != ERR_NOT_LEADER:
                            raise
                        last = e
                        self.stats["redirects"] += 1
                        self._advance(e.hint)
                        continue
                    except (ConnectionError, TimeoutError, OSError) as e:
                        last = e
                        self.stats["failovers"] += 1
                        self._advance(None)
                        break  # transport fault: back off, then retry
                    got = payload.get(EPOCH_FIELD)
                    if isinstance(got, int) and got > self.epoch:
                        self.epoch = got
                    return payload
                if delay is None:
                    break
                self.policy.sleep(delay)
            raise RetriesExhausted(
                f"{self.what} {msg.name} failed over "
                f"{len(self.addresses)} address(es) after "
                f"{self.policy.attempts} attempts: {last!r}") from last

    def stale(self, payload: dict) -> bool:
        """True when ``payload`` was produced by a deposed leader: its
        epoch is below the highest this channel has ever observed.
        (A payload with no epoch predates epochs and is never fenced.)"""
        got = payload.get(EPOCH_FIELD)
        return isinstance(got, int) and got < self.epoch

    # -- lifecycle ---------------------------------------------------------

    def _close_sock(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    def close(self) -> None:
        with self._lock:
            self._close_sock()
