"""Cluster xDFS: striped, replicated multi-node storage.

A :class:`MetaNode` (metadata/placement service) fronts a fleet of
:class:`DataNode` block stores (each an ``XdfsServer``); a
:class:`ClusterClient` stripes files into fixed-size blocks placed
across nodes with a replication factor. Block bytes always move over
ordinary xDFS sessions (the tuned zero-copy, syscall-batched datapath);
this package is only the control plane: placement, heartbeats + block
reports, failure detection, re-replication, and rebalancing.

The control plane is durable and fail-over-able: every namespace
mutation is write-ahead journaled (:class:`Journal`, with periodic
atomic snapshots), so a crashed MetaNode restarts with every
acknowledged commit intact; standby metanodes tail the leader's journal
and promote themselves — bumping the leader **epoch** — when its lease
expires, while clients and data nodes fail over along a metanode
address list (:class:`ControlChannel`) and fence replies from deposed
leaders. See docs/ARCHITECTURE.md ("Control-plane durability" and
"Leader epochs and fencing").

See docs/ARCHITECTURE.md ("Cluster control plane") for the wire spec
and examples/cluster_quickstart.py for a runnable 3-node demo.
"""
from repro.cluster.client import DEFAULT_CLUSTER_BLOCK, ClusterClient
from repro.cluster.datanode import DataNode
from repro.cluster.journal import Journal
from repro.cluster.leader import ControlChannel, LeaderLease
from repro.cluster.metanode import FailureDetector, MetaNode, NodeInfo
from repro.cluster.placement import (
    Move,
    choose_replicas,
    plan_put,
    plan_rebalance,
    plan_replication,
    scan_replication,
    spread,
)
from repro.cluster.wire import (
    CMD_DROP,
    CMD_REPLICATE,
    EPOCH_FIELD,
    ERR_NOT_LEADER,
    ERR_UNREGISTERED,
    ClusterError,
    ClusterMsg,
    block_name,
    new_block_id,
)

__all__ = [
    "CMD_DROP",
    "CMD_REPLICATE",
    "ClusterClient",
    "ClusterError",
    "ClusterMsg",
    "ControlChannel",
    "DEFAULT_CLUSTER_BLOCK",
    "DataNode",
    "EPOCH_FIELD",
    "ERR_NOT_LEADER",
    "ERR_UNREGISTERED",
    "FailureDetector",
    "Journal",
    "LeaderLease",
    "MetaNode",
    "Move",
    "NodeInfo",
    "block_name",
    "choose_replicas",
    "new_block_id",
    "plan_put",
    "plan_rebalance",
    "plan_replication",
    "scan_replication",
    "spread",
]
