"""Cluster xDFS: striped, replicated multi-node storage.

A :class:`MetaNode` (metadata/placement service) fronts a fleet of
:class:`DataNode` block stores (each an ``XdfsServer``); a
:class:`ClusterClient` stripes files into fixed-size blocks placed
across nodes with a replication factor. Block bytes always move over
ordinary xDFS sessions (the tuned zero-copy, syscall-batched datapath);
this package is only the control plane: placement, heartbeats + block
reports, failure detection, re-replication, and rebalancing.

See docs/ARCHITECTURE.md ("Cluster control plane") for the wire spec
and examples/cluster_quickstart.py for a runnable 3-node demo.
"""
from repro.cluster.client import DEFAULT_CLUSTER_BLOCK, ClusterClient
from repro.cluster.datanode import DataNode
from repro.cluster.metanode import FailureDetector, MetaNode, NodeInfo
from repro.cluster.placement import (
    Move,
    choose_replicas,
    plan_put,
    plan_rebalance,
    plan_replication,
    spread,
)
from repro.cluster.wire import (
    CMD_DROP,
    CMD_REPLICATE,
    ClusterError,
    ClusterMsg,
    block_name,
    new_block_id,
)

__all__ = [
    "CMD_DROP",
    "CMD_REPLICATE",
    "ClusterClient",
    "ClusterError",
    "ClusterMsg",
    "DEFAULT_CLUSTER_BLOCK",
    "DataNode",
    "FailureDetector",
    "MetaNode",
    "Move",
    "NodeInfo",
    "block_name",
    "choose_replicas",
    "new_block_id",
    "plan_put",
    "plan_rebalance",
    "plan_replication",
    "spread",
]
