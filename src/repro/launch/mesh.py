"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required for the dry-run XLA_FLAGS dance.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips ('data','model').
    Multi-pod: 2x16x16 = 512 chips ('pod','data','model')."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Smoke-test mesh over however many devices exist locally."""
    return jax.make_mesh((data, model), ("data", "model"))
