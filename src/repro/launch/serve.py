"""Serving driver: batched prefill + decode loop with the sequence-sharded
(flash-decoding) KV cache layout.

Example (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.launch.mesh import make_local_mesh
from repro.models.transformer import build_model


def generate(cfg, mesh, params, prompts, gen_tokens: int, greedy: bool = True,
             key=None):
    """prompts: (B, S) int32 (or (B,S,d) embeds for stub-frontend archs)."""
    with mesh:
        mp = build_model(cfg, mesh, "prefill")
        md = build_model(cfg, mesh, "decode")
        prefill = jax.jit(mp.prefill)
        decode = jax.jit(md.decode_step)

        logits, caches = prefill(params, {"inputs": prompts})
        s = prompts.shape[1]
        out = []
        key = key if key is not None else jax.random.key(0)
        for t in range(gen_tokens):
            if greedy:
                nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            else:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, logits[:, -1]).astype(jnp.int32)
            out.append(nxt)
            step_in = nxt[:, None]
            if cfg.frontend:  # stub frontend: embed via a fixed projection
                step_in = jnp.zeros(
                    (prompts.shape[0], 1, cfg.d_model), jnp.bfloat16
                )
            logits, caches = decode(
                params, {"inputs": step_in, "caches": caches, "pos": jnp.int32(s + t)}
            )
        return jnp.stack(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    mesh = make_local_mesh(1, 1)
    with mesh:
        model = build_model(cfg, mesh, "prefill")
        params = model.init(jax.random.key(0))
    if cfg.frontend:
        prompts = jax.random.normal(
            jax.random.key(1), (args.batch, args.prompt_len, cfg.d_model), jnp.bfloat16
        )
    else:
        prompts = jax.random.randint(
            jax.random.key(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
        )
    t0 = time.perf_counter()
    toks = generate(cfg, mesh, params, prompts, args.gen)
    dt = time.perf_counter() - t0
    print(f"[serve] generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print(toks[0])


if __name__ == "__main__":
    main()
