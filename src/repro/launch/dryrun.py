import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and record memory/cost/collective analyses for the roofline.

The XLA_FLAGS line above MUST stay the first statement (before any jax
import): jax locks the device count on first backend initialization.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--force]

Results: benchmarks/dryrun_results/<arch>__<shape>__<mesh>.json (idempotent;
existing cells are skipped unless --force).
"""
import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs.base import SHAPES, get_config, list_configs  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.transformer import build_model  # noqa: E402
from repro.optim import make_optimizer  # noqa: E402
from repro.launch.hlo_analysis import analyze_hlo  # noqa: E402
from repro.runtime.train import init_state, state_shardings  # noqa: E402

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "dryrun_results"

def _model_flops(model, shape) -> dict:
    """Analytic MODEL_FLOPS: 6*N_eff*D (train) / 2*N_eff*D (serve), matmul
    params only (embedding gather excluded; tied tables count once as head)."""
    cfg = model.cfg
    abs_params = model.abstract()
    total = sum(x.size for x in jax.tree.leaves(abs_params))
    flat = jax.tree_util.tree_flatten_with_path(abs_params)[0]
    expert = sum(
        x.size
        for path, x in flat
        if any(getattr(k, "key", None) == "moe" for k in path)
        and not any("router" in str(k) for k in path)
    )
    embed = 0
    if cfg.frontend is None and not cfg.tie_embeddings:
        embed = model.vocab_pad * cfg.d_model  # gather-only table
    n_eff = total - embed - expert + expert * (cfg.top_k / max(cfg.num_experts, 1))
    if shape.kind == "train":
        d_tok = shape.global_batch * shape.seq_len
        flops = 6.0 * n_eff * d_tok
    elif shape.kind == "prefill":
        d_tok = shape.global_batch * shape.seq_len
        flops = 2.0 * n_eff * d_tok
    else:
        d_tok = shape.global_batch
        flops = 2.0 * n_eff * d_tok
    return {
        "params_total": int(total),
        "params_active": int(n_eff),
        "tokens": int(d_tok),
        "model_flops": flops,
    }


def lower_cell(arch: str, shape_name: str, multi_pod: bool):
    """Build and lower one cell; returns (lowered, model, shape, mesh)."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.supports_long_context:
        raise SystemExit(f"{arch} skips long_500k (quadratic attention)")
    model = build_model(cfg, mesh, shape.kind)
    in_struct = model.input_struct(shape)
    in_sh = jax.tree.map(
        lambda spec: NamedSharding(mesh, spec),
        model.input_specs(shape),
        is_leaf=lambda x: isinstance(x, P),
    )
    with mesh:
        if shape.kind == "train":
            from repro.runtime.train import TrainState, make_train_step

            optimizer = make_optimizer(cfg)
            step = make_train_step(model, optimizer)
            ss = state_shardings(model, optimizer)
            params_abs = model.abstract()
            state_abs = TrainState(
                params=params_abs,
                opt_state=jax.eval_shape(optimizer.init, params_abs),
                step=jax.ShapeDtypeStruct((), jnp.int32),
            )
            fn = jax.jit(step, in_shardings=(ss, in_sh), donate_argnums=(0,))
            lowered = fn.lower(state_abs, in_struct)
        elif shape.kind == "prefill":
            params_sh = model.policy.param_shardings(model.defs)
            fn = jax.jit(model.prefill, in_shardings=(params_sh, in_sh))
            lowered = fn.lower(model.abstract(), in_struct)
        else:
            params_sh = model.policy.param_shardings(model.defs)
            fn = jax.jit(
                model.decode_step,
                in_shardings=(params_sh, in_sh),
                donate_argnums=(1,),
            )
            lowered = fn.lower(model.abstract(), in_struct)
    return lowered, model, shape, mesh


def run_cell(arch: str, shape_name: str, multi_pod: bool, force: bool = False) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    out_path = RESULTS_DIR / f"{arch}__{shape_name}__{mesh_name}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    t0 = time.time()
    lowered, model, shape, mesh = lower_cell(arch, shape_name, multi_pod)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    mem_d = {}
    for k in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        v = getattr(mem, k, None)
        if v is not None:
            mem_d[k] = int(v)
    cost = compiled.cost_analysis() or {}
    cost_d = {k: float(v) for k, v in cost.items() if isinstance(v, (int, float))}

    hlo = compiled.as_text()
    t0 = time.time()
    analysis = analyze_hlo(hlo)  # trip-count-corrected per-device accounting
    t_analyze = time.time() - t0

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "devices": int(mesh.devices.size),
        "kind": shape.kind,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "analyze_s": round(t_analyze, 2),
        "memory_analysis": mem_d,
        # per-device, trip-count-corrected (see hlo_analysis.py)
        "dot_flops_per_dev": analysis["dot_flops"],
        "hbm_bytes_per_dev": analysis["hbm_bytes"],
        "hbm_bytes_by_op": analysis["hbm_bytes_by_op"],
        "transcendental_elems_per_dev": analysis["transcendental_elems"],
        "bf16_upcast_artifact_bytes": analysis["bf16_upcast_artifact_bytes"],
        "collectives": analysis["collectives"],
        # raw XLA numbers (while bodies counted once — reference only)
        "xla_cost_flops": cost_d.get("flops", 0.0),
        "xla_cost_bytes_accessed": cost_d.get("bytes accessed", 0.0),
        "hlo_bytes": len(hlo),
        **_model_flops(model, shape),
    }
    out_path.write_text(json.dumps(result, indent=1))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    cells = []
    archs = [args.arch] if args.arch else list(list_configs())
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    for a in archs:
        cfg = get_config(a)
        for s in shapes:
            if s == "long_500k" and not cfg.supports_long_context:
                print(f"SKIP {a} {s}: quadratic attention (see DESIGN.md)")
                continue
            for mp in meshes:
                cells.append((a, s, mp))

    n_fail = 0
    for a, s, mp in cells:
        tag = f"{a:18s} {s:12s} {'2x16x16' if mp else '16x16'}"
        try:
            r = run_cell(a, s, mp, force=args.force)
            mem = r["memory_analysis"]
            per_dev = (
                mem.get("argument_size_in_bytes", 0)
                + mem.get("output_size_in_bytes", 0)
                + mem.get("temp_size_in_bytes", 0)
                - mem.get("alias_size_in_bytes", 0)
            )
            print(
                f"OK   {tag} compile={r['compile_s']:7.1f}s "
                f"flops/dev={r['dot_flops_per_dev']:.3e} mem/dev={per_dev/2**30:.2f}GiB",
                flush=True,
            )
        except SystemExit as e:
            print(f"SKIP {tag}: {e}")
        except Exception:
            n_fail += 1
            print(f"FAIL {tag}")
            traceback.print_exc()
    if n_fail:
        raise SystemExit(f"{n_fail} cells failed")


if __name__ == "__main__":
    main()
