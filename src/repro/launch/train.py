"""End-to-end training driver: model + data pipeline + async xDFS
checkpointing + CFSM fault supervisor + (optional) simulated fault injection.

Example (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ck --ckpt-every 20
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.checkpoint import xdfs_ckpt
from repro.checkpoint.async_ckpt import AsyncCheckpointer
from repro.configs.base import ShapeConfig, get_config
from repro.data.pipeline import PrefetchPipeline
from repro.data.synthetic import StreamSpec
from repro.launch.mesh import make_local_mesh
from repro.models.transformer import build_model
from repro.optim import make_optimizer
from repro.runtime.fault import Supervisor
from repro.runtime.train import (
    TrainState,
    init_state,
    jit_train_step,
    make_dp_xdfs_train_step,
    state_shardings,
)


def train_loop(
    cfg,
    mesh,
    *,
    steps: int,
    batch: int,
    seq: int,
    ckpt_dir: str = "",
    ckpt_every: int = 0,
    lr: float = 3e-4,
    use_xdfs_dp: bool = False,
    inject_fault_at: int = -1,
    log_every: int = 10,
    seed: int = 0,
):
    shape = ShapeConfig("custom", seq, batch, "train")
    model = build_model(cfg, mesh, "train", plain=use_xdfs_dp)
    optimizer = make_optimizer(cfg, lr=lr)
    sup = Supervisor(heartbeat_timeout=120.0)
    sup.start()

    with mesh:
        state = init_state(model, jax.random.key(seed), optimizer)
        ss = state_shardings(model, optimizer)
        state = jax.tree.map(lambda x, sh: jax.device_put(x, sh), state, ss)
        if use_xdfs_dp:
            step_fn = make_dp_xdfs_train_step(model, optimizer)
        else:
            step_fn = jit_train_step(model, optimizer, shape)

        in_sh = jax.tree.map(
            lambda spec: NamedSharding(mesh, spec),
            model.input_specs(shape),
            is_leaf=lambda s: isinstance(s, P),
        )

        start_step = 0
        ckpt = AsyncCheckpointer(ckpt_dir) if ckpt_dir and ckpt_every else None
        if ckpt_dir and xdfs_ckpt.latest_step(ckpt_dir) is not None:
            state_like = jax.eval_shape(lambda: state)
            state, start_step = xdfs_ckpt.restore(ckpt_dir, state_like, shardings=ss)
            print(f"[train] restored from step {start_step}")

        spec = StreamSpec(
            cfg.vocab_size, seq, batch, seed=seed,
            embed_dim=cfg.d_model if cfg.frontend else 0,
        )

        def put(b):
            if cfg.frontend:
                inp = jax.device_put(jnp.asarray(b["inputs"], jnp.bfloat16), in_sh["inputs"])
            else:
                inp = jax.device_put(b["inputs"], in_sh["inputs"])
            return {"inputs": inp, "labels": jax.device_put(b["labels"], in_sh["labels"])}

        pipe = PrefetchPipeline(spec, start_step=start_step, put_fn=put)
        losses = []
        step = start_step
        try:
            while step < steps:
                step, data = next(pipe)
                if step >= steps:
                    break
                t0 = time.perf_counter()
                if step == inject_fault_at:
                    inject_fault_at = -1  # one-shot
                    # simulated node failure: drop live state, recover from ckpt
                    sup.report_fault("injected node failure")
                    if ckpt is not None:
                        ckpt.wait()
                    state_like = jax.eval_shape(lambda: state)
                    state, rstep = xdfs_ckpt.restore(
                        ckpt_dir, state_like, shardings=ss
                    )
                    pipe.close()
                    pipe = PrefetchPipeline(spec, start_step=rstep, put_fn=put)
                    sup.restored()
                    print(f"[train] fault at {step}; resumed from {rstep}")
                    step = rstep
                    continue
                state, metrics = step_fn(state, data)
                loss = float(metrics["loss"])
                wall = time.perf_counter() - t0
                rec = sup.record_step(step, wall)
                sup.heartbeat("worker0")
                losses.append(loss)
                if log_every and step % log_every == 0:
                    print(
                        f"[train] step {step:5d} loss {loss:8.4f} "
                        f"{wall*1e3:8.1f} ms{' STRAGGLER' if rec.straggler else ''}",
                        flush=True,
                    )
                if ckpt is not None and step and step % ckpt_every == 0:
                    with sup.checkpoint_scope():
                        # state has CONSUMED batch `step`; label with the
                        # next step to run so resume does not replay it
                        ckpt.save(state, step + 1)
                step += 1
        finally:
            pipe.close()
            if ckpt is not None:
                ckpt.save(state, step)
                ckpt.close()
        sup.fsm.step("stop")
        return state, losses, sup


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--xdfs-dp", action="store_true")
    ap.add_argument("--inject-fault-at", type=int, default=-1)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    mesh = make_local_mesh(1, 1)
    _, losses, sup = train_loop(
        cfg, mesh,
        steps=args.steps, batch=args.batch, seq=args.seq, lr=args.lr,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        use_xdfs_dp=args.xdfs_dp, inject_fault_at=args.inject_fault_at,
    )
    print(
        f"[train] done: first loss {losses[0]:.4f} -> last {losses[-1]:.4f}; "
        f"stragglers={sup.stragglers} faults={len(sup.faults)}"
    )


if __name__ == "__main__":
    main()
