"""Post-SPMD HLO accounting with while-loop trip-count multipliers.

``compiled.cost_analysis()`` counts while bodies ONCE (scan bodies are not
multiplied by trip count), under-reporting FLOPs/bytes by ~num_layers for
scan-over-layers programs. This module parses the optimized HLO text instead:

  * builds per-computation symbol tables (op name -> result type) since the
    optimized print mode omits operand types,
  * builds the computation call graph (while body/condition, fusion calls,
    conditionals) and propagates an execution-count multiplier from ENTRY;
    trip counts come from each while condition's ``compare(.., constant(N))``,
  * dot FLOPs = 2 * numel(result) * prod(lhs contracting dims)  (per device),
  * HBM bytes = operand+result sizes of ops at fusion boundaries
    (ops *inside* fusions don't touch HBM),
  * collective bytes per kind (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute), trip-multiplied.

All shapes in post-SPMD optimized HLO are per-device shapes, so every number
here is per-device — exactly what the roofline terms need.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?(%?[\w.\-]+)\s*\(.*\)\s*->\s*.+\s*\{")
_CALL_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "while", "conditional",
}
_COLLECTIVES = {
    "all-gather", "all-gather-start", "all-reduce", "all-reduce-start",
    "reduce-scatter", "all-to-all", "collective-permute",
    "collective-permute-start",
}
_ASYNC_DONE = {"all-gather-done", "all-reduce-done", "collective-permute-done"}


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


class Op:
    __slots__ = ("name", "rtype", "opcode", "operands", "attrs")

    def __init__(self, name, rtype, opcode, operands, attrs):
        self.name = name
        self.rtype = rtype
        self.opcode = opcode
        self.operands = operands
        self.attrs = attrs


def _parse_op(line: str) -> Optional[Op]:
    line = line.strip()
    if line.startswith("ROOT "):
        line = line[5:]
    eq = line.find(" = ")
    if eq < 0 or not (line.startswith("%") or re.match(r"[\w.\-]+ =", line)):
        return None
    name = line[:eq].strip().lstrip("%")
    rest = line[eq + 3 :]
    # result type: balanced parens tuple or single token
    if rest.startswith("("):
        depth = 0
        for i, c in enumerate(rest):
            depth += c == "("
            depth -= c == ")"
            if depth == 0:
                rtype = rest[: i + 1]
                rest = rest[i + 1 :].strip()
                break
        else:
            return None
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        rtype = rest[:sp]
        rest = rest[sp + 1 :]
    m = re.match(r"([\w\-]+)\(", rest)
    if not m:
        return None
    opcode = m.group(1)
    body = rest[m.end() :]
    depth = 1
    for i, c in enumerate(body):
        depth += c == "("
        depth -= c == ")"
        if depth == 0:
            operand_str = body[:i]
            attrs = body[i + 1 :]
            break
    else:
        operand_str, attrs = body, ""
    operands = [
        o.strip().lstrip("%")
        for o in re.split(r",\s*(?![^(]*\))", operand_str)
        if o.strip()
    ]
    return Op(name, rtype, opcode, operands, attrs)


def _split_computations(text: str) -> Tuple[Dict[str, List[Op]], str]:
    comps: Dict[str, List[Op]] = {}
    cur = None
    entry = ""
    for raw in text.splitlines():
        line = raw.rstrip()
        m = _COMP_HDR.match(line.strip())
        if m and not raw.startswith("    "):
            cur = m.group(2).lstrip("%")
            comps[cur] = []
            if m.group(1):
                entry = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        op = _parse_op(line)
        if op is not None:
            comps[cur].append(op)
    return comps, entry


def _dot_flops(op: Op, symtab: Dict[str, str]) -> float:
    rm = _SHAPE_RE.search(op.rtype)
    if not rm:
        return 0.0
    numel = 1
    if rm.group(2):
        for d in rm.group(2).split(","):
            numel *= int(d)
    contract = 1
    if op.operands:
        lhs_t = symtab.get(op.operands[0], "")
        lm = _SHAPE_RE.search(lhs_t)
        lhs_dims = (
            [int(d) for d in lm.group(2).split(",")] if lm and lm.group(2) else []
        )
        cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
        if cm and cm.group(1):
            for i in cm.group(1).split(","):
                if int(i) < len(lhs_dims):
                    contract *= lhs_dims[int(i)]
    return 2.0 * numel * contract


def _trip_count(cond_ops: List[Op]) -> int:
    """Max integer constant in the while condition (lax scan/fori pattern)."""
    best = 1
    for op in cond_ops:
        if op.opcode == "constant" and op.operands and op.operands[0].isdigit():
            best = max(best, int(op.operands[0]))
    return best


def analyze_hlo(text: str) -> dict:
    comps, entry = _split_computations(text)
    symtabs = {
        name: {op.name: op.rtype for op in ops} for name, ops in comps.items()
    }

    # ----- call graph + fusion marking --------------------------------------
    edges: Dict[str, List[tuple]] = {}
    fused: set = set()
    for name, ops in comps.items():
        es = []
        for op in ops:
            if op.opcode == "while":
                body = re.search(r"body=%?([\w.\-]+)", op.attrs)
                cond = re.search(r"condition=%?([\w.\-]+)", op.attrs)
                if body and cond:
                    es.append((body.group(1), "while", cond.group(1)))
                    es.append((cond.group(1), "call", None))
            else:
                bm = _BRANCH_RE.search(op.attrs)
                if bm:
                    for b in bm.group(1).split(","):
                        es.append((b.strip().lstrip("%"), "call", None))
                for cm in _CALL_RE.finditer(op.attrs):
                    es.append((cm.group(1), "fusion" if op.opcode == "fusion" else "call", None))
                    if op.opcode == "fusion":
                        fused.add(cm.group(1))
        edges[name] = es

    # ----- multiplier propagation -------------------------------------------
    mult: Dict[str, float] = {entry: 1.0}
    order = [entry]
    i = 0
    while i < len(order):
        cur = order[i]
        i += 1
        for e in edges.get(cur, []):
            callee, kind = e[0], e[1]
            if callee not in comps:
                continue
            m = mult.get(cur, 0.0)
            if kind == "while":
                m *= _trip_count(comps.get(e[2], []))
            if callee not in mult:
                order.append(callee)
            mult[callee] = mult.get(callee, 0.0) + m

    # ----- accounting ---------------------------------------------------------
    flops = 0.0
    hbm_bytes = 0.0
    transcendental_elems = 0.0
    coll: Dict[str, dict] = {}
    by_op: Dict[str, float] = {}
    for name, ops in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        st = symtabs[name]
        in_fusion = name in fused
        for op in ops:
            if op.opcode == "dot":
                flops += m * _dot_flops(op, st)
            elif op.opcode in ("exponential", "tanh", "log", "rsqrt", "power",
                               "exponential-minus-one", "logistic"):
                rm = _SHAPE_RE.search(op.rtype)
                if rm and rm.group(2):
                    n = 1
                    for d in rm.group(2).split(","):
                        n *= int(d)
                    transcendental_elems += m * n
            if op.opcode in _COLLECTIVES:
                base = op.opcode.replace("-start", "")
                obytes = sum(_shape_bytes(st.get(o, "")) for o in op.operands)
                rbytes = _shape_bytes(op.rtype)
                d = coll.setdefault(
                    base,
                    {"count": 0.0, "operand_bytes": 0.0, "result_bytes": 0.0,
                     "wire_bytes": 0.0},
                )
                d["count"] += m
                d["operand_bytes"] += m * obytes
                d["result_bytes"] += m * rbytes
                d["wire_bytes"] += m * _wire_bytes(base, obytes, rbytes, op.attrs)
            if (
                not in_fusion
                and op.opcode not in _SKIP_BYTES_OPS
                and op.opcode not in _ASYNC_DONE
            ):
                b = _op_hbm_bytes(op, st)
                hbm_bytes += m * b
                by_op[op.opcode] = by_op.get(op.opcode, 0.0) + m * b
    for d in coll.values():
        d["count"] = int(d["count"])

    # XLA:CPU emulates bf16 by upcasting; loop-invariant motion then clones
    # whole bf16 residual stacks as f32 buffers. Real TPUs compute bf16
    # natively, so we quantify these artifact buffers (f32 results of pure
    # convert fusions whose input is a same-shape bf16 buffer) for an
    # adjusted temp-memory estimate.
    upcast_artifact = 0.0
    for name, ops in comps.items():
        if mult.get(name, 0.0) == 0.0 or name in fused:
            continue
        st = symtabs[name]
        for op in ops:
            if not op.name.startswith("wrapped_convert"):
                continue
            rm = _SHAPE_RE.search(op.rtype)
            if not rm or not rm.group(1) == "f32":
                continue
            src = st.get(op.operands[0], "") if op.operands else ""
            sm = _SHAPE_RE.search(src)
            if sm and sm.group(1) == "bf16" and sm.group(2) == rm.group(2):
                upcast_artifact += _shape_bytes(op.rtype)

    return {
        "dot_flops": flops,
        "hbm_bytes": hbm_bytes,
        "hbm_bytes_by_op": dict(
            sorted(by_op.items(), key=lambda kv: -kv[1])[:12]
        ),
        "transcendental_elems": transcendental_elems,
        "collectives": coll,
        "bf16_upcast_artifact_bytes": upcast_artifact,
        "n_computations": len(comps),
    }


_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _group_size(attrs: str) -> int:
    m = _GROUPS_IOTA.search(attrs)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST.search(attrs)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 2  # unknown: assume >=2 so ratios stay sane


def _wire_bytes(kind: str, obytes: float, rbytes: float, attrs: str) -> float:
    """Per-device ICI wire bytes under ring algorithms.

    all-gather: (n-1)/n * result; reduce-scatter: (n-1)/n * operand;
    all-reduce: 2(n-1)/n * operand (RS+AG); all-to-all: (n-1)/n * operand;
    collective-permute: operand (one hop)."""
    n = _group_size(attrs)
    f = (n - 1) / n
    if kind == "all-gather":
        return f * rbytes
    if kind == "reduce-scatter":
        return f * obytes
    if kind == "all-reduce":
        return 2.0 * f * obytes
    if kind == "all-to-all":
        return f * obytes
    return obytes  # collective-permute


def _op_hbm_bytes(op: Op, st: Dict[str, str]) -> float:
    """Approximate HBM traffic of one fusion-boundary op.

    Slice-like ops only move the slice, not the whole buffer; in-place
    dynamic-update-slice moves the update twice (read-modify-write slot)."""
    if op.opcode == "dynamic-slice":
        return 2.0 * _shape_bytes(op.rtype)
    if op.opcode == "dynamic-update-slice":
        upd = st.get(op.operands[1], "") if len(op.operands) > 1 else ""
        return 2.0 * _shape_bytes(upd)
    if op.opcode == "fusion" and "dynamic-update-slice" in op.name:
        # in-place DUS fusion: traffic = everything except the big aliased
        # buffer, twice (read-modify-write of the updated slice region)
        sizes = sorted(
            (_shape_bytes(st.get(o, "")) for o in op.operands), reverse=True
        )
        return 2.0 * sum(sizes[1:]) if sizes else 0.0
    if op.opcode == "gather":
        idx = st.get(op.operands[1], "") if len(op.operands) > 1 else ""
        return 2.0 * _shape_bytes(op.rtype) + _shape_bytes(idx)
    if op.opcode == "scatter":
        upd = st.get(op.operands[2], "") if len(op.operands) > 2 else ""
        return 2.0 * _shape_bytes(upd) + _shape_bytes(op.rtype) * 0.0
    obytes = sum(_shape_bytes(st.get(o, "")) for o in op.operands)
    return obytes + _shape_bytes(op.rtype)
