"""repro — xDFS reproduction grown toward a production-scale system.

Cross-version jax compatibility: ``jax.shard_map`` is the public name on
newer jax, but this container ships a jax where it still lives in
``jax.experimental.shard_map``. Alias it here (the package root imports
before any model/runtime module) so call sites can use the public name.
"""
import functools

import jax
from jax import lax as _lax

if not hasattr(jax, "shard_map"):  # jax < 0.6 compatibility
    from jax.experimental.shard_map import shard_map as _shard_map

    @functools.wraps(_shard_map)
    def _compat_shard_map(*args, **kwargs):
        if "check_vma" in kwargs:  # renamed from check_rep in newer jax
            kwargs["check_rep"] = kwargs.pop("check_vma")
        return _shard_map(*args, **kwargs)

    jax.shard_map = _compat_shard_map

if not hasattr(_lax, "axis_size"):  # jax < 0.4.32 compatibility
    import jax.core as _core

    _lax.axis_size = _core.axis_frame  # returns the named axis size
