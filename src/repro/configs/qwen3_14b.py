"""Qwen3-14B [hf:Qwen/Qwen3-*].

40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936, qk-norm, SwiGLU.
40 heads don't divide the 16-way model axis -> context-parallel profile:
sequence over 'model' with xDFS ring attention, ZeRO-3 over (data, model).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="qwen3-14b",
        family="dense",
        num_layers=40,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=17408,
        vocab_size=151936,
        layer_pattern="g",
        qk_norm=True,
        rope_theta=1000000.0,
        act="silu",
        tie_embeddings=False,
        shard_profile="cp",
        fsdp=True,
        optimizer="adamw",
        supports_long_context=False,
        notes="qk_norm GQA; CP ring-attention profile",
    )
)
