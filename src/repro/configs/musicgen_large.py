"""MusicGen-large backbone [arXiv:2306.05284].

48L d_model=2048 32H (MHA kv=32) d_ff=8192 vocab=2048 (EnCodec codebook).
Decoder-only over EnCodec tokens; the EnCodec frontend is a STUB:
input_specs() provides precomputed frame embeddings (audio modality).
Plain (non-gated) GELU FFN per the original transformer decoder.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="musicgen-large",
        family="audio",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        head_dim=64,
        d_ff=8192,
        vocab_size=2048,
        layer_pattern="g",
        rope_theta=10000.0,
        act="gelu_plain",
        tie_embeddings=False,
        frontend="audio",
        shard_profile="tp",
        fsdp=True,
        optimizer="adamw",
        supports_long_context=False,
        notes="decoder-only over EnCodec tokens; frame-embedding stub frontend",
    )
)
