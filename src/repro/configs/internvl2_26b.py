"""InternVL2-26B LM backbone (InternLM2-20B) [arXiv:2404.16821].

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.
The InternViT vision tower is a STUB: input_specs() provides precomputed
patch embeddings (vision modality). vocab padded to a multiple of 256 for
16-way vocab sharding (Megatron-style; noted in EXPERIMENTS.md).
kv=8 < tp=16 -> GQA kv-head replication x2.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="internvl2-26b",
        family="vlm",
        num_layers=48,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=92553,
        layer_pattern="g",
        rope_theta=1000000.0,
        act="silu",
        tie_embeddings=False,
        frontend="vision",
        shard_profile="tp",
        fsdp=True,
        optimizer="adamw",
        supports_long_context=False,
        notes="InternViT stub frontend + InternLM2 backbone",
    )
)
