"""OLMoE-1B-7B [arXiv:2409.02060].

16L d_model=2048 16H (MHA kv=16) d_ff(expert)=1024 vocab=50304,
MoE 64 experts top-8, qk-norm. TP over 'model' (16 heads / 16), EP experts
over 'model', FSDP over 'data'.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="olmoe-1b-7b",
        family="moe",
        num_layers=16,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=1024,
        vocab_size=50304,
        layer_pattern="g",
        qk_norm=True,
        rope_theta=10000.0,
        act="silu",
        tie_embeddings=False,
        moe=True,
        num_experts=64,
        top_k=8,
        moe_dff=1024,
        dense_residual=False,
        capacity_factor=1.25,
        shard_profile="tp",
        fsdp=True,
        optimizer="adamw",
        supports_long_context=False,
        notes="64e top-8 MoE",
    )
)
