"""SmolLM-135M [hf:HuggingFaceTB/SmolLM-135M].

30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152, llama-arch small,
tied embeddings. 9 heads don't divide the model axis -> pure-DP profile
(batch over data x model), params small enough to replicate.
Also the end-to-end training-example architecture.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="smollm-135m",
        family="dense",
        num_layers=30,
        d_model=576,
        num_heads=9,
        num_kv_heads=3,
        head_dim=64,
        d_ff=1536,
        vocab_size=49152,
        layer_pattern="g",
        rope_theta=10000.0,
        act="silu",
        tie_embeddings=True,
        shard_profile="dp",
        fsdp=True,
        optimizer="adamw",
        supports_long_context=False,
        notes="llama-arch small; e2e training example",
    )
)
