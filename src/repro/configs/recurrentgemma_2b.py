"""RecurrentGemma-2B (Griffin) [arXiv:2402.19427].

26L d_model=2560 10H (MQA kv=1, head_dim 256) d_ff=7680 vocab=256000.
Pattern: (RG-LRU, RG-LRU, local-attn) repeating — 1 local : 2 recurrent;
window 2048, GeGLU, tied embeddings, (1+w) RMSNorm, final softcap 30.
Sub-quadratic (bounded window + O(1) recurrent state): runs long_500k.
10 heads don't divide the model axis -> pure-DP profile, FSDP over data.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        num_layers=26,
        d_model=2560,
        num_heads=10,
        num_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab_size=256000,
        layer_pattern="rrl",  # 2 recurrent : 1 local attention
        window_size=2048,
        final_logit_softcap=30.0,
        rope_theta=10000.0,
        act="gelu",
        tie_embeddings=True,
        gemma_norm=True,
        embed_scale=True,
        lru_width=2560,
        conv1d_width=4,
        shard_profile="dp",
        fsdp=True,
        optimizer="adamw",
        supports_long_context=True,
        notes="RG-LRU + local attn 1:2 (Griffin)",
    )
)
