"""Gemma 2 27B [arXiv:2408.00118; hf].

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000.
Local(4096)+global alternating attention, attn logit softcap 50, final logit
softcap 30, GeGLU, tied embeddings, (1+w) RMSNorm, pre+post block norms,
query_pre_attn_scalar = d_model/num_heads = 144.
long_500k skipped: global layers are full attention (quadratic).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="gemma2-27b",
        family="dense",
        num_layers=46,
        d_model=4608,
        num_heads=32,
        num_kv_heads=16,
        head_dim=128,
        d_ff=36864,
        vocab_size=256000,
        layer_pattern="lg",  # local, global alternating
        window_size=4096,
        attn_logit_softcap=50.0,
        final_logit_softcap=30.0,
        rope_theta=10000.0,
        query_pre_attn_scalar=144.0,  # d_model / num_heads
        act="gelu",
        tie_embeddings=True,
        gemma_norm=True,
        post_block_norm=True,
        embed_scale=True,
        shard_profile="tp",
        fsdp=True,
        optimizer="adamw",
        remat_policy="nothing",
        supports_long_context=False,
        notes="local+global alternating, logit softcaps",
    )
)
