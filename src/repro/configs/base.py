"""Config system: architecture configs, input-shape cells, sharding policies.

Every assigned architecture is a ``ModelConfig`` built from its published
hyper-parameters. ``SHAPES`` defines the assigned input-shape set; the cross
product (arch x shape) defines the dry-run cells.

Sharding profiles (see DESIGN.md SS4):
  * ``tp``  -- Megatron tensor parallel over 'model' (+ DP over 'data',
               FSDP params over 'data').
  * ``cp``  -- context parallel: sequence over 'model' (ring attention via the
               xDFS channel engine), ZeRO-3 params over ('data','model').
               Used when head counts don't divide the model axis.
  * ``dp``  -- pure data parallel over ('data','model') with FSDP over 'data'.
               Used for small or head-indivisible recurrent archs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Input shape cells (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm

    num_layers: int = 12
    d_model: int = 512
    num_heads: int = 8
    num_kv_heads: int = 8
    head_dim: int = 64
    d_ff: int = 2048
    vocab_size: int = 32000

    # attention variants -----------------------------------------------------
    # per-layer block pattern, cycled over layers:
    #   'g' global attention, 'l' local (sliding window), 'r' RG-LRU recurrent,
    #   'k' RWKV6 time-mix block.
    layer_pattern: str = "g"
    window_size: int = 4096
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    qk_norm: bool = False
    rope_theta: float = 10000.0
    # gemma-style scaling: attn scale = query_pre_attn_scalar ** -0.5
    query_pre_attn_scalar: Optional[float] = None  # default: head_dim

    # ffn ---------------------------------------------------------------------
    act: str = "silu"  # silu (gated) | gelu (gated) | gelu_plain
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    gemma_norm: bool = True if False else False  # (1 + w) RMSNorm scaling
    post_block_norm: bool = False  # gemma2-style post norms
    embed_scale: bool = False  # multiply embeddings by sqrt(d_model)

    # moe ----------------------------------------------------------------------
    moe: bool = False
    num_experts: int = 0
    top_k: int = 0
    moe_dff: int = 0
    dense_residual: bool = False  # arctic: dense MLP in parallel with MoE
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    router_z_weight: float = 1e-4
    # ZxDFS compressed channel on the expert-parallel all-to-all (int8 wire
    # payloads, per-row scales). Opt-in: ~0.4% activation quantization noise.
    moe_a2a_compress: bool = False

    # rwkv / rglru ---------------------------------------------------------------
    rwkv_head_dim: int = 64
    lru_width: int = 0  # 0 -> d_model
    conv1d_width: int = 4

    # modality frontend stub -----------------------------------------------------
    frontend: Optional[str] = None  # None | 'audio' | 'vision'

    # sharding / runtime ----------------------------------------------------------
    shard_profile: str = "tp"  # tp | cp | dp
    fsdp: bool = True
    optimizer: str = "adamw"  # adamw | adafactor
    microbatches: int = 1  # >1: grad-accumulation scan (tp/cp profiles)
    remat_policy: str = "nothing"  # nothing | dots | full(no remat)
    attn_chunk: int = 1024  # q-chunk for XLA chunked attention
    ce_chunk: int = 512  # token chunk for fused cross-entropy
    # when kv_heads < tp_size, kv heads are repeated to tp size (Megatron GQA)
    supports_long_context: bool = False  # sub-quadratic -> run long_500k
    notes: str = ""

    # ------------------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def lru_dim(self) -> int:
        return self.lru_width or self.d_model

    def pattern_for_layers(self) -> Tuple[str, ...]:
        p = self.layer_pattern
        return tuple(p[i % len(p)] for i in range(self.num_layers))

    def padded_vocab(self, multiple: int = 256) -> int:
        return ((self.vocab_size + multiple - 1) // multiple) * multiple

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        n_layers = max(2, min(4, len(self.layer_pattern)))
        return replace(
            self,
            num_layers=n_layers,
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads < self.num_heads else 4,
            head_dim=16,
            d_ff=128,
            vocab_size=512,
            window_size=32,
            num_experts=4 if self.moe else 0,
            top_k=min(2, self.top_k) if self.moe else 0,
            moe_dff=64 if self.moe else 0,
            lru_width=64 if self.lru_width else 0,
            rwkv_head_dim=16,
            attn_chunk=32,
            ce_chunk=64,
            fsdp=False,
        )


_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def list_configs() -> Tuple[str, ...]:
    if not _REGISTRY:
        _load_all()
    return tuple(sorted(_REGISTRY))


def _load_all() -> None:
    # import for registration side effects
    from repro.configs import (  # noqa: F401
        gemma2_27b,
        llama3_8b,
        smollm_135m,
        qwen3_14b,
        rwkv6_3b,
        arctic_480b,
        olmoe_1b_7b,
        musicgen_large,
        recurrentgemma_2b,
        internvl2_26b,
    )


def cells(include_skipped: bool = False):
    """Yield every (arch, shape) dry-run cell; skip long_500k for quadratic archs."""
    for name in list_configs():
        cfg = get_config(name)
        for sname, shape in SHAPES.items():
            if sname == "long_500k" and not cfg.supports_long_context:
                if include_skipped:
                    yield cfg, shape, False
                continue
            yield (cfg, shape, True) if include_skipped else (cfg, shape)
