"""Llama 3 8B [arXiv:2407.21783].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256, rope theta 5e5,
SwiGLU, untied embeddings. kv=8 < tp=16 -> GQA kv-head replication x2.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="llama3-8b",
        family="dense",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=128256,
        layer_pattern="g",
        rope_theta=500000.0,
        act="silu",
        tie_embeddings=False,
        shard_profile="tp",
        fsdp=True,
        optimizer="adamw",
        supports_long_context=False,
        notes="GQA, 128k vocab",
    )
)
