"""RWKV-6 (Finch) 3B [arXiv:2404.05892].

32L d_model=2560 attention-free, d_ff=8960 (channel-mix), vocab=65536,
data-dependent decay time-mix with 40 heads of dim 64. Sub-quadratic:
runs the long_500k cell. Head structure doesn't divide the model axis ->
pure-DP profile with FSDP over data.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="rwkv6-3b",
        family="ssm",
        num_layers=32,
        d_model=2560,
        num_heads=40,  # d_model / rwkv_head_dim
        num_kv_heads=40,
        head_dim=64,
        d_ff=8960,
        vocab_size=65536,
        layer_pattern="k",
        rwkv_head_dim=64,
        act="silu",
        tie_embeddings=False,
        shard_profile="dp",
        fsdp=True,
        optimizer="adamw",
        supports_long_context=True,
        notes="Finch: data-dependent decay; attention-free",
    )
)
