"""Snowflake Arctic 480B [hf:Snowflake/snowflake-arctic-base].

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128 experts top-2
IN PARALLEL with a dense residual MLP (dense-MoE hybrid).
56 heads don't divide the model axis -> context-parallel attention; experts
EP-sharded over 'model'; ZeRO-3 over (data, model).
Optimizer: Adafactor — AdamW fp32 states (3.7 TB) exceed single-pod HBM
(256 x 16 GB); see EXPERIMENTS.md.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="arctic-480b",
        family="moe",
        num_layers=35,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        head_dim=128,
        d_ff=4864,
        vocab_size=32000,
        layer_pattern="g",
        rope_theta=10000.0,
        act="silu",
        tie_embeddings=False,
        moe=True,
        num_experts=128,
        top_k=2,
        moe_dff=4864,
        dense_residual=True,
        capacity_factor=1.25,
        attn_chunk=64,  # keep gathered-KV score transients <1 GiB/dev
        shard_profile="cp",
        fsdp=True,
        optimizer="adafactor",
        remat_policy="nothing",
        supports_long_context=False,
        notes="128e top-2 + dense residual; EP+CP+ZeRO-3; adafactor",
    )
)
