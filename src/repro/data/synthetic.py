"""Deterministic synthetic token stream: seeded, reproducible, resumable.

Batches are a pure function of (seed, step) so a restarted job resumes the
exact stream from its checkpointed step — a fault-tolerance requirement, not
a convenience (tests assert bit-exact resume).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class StreamSpec:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    embed_dim: int = 0  # >0: modality-stub mode (emit embeddings, not tokens)


def batch_at(spec: StreamSpec, step: int) -> dict:
    """The batch for a given step (pure function; zipfian-ish token dist)."""
    rng = np.random.default_rng(np.random.SeedSequence([spec.seed, step]))
    b, s = spec.global_batch, spec.seq_len
    # zipf-flavored distribution over the vocab, cheap to sample
    u = rng.random((b, s + 1))
    toks = (spec.vocab_size * u ** 2.2).astype(np.int32)
    toks = np.minimum(toks, spec.vocab_size - 1)
    if spec.embed_dim:
        emb = rng.standard_normal((b, s, spec.embed_dim), dtype=np.float32)
        return {"inputs": emb.astype(np.float32), "labels": toks[:, 1:]}
    return {"inputs": toks[:, :-1], "labels": toks[:, 1:]}
