"""Host input pipeline: background prefetch into a bounded ring.

The trainer-side twin of the xDFS download path: a producer thread streams
batches (the 'file blocks') into a bounded buffer; the training loop consumes
without ever blocking on data generation in steady state.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import jax

from repro.data.synthetic import StreamSpec, batch_at


class PrefetchPipeline:
    def __init__(
        self,
        spec: StreamSpec,
        start_step: int = 0,
        depth: int = 4,
        put_fn: Optional[Callable] = None,  # e.g. device_put with shardings
    ):
        self.spec = spec
        self.depth = depth
        self.put_fn = put_fn or (lambda b: b)
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self):
        step = self._step
        while not self._stop.is_set():
            batch = batch_at(self.spec, step)
            try:
                self._q.put((step, batch), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        while True:
            try:
                step, batch = self._q.get(timeout=1.0)
                return step, self.put_fn(batch)
            except queue.Empty:
                if self._stop.is_set():
                    raise StopIteration

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
