"""Shared model building blocks + the ParamDef declarative parameter system.

Parameters are declared as trees of ``PD`` (shape + logical axes + init);
one source of truth yields both materialized params (``init_params``) and
PartitionSpec trees (``pspec_tree``) so sharding can never drift from shapes.
"""
from __future__ import annotations

import hashlib
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


class PD(NamedTuple):
    """Parameter definition: shape, logical axis names, init spec."""

    shape: tuple
    axes: tuple  # logical axis name (str) or None per dim
    dtype: Any = jnp.bfloat16
    init: str = "normal"  # normal | zeros | ones | embed
    scale: float = 1.0


def _leaf_key(root: jax.Array, path) -> jax.Array:
    h = hashlib.md5(jax.tree_util.keystr(path).encode()).digest()
    return jax.random.fold_in(root, int.from_bytes(h[:4], "little"))


def _init_leaf(pd: PD, key: jax.Array) -> jax.Array:
    if pd.init == "zeros":
        return jnp.zeros(pd.shape, pd.dtype)
    if pd.init == "ones":
        return jnp.ones(pd.shape, pd.dtype)
    if pd.init == "embed":
        return (jax.random.normal(key, pd.shape, jnp.float32) * pd.scale).astype(pd.dtype)
    # fan-in scaled truncated-normal-ish init
    fan_in = pd.shape[-2] if len(pd.shape) >= 2 else pd.shape[-1]
    std = pd.scale / (fan_in ** 0.5)
    return (jax.random.normal(key, pd.shape, jnp.float32) * std).astype(pd.dtype)


def is_pd(x) -> bool:
    return isinstance(x, PD)


def init_params(defs, key: jax.Array):
    """Materialize a ParamDef tree into arrays (deterministic per-path keys)."""
    flat = jax.tree_util.tree_flatten_with_path(defs, is_leaf=is_pd)
    leaves = [_init_leaf(pd, _leaf_key(key, path)) for path, pd in flat[0]]
    return jax.tree_util.tree_unflatten(flat[1], leaves)


def abstract_params(defs):
    """ShapeDtypeStruct tree (for dry-run lowering: no allocation)."""
    return jax.tree.map(
        lambda pd: jax.ShapeDtypeStruct(pd.shape, pd.dtype), defs, is_leaf=is_pd
    )


def pspec_tree(defs, rules: dict):
    """Map logical axes -> mesh axes using ``rules`` (missing -> replicated)."""

    def spec(pd: PD) -> P:
        return P(*(rules.get(a) for a in pd.axes))

    return jax.tree.map(spec, defs, is_leaf=is_pd)


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------


def rms_norm(x, w, eps: float, gemma_style: bool):
    """RMSNorm with f32-accumulated sum-of-squares but NO materialized f32
    copy of x: a full f32 (B,S,d) intermediate gets saved/stacked as a scan
    residual (2.5x activation memory, measured on llama3-8b/arctic-480b —
    EXPERIMENTS.md §Dry-run), so the variance is accumulated via an einsum
    with preferred_element_type=f32 and the normalize multiply stays bf16."""
    ss = jnp.einsum("...d,...d->...", x, x, preferred_element_type=jnp.float32)
    r = jax.lax.rsqrt(ss / x.shape[-1] + eps)[..., None]
    scale = (1.0 + w.astype(jnp.float32)) if gemma_style else w.astype(jnp.float32)
    return (x * r.astype(x.dtype)) * scale.astype(x.dtype)


def softcap(x, cap: Optional[float]):
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


def rope(x, positions, theta: float):
    """Rotary embedding. x: (..., S, H, D); positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    angles = angles[..., None, :]  # broadcast over heads
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "gelu_plain": jax.nn.gelu}[name]


# ---------------------------------------------------------------------------
# MLP / embedding defs
# ---------------------------------------------------------------------------


def mlp_defs(cfg, d_ff: Optional[int] = None, prefix_axes=()) -> dict:
    """Gated (SwiGLU/GeGLU) or plain FFN param defs.

    prefix_axes: extra leading (shape, axis) pairs, e.g. layer stacking.
    """
    d_ff = d_ff or cfg.d_ff
    pre_s = tuple(s for s, _ in prefix_axes)
    pre_a = tuple(a for _, a in prefix_axes)
    gated = cfg.act != "gelu_plain"
    defs = {
        "w_in": PD(pre_s + (cfg.d_model, d_ff), pre_a + ("embed", "ff")),
        "w_out": PD(pre_s + (d_ff, cfg.d_model), pre_a + ("ff", "embed_out")),
    }
    if gated:
        defs["w_gate"] = PD(pre_s + (cfg.d_model, d_ff), pre_a + ("embed", "ff"))
    return defs


def mlp_apply(params: dict, x, cfg, d_ff: Optional[int] = None):
    a = act_fn(cfg.act)
    h = x @ params["w_in"]
    if "w_gate" in params:
        h = a(x @ params["w_gate"]) * h
    else:
        h = a(h)
    return h @ params["w_out"]
